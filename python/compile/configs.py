"""Model/artifact configuration presets shared by the compile path and tests.

Shapes are static: every preset bakes its batch size, max sequence length and
draft width into the lowered HLO. The Rust runtime reads the emitted
``artifacts/manifest.json`` and never guesses shapes.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    """Static configuration for the GPT-style rollout/training model."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    head_dim: int
    max_seq: int        # KV cache capacity (multiple of kv_block)
    batch: int          # decode/verify batch size baked into artifacts
    prefill_len: int    # prompt window for the prefill entry point
    train_len: int      # sequence window for the train_step entry point
    draft_width: int    # gamma_max + 1: query positions per verify step
    kv_block: int       # pallas KV tile (VMEM block along the seq axis)
    dtype: str = "float32"

    def __post_init__(self):
        assert self.d_model == self.n_heads * self.head_dim
        assert self.max_seq % self.kv_block == 0, "max_seq must tile by kv_block"
        assert self.prefill_len <= self.max_seq
        assert self.train_len <= self.max_seq

    def to_dict(self):
        return asdict(self)


# Fast preset for pytest / cargo test / quickstart.
TINY = ModelConfig(
    name="tiny", vocab=256, d_model=128, n_layers=2, n_heads=4, head_dim=32,
    max_seq=192, batch=4, prefill_len=32, train_len=48, draft_width=4,
    kv_block=64,
)

# Default artifact preset: ~3.7M params, sub-second CPU train steps; used by
# the end-to-end GRPO example and the real-model rollout path.
SMALL = ModelConfig(
    name="small", vocab=1024, d_model=256, n_layers=4, n_heads=8, head_dim=32,
    max_seq=512, batch=8, prefill_len=64, train_len=128, draft_width=8,
    kv_block=64,
)

# ~91M params — the paper-scale e2e config. CPU-feasible for a short run
# only; see EXPERIMENTS.md for the measured per-step cost.
MEDIUM = ModelConfig(
    name="medium", vocab=8192, d_model=768, n_layers=12, n_heads=12,
    head_dim=64, max_seq=1024, batch=8, prefill_len=128, train_len=256,
    draft_width=8, kv_block=128,
)

PRESETS = {c.name: c for c in (TINY, SMALL, MEDIUM)}
