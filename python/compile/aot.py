"""AOT lowering: JAX entry points -> HLO *text* artifacts + manifest.

HLO text (not ``HloModule.serialize()``) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Python runs only here — ``make artifacts`` — never on the request path.
The manifest records, for every entry point, the flattened argument and
result layouts (pytree order = jax tree_flatten order) so the Rust runtime
can marshal buffers without re-deriving the pytree structure.

Usage: python -m compile.aot --out ../artifacts [--preset small] [--no-pallas]
"""

import argparse
import hashlib
import json
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from .configs import PRESETS
from .model import make_entries
from .params import init_params, init_opt_state, param_leaves, count_params


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _leaf_spec(x):
    # Works for concrete arrays and jax.ShapeDtypeStruct alike.
    shape = list(getattr(x, "shape"))
    dtype = str(np.dtype(getattr(x, "dtype")))
    return {"shape": shape, "dtype": dtype}


def _flat_arg_specs(args):
    leaves = jax.tree_util.tree_leaves(args)
    return [_leaf_spec(l) for l in leaves]


def lower_entry(name, fn, example_args):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    out_tree = jax.eval_shape(fn, *example_args)
    return text, {
        "name": name,
        "args": _flat_arg_specs(example_args),
        "results": [_leaf_spec(l) for l in jax.tree_util.tree_leaves(out_tree)],
    }


def emit(out_dir, preset, use_pallas=True, seed=0):
    cfg = PRESETS[preset]
    os.makedirs(out_dir, exist_ok=True)
    entries = make_entries(cfg, use_pallas=use_pallas)

    manifest = {
        "preset": preset,
        "config": cfg.to_dict(),
        "use_pallas": use_pallas,
        "entries": {},
        "param_layout": [],
        "n_params": 0,
    }

    params = init_params(cfg, seed=seed)
    manifest["n_params"] = int(count_params(params))
    for pname, leaf in param_leaves(params):
        manifest["param_layout"].append({"name": pname, **_leaf_spec(leaf)})

    # Initial weights + Adam state, flattened in manifest order, as a raw
    # little-endian f32 blob the Rust side can mmap-read.
    with open(os.path.join(out_dir, f"{preset}.params.bin"), "wb") as f:
        for _, leaf in param_leaves(params):
            f.write(np.asarray(leaf, np.float32).tobytes())
    opt = init_opt_state(params)

    for name, (fn, example_args) in entries.items():
        text, spec = lower_entry(name, fn, example_args)
        path = os.path.join(out_dir, f"{preset}.{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        spec["file"] = os.path.basename(path)
        spec["hlo_sha256"] = hashlib.sha256(text.encode()).hexdigest()
        manifest["entries"][name] = spec
        print(f"  {name}: {len(text)} chars -> {path}")

    mpath = os.path.join(out_dir, f"{preset}.manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  manifest -> {mpath} ({manifest['n_params']} params)")
    return mpath


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--preset", default=None,
                    help="preset name; default: tiny and small")
    ap.add_argument("--no-pallas", action="store_true",
                    help="build L2 against the jnp reference attention")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    presets = [args.preset] if args.preset else ["tiny", "small"]
    for p in presets:
        print(f"[aot] lowering preset '{p}' (pallas={not args.no_pallas})")
        emit(args.out, p, use_pallas=not args.no_pallas, seed=args.seed)


if __name__ == "__main__":
    main()
