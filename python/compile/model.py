"""L2: the rollout/training model as pure JAX functions over explicit state.

Four AOT entry points (all shapes static per ``configs.ModelConfig``):

- ``prefill``:     prompt -> last-token logits + populated KV caches.
- ``decode_step``: one token per sequence -> logits + updated caches.
                   Attention runs through the L1 Pallas flash-decode kernel.
- ``verify_step``: G draft tokens per sequence -> (B, G, V) logits + caches,
                   via the L1 Pallas verification kernel. Acceptance is
                   decided by the Rust coordinator from the logits; rejected
                   suffix positions are naturally masked out of later steps
                   because the coordinator only advances ``cache_lens`` by
                   the accepted count.
- ``train_step``:  GRPO policy-gradient step (token logp weighted by group
                   advantage) with a hand-rolled Adam update.

Sampling is done Rust-side from the returned logits, keeping the artifacts
deterministic and the RNG under the coordinator's control.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.decode_attention import decode_attention
from .kernels.spec_verify import verify_attention
from .kernels.ref import decode_attention_ref, verify_attention_ref


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _split_heads(x, n_heads, head_dim):
    # (..., d) -> (..., H, Dh) -> move H before the seq axis at call sites.
    return x.reshape(x.shape[:-1] + (n_heads, head_dim))


def _mlp(x, layer):
    h = jnp.dot(x, layer["wi"])
    h = jax.nn.gelu(h)
    return jnp.dot(h, layer["wo_mlp"])


# ---------------------------------------------------------------------------
# Full-sequence forward (prefill & training): plain jnp causal attention.
# The decode/verify hot path is what the paper optimizes; it uses the L1
# Pallas kernels below.
# ---------------------------------------------------------------------------

def _causal_attn(q, k, v, seq_lens):
    """q,k,v: (B, T, H, Dh); valid positions < seq_lens[b]."""
    B, T, H, Dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(Dh))
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    causal = jnp.tril(jnp.ones((T, T), bool))
    valid = jnp.arange(T)[None, :] < seq_lens[:, None]        # (B, T) keys
    mask = causal[None, None] & valid[:, None, None, :]
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    # Fully-masked rows (query beyond seq_len) produce NaN; zero them.
    p = jnp.where(jnp.any(mask, axis=-1, keepdims=True), p, 0.0)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def _forward_seq(params, cfg, tokens, seq_lens, positions=None):
    """Forward over a full (B, T) window. Returns (hidden, k_all, v_all)
    where k_all/v_all are per-layer (B, T, H, Dh) tensors."""
    B, T = tokens.shape
    if positions is None:
        positions = jnp.arange(T)[None, :].repeat(B, 0)
    x = params["tok_emb"][tokens] + params["pos_emb"][positions]
    ks, vs = [], []
    for layer in params["layers"]:
        h = _layernorm(x, layer["ln1_g"], layer["ln1_b"])
        qkv = jnp.dot(h, layer["wqkv"])
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = _split_heads(q, cfg.n_heads, cfg.head_dim)
        k = _split_heads(k, cfg.n_heads, cfg.head_dim)
        v = _split_heads(v, cfg.n_heads, cfg.head_dim)
        attn = _causal_attn(q, k, v, seq_lens)
        x = x + jnp.dot(attn.reshape(B, T, -1), layer["wo"])
        h2 = _layernorm(x, layer["ln2_g"], layer["ln2_b"])
        x = x + _mlp(h2, layer)
        ks.append(k)
        vs.append(v)
    return x, ks, vs


def prefill_one(params, cfg, tokens, seq_lens):
    """Single-sequence prefill (B=1): used by the rollout engine to admit
    one request into a batch slot without recomputing the other slots.
    Returns (logits (1, V), k1, v1) with caches (L, 1, H, S, Dh)."""
    return prefill(params, cfg, tokens, seq_lens)


def slot_update(cfg, k_cache, v_cache, k1, v1, slot):
    """Insert a single-sequence cache (from prefill_one / slot_extract)
    into batch slot `slot`. Shapes: caches (L, B, H, S, Dh), k1/v1
    (L, 1, H, S, Dh); slot scalar int32."""
    zero = jnp.int32(0)
    start = (zero, slot, zero, zero, zero)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k1, start)
    v_cache = jax.lax.dynamic_update_slice(v_cache, v1, start)
    return k_cache, v_cache


def slot_extract(cfg, k_cache, v_cache, slot):
    """Extract one slot's cache as (L, 1, H, S, Dh) pair — the engine
    parks it in the global KV pool (host DRAM) when a chunk lease ends."""
    L, B, H, S, D = k_cache.shape
    zero = jnp.int32(0)
    start = (zero, slot, zero, zero, zero)
    sizes = (L, 1, H, S, D)
    k1 = jax.lax.dynamic_slice(k_cache, start, sizes)
    v1 = jax.lax.dynamic_slice(v_cache, start, sizes)
    return k1, v1


def prefill(params, cfg, tokens, seq_lens):
    """tokens: (B, P) prompt window, seq_lens: (B,) true prompt lengths.

    Returns (logits_last (B, V), k_cache, v_cache) where the caches are
    (L, B, H, S, Dh) with positions [0, P) populated.
    """
    B, P = tokens.shape
    x, ks, vs = _forward_seq(params, cfg, tokens, seq_lens)
    x = _layernorm(x, params["lnf_g"], params["lnf_b"])
    logits = jnp.dot(x, params["lm_head"])                     # (B, P, V)
    last = jnp.clip(seq_lens - 1, 0, P - 1)
    logits_last = jnp.take_along_axis(
        logits, last[:, None, None].repeat(logits.shape[-1], 2), axis=1
    )[:, 0, :]

    L, S = cfg.n_layers, cfg.max_seq
    k_cache = jnp.zeros((L, B, cfg.n_heads, S, cfg.head_dim), jnp.float32)
    v_cache = jnp.zeros_like(k_cache)
    for l in range(L):
        # (B, P, H, Dh) -> (B, H, P, Dh)
        k_cache = k_cache.at[l, :, :, :P, :].set(ks[l].transpose(0, 2, 1, 3))
        v_cache = v_cache.at[l, :, :, :P, :].set(vs[l].transpose(0, 2, 1, 3))
    return logits_last, k_cache, v_cache


# ---------------------------------------------------------------------------
# Decode / verify steps (the hot path; L1 Pallas kernels).
# ---------------------------------------------------------------------------

def _write_cache(cache_l, new, pos):
    """cache_l: (B, H, S, Dh); new: (B, H, W, Dh); write at pos[b]."""
    def one(c, n, p):
        return jax.lax.dynamic_update_slice(c, n, (0, p, 0))
    return jax.vmap(one)(cache_l, new, pos)


def decode_step(params, cfg, tokens, cache_lens, k_cache, v_cache,
                use_pallas=True):
    """tokens: (B,) current token ids; cache_lens: (B,) committed KV length.

    Returns (logits (B, V), k_cache, v_cache) with the new K/V written at
    position cache_lens[b] (the caller advances cache_lens by 1).
    """
    B = tokens.shape[0]
    x = params["tok_emb"][tokens] + params["pos_emb"][cache_lens]  # (B, d)
    attn_fn = decode_attention if use_pallas else decode_attention_ref
    for l, layer in enumerate(params["layers"]):
        h = _layernorm(x, layer["ln1_g"], layer["ln1_b"])
        qkv = jnp.dot(h, layer["wqkv"])
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = _split_heads(q, cfg.n_heads, cfg.head_dim)         # (B, H, Dh)
        k = _split_heads(k, cfg.n_heads, cfg.head_dim)[:, :, None, :]
        v = _split_heads(v, cfg.n_heads, cfg.head_dim)[:, :, None, :]
        k_cache = k_cache.at[l].set(_write_cache(k_cache[l], k, cache_lens))
        v_cache = v_cache.at[l].set(_write_cache(v_cache[l], v, cache_lens))
        if use_pallas:
            attn = attn_fn(q, k_cache[l], v_cache[l], cache_lens + 1,
                           kv_block=cfg.kv_block)
        else:
            attn = attn_fn(q, k_cache[l], v_cache[l], cache_lens + 1)
        x = x + jnp.dot(attn.reshape(B, -1), layer["wo"])
        h2 = _layernorm(x, layer["ln2_g"], layer["ln2_b"])
        x = x + _mlp(h2, layer)
    x = _layernorm(x, params["lnf_g"], params["lnf_b"])
    return jnp.dot(x, params["lm_head"]), k_cache, v_cache


def verify_step(params, cfg, draft_tokens, cache_lens, k_cache, v_cache,
                use_pallas=True):
    """draft_tokens: (B, G) — position 0 is the last accepted token, 1..G-1
    are the draft continuation. Returns (logits (B, G, V), caches) where
    logits[:, i] scores the token *after* draft position i.
    """
    B, G = draft_tokens.shape
    positions = cache_lens[:, None] + jnp.arange(G)[None, :]   # (B, G)
    x = params["tok_emb"][draft_tokens] + params["pos_emb"][positions]
    attn_fn = verify_attention if use_pallas else verify_attention_ref
    for l, layer in enumerate(params["layers"]):
        h = _layernorm(x, layer["ln1_g"], layer["ln1_b"])
        qkv = jnp.dot(h, layer["wqkv"])
        q, k, v = jnp.split(qkv, 3, axis=-1)
        # (B, G, H, Dh) -> (B, H, G, Dh)
        q = _split_heads(q, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        k = _split_heads(k, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        v = _split_heads(v, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        k_cache = k_cache.at[l].set(_write_cache(k_cache[l], k, cache_lens))
        v_cache = v_cache.at[l].set(_write_cache(v_cache[l], v, cache_lens))
        if use_pallas:
            attn = attn_fn(q, k_cache[l], v_cache[l], cache_lens,
                           kv_block=cfg.kv_block)
        else:
            attn = attn_fn(q, k_cache[l], v_cache[l], cache_lens)
        attn = attn.transpose(0, 2, 1, 3).reshape(B, G, -1)
        x = x + jnp.dot(attn, layer["wo"])
        h2 = _layernorm(x, layer["ln2_g"], layer["ln2_b"])
        x = x + _mlp(h2, layer)
    x = _layernorm(x, params["lnf_g"], params["lnf_b"])
    return jnp.dot(x, params["lm_head"]), k_cache, v_cache


# ---------------------------------------------------------------------------
# GRPO training step.
# ---------------------------------------------------------------------------

def grpo_loss(params, cfg, tokens, loss_mask, advantages):
    """Token-level policy gradient: L = -mean_b adv_b * mean_t logp(t).

    tokens: (B, T); loss_mask: (B, T) — 1 on *generated* positions (the
    model predicts tokens[t] from tokens[:t], so mask position t means
    "tokens[t] was sampled by the policy"); advantages: (B,) group-
    normalized GRPO advantages computed by the Rust coordinator.
    """
    B, T = tokens.shape
    seq_lens = jnp.full((B,), T, jnp.int32)
    x, _, _ = _forward_seq(params, cfg, tokens, seq_lens)
    x = _layernorm(x, params["lnf_g"], params["lnf_b"])
    logits = jnp.dot(x, params["lm_head"])                     # (B, T, V)
    logp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    tgt = tokens[:, 1:]
    tok_logp = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    mask = loss_mask[:, 1:].astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask, axis=1), 1.0)
    seq_logp = jnp.sum(tok_logp * mask, axis=1) / denom        # (B,)
    return -jnp.mean(advantages * seq_logp)


def train_step(params, cfg, opt_state, step, tokens, loss_mask, advantages,
               lr=3e-4, b1=0.9, b2=0.999, eps=1e-8):
    """One Adam step on the GRPO loss. Returns (params', opt', loss)."""
    loss, grads = jax.value_and_grad(grpo_loss)(
        params, cfg, tokens, loss_mask, advantages
    )
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        p2 = p - lr * (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
        return p2, m2, v2

    flat = jax.tree_util.tree_map(
        upd, params, grads, opt_state["m"], opt_state["v"],
        is_leaf=lambda x: isinstance(x, jnp.ndarray),
    )
    new_params = jax.tree_util.tree_map(lambda t3: t3[0], flat,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t3: t3[1], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda t3: t3[2], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v}, loss


# ---------------------------------------------------------------------------
# Entry-point factories: close over the static config for jit/lowering.
# ---------------------------------------------------------------------------

def make_entries(cfg, use_pallas=True):
    """Returns a dict of name -> (fn, example_args) for AOT lowering."""
    import numpy as np

    B, P, T, G = cfg.batch, cfg.prefill_len, cfg.train_len, cfg.draft_width
    S, L = cfg.max_seq, cfg.n_layers
    from .params import init_params, init_opt_state
    params = init_params(cfg)
    opt = init_opt_state(params)

    tok_p = np.zeros((B, P), np.int32)
    tok_1 = np.zeros((B,), np.int32)
    tok_g = np.zeros((B, G), np.int32)
    lens = np.ones((B,), np.int32)
    kc = np.zeros((L, B, cfg.n_heads, S, cfg.head_dim), np.float32)
    tokens_t = np.zeros((B, T), np.int32)
    mask_t = np.ones((B, T), np.int32)
    adv = np.zeros((B,), np.float32)
    step = np.int32(0)

    def prefill_fn(params, tokens, seq_lens):
        return prefill(params, cfg, tokens, seq_lens)

    def prefill_one_fn(params, tokens, seq_lens):
        return prefill_one(params, cfg, tokens, seq_lens)

    def slot_update_fn(k_cache, v_cache, k1, v1, slot):
        return slot_update(cfg, k_cache, v_cache, k1, v1, slot)

    def slot_extract_fn(k_cache, v_cache, slot):
        return slot_extract(cfg, k_cache, v_cache, slot)

    def decode_fn(params, tokens, cache_lens, k_cache, v_cache):
        return decode_step(params, cfg, tokens, cache_lens, k_cache, v_cache,
                           use_pallas=use_pallas)

    def verify_fn(params, draft_tokens, cache_lens, k_cache, v_cache):
        return verify_step(params, cfg, draft_tokens, cache_lens,
                           k_cache, v_cache, use_pallas=use_pallas)

    def train_fn(params, opt_state, step, tokens, loss_mask, advantages):
        return train_step(params, cfg, opt_state, step, tokens, loss_mask,
                          advantages)

    tok_p1 = np.zeros((1, P), np.int32)
    lens1 = np.ones((1,), np.int32)
    kc1 = np.zeros((L, 1, cfg.n_heads, S, cfg.head_dim), np.float32)
    slot = np.int32(0)

    return {
        "prefill": (prefill_fn, (params, tok_p, lens)),
        "prefill_one": (prefill_one_fn, (params, tok_p1, lens1)),
        "slot_update": (slot_update_fn, (kc, kc, kc1, kc1, slot)),
        "slot_extract": (slot_extract_fn, (kc, kc, slot)),
        "decode_step": (decode_fn, (params, tok_1, lens, kc, kc)),
        "verify_step": (verify_fn, (params, tok_g, lens, kc, kc)),
        "train_step": (train_fn, (params, opt, step, tokens_t, mask_t, adv)),
    }
