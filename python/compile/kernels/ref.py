"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: ``python/tests`` asserts the Pallas
kernels match these implementations across shape/dtype sweeps, and the L2
model can be built against either implementation (``use_pallas`` flag) so a
numerics regression can always be bisected to one layer.
"""

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, kv_lens):
    """Single-position decode attention.

    Args:
      q:        (B, H, D)    query for the current decode position.
      k_cache:  (B, H, S, D) key cache (garbage beyond ``kv_lens`` is masked).
      v_cache:  (B, H, S, D) value cache.
      kv_lens:  (B,) int32   valid KV length per sequence (includes the
                current position's K/V, i.e. attention span is [0, kv_lens)).

    Returns:
      (B, H, D) attention output in float32.
    """
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    s = jnp.einsum(
        "bhd,bhsd->bhs", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    span = jnp.arange(k_cache.shape[2])[None, :] < kv_lens[:, None]  # (B, S)
    s = jnp.where(span[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", p, v_cache.astype(jnp.float32))


def verify_attention_ref(q, k_cache, v_cache, prefix_lens):
    """Speculative-verification attention over G draft positions.

    Query position ``i`` (0-based) sits at absolute position
    ``prefix_lens[b] + i`` and attends to KV positions
    ``[0, prefix_lens[b] + i + 1)`` — causal within the draft block, full
    over the committed prefix. The draft K/V must already be written into
    the caches at those positions.

    Args:
      q:           (B, H, G, D) queries for the G draft positions.
      k_cache:     (B, H, S, D)
      v_cache:     (B, H, S, D)
      prefix_lens: (B,) int32 committed prefix length (excludes drafts).

    Returns:
      (B, H, G, D) float32.
    """
    G = q.shape[2]
    S = k_cache.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    s = jnp.einsum(
        "bhgd,bhsd->bhgs", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    pos = jnp.arange(S)[None, None, :]                       # (1, 1, S)
    limit = prefix_lens[:, None, None] + jnp.arange(G)[None, :, None] + 1
    mask = pos < limit                                       # (B, G, S)
    s = jnp.where(mask[:, None, :, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgs,bhsd->bhgd", p, v_cache.astype(jnp.float32))
