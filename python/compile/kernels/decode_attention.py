"""L1 Pallas kernel: flash-decode attention for a single query position.

TPU adaptation of the paper's GPU decode hot spot (DESIGN.md
§Hardware-Adaptation): instead of CUDA threadblocks staging KV tiles
through shared memory, the KV sequence is tiled by ``BlockSpec`` into
VMEM-resident ``(B, H, kv_block, D)`` tiles, the (sequential) grid walks
the tiles, and the online-softmax state (running max ``m``, normalizer
``l``, weighted accumulator ``acc``) is carried in VMEM scratch.

Tiling choice (perf iteration 1, EXPERIMENTS.md §Perf): the grid covers
*only* the KV axis; batch and heads stay whole inside each tile. For the
model sizes this repo ships, a tile is B×H×kv_block×D×4B ≤ 2 MB and the
carried state ≤ 0.3 MB — comfortably VMEM-resident — and every grid step
is one dense (B·H, kv_block, D) contraction that maps onto the MXU. (The
original B×H×KV grid had identical numerics but serialized B·H tiny
matmuls per tile; on the CPU interpret path it was ~10x slower, and on a
real TPU it would under-fill the systolic array the same way.)

Runs with ``interpret=True`` everywhere (CPU PJRT cannot execute Mosaic
custom-calls); the grid lowers to an XLA ``while`` loop, so the AOT'd HLO
stays compact regardless of sequence length.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _decode_attn_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref,
                        m_ref, l_ref, acc_ref, *, kv_block, scale):
    """Grid = (S // kv_block,): the KV-tile walk."""
    kb = pl.program_id(0)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)          # (B, H, D)
    k = k_ref[...].astype(jnp.float32)          # (B, H, BK, D)
    v = v_ref[...].astype(jnp.float32)          # (B, H, BK, D)

    # (B, H, BK) scores: one dense contraction per tile.
    s = jnp.einsum("bhd,bhkd->bhk", q, k,
                   preferred_element_type=jnp.float32) * scale
    pos = kb * kv_block + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 2
    )
    valid = pos < lens_ref[:][:, None, None]     # (B, 1, 1) vs (B,H,BK)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                          # (B, H)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=2))
    # An all-masked tile keeps m at -inf; exp(-inf - -inf) is NaN, so pin
    # the correction factor to zero-effect in that case.
    corr = jnp.where(m_new == NEG_INF, 1.0, jnp.exp(m_prev - m_new))
    p = jnp.where(s == NEG_INF, 0.0, jnp.exp(s - m_new[..., None]))

    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=2)
    acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum(
        "bhk,bhkd->bhd", p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(kb == pl.num_programs(0) - 1)
    def _finish():
        o_ref[...] = (
            acc_ref[...] / l_ref[...][..., None]
        ).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, kv_lens, *, kv_block=64,
                     interpret=True):
    """Pallas flash-decode attention. Same contract as
    :func:`ref.decode_attention_ref`.

    Args:
      q:        (B, H, D)
      k_cache:  (B, H, S, D) with S % kv_block == 0
      v_cache:  (B, H, S, D)
      kv_lens:  (B,) int32, 1 <= kv_lens[b] <= S
      kv_block: KV tile length along the sequence axis (VMEM block).

    Returns:
      (B, H, D) float32.
    """
    B, H, D = q.shape
    S = k_cache.shape[2]
    assert S % kv_block == 0, (S, kv_block)
    scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _decode_attn_kernel, kv_block=kv_block, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=(S // kv_block,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),       # kv_lens
            pl.BlockSpec((B, H, D), lambda kb: (0, 0, 0)),
            pl.BlockSpec((B, H, kv_block, D), lambda kb: (0, 0, kb, 0)),
            pl.BlockSpec((B, H, kv_block, D), lambda kb: (0, 0, kb, 0)),
        ],
        out_specs=pl.BlockSpec((B, H, D), lambda kb: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((B, H), jnp.float32),     # m: running max
            pltpu.VMEM((B, H), jnp.float32),     # l: running normalizer
            pltpu.VMEM((B, H, D), jnp.float32),  # acc: weighted value sum
        ],
        interpret=interpret,
    )(kv_lens, q, k_cache, v_cache)
