"""L1 Pallas kernel: speculative-verification attention.

The paper's core decoding insight — parallel verification of gamma draft
tokens converts memory-bound serial decode into one compute-dense pass —
maps onto the TPU as follows (DESIGN.md §Hardware-Adaptation): the G draft
queries are batched into a single ``(B·H·G, D) × (D, kv_block)`` MXU
contraction per KV tile instead of G serial decode steps; the KV walk is
the sequential grid axis with ``(B, H, kv_block, D)`` VMEM tiles; and the
per-query online-softmax state ``(m, l, acc)`` of shape
``(B, H, G) / (B, H, G) / (B, H, G, D)`` lives in VMEM scratch. Like
`decode_attention`, batch and heads stay whole per tile (perf iteration 1,
EXPERIMENTS.md §Perf) — the tile plus state stays ≤ 3 MB for the shipped
model sizes.

Masking: query i sits at absolute position ``prefix_lens[b] + i`` and may
attend to KV positions ``[0, prefix_lens[b] + i + 1)`` — full over the
committed prefix, causal inside the draft block.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _verify_attn_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref,
                        m_ref, l_ref, acc_ref, *, kv_block, n_drafts, scale):
    """Grid = (S // kv_block,)."""
    kb = pl.program_id(0)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)           # (B, H, G, D)
    k = k_ref[...].astype(jnp.float32)           # (B, H, BK, D)
    v = v_ref[...].astype(jnp.float32)           # (B, H, BK, D)

    s = jnp.einsum("bhgd,bhkd->bhgk", q, k,
                   preferred_element_type=jnp.float32) * scale
    pos = kb * kv_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 3)
    limit = (
        lens_ref[:][:, None, None, None]
        + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        + 1
    )
    s = jnp.where(pos < limit, s, NEG_INF)

    m_prev = m_ref[...]                          # (B, H, G)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=3))
    corr = jnp.where(m_new == NEG_INF, 1.0, jnp.exp(m_prev - m_new))
    p = jnp.where(s == NEG_INF, 0.0, jnp.exp(s - m_new[..., None]))

    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=3)
    acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum(
        "bhgk,bhkd->bhgd", p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(kb == pl.num_programs(0) - 1)
    def _finish():
        o_ref[...] = (
            acc_ref[...] / l_ref[...][..., None]
        ).astype(o_ref.dtype)


def verify_attention(q, k_cache, v_cache, prefix_lens, *, kv_block=64,
                     interpret=True):
    """Pallas verification attention. Same contract as
    :func:`ref.verify_attention_ref`.

    Args:
      q:           (B, H, G, D) draft-position queries.
      k_cache:     (B, H, S, D) with draft K/V already written at
                   positions [prefix_lens[b], prefix_lens[b]+G).
      v_cache:     (B, H, S, D)
      prefix_lens: (B,) int32 committed prefix length.

    Returns:
      (B, H, G, D) float32.
    """
    B, H, G, D = q.shape
    S = k_cache.shape[2]
    assert S % kv_block == 0, (S, kv_block)
    scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _verify_attn_kernel, kv_block=kv_block, n_drafts=G, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=(S // kv_block,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),       # prefix_lens
            pl.BlockSpec((B, H, G, D), lambda kb: (0, 0, 0, 0)),
            pl.BlockSpec((B, H, kv_block, D), lambda kb: (0, 0, kb, 0)),
            pl.BlockSpec((B, H, kv_block, D), lambda kb: (0, 0, kb, 0)),
        ],
        out_specs=pl.BlockSpec((B, H, G, D), lambda kb: (0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, G, D), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((B, H, G), jnp.float32),
            pltpu.VMEM((B, H, G), jnp.float32),
            pltpu.VMEM((B, H, G, D), jnp.float32),
        ],
        interpret=interpret,
    )(prefix_lens, q, k_cache, v_cache)
