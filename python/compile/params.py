"""Parameter initialization and the canonical flattening order.

The Rust runtime holds parameters as an opaque ordered list of buffers; the
order is whatever ``jax.tree_util.tree_flatten`` yields for the params dict,
which is deterministic (sorted dict keys). ``aot.py`` records every leaf's
name/shape/dtype in the manifest so the Rust side can build, save and
restore the list without re-deriving the pytree.
"""

import jax
import jax.numpy as jnp


def init_params(cfg, seed=0):
    """GPT-style decoder weights. Layout:

    - tok_emb (V, d), pos_emb (S, d)
    - per layer l: ln1_{g,b}, wqkv (d, 3d), wo (d, d), ln2_{g,b},
      wi (d, 4d), wo_mlp (4d, d)
    - lnf_{g,b}, lm_head (d, V)
    """
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4 + 6 * cfg.n_layers)
    d = cfg.d_model
    std = 0.02

    def norm(k, shape, scale=std):
        return (jax.random.normal(k, shape) * scale).astype(jnp.float32)

    params = {
        "tok_emb": norm(ks[0], (cfg.vocab, d)),
        "pos_emb": norm(ks[1], (cfg.max_seq, d)),
        "lnf_g": jnp.ones((d,), jnp.float32),
        "lnf_b": jnp.zeros((d,), jnp.float32),
        "lm_head": norm(ks[2], (d, cfg.vocab)),
        "layers": [],
    }
    resid_scale = std / (2 * cfg.n_layers) ** 0.5
    for l in range(cfg.n_layers):
        kk = ks[4 + 6 * l : 4 + 6 * (l + 1)]
        params["layers"].append({
            "ln1_g": jnp.ones((d,), jnp.float32),
            "ln1_b": jnp.zeros((d,), jnp.float32),
            "wqkv": norm(kk[0], (d, 3 * d)),
            "wo": norm(kk[1], (d, d), resid_scale),
            "ln2_g": jnp.ones((d,), jnp.float32),
            "ln2_b": jnp.zeros((d,), jnp.float32),
            "wi": norm(kk[2], (d, 4 * d)),
            "wo_mlp": norm(kk[3], (4 * d, d), resid_scale),
        })
    return params


def param_leaves(params):
    """Flatten params into the canonical (path, leaf) list."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        name = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                        for p in path)
        out.append((name, leaf))
    return out


def count_params(params):
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


def init_opt_state(params):
    """Adam first/second-moment state, mirroring the params pytree."""
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
    }
