"""L1 correctness: Pallas verification attention vs the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.spec_verify import verify_attention
from compile.kernels.decode_attention import decode_attention
from compile.kernels.ref import verify_attention_ref


def _run_case(B, H, G, S, D, kv_block, prefix_lens, dtype, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (B, H, G, D), dtype)
    k = jax.random.normal(k2, (B, H, S, D), dtype)
    v = jax.random.normal(k3, (B, H, S, D), dtype)
    lens = jnp.asarray(prefix_lens, jnp.int32)
    out = verify_attention(q, k, v, lens, kv_block=kv_block)
    ref = verify_attention_ref(q, k, v, lens)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


@settings(max_examples=25, deadline=None)
@given(
    B=st.integers(1, 4),
    H=st.integers(1, 3),
    G=st.integers(1, 6),
    sblocks=st.integers(1, 4),
    kv_block=st.sampled_from([8, 16, 32]),
    D=st.sampled_from([8, 16]),
    data=st.data(),
)
def test_matches_ref_shape_sweep(B, H, G, sblocks, kv_block, D, data):
    S = sblocks * kv_block
    # prefix + G draft positions must fit in the cache.
    max_prefix = max(S - G, 1)
    lens = data.draw(
        st.lists(st.integers(0, max_prefix), min_size=B, max_size=B),
        label="prefix_lens",
    )
    _run_case(B, H, G, S, D, kv_block, lens, jnp.float32,
              seed=data.draw(st.integers(0, 2**16), label="seed"))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    _run_case(2, 2, 4, 64, 16, 16, [0, 40], dtype)


def test_g1_equals_decode_attention():
    # With one draft position, verify(prefix) == decode(prefix + 1).
    B, H, S, D = 3, 2, 64, 16
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(k1, (B, H, 1, D))
    k = jax.random.normal(k2, (B, H, S, D))
    v = jax.random.normal(k3, (B, H, S, D))
    lens = jnp.array([0, 10, 63], jnp.int32)
    out = verify_attention(q, k, v, lens, kv_block=16)
    dec = decode_attention(q[:, :, 0, :], k, v, lens + 1, kv_block=16)
    np.testing.assert_allclose(np.asarray(out[:, :, 0, :]), np.asarray(dec),
                               rtol=1e-5, atol=1e-5)


def test_causal_within_draft():
    # Draft position i must NOT see K/V at positions > prefix + i: poisoning
    # the cache at position prefix+j must leave outputs of queries i<j
    # unchanged.
    B, H, G, S, D = 1, 1, 4, 32, 8
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(k1, (B, H, G, D))
    k = jax.random.normal(k2, (B, H, S, D))
    v = jax.random.normal(k3, (B, H, S, D))
    prefix = jnp.array([8], jnp.int32)
    base = verify_attention(q, k, v, prefix, kv_block=8)
    j = 2
    k_p = k.at[:, :, 8 + j, :].set(1e9)
    v_p = v.at[:, :, 8 + j, :].set(-1e9)
    poisoned = verify_attention(q, k_p, v_p, prefix, kv_block=8)
    np.testing.assert_allclose(np.asarray(base[:, :, :j, :]),
                               np.asarray(poisoned[:, :, :j, :]),
                               rtol=1e-6, atol=1e-6)
    # ...while queries at i >= j do see it.
    assert not np.allclose(np.asarray(base[:, :, j, :]),
                           np.asarray(poisoned[:, :, j, :]))


def test_zero_prefix():
    # prefix 0: query i attends only to draft positions [0, i].
    _run_case(2, 1, 3, 16, 8, 8, [0, 0], jnp.float32)


def test_under_jit():
    B, H, G, S, D = 2, 2, 4, 64, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, G, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, S, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, D))
    lens = jnp.array([5, 50], jnp.int32)
    f = jax.jit(lambda q, k, v, l: verify_attention(q, k, v, l, kv_block=16))
    np.testing.assert_allclose(
        np.asarray(f(q, k, v, lens)),
        np.asarray(verify_attention_ref(q, k, v, lens)),
        rtol=2e-5, atol=2e-5,
    )
