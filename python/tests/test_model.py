"""L2 correctness: prefill/decode/verify consistency and training behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import TINY
from compile import model as M
from compile.params import (
    init_params, init_opt_state, param_leaves, count_params,
)

CFG = TINY


@pytest.fixture(scope="module")
def params():
    return init_params(CFG)


@pytest.fixture(scope="module")
def prefilled(params):
    rng = np.random.default_rng(0)
    tokens = jnp.array(rng.integers(0, CFG.vocab, (CFG.batch, CFG.prefill_len)),
                       jnp.int32)
    seq_lens = jnp.array([5, 12, CFG.prefill_len, 7], jnp.int32)
    logits, kc, vc = jax.jit(
        lambda p, t, l: M.prefill(p, CFG, t, l)
    )(params, tokens, seq_lens)
    return tokens, seq_lens, logits, kc, vc


def test_prefill_shapes(prefilled):
    _, _, logits, kc, vc = prefilled
    assert logits.shape == (CFG.batch, CFG.vocab)
    assert kc.shape == (CFG.n_layers, CFG.batch, CFG.n_heads, CFG.max_seq,
                        CFG.head_dim)
    assert vc.shape == kc.shape
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_prefill_last_logit_ignores_padding(params):
    """Logits at seq_len-1 must not depend on the padded tail of the window."""
    rng = np.random.default_rng(1)
    t1 = rng.integers(0, CFG.vocab, (CFG.batch, CFG.prefill_len))
    t2 = t1.copy()
    seq_lens = jnp.array([4, 9, 16, 3], jnp.int32)
    for b, l in enumerate(np.asarray(seq_lens)):
        t2[b, l:] = rng.integers(0, CFG.vocab, CFG.prefill_len - l)
    f = jax.jit(lambda p, t, l: M.prefill(p, CFG, t, l)[0])
    l1 = f(params, jnp.array(t1, jnp.int32), seq_lens)
    l2 = f(params, jnp.array(t2, jnp.int32), seq_lens)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-5, atol=1e-5)


def test_decode_pallas_matches_ref(params, prefilled):
    _, seq_lens, _, kc, vc = prefilled
    rng = np.random.default_rng(2)
    tok = jnp.array(rng.integers(0, CFG.vocab, (CFG.batch,)), jnp.int32)
    f_p = jax.jit(lambda p, t, l, k, v: M.decode_step(p, CFG, t, l, k, v,
                                                      use_pallas=True))
    f_r = jax.jit(lambda p, t, l, k, v: M.decode_step(p, CFG, t, l, k, v,
                                                      use_pallas=False))
    lp, kcp, vcp = f_p(params, tok, seq_lens, kc, vc)
    lr, kcr, vcr = f_r(params, tok, seq_lens, kc, vc)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lr),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(kcp), np.asarray(kcr),
                               rtol=1e-5, atol=1e-5)


def test_verify_matches_serial_decode(params, prefilled):
    """verify_step logits at draft position i == decode_step logits after
    serially feeding draft tokens 0..i — speculative decoding is lossless."""
    _, seq_lens, _, kc, vc = prefilled
    G = CFG.draft_width
    rng = np.random.default_rng(3)
    drafts = jnp.array(rng.integers(0, CFG.vocab, (CFG.batch, G)), jnp.int32)

    vf = jax.jit(lambda p, t, l, k, v: M.verify_step(p, CFG, t, l, k, v,
                                                     use_pallas=True))
    vlogits, _, _ = vf(params, drafts, seq_lens, kc, vc)

    df = jax.jit(lambda p, t, l, k, v: M.decode_step(p, CFG, t, l, k, v,
                                                     use_pallas=True))
    lens, kcs, vcs = seq_lens, kc, vc
    for i in range(G):
        li, kcs, vcs = df(params, drafts[:, i], lens, kcs, vcs)
        np.testing.assert_allclose(np.asarray(vlogits[:, i, :]),
                                   np.asarray(li), rtol=5e-4, atol=5e-4)
        lens = lens + 1


def test_decode_chain_matches_prefill(params):
    """Prefill over [t0..t3] then decode == prefill over [t0..t4]:
    growing the cache one token at a time reproduces full-window logits."""
    rng = np.random.default_rng(4)
    full = rng.integers(0, CFG.vocab, (CFG.batch, CFG.prefill_len))
    n0 = 6
    lens0 = jnp.full((CFG.batch,), n0, jnp.int32)
    toks = jnp.array(full, jnp.int32)
    _, kc, vc = jax.jit(lambda p, t, l: M.prefill(p, CFG, t, l))(
        params, toks, lens0)
    df = jax.jit(lambda p, t, l, k, v: M.decode_step(p, CFG, t, l, k, v,
                                                     use_pallas=True))
    lens = lens0
    logits = None
    for i in range(n0, n0 + 4):
        logits, kc, vc = df(params, toks[:, i], lens, kc, vc)
        lens = lens + 1
    # After decoding tokens at indices n0..n0+3 the consumed prefix is
    # n0+4 tokens; the last decode's logits correspond to position n0+3.
    ref_lens = jnp.full((CFG.batch,), n0 + 4, jnp.int32)
    ref_logits, _, _ = jax.jit(lambda p, t, l: M.prefill(p, CFG, t, l))(
        params, toks, ref_lens)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               rtol=5e-4, atol=5e-4)


def test_grpo_loss_sign(params):
    """Positive advantage + higher logp => lower loss (policy gradient)."""
    rng = np.random.default_rng(5)
    T = CFG.train_len
    toks = jnp.array(rng.integers(0, CFG.vocab, (CFG.batch, T)), jnp.int32)
    mask = jnp.ones((CFG.batch, T), jnp.int32)
    pos = jnp.ones((CFG.batch,), jnp.float32)
    neg = -pos
    lp = M.grpo_loss(params, CFG, toks, mask, pos)
    ln = M.grpo_loss(params, CFG, toks, mask, neg)
    np.testing.assert_allclose(float(lp), -float(ln), rtol=1e-6)
    # loss with positive advantage is -mean logp > 0 for a random model
    assert float(lp) > 0


def test_train_step_reduces_loss(params):
    """Repeated positive-advantage steps on a fixed batch must increase
    likelihood (loss strictly decreases over a few steps)."""
    rng = np.random.default_rng(6)
    T = CFG.train_len
    toks = jnp.array(rng.integers(0, CFG.vocab, (CFG.batch, T)), jnp.int32)
    mask = jnp.ones((CFG.batch, T), jnp.int32)
    adv = jnp.ones((CFG.batch,), jnp.float32)
    opt = init_opt_state(params)
    f = jax.jit(lambda p, o, s, t, m, a: M.train_step(p, CFG, o, s, t, m, a))
    p, losses = params, []
    for step in range(5):
        p, opt, loss = f(p, opt, jnp.int32(step), toks, mask, adv)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
    assert all(np.isfinite(losses))


def test_loss_mask_excludes_prompt(params):
    """Zero-masked (prompt) positions must not contribute to the loss."""
    rng = np.random.default_rng(7)
    T = CFG.train_len
    t1 = rng.integers(0, CFG.vocab, (CFG.batch, T))
    t2 = t1.copy()
    t2[:, :8] = rng.integers(0, CFG.vocab, (CFG.batch, 8))
    # Mask out the first 9 positions: t[8] is the last prompt token and
    # position 8's prediction (of t[8]) uses mask index 8.
    mask = np.ones((CFG.batch, T), np.int32)
    mask[:, :9] = 0
    adv = jnp.ones((CFG.batch,), jnp.float32)
    l1 = M.grpo_loss(params, CFG, jnp.array(t1, jnp.int32),
                     jnp.array(mask), adv)
    # NOTE: different prompt tokens change the *context* of later positions,
    # so losses legitimately differ; instead verify the mask path by zeroing
    # everything — loss must be exactly 0.
    l0 = M.grpo_loss(params, CFG, jnp.array(t1, jnp.int32),
                     jnp.zeros_like(jnp.array(mask)), adv)
    assert float(l0) == 0.0
    assert np.isfinite(float(l1))


def test_param_layout_deterministic():
    p1 = param_leaves(init_params(CFG, seed=0))
    p2 = param_leaves(init_params(CFG, seed=1))
    assert [n for n, _ in p1] == [n for n, _ in p2]
    assert count_params(init_params(CFG)) == sum(x.size for _, x in p1)
