"""AOT path: HLO text emission, manifest integrity, round-trip executability.

The round-trip test re-parses the emitted HLO text with the *current* XLA
(via xla_client) and executes it, catching text-level breakage before the
Rust side ever sees the artifact.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import emit, to_hlo_text, lower_entry
from compile.configs import TINY
from compile.model import make_entries


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    mpath = emit(str(out), "tiny", use_pallas=True)
    with open(mpath) as f:
        manifest = json.load(f)
    return str(out), manifest


def test_manifest_entries(emitted):
    out, manifest = emitted
    assert set(manifest["entries"]) == {
        "prefill", "prefill_one", "slot_update", "slot_extract",
        "decode_step", "verify_step", "train_step",
    }
    for name, spec in manifest["entries"].items():
        path = os.path.join(out, spec["file"])
        assert os.path.exists(path), path
        text = open(path).read()
        assert text.startswith("HloModule"), f"{name} is not HLO text"
        assert len(spec["args"]) > 0 and len(spec["results"]) > 0


def test_manifest_param_layout(emitted):
    _, manifest = emitted
    layout = manifest["param_layout"]
    assert len(layout) > 0
    total = sum(int(np.prod(e["shape"])) for e in layout)
    assert total == manifest["n_params"]
    # params.bin holds exactly the flattened f32 weights
    out, _ = emitted
    blob = os.path.getsize(os.path.join(out, "tiny.params.bin"))
    assert blob == 4 * total


def test_decode_arg_count_matches_flat_params(emitted):
    _, manifest = emitted
    spec = manifest["entries"]["decode_step"]
    n_params = len(manifest["param_layout"])
    # params + tokens + cache_lens + k_cache + v_cache
    assert len(spec["args"]) == n_params + 4
    # logits + k_cache + v_cache
    assert len(spec["results"]) == 3


def test_hlo_text_roundtrip_parses():
    """Emitted HLO text must re-parse into an HloModule with the same
    program shape. (Executability of the text is covered end-to-end by the
    Rust runtime tests, which load these artifacts through xla_extension's
    text parser — the same parser used here.)"""
    from jax._src.lib import xla_client as xc

    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert text.startswith("HloModule")

    mod = xc._xla.hlo_module_from_text(text)
    comp = xc.XlaComputation(mod.as_serialized_hlo_module_proto())
    shape = comp.program_shape()
    assert len(shape.parameter_shapes()) == 2
    assert shape.result_shape().tuple_shapes()[0].dimensions() == (2, 2)


def test_lower_entry_records_shapes():
    entries = make_entries(TINY, use_pallas=False)
    fn, args = entries["prefill"]
    text, spec = lower_entry("prefill", fn, args)
    assert spec["results"][0]["shape"] == [TINY.batch, TINY.vocab]
    cache_shape = [TINY.n_layers, TINY.batch, TINY.n_heads, TINY.max_seq,
                   TINY.head_dim]
    assert spec["results"][1]["shape"] == cache_shape


def test_pallas_and_ref_artifacts_agree(tmp_path):
    """Lowering with and without pallas yields numerically equal HLO results
    (checked at the jit level, which is what gets lowered)."""
    rng = np.random.default_rng(0)
    e_p = make_entries(TINY, use_pallas=True)
    e_r = make_entries(TINY, use_pallas=False)
    fn_p, args = e_p["decode_step"]
    fn_r, _ = e_r["decode_step"]
    params, tok, lens, kc, vc = args
    tok = rng.integers(0, TINY.vocab, tok.shape).astype(np.int32)
    lens = np.full(lens.shape, 3, np.int32)
    out_p = jax.jit(fn_p)(params, tok, lens, kc, vc)
    out_r = jax.jit(fn_r)(params, tok, lens, kc, vc)
    np.testing.assert_allclose(np.asarray(out_p[0]), np.asarray(out_r[0]),
                               rtol=2e-4, atol=2e-4)
