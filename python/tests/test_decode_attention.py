"""L1 correctness: Pallas flash-decode attention vs the pure-jnp oracle.

Hypothesis sweeps shapes, dtypes, block sizes and KV lengths; explicit
regression cases pin the corner cases (single-token KV, full cache, masked
tail tiles, bf16 inputs).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.decode_attention import decode_attention
from compile.kernels.ref import decode_attention_ref


def _run_case(B, H, S, D, kv_block, lens, dtype, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (B, H, D), dtype)
    k = jax.random.normal(k2, (B, H, S, D), dtype)
    v = jax.random.normal(k3, (B, H, S, D), dtype)
    lens = jnp.asarray(lens, jnp.int32)
    out = decode_attention(q, k, v, lens, kv_block=kv_block)
    ref = decode_attention_ref(q, k, v, lens)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


@settings(max_examples=25, deadline=None)
@given(
    B=st.integers(1, 5),
    H=st.integers(1, 4),
    sblocks=st.integers(1, 4),
    kv_block=st.sampled_from([8, 16, 32]),
    D=st.sampled_from([8, 16, 32]),
    data=st.data(),
)
def test_matches_ref_shape_sweep(B, H, sblocks, kv_block, D, data):
    S = sblocks * kv_block
    lens = data.draw(
        st.lists(st.integers(1, S), min_size=B, max_size=B), label="lens"
    )
    _run_case(B, H, S, D, kv_block, lens, jnp.float32,
              seed=data.draw(st.integers(0, 2**16), label="seed"))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    _run_case(3, 2, 64, 16, 16, [1, 33, 64], dtype)


def test_single_token_kv():
    # Only position 0 valid: output must equal v[:, :, 0, :].
    B, H, S, D = 2, 2, 32, 8
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(k1, (B, H, D))
    k = jax.random.normal(k2, (B, H, S, D))
    v = jax.random.normal(k3, (B, H, S, D))
    lens = jnp.ones((B,), jnp.int32)
    out = decode_attention(q, k, v, lens, kv_block=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(v[:, :, 0, :]),
                               rtol=1e-5, atol=1e-5)


def test_full_cache():
    _run_case(2, 3, 96, 16, 32, [96, 96], jnp.float32)


def test_len_one_less_than_tile_boundary():
    # Exercises an almost-fully-masked trailing tile.
    _run_case(1, 1, 64, 8, 16, [17], jnp.float32)


def test_mask_excludes_tail_garbage():
    # Poison the cache beyond lens with huge values: result must not change.
    B, H, S, D = 2, 2, 48, 8
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(k1, (B, H, D))
    k = jax.random.normal(k2, (B, H, S, D))
    v = jax.random.normal(k3, (B, H, S, D))
    lens = jnp.array([10, 20], jnp.int32)
    base = decode_attention(q, k, v, lens, kv_block=16)
    mask = jnp.arange(S)[None, None, :, None] >= lens[:, None, None, None]
    k_p = jnp.where(mask, 1e9, k)
    v_p = jnp.where(mask, -1e9, v)
    poisoned = decode_attention(q, k_p, v_p, lens, kv_block=16)
    np.testing.assert_allclose(np.asarray(base), np.asarray(poisoned),
                               rtol=1e-6, atol=1e-6)


def test_under_jit():
    B, H, S, D = 2, 2, 64, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, S, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, D))
    lens = jnp.array([5, 64], jnp.int32)
    f = jax.jit(lambda q, k, v, l: decode_attention(q, k, v, l, kv_block=16))
    np.testing.assert_allclose(
        np.asarray(f(q, k, v, lens)),
        np.asarray(decode_attention_ref(q, k, v, lens)),
        rtol=2e-5, atol=2e-5,
    )
