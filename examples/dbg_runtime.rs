//! Probe PJRT output structure + runtime call costs (perf-pass tooling).

use std::time::Instant;

use seer::runtime::manifest::default_artifact_dir;
use seer::runtime::{ModelRuntime, Runtime};

fn main() -> anyhow::Result<()> {
    let dir = default_artifact_dir();
    let preset =
        std::env::args().nth(1).unwrap_or_else(|| "small".to_string());

    // Raw output structure: does PJRT untuple results?
    let rt = Runtime::cpu()?;
    let m = seer::runtime::Manifest::load(&dir, &preset)?;
    let entry = m.entry("slot_extract")?;
    let exe = rt.load_hlo(&m.hlo_path(entry))?;
    let d = m.dims;
    let kc = xla::Literal::vec1(&vec![
        0f32;
        d.n_layers * d.batch * d.n_heads * d.max_seq * d.head_dim
    ])
    .reshape(&[
        d.n_layers as i64,
        d.batch as i64,
        d.n_heads as i64,
        d.max_seq as i64,
        d.head_dim as i64,
    ])?;
    let slot = xla::Literal::scalar(0i32);
    let out = exe.execute::<&xla::Literal>(&[&kc, &kc, &slot])?;
    println!(
        "slot_extract (2 results): replicas={} buffers_per_replica={}",
        out.len(),
        out[0].len()
    );

    // Per-entry wall cost.
    let model = ModelRuntime::load(&dir, &preset)?;
    let b = d.batch;
    let tokens = vec![0i32; b * d.prefill_len];
    let lens = vec![4i32; b];
    let t0 = Instant::now();
    let (_, kc, vc) = model.prefill(&tokens, &lens)?;
    println!("prefill: {:?}", t0.elapsed());

    let cur = vec![1i32; b];
    for name in ["decode1", "decode2", "decode3"] {
        let t = Instant::now();
        let _ = model.decode(&cur, &lens, &kc, &vc)?;
        println!("{name}: {:?}", t.elapsed());
    }
    let drafts = vec![1i32; b * d.draft_width];
    let t = Instant::now();
    let _ = model.verify(&drafts, &lens, &kc, &vc)?;
    println!("verify: {:?}", t.elapsed());

    let padded = vec![1i32; d.prefill_len];
    let t = Instant::now();
    let _ = model.prefill_one(&padded, 4)?;
    println!("prefill_one: {:?}", t.elapsed());
    let t = Instant::now();
    let _ = model.slot_extract(&kc, &vc, 0)?;
    println!("slot_extract: {:?}", t.elapsed());

    // Train probe.
    let mut model2 = ModelRuntime::load(&dir, &preset)?;
    let dd = model2.manifest.dims;
    let ttok: Vec<i32> = (0..dd.batch * dd.train_len).map(|i| (i % dd.vocab) as i32).collect();
    let tmask = vec![1i32; dd.batch * dd.train_len];
    let tadv = vec![1f32; dd.batch];
    for i in 0..3 {
        println!("train call {i} ...");
        let loss = model2.train(&ttok, &tmask, &tadv)?;
        println!("  loss {loss}");
    }
    drop(model2);

    // Leak probe: repeated decode calls, watching RSS.
    println!("rss before loop: {:.0} MB", rss_mb());
    let mut state = (kc, vc);
    for i in 0..60 {
        let (_, nkc, nvc) = model.decode(&cur, &lens, &state.0, &state.1)?;
        state = (nkc, nvc);
        if i % 20 == 19 {
            println!("after {} decodes: rss {:.0} MB", i + 1, rss_mb());
        }
    }
    Ok(())
}

#[allow(dead_code)]
fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/statm").unwrap_or_default();
    let pages: f64 = s
        .split_whitespace()
        .nth(1)
        .and_then(|x| x.parse().ok())
        .unwrap_or(0.0);
    pages * 4096.0 / 1e6
}
