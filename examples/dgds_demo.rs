//! DGDS walkthrough: the paper's Appendix-A.2 workflow — async appends,
//! periodic fetch, batched local speculation — exercised with concurrent
//! producer threads and group-correlated streams.
//!
//! Run:  cargo run --release --example dgds_demo

use std::sync::Arc;

use seer::spec::dgds::{DraftClient, DraftServer, SpeculationArgs};
use seer::workload::tokens::{GroupTokenGen, TokenGenConfig};

fn main() {
    let server = Arc::new(DraftServer::spawn());
    let gen = GroupTokenGen::new(TokenGenConfig::default(), 1);
    server.register_group("g0", 600);

    // Four concurrent "inference instances" streaming sibling responses.
    let mut producers = vec![];
    for req in 0..4u64 {
        let s = Arc::clone(&server);
        let tokens = gen.response(req as usize, 3000, 100 + req);
        producers.push(std::thread::spawn(move || {
            // update_cst in 32-token batches (the paper's batching note).
            for start in (0..tokens.len()).step_by(32) {
                let end = (start + 32).min(tokens.len());
                s.update_cst("g0", req, start, &tokens[start..end]);
            }
        }));
    }
    for p in producers {
        p.join().unwrap();
    }
    server.flush();

    // A draft client speculating for a fifth sibling.
    let mut client = DraftClient::new();
    client.fetch(&server, &["g0".to_string()]);
    let target = gen.response(4, 2000, 999);

    let mut accepted_total = 0usize;
    let mut steps = 0usize;
    let mut pos = 24usize;
    while pos + 1 < target.len() {
        let pattern = &target[pos.saturating_sub(24)..pos];
        let drafts = client.batch_speculate(&[(
            "g0",
            pattern,
            SpeculationArgs {
                max_spec_tokens: 8,
                top_k: 2,
                ..Default::default()
            },
        )]);
        let best = drafts[0]
            .iter()
            .map(|p| {
                p.tokens
                    .iter()
                    .zip(&target[pos..])
                    .take_while(|(a, b)| a == b)
                    .count()
            })
            .max()
            .unwrap_or(0);
        accepted_total += best;
        steps += 1;
        pos += best + 1;
    }
    println!(
        "speculated {} tokens over {} steps: mean acceptance length {:.2} (incl. bonus)",
        accepted_total,
        steps,
        1.0 + accepted_total as f64 / steps as f64
    );
    println!(
        "paper Table 2 reference: 1.70 (no group refs) -> 2.5-2.9 (full group context)"
    );
}
