//! Quickstart: load the AOT artifacts, run a real-model rollout through
//! the Seer slot engine (probe-first scheduling + grouped speculative
//! decoding), and print throughput/acceptance statistics.
//!
//! Run with:  `make artifacts && cargo run --release --example quickstart`

use anyhow::Result;
use seer::rl::task::CopyTask;
use seer::rollout::engine::{
    RealRollout, RealRolloutConfig, SeqRequest, StopRule,
};
use seer::runtime::manifest::default_artifact_dir;
use seer::runtime::ModelRuntime;
use seer::sim::Rng;

fn main() -> Result<()> {
    let dir = default_artifact_dir();
    println!("loading 'tiny' artifacts from {dir:?} ...");
    let model = ModelRuntime::load(&dir, "tiny")?;
    println!(
        "platform {}  |  {} parameter leaves  |  pallas={}",
        model.platform(),
        model.n_param_leaves(),
        model.manifest.use_pallas
    );

    // Build two GRPO groups of four requests each.
    let task = CopyTask::default();
    let mut rng = Rng::new(7);
    let mut requests = vec![];
    for group in 0..2 {
        let (prompt, _) = task.sample_prompt(&mut rng);
        for _ in 0..4 {
            requests.push(SeqRequest {
                group,
                prompt: prompt.clone(),
                stop: StopRule::MaxTokens(32),
            });
        }
    }

    let mut roller = RealRollout::new(
        &model,
        RealRolloutConfig {
            use_spec: true,
            context_aware: true,
            chunk_tokens: 16, // divided rollout: 16-token slot leases
            max_gen: 32,
            ..Default::default()
        },
    );
    let report = roller.run(requests)?;

    println!(
        "\ngenerated {} tokens over {} engine steps ({} verify) in {:.2}s",
        report.tokens_generated,
        report.engine_steps,
        report.verify_steps,
        report.wall_secs
    );
    println!(
        "throughput {:.0} tok/s  |  mean acceptance length {:.2}  |  {} slot migrations",
        report.throughput(),
        report.mean_acceptance_len(),
        report.migrations
    );
    for (i, r) in report.results.iter().enumerate() {
        println!(
            "  seq {i}: group {} prompt {} -> {} tokens ({} migrations)",
            r.group,
            r.prompt_len,
            r.tokens.len(),
            r.migrations
        );
    }
    Ok(())
}
