//! Quickstart: load the AOT artifacts, run a real-model rollout through
//! the unified session API (probe-first scheduling + grouped speculative
//! decoding), and print throughput/acceptance statistics.
//!
//! Run with:  `make artifacts && cargo run --release --example quickstart`

use anyhow::Result;
use seer::rl::task::CopyTask;
use seer::rollout::engine::{RealRolloutConfig, SeqRequest, StopRule};
use seer::rollout::RolloutSession;
use seer::runtime::manifest::default_artifact_dir;
use seer::runtime::ModelRuntime;
use seer::sim::Rng;
use seer::workload::GroupId;

fn main() -> Result<()> {
    let dir = default_artifact_dir();
    println!("loading 'tiny' artifacts from {dir:?} ...");
    let model = ModelRuntime::load(&dir, "tiny")?;
    println!(
        "platform {}  |  {} parameter leaves  |  pallas={}",
        model.platform(),
        model.n_param_leaves(),
        model.manifest.use_pallas
    );

    // Build two GRPO groups of four requests each.
    let task = CopyTask::default();
    let mut rng = Rng::new(7);
    let mut requests = vec![];
    for group in 0..2u32 {
        let (prompt, _) = task.sample_prompt(&mut rng);
        for _ in 0..4 {
            requests.push(SeqRequest {
                group: GroupId(group),
                prompt: prompt.clone(),
                stop: StopRule::MaxTokens(32),
            });
        }
    }

    let report = RolloutSession::builder()
        .real(
            &model,
            RealRolloutConfig {
                use_spec: true,
                context_aware: true,
                chunk_tokens: 16, // divided rollout: 16-token slot leases
                max_gen: 32,
                ..Default::default()
            },
        )
        .requests(requests)
        .run()?;

    println!(
        "\ngenerated {} tokens over {} engine steps ({} verify) in {:.2}s",
        report.metrics.tokens_generated,
        report.metrics.engine_steps,
        report.metrics.verify_steps,
        report.wall_secs
    );
    println!(
        "throughput {:.0} tok/s  |  mean acceptance length {:.2}  |  {} slot migrations",
        report.throughput(),
        report.mean_acceptance_len(),
        report.metrics.migrations
    );
    for r in &report.sequences {
        println!(
            "  seq {}: group {} prompt {} -> {} tokens ({} migrations)",
            r.id.0,
            r.group.0,
            r.prompt_len,
            r.tokens.len(),
            r.migrations
        );
    }
    Ok(())
}
