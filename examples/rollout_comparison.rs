//! Cluster-scale scheduler comparison on the paper's three production
//! workloads (a compact Figure 7): veRL vs StreamRL-Oracle vs SEER
//! variants, with and without grouped speculative decoding.
//!
//! Run:  cargo run --release --example rollout_comparison -- [--full]

use seer::config::{SystemConfig, TaskPreset, ALL_PRESETS};
use seer::engine::cluster::run_rollout;
use seer::scheduler::{
    ContextMode, Scheduler, SeerScheduler, StreamRlOracle, VerlScheduler,
};
use seer::spec::simmodel::SdStrategy;
use seer::util::cli::Args;
use seer::util::table::{fmt_pct, fmt_x, Table};

fn main() {
    let args = Args::from_env(&["full"]);
    let full = args.has_flag("full");
    let seed = args.get_u64("seed", 42);

    for preset in ALL_PRESETS {
        let cfg = if full {
            preset.workload()
        } else {
            match preset {
                TaskPreset::Moonlight => preset.workload().scaled(2, 16),
                TaskPreset::Qwen2Vl72b => preset.workload().scaled(2, 8),
                TaskPreset::KimiK2 => preset.workload().scaled(2, 16),
            }
        };
        let mut sys = SystemConfig::default();
        if !full {
            sys.chunk_size = (cfg.avg_gen_len / 4).clamp(64, 2048);
        }

        let systems: Vec<(&str, fn() -> Box<dyn Scheduler>, SdStrategy)> = vec![
            ("veRL", (|| Box::new(VerlScheduler::new()) as Box<dyn Scheduler>) as fn() -> _, SdStrategy::None),
            ("StreamRL-Oracle", || Box::new(StreamRlOracle::new()), SdStrategy::None),
            ("SEER (no SD)", || Box::new(SeerScheduler::new(ContextMode::Learned)), SdStrategy::None),
            ("SEER", || Box::new(SeerScheduler::new(ContextMode::Learned)), SdStrategy::GroupedCst),
        ];

        let mut t = Table::new(
            &format!("{} — {} reqs, {} instances", cfg.name,
                     cfg.reqs_per_iter, cfg.n_instances),
            &["System", "Throughput tok/s", "vs veRL", "Tail(10%)",
              "Preempt", "Migrations", "Util"],
        );
        let mut base = 0.0;
        for (name, mk, sd) in systems {
            let out = run_rollout(&cfg, &sys, mk(), sd, seed);
            let m = &out.metrics;
            let tp = m.throughput();
            if base == 0.0 {
                base = tp;
            }
            t.row(&[
                name.to_string(),
                format!("{tp:.0}"),
                fmt_x(tp / base),
                format!("{:.1}s", m.tail_time(0.10).as_secs_f64()),
                m.preemptions.to_string(),
                m.migrations.to_string(),
                fmt_pct(m.mean_utilization()),
            ]);
        }
        t.print();
    }
}
