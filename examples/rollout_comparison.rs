//! Cluster-scale scheduler comparison on the paper's three production
//! workloads (a compact Figure 7): veRL vs StreamRL-Oracle vs SEER
//! variants, with and without grouped speculative decoding. All runs go
//! through the unified `RolloutSession` builder with registry names.
//!
//! Run:  cargo run --release --example rollout_comparison -- [--full]

use seer::config::{SystemConfig, TaskPreset, ALL_PRESETS};
use seer::rollout::RolloutSession;
use seer::spec::simmodel::SdStrategy;
use seer::util::cli::Args;
use seer::util::table::{fmt_pct, fmt_x, Table};

fn main() {
    let args = Args::from_env(&["full"]);
    let full = args.has_flag("full");
    let seed = args.get_u64("seed", 42);

    for preset in ALL_PRESETS {
        let cfg = if full {
            preset.workload()
        } else {
            match preset {
                TaskPreset::Moonlight => preset.workload().scaled(2, 16),
                TaskPreset::Qwen2Vl72b => preset.workload().scaled(2, 8),
                TaskPreset::KimiK2 => preset.workload().scaled(2, 16),
            }
        };
        let mut sys = SystemConfig::default();
        if !full {
            sys.chunk_size = (cfg.avg_gen_len / 4).clamp(64, 2048);
        }

        let systems: Vec<(&str, &str, SdStrategy)> = vec![
            ("veRL", "verl", SdStrategy::None),
            ("StreamRL-Oracle", "streamrl", SdStrategy::None),
            ("SEER (no SD)", "seer", SdStrategy::None),
            ("SEER", "seer", SdStrategy::GroupedCst),
        ];

        let mut t = Table::new(
            &format!("{} — {} reqs, {} instances", cfg.name,
                     cfg.reqs_per_iter, cfg.n_instances),
            &["System", "Throughput tok/s", "vs veRL", "Tail(10%)",
              "Preempt", "Migrations", "Util"],
        );
        let mut base = 0.0;
        for (name, sched, sd) in systems {
            let out = RolloutSession::builder()
                .workload(cfg.clone())
                .system(sys.clone())
                .scheduler(sched)
                .sd_strategy(sd)
                .seed(seed)
                .run()
                .expect("rollout session failed");
            let m = &out.metrics;
            let tp = m.throughput();
            if base == 0.0 {
                base = tp;
            }
            t.row(&[
                name.to_string(),
                format!("{tp:.0}"),
                fmt_x(tp / base),
                format!("{:.1}s", m.tail_time(0.10).as_secs_f64()),
                m.preemptions.to_string(),
                m.migrations.to_string(),
                fmt_pct(m.mean_utilization()),
            ]);
        }
        t.print();
    }
}
