use seer::config::TaskPreset;
use seer::config::SystemConfig;
use seer::engine::cluster::ClusterSim;
use seer::scheduler::{ContextMode, SeerScheduler};
use seer::spec::simmodel::SdStrategy;
use seer::sim::clock::SimTime;

fn main() {
    let which = std::env::args().nth(1).unwrap_or("moonlight".into());
    let preset = TaskPreset::from_name(&which).unwrap();
    let cfg = preset.workload_for_test();
    eprintln!("cfg: reqs={} insts={} cap={} max_batch={} avg={} max={}",
        cfg.reqs_per_iter, cfg.n_instances, cfg.hw.kv_capacity_tokens,
        cfg.hw.max_batch, cfg.avg_gen_len, cfg.max_gen_len);
    let sys = SystemConfig { chunk_size: 128, ..Default::default() };
    let w = seer::workload::generate_iteration(&cfg, 42);
    let out = ClusterSim::new(cfg, sys, w.groups,
        Box::new(SeerScheduler::new(ContextMode::Learned)), SdStrategy::GroupedCst)
        .sample_interval(SimTime::from_secs(2))
        .run();
    eprintln!("done: makespan={:?} completions={}", out.metrics.makespan, out.metrics.completions.len());
}
