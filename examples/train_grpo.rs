//! End-to-end synchronous GRPO training — the repo's e2e validation
//! driver (EXPERIMENTS.md §E2E): train the real transformer for a few
//! hundred steps on the pattern-continuation task and log the
//! reward/loss curves. All three layers run: Pallas kernels inside the
//! decode/verify artifacts, the JAX train_step for optimization, and the
//! Rust coordinator on the request path.
//!
//! Run:  cargo run --release --example train_grpo -- [--preset small]
//!       [--iters 100] [--spec] [--max-gen 24] [--seed 0]

use anyhow::Result;
use seer::rl::{GrpoConfig, GrpoTrainer};
use seer::runtime::manifest::default_artifact_dir;
use seer::runtime::ModelRuntime;
use seer::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(&["spec", "no-context"]);
    let preset = args.get_or("preset", "tiny");
    let iters = args.get_usize("iters", 60);
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifact_dir);

    println!("# GRPO end-to-end training ({preset}, {iters} iterations)");
    let model = ModelRuntime::load(&dir, preset)?;
    let b = model.manifest.dims.batch;
    println!(
        "platform {}  params {}  batch {}",
        model.platform(),
        model.manifest.n_params,
        b
    );

    let cfg = GrpoConfig {
        prompts_per_iter: b.max(4),
        group_size: 4,
        max_gen: args.get_usize("max-gen", 24),
        use_spec: args.has_flag("spec"),
        context_aware: !args.has_flag("no-context"),
        seed: args.get_u64("seed", 0),
        ..Default::default()
    };
    let train_steps_per_iter =
        (cfg.prompts_per_iter * cfg.group_size).div_ceil(b);
    println!(
        "{} prompts x G={} per iter; {} train steps per iter\n",
        cfg.prompts_per_iter, cfg.group_size, train_steps_per_iter
    );

    let mut trainer = GrpoTrainer::new(model, cfg);
    println!("{:>5} {:>8} {:>10} {:>8} {:>9} {:>8}",
             "iter", "reward", "loss", "tokens", "rollout", "train");
    for i in 0..iters {
        let s = trainer.run_iteration(i)?;
        println!(
            "{:>5} {:>8.3} {:>10.4} {:>8} {:>8.2}s {:>7.2}s",
            s.iter, s.mean_reward, s.mean_loss, s.tokens,
            s.rollout_secs, s.train_secs
        );
    }

    // Learning check: compare reward over the first and last quartiles.
    let h = &trainer.history;
    let q = (h.len() / 4).max(1);
    let early: f32 =
        h[..q].iter().map(|s| s.mean_reward).sum::<f32>() / q as f32;
    let late: f32 = h[h.len() - q..].iter().map(|s| s.mean_reward).sum::<f32>()
        / q as f32;
    println!(
        "\nmean reward: first {q} iters {early:.3} -> last {q} iters {late:.3} ({})",
        if late > early { "LEARNING ✓" } else { "no improvement" }
    );
    println!(
        "total train steps: {}",
        trainer.model.train_steps_taken()
    );
    Ok(())
}
