//! Long-tail anatomy study: where does rollout time go, and which of
//! Seer's mechanisms reclaims it? Sweeps chunk size and the starvation
//! guard, and prints the completion-time CDF for baseline vs SEER — the
//! ablation DESIGN.md §5 lists beyond the paper's own figures.
//!
//! Run:  cargo run --release --example longtail_study

use seer::config::{SystemConfig, TaskPreset};
use seer::rollout::RolloutSession;
use seer::spec::simmodel::SdStrategy;
use seer::util::cli::Args;
use seer::util::table::Table;

fn main() {
    let args = Args::from_env(&[]);
    let seed = args.get_u64("seed", 42);
    let cfg = TaskPreset::Qwen2Vl72b.workload().scaled(2, 8);
    let sys = SystemConfig {
        chunk_size: (cfg.avg_gen_len / 4).clamp(64, 2048),
        ..Default::default()
    };

    // ---- completion-time CDF: veRL vs SEER --------------------------
    println!("# Completion-time CDF (Qwen2-VL, scaled)");
    let runs: Vec<(&str, &str, SdStrategy)> = vec![
        ("veRL", "verl", SdStrategy::None),
        ("SEER", "seer", SdStrategy::GroupedCst),
    ];
    for (name, sched, sd) in runs {
        let out = RolloutSession::builder()
            .workload(cfg.clone())
            .system(sys.clone())
            .scheduler(sched)
            .sd_strategy(sd)
            .seed(seed)
            .run()
            .expect("rollout session failed");
        let mut s = out.metrics.completion_summary();
        println!(
            "{name:>6}: p50 {:>6.1}s  p90 {:>6.1}s  p99 {:>6.1}s  max {:>6.1}s  (makespan {:.1}s)",
            s.percentile(50.0),
            s.percentile(90.0),
            s.percentile(99.0),
            s.max(),
            out.metrics.makespan.as_secs_f64()
        );
    }

    // ---- chunk-size sweep (divided rollout granularity) --------------
    let mut t = Table::new(
        "Chunk-size sweep (SEER, no SD) — finer chunks = better balance vs more migrations",
        &["chunk", "makespan", "tail(10%)", "migrations", "migrated GiB"],
    );
    for chunk in [256u32, 512, 1024, 2048, 4096] {
        let sys = SystemConfig {
            chunk_size: chunk,
            ..Default::default()
        };
        let out = RolloutSession::builder()
            .workload(cfg.clone())
            .system(sys)
            .scheduler("seer")
            .sd_strategy(SdStrategy::None)
            .seed(seed)
            .run()
            .expect("rollout session failed");
        let m = &out.metrics;
        t.row(&[
            chunk.to_string(),
            format!("{:.1}s", m.makespan.as_secs_f64()),
            format!("{:.1}s", m.tail_time(0.10).as_secs_f64()),
            m.migrations.to_string(),
            format!("{:.1}", m.migrated_bytes as f64 / (1u64 << 30) as f64),
        ]);
    }
    t.print();

    // ---- starvation-guard sweep --------------------------------------
    let mut t2 = Table::new(
        "Starvation-guard sweep (fraction of cycles yielding to underserved groups)",
        &["guard", "makespan", "tail(10%)", "p99 completion"],
    );
    for guard in [0.0, 0.05, 0.2, 0.5] {
        let sys = SystemConfig {
            chunk_size: (cfg.avg_gen_len / 4).clamp(64, 2048),
            starvation_guard_frac: guard,
            ..Default::default()
        };
        let out = RolloutSession::builder()
            .workload(cfg.clone())
            .system(sys)
            .scheduler("seer")
            .sd_strategy(SdStrategy::None)
            .seed(seed)
            .run()
            .expect("rollout session failed");
        let mut s = out.metrics.completion_summary();
        t2.row(&[
            format!("{guard}"),
            format!("{:.1}s", out.metrics.makespan.as_secs_f64()),
            format!("{:.1}s", out.metrics.tail_time(0.10).as_secs_f64()),
            format!("{:.1}s", s.percentile(99.0)),
        ]);
    }
    t2.print();
}
