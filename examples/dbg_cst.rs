use seer::experiments::table2_acceptance::replay;
use seer::spec::cst::Cst;
use seer::spec::multipath::speculate_multipath;
use seer::workload::tokens::{GroupTokenGen, TokenGenConfig};

fn main() {
    // Pure repetition sanity: acceptance should approach gamma+1.
    let cyc: Vec<u32> = (0..600).map(|i| 10 + (i % 7)).collect();
    println!("pure cycle acceptance: {:.2}", replay(&[], &cyc, 16, 1));

    // Correlated group streams.
    let gen = GroupTokenGen::new(TokenGenConfig::default(), 99);
    let target = gen.response(0, 1200, 1);
    for n in [0usize, 1, 5, 15] {
        let refs: Vec<Vec<u32>> =
            (0..n).map(|i| gen.response(i + 1, 1200, 2 + i as u64)).collect();
        for k in [1usize, 2, 4] {
            print!("n={n} k={k}: {:.2}  ", replay(&refs, &target, 16, k));
        }
        println!();
    }

    // Multipath sanity on diverging corpus.
    let mut cst = Cst::new();
    cst.append(0, 0, &[1, 2, 3, 4, 5]);
    cst.append(1, 0, &[1, 2, 3, 9, 8]);
    let paths = speculate_multipath(&cst, &[1, 2, 3], 2, 8, 1, 4, 0.0);
    println!("paths: {paths:?}");
}
