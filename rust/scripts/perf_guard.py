#!/usr/bin/env python3
"""CI perf guard for the sim hot path (ISSUE 5 satellite).

Compares a freshly measured bench suite against the checked-in
`BENCH_rollout.json` baseline and fails when any shared bench regressed
beyond the threshold. The threshold is deliberately generous (2x by
default): this guard exists to catch *complexity* regressions — an
O(n)-per-event scan sneaking back onto the steady-state path — not
machine-to-machine noise.

Usage: perf_guard.py BASELINE.json FRESH.json [THRESHOLD]

Behavior:
  * baseline with an empty `benches` map  -> comparison skipped (print a
    notice; commit a measured BENCH_rollout.json to arm the guard)
  * bench present in baseline but missing from the fresh run -> error
    (a silently dropped bench would disarm the guard)
  * any fresh mean_ns > THRESHOLD * baseline mean_ns -> exit 1
"""

import json
import sys


def main() -> int:
    if len(sys.argv) < 3:
        print(__doc__)
        return 2
    baseline_path, fresh_path = sys.argv[1], sys.argv[2]
    threshold = float(sys.argv[3]) if len(sys.argv) > 3 else 2.0
    base = json.load(open(baseline_path))["benches"]
    cur = json.load(open(fresh_path))["benches"]
    if not base:
        # The ::warning line renders as a GitHub Actions annotation, so a
        # disarmed guard is visibly different from a passing one in the
        # run summary (it is inert noise when run outside Actions).
        print(
            "::warning title=perf guard disarmed::baseline "
            f"{baseline_path} has no benches — comparison skipped. "
            "Download the 'bench-rollout' artifact of this run and commit "
            "it as rust/BENCH_rollout.json to arm the guard."
        )
        return 0
    failures = []
    for name, b in sorted(base.items()):
        if b.get("mean_ns", 0) <= 0:
            continue
        c = cur.get(name)
        if c is None:
            print(f"perf guard: bench '{name}' missing from fresh run")
            failures.append((name, float("inf")))
            continue
        ratio = c["mean_ns"] / b["mean_ns"]
        print(
            f"perf guard: {name}: {c['mean_ns']:.0f}ns "
            f"vs baseline {b['mean_ns']:.0f}ns ({ratio:.2f}x)"
        )
        if ratio > threshold:
            failures.append((name, ratio))
    if failures:
        print(f"perf guard: regression beyond {threshold}x: {failures}")
        return 1
    print("perf guard: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
