//! Tests for the unified rollout session layer: registry round-trips,
//! builder-default equivalence with the direct simulator path, observer
//! event-stream consistency, and JSON report output.

use std::cell::RefCell;
use std::rc::Rc;

use seer::config::{SystemConfig, TaskPreset};
use seer::engine::cluster::ClusterSim;
use seer::metrics::EventCounts;
use seer::rollout::{PolicyRegistry, RolloutSession};
use seer::spec::simmodel::SdStrategy;
use seer::util::json::Json;
use seer::workload::generate_iteration;

/// Every scheduler and SD name the CLI USAGE string advertises.
const CLI_SCHEDULERS: [&str; 5] =
    ["seer", "verl", "streamrl", "no-context", "oracle"];
const CLI_SDS: [&str; 5] =
    ["none", "grouped-cst", "suffix-decoding", "draft-model", "mtp"];

#[test]
fn registry_round_trips_every_cli_name() {
    let reg = PolicyRegistry::builtin();
    for name in CLI_SCHEDULERS {
        let s = reg
            .scheduler(name)
            .unwrap_or_else(|e| panic!("scheduler '{name}': {e:#}"));
        assert!(!s.name().is_empty());
        assert!(
            reg.scheduler_names().contains(&name),
            "'{name}' not listed by the registry"
        );
    }
    for name in CLI_SDS {
        let sd = reg
            .sd(name)
            .unwrap_or_else(|e| panic!("sd '{name}': {e:#}"));
        // SD names are their own registry keys.
        assert_eq!(sd.name(), name);
        assert!(reg.sd_names().contains(&name));
    }
    // And nothing beyond what the CLI advertises.
    assert_eq!(reg.scheduler_names().len(), CLI_SCHEDULERS.len());
    assert_eq!(reg.sd_names().len(), CLI_SDS.len());
}

#[test]
fn registry_rejects_unknown_names() {
    let reg = PolicyRegistry::builtin();
    assert!(reg.scheduler("fifo").is_err());
    assert!(reg.sd("eagle").is_err());
    let err = RolloutSession::builder()
        .workload(TaskPreset::Moonlight.workload_for_test())
        .sd("eagle")
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown SD strategy 'eagle'"), "{err}");
}

/// The builder with explicit knobs must reproduce the pre-session
/// `run_rollout()` path (workload generation + ClusterSim) bit-for-bit.
#[test]
fn builder_matches_direct_cluster_sim_bit_for_bit() {
    let cfg = TaskPreset::Moonlight.workload_for_test();
    let sys = SystemConfig {
        chunk_size: 128,
        ..Default::default()
    };
    let seed = 7u64;

    let reg = PolicyRegistry::builtin();
    let w = generate_iteration(&cfg, seed);
    let direct = ClusterSim::new(
        cfg.clone(),
        sys.clone(),
        w.groups,
        reg.scheduler("seer").unwrap(),
        SdStrategy::GroupedCst,
    )
    .run();

    let report = RolloutSession::builder()
        .workload(cfg)
        .system(sys)
        .scheduler("seer")
        .sd("grouped-cst")
        .seed(seed)
        .run()
        .unwrap();

    assert_eq!(report.backend, "sim");
    assert_eq!(report.scheduler, "seer");
    assert_eq!(report.metrics.makespan, direct.metrics.makespan);
    assert_eq!(
        report.metrics.tokens_generated,
        direct.metrics.tokens_generated
    );
    assert_eq!(report.metrics.preemptions, direct.metrics.preemptions);
    assert_eq!(report.metrics.migrations, direct.metrics.migrations);
    assert_eq!(
        report.metrics.completions.len(),
        direct.metrics.completions.len()
    );
    assert_eq!(report.sequences.len(), direct.buffer.len());
}

#[test]
fn observer_event_stream_is_consistent_with_metrics() {
    let counts = Rc::new(RefCell::new(EventCounts::default()));
    let cfg = TaskPreset::Qwen2Vl72b.workload_for_test();
    let reqs = cfg.reqs_per_iter;
    let report = RolloutSession::builder()
        .workload(cfg)
        .system(SystemConfig {
            chunk_size: 128,
            ..Default::default()
        })
        .scheduler("seer")
        .sd("grouped-cst")
        .seed(42)
        .observer(Box::new(counts.clone()))
        .run()
        .unwrap();
    let c = *counts.borrow();
    assert_eq!(c.finished, reqs as u64, "every request must finish");
    assert_eq!(c.finished, report.metrics.completions.len() as u64);
    assert_eq!(c.migrations, report.metrics.migrations);
    assert_eq!(c.preemptions, report.metrics.preemptions);
    assert_eq!(
        c.tokens, report.metrics.tokens_generated,
        "Step events must account for every generated token"
    );
    assert!(c.scheduled >= c.finished, "each finish follows a schedule");
    // Every chunk end / preemption re-enters the waiting queue before it
    // can finish (in-flight admission bounces may add extra schedules).
    assert!(c.scheduled >= c.finished + c.chunk_ends);
    assert!(c.steps > 0);
}

#[test]
fn observers_do_not_perturb_the_run() {
    let cfg = TaskPreset::Moonlight.workload_for_test();
    let sys = SystemConfig {
        chunk_size: 128,
        ..Default::default()
    };
    let observed = RolloutSession::builder()
        .workload(cfg.clone())
        .system(sys.clone())
        .seed(3)
        .observer(Box::new(Rc::new(RefCell::new(EventCounts::default()))))
        .run()
        .unwrap();
    let bare = RolloutSession::builder()
        .workload(cfg)
        .system(sys)
        .seed(3)
        .run()
        .unwrap();
    assert_eq!(observed.metrics.makespan, bare.metrics.makespan);
    assert_eq!(
        observed.metrics.tokens_generated,
        bare.metrics.tokens_generated
    );
}

#[test]
fn per_request_results_unify_with_metrics() {
    let report = RolloutSession::builder()
        .workload(TaskPreset::Qwen2Vl72b.workload_for_test())
        .system(SystemConfig {
            chunk_size: 128,
            ..Default::default()
        })
        .scheduler("seer")
        .sd("grouped-cst")
        .seed(42)
        .run()
        .unwrap();
    let total_gen: u64 =
        report.sequences.iter().map(|s| s.gen_len as u64).sum();
    assert_eq!(total_gen, report.metrics.tokens_generated);
    let migrations: u64 =
        report.sequences.iter().map(|s| s.migrations as u64).sum();
    assert_eq!(migrations, report.metrics.migrations);
    let preemptions: u64 =
        report.sequences.iter().map(|s| s.preemptions as u64).sum();
    assert_eq!(preemptions, report.metrics.preemptions);
    for s in &report.sequences {
        assert!(s.chunks >= 1, "every finished request ran at least once");
        assert!(s.tokens.is_empty(), "fluid backend carries no token ids");
    }
}

#[test]
fn stop_after_skips_completion_check() {
    let cfg = TaskPreset::Moonlight.workload_for_test();
    let target = cfg.reqs_per_iter / 2;
    let report = RolloutSession::builder()
        .workload(cfg.clone())
        .scheduler("verl")
        .sd("none")
        .seed(3)
        .stop_after(target)
        .run()
        .unwrap();
    assert!(report.metrics.completions.len() >= target);
    assert!(report.metrics.completions.len() < cfg.reqs_per_iter);
}

#[test]
fn report_serializes_to_parseable_json() {
    let report = RolloutSession::builder()
        .workload(TaskPreset::Moonlight.workload_for_test())
        .system(SystemConfig {
            chunk_size: 128,
            ..Default::default()
        })
        .seed(42)
        .run()
        .unwrap();
    let text = report.to_json().to_string();
    let parsed = Json::parse(&text).expect("report JSON must round-trip");
    assert_eq!(parsed.expect("backend").as_str(), Some("sim"));
    assert_eq!(parsed.expect("scheduler").as_str(), Some("seer"));
    assert_eq!(
        parsed.expect("tokens_generated").as_u64(),
        Some(report.metrics.tokens_generated)
    );
    assert!(parsed.expect("throughput_tok_s").as_f64().unwrap() > 0.0);
    assert!(parsed.expect("gen_len").get("p90").is_some());
}
