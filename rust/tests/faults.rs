//! Fault & elasticity layer tests (ISSUE 3): determinism under faults,
//! request conservation (every request completes or is explicitly
//! aborted — none silently lost), warm-context preservation across
//! fault-driven migration, the Partial-Rollout stop-threshold regression,
//! and the `RolloutReport::to_json` golden schema snapshot.

mod common;

use seer::config::{SystemConfig, TaskPreset, WorkloadConfig};
use seer::coordinator::RequestBuffer;
use seer::rollout::{RolloutReport, RolloutSession};
use seer::scheduler::{ContextMode, Scheduler, SeerScheduler};
use seer::sim::faults::{FaultEvent, FaultPlan};
use seer::util::json::Json;
use seer::workload::{generate_iteration, InstanceId, RequestId};

fn test_cfg() -> WorkloadConfig {
    TaskPreset::Moonlight.workload_for_test()
}

fn test_sys() -> SystemConfig {
    SystemConfig {
        chunk_size: 128, // small chunks: divided rollout actually divides
        ..Default::default()
    }
}

fn run(scheduler: &str, seed: u64, plan: FaultPlan) -> RolloutReport {
    RolloutSession::builder()
        .workload(test_cfg())
        .system(test_sys())
        .scheduler(scheduler)
        .sd("grouped-cst")
        .seed(seed)
        .faults(plan)
        .run()
        .expect("rollout session failed")
}

/// Makespan of a fault-free run, used to pin fault times to fractions of
/// the run so the scenario shape is scale-independent.
fn clean_makespan(scheduler: &str, seed: u64) -> f64 {
    let r = run(scheduler, seed, FaultPlan::new());
    r.metrics.makespan.as_secs_f64()
}

/// A crash + elasticity script covering InstanceDown, ScaleUp, ScaleDown
/// and InstanceRecover, timed well inside the rollout.
fn crash_and_scale_plan(horizon: f64) -> FaultPlan {
    FaultPlan::new()
        .at(
            0.20 * horizon,
            FaultEvent::InstanceDown {
                instance: InstanceId(1),
            },
        )
        .at(0.35 * horizon, FaultEvent::ScaleUp { n: 1 })
        .at(0.55 * horizon, FaultEvent::ScaleDown { n: 1 })
        .at(
            0.70 * horizon,
            FaultEvent::InstanceRecover {
                instance: InstanceId(1),
            },
        )
        .sorted()
}

/// The report JSON with the host-wall-clock field (the only
/// nondeterministic value) removed.
fn stripped_json(report: &RolloutReport) -> String {
    let mut j = report.to_json();
    if let Json::Obj(m) = &mut j {
        m.remove("wall_secs");
    }
    j.to_string()
}

#[test]
fn fixture_plan_loads_and_round_trips() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/fault_basic.json");
    let plan = FaultPlan::load(&path).expect("fixture must parse");
    assert_eq!(plan.len(), 5, "fixture drifted from its documented shape");
    let back = FaultPlan::from_json_str(&plan.to_json().to_string()).unwrap();
    assert_eq!(back, plan);
    // The fixture replays cleanly end to end (conservation holds whether
    // or not every event fires before completion at this scale).
    let report = run("seer", 11, plan);
    assert_eq!(
        report.metrics.completions.len(),
        test_cfg().reqs_per_iter
    );
}

#[test]
fn determinism_same_seed_same_plan_identical_report() {
    let horizon = clean_makespan("seer", 42);
    let plan = crash_and_scale_plan(horizon);
    let a = run("seer", 42, plan.clone());
    let b = run("seer", 42, plan.clone());
    // The faults really fired — this is not a vacuously healthy run.
    assert!(a.metrics.instances_lost >= 2, "{}", a.metrics.instances_lost);
    assert!(a.metrics.instances_added >= 1);
    assert_eq!(stripped_json(&a), stripped_json(&b));
    // And the script is not a no-op: the report differs from fault-free.
    let clean = run("seer", 42, FaultPlan::new());
    assert_ne!(stripped_json(&a), stripped_json(&clean));
}

#[test]
fn no_request_lost_under_down_and_scale_any_scheduler() {
    for scheduler in ["seer", "verl", "streamrl", "rollpacker"] {
        let horizon = clean_makespan(scheduler, 7);
        let plan = crash_and_scale_plan(horizon);
        let report = run(scheduler, 7, plan);
        let m = &report.metrics;
        assert!(
            m.instances_lost >= 2,
            "{scheduler}: script did not fire ({} lost)",
            m.instances_lost
        );
        assert!(
            m.fault_requeued >= 1,
            "{scheduler}: nothing drained off the lost instances"
        );
        // Conservation: every request completed exactly once...
        let cfg = test_cfg();
        assert_eq!(
            m.completions.len(),
            cfg.reqs_per_iter,
            "{scheduler} lost requests"
        );
        let mut ids: Vec<u32> = m.completions.iter().map(|c| c.id.0).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), cfg.reqs_per_iter, "{scheduler} double-counted");
        // ...generating exactly the workload's tokens (crash-lost
        // progress was re-generated, never silently dropped or
        // double-counted).
        let expected = generate_iteration(&cfg, 7).total_gen_tokens();
        assert_eq!(m.tokens_generated, expected, "{scheduler} token drift");
        assert!(report.sequences.iter().all(|s| !s.aborted));
    }
}

#[test]
fn aborts_are_terminal_and_excluded_from_completions() {
    let horizon = clean_makespan("seer", 3);
    // Two aborts at t=0 (before anything can finish) plus one mid-run
    // (which may be a no-op if that request already completed).
    let plan = FaultPlan::new()
        .at(0.0, FaultEvent::RequestAbort { req: RequestId(1) })
        .at(0.0, FaultEvent::RequestAbort { req: RequestId(5) })
        .at(0.30 * horizon, FaultEvent::RequestAbort { req: RequestId(2) })
        .sorted();
    let report = run("seer", 3, plan);
    let m = &report.metrics;
    let total = test_cfg().reqs_per_iter;
    assert!(m.aborted >= 2, "t=0 aborts must fire: {}", m.aborted);
    assert_eq!(m.completions.len() + m.aborted as usize, total);
    for s in &report.sequences {
        if s.id.0 == 1 || s.id.0 == 5 {
            assert!(s.aborted, "request {} not flagged aborted", s.id.0);
        }
    }
    // Aborted requests never appear among completions.
    let aborted: Vec<u32> = report
        .sequences
        .iter()
        .filter(|s| s.aborted)
        .map(|s| s.id.0)
        .collect();
    for c in &m.completions {
        assert!(!aborted.contains(&c.id.0));
    }
}

/// Warm-context preservation across fault-driven migration: a request
/// drained off a crashed instance reports its in-flight progress through
/// the default `on_instance_lost` → `on_chunk_end` path, so a stale
/// estimate (or a short sibling finishing) cannot demote its group below
/// the length it already demonstrated.
#[test]
fn fault_drain_preserves_context_manager_progress() {
    let cfg = test_cfg();
    let w = generate_iteration(&cfg, 5);
    let mut buffer = RequestBuffer::from_groups(&w.groups);
    let mut s = SeerScheduler::new(ContextMode::Learned);
    s.init(&w.groups, &cfg, &SystemConfig::default());

    // A request runs on instance 0 and generates 700 tokens...
    let id = buffer.all()[0].id();
    let group = buffer.get(id).group();
    buffer.mark_scheduled(id);
    buffer.get_mut(id).generated = 700;
    // ...then the instance dies: the driver drains it back to waiting
    // and notifies the policy.
    buffer.mark_waiting(id);
    s.on_instance_lost(
        InstanceId(0),
        &[id],
        &[InstanceId(1)],
        &buffer,
    );

    // A short sibling finishing afterwards must not demote the group
    // below the drained request's demonstrated progress (before any
    // finish the estimate is the conservative bound by design; the
    // progress floor recorded by the drain kicks in from the first
    // completion).
    let sib = buffer
        .all()
        .iter()
        .find(|r| r.group() == group && r.id() != id)
        .unwrap()
        .id();
    buffer.mark_scheduled(sib);
    buffer.get_mut(sib).generated = 10;
    buffer.mark_finished(sib);
    s.on_finished(buffer.get(sib));
    assert_eq!(s.context_manager().estimate(group), 700);
}

/// Regression (satellite 4): the Partial-Rollout stop threshold counts
/// unique *completions*. A request re-queued by migration or a fault
/// drain must not be double-counted toward it, and fault-aborted
/// requests (terminal but never completed) must not count at all.
#[test]
fn stop_after_counts_unique_completions_only() {
    let cfg = test_cfg();
    let target = cfg.reqs_per_iter / 2;
    let horizon = clean_makespan("seer", 9);
    // Early aborts + a crash: under the old phase-scan accounting the
    // aborted (phase-finished) requests would have counted toward the
    // threshold and the run would stop short of `target` completions.
    let plan = FaultPlan::new()
        .at(0.0, FaultEvent::RequestAbort { req: RequestId(0) })
        .at(0.0, FaultEvent::RequestAbort { req: RequestId(9) })
        .at(
            0.10 * horizon,
            FaultEvent::InstanceDown {
                instance: InstanceId(1),
            },
        )
        .at(
            0.25 * horizon,
            FaultEvent::InstanceRecover {
                instance: InstanceId(1),
            },
        )
        .sorted();
    let report = RolloutSession::builder()
        .workload(cfg.clone())
        .system(test_sys())
        .scheduler("seer")
        .sd("grouped-cst")
        .seed(9)
        .stop_after(target)
        .faults(plan)
        .run()
        .unwrap();
    let m = &report.metrics;
    assert!(m.aborted >= 2);
    assert!(
        m.completions.len() >= target,
        "stopped short: {} < {target} (aborts/requeues miscounted)",
        m.completions.len()
    );
    let mut ids: Vec<u32> = m.completions.iter().map(|c| c.id.0).collect();
    let n = ids.len();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), n, "a migrated request completed twice");
    // Migration really happened at this chunk size, so the uniqueness
    // assertion above actually bit.
    assert!(
        report.sequences.iter().any(|s| s.chunks > 1),
        "no request ran as more than one chunk — regression test vacuous"
    );
}

/// Utilization accounting under elasticity (PR-9 bugfix): an instance
/// added mid-run by `ScaleUp` is measured over its *live* interval, not
/// the full makespan — and for an always-live fleet the new accounting
/// is exactly the old `Σ busy / (makespan · n)`.
#[test]
fn utilization_counts_late_joiners_over_their_live_interval() {
    // Always-live fleet: live-interval accounting changes nothing.
    let clean = run("seer", 21, FaultPlan::new());
    let m = &clean.metrics;
    let naive = |m: &seer::metrics::RolloutMetrics| {
        m.busy_time
            .iter()
            .map(|b| b.as_secs_f64() / m.makespan.as_secs_f64())
            .sum::<f64>()
            / m.busy_time.len() as f64
    };
    assert!(
        (m.mean_utilization() - naive(m)).abs() < 1e-12,
        "always-live fleet: {} != naive {}",
        m.mean_utilization(),
        naive(m)
    );

    // Scale one instance in late: it must not deflate the mean.
    let horizon = clean.metrics.makespan.as_secs_f64();
    let plan = FaultPlan::new()
        .at(0.50 * horizon, FaultEvent::ScaleUp { n: 1 })
        .sorted();
    let scaled = run("seer", 21, plan);
    let m = &scaled.metrics;
    assert!(m.instances_added >= 1, "scale-up never fired");
    // The joiner really has a shorter live interval and did real work,
    // so the strict inequality below is not vacuous.
    let joiner = m.busy_time.len() - 1;
    assert!(m.busy_time[joiner] > seer::sim::clock::SimTime::ZERO);
    assert!(
        m.live_time[joiner] < m.makespan,
        "joiner live {:?} !< makespan {:?}",
        m.live_time[joiner],
        m.makespan
    );
    assert!(
        m.mean_utilization() > naive(m),
        "late joiner still deflates utilization: {} <= naive {}",
        m.mean_utilization(),
        naive(m)
    );
}

/// Golden snapshot (satellite 3) of the `RolloutReport::to_json` schema:
/// the set of key paths is pinned to a checked-in fixture so report-shape
/// regressions fail loudly. Values are covered by the determinism tests
/// above (and `wall_secs` is host-dependent by design), so the snapshot
/// pins *shape*, not numbers.
///
/// Regen path (documented): run with `SEER_REGEN_GOLDEN=1` —
/// `SEER_REGEN_GOLDEN=1 cargo test -q --test faults report_json_schema` —
/// which rewrites `tests/fixtures/report_golden_keys.json` from the
/// current report and passes; commit the updated fixture.
#[test]
fn report_json_schema_matches_golden() {
    let report = run("seer", 7, FaultPlan::new());
    let keys = common::flatten_key_paths(&report.to_json());
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/report_golden_keys.json");
    common::check_golden_keys(&keys, &path);
}

/// Determinism of the JSON pipeline end to end: two identical faulty runs
/// print byte-identical reports through the CLI's serialization path.
#[test]
fn fixture_replay_is_deterministic() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/fault_basic.json");
    let plan = FaultPlan::load(&path).unwrap();
    let a = run("verl", 13, plan.clone());
    let b = run("verl", 13, plan);
    assert_eq!(stripped_json(&a), stripped_json(&b));
}
