//! Runtime integration tests: load the AOT HLO-text artifacts through the
//! PJRT CPU client and validate end-to-end numerics — the rust side of
//! the L1/L2/L3 composition chain. Requires `make artifacts`.

use seer::rollout::engine::{RealRolloutConfig, SeqRequest, StopRule};
use seer::rollout::RolloutSession;
use seer::runtime::manifest::default_artifact_dir;
use seer::runtime::ModelRuntime;
use seer::workload::GroupId;

fn model() -> Option<ModelRuntime> {
    let dir = default_artifact_dir();
    if !dir.join("tiny.manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(ModelRuntime::load(&dir, "tiny").expect("load tiny artifacts"))
}

#[test]
fn loads_and_compiles_all_entries() {
    let Some(m) = model() else { return };
    assert_eq!(m.platform().to_lowercase(), "cpu");
    for entry in [
        "prefill",
        "prefill_one",
        "slot_update",
        "slot_extract",
        "decode_step",
        "verify_step",
        "train_step",
    ] {
        assert!(m.manifest.entries.contains_key(entry), "{entry} missing");
    }
}

#[test]
fn decode_chain_is_consistent() {
    // Greedy decode after prefill must equal greedy decode after feeding
    // the same tokens one by one (KV-cache correctness through the
    // Pallas decode kernel).
    let Some(m) = model() else { return };
    let d = m.manifest.dims;
    let b = d.batch;

    // Prefill a 6-token prompt on all slots.
    let prompt: Vec<i32> = vec![5, 9, 13, 2, 7, 11];
    let mut tokens = vec![0i32; b * d.prefill_len];
    for slot in 0..b {
        for (i, &t) in prompt.iter().enumerate() {
            tokens[slot * d.prefill_len + i] = t;
        }
    }
    let lens = vec![prompt.len() as i32; b];
    let (logits, kc, vc) = m.prefill(&tokens, &lens).unwrap();

    // Greedy next token from prefill.
    let v = d.vocab;
    let argmax = |row: &[f32]| -> i32 {
        row.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as i32
    };
    let t0 = argmax(&logits[..v]);

    // Decode 4 greedy steps.
    let mut cache_lens = lens.clone();
    let (mut kc, mut vc) = (kc, vc);
    let mut cur = vec![t0; b];
    let mut chain = vec![t0];
    for _ in 0..4 {
        let (lg, nkc, nvc) = m.decode(&cur, &cache_lens, &kc, &vc).unwrap();
        kc = nkc;
        vc = nvc;
        for l in cache_lens.iter_mut() {
            *l += 1;
        }
        let t = argmax(&lg[..v]);
        cur = vec![t; b];
        chain.push(t);
    }

    // Verify path over the same tokens must accept everything (greedy
    // drafts == greedy continuation), proving verify == serial decode.
    let (_, kc2, vc2) = m.prefill(&tokens, &lens).unwrap();
    let g = d.draft_width;
    let mut drafts = vec![0i32; b * g];
    for slot in 0..b {
        for (i, &t) in chain.iter().take(g).enumerate() {
            drafts[slot * g + i] = t;
        }
    }
    let (vlogits, _, _) = m.verify(&drafts, &lens, &kc2, &vc2).unwrap();
    // Position i of verify predicts chain[i+1].
    for i in 0..(g - 1).min(chain.len() - 1) {
        let row = &vlogits[i * v..(i + 1) * v];
        assert_eq!(
            argmax(row),
            chain[i + 1],
            "verify diverged from serial decode at position {i}"
        );
    }
}

#[test]
fn slot_update_extract_roundtrip() {
    let Some(m) = model() else { return };
    let d = m.manifest.dims;
    let b = d.batch;
    let mut tokens = vec![0i32; b * d.prefill_len];
    for slot in 0..b {
        for i in 0..8 {
            tokens[slot * d.prefill_len + i] = (slot * 13 + i + 1) as i32;
        }
    }
    let lens = vec![8i32; b];
    let (_, kc, vc) = m.prefill(&tokens, &lens).unwrap();

    // Extract slot 1, overwrite slot 1 with slot 0's cache, then restore.
    let (k1, v1) = m.slot_extract(&kc, &vc, 1).unwrap();
    let (k0, v0) = m.slot_extract(&kc, &vc, 0).unwrap();
    let (kc2, vc2) = m.slot_update(&kc, &vc, &k0, &v0, 1).unwrap();
    let (kc3, vc3) = m.slot_update(&kc2, &vc2, &k1, &v1, 1).unwrap();

    // After restore, decode logits must match the original caches.
    let cur = vec![3i32; b];
    let (la, _, _) = m.decode(&cur, &lens, &kc, &vc).unwrap();
    let (lb, _, _) = m.decode(&cur, &lens, &kc3, &vc3).unwrap();
    let max_diff = la
        .iter()
        .zip(&lb)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-5, "roundtrip changed logits by {max_diff}");
}

#[test]
fn train_step_changes_params_and_reduces_loss() {
    let Some(mut m) = model() else { return };
    let d = m.manifest.dims;
    let before = m.param_leaf(0).unwrap();
    let tokens: Vec<i32> = (0..d.batch * d.train_len)
        .map(|i| ((i * 7 + 3) % d.vocab) as i32)
        .collect();
    let mask = vec![1i32; d.batch * d.train_len];
    let adv = vec![1f32; d.batch];
    let mut losses = vec![];
    for _ in 0..4 {
        losses.push(m.train(&tokens, &mask, &adv).unwrap());
    }
    let after = m.param_leaf(0).unwrap();
    assert_ne!(before, after, "params unchanged by train_step");
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss not decreasing: {losses:?}"
    );
    assert_eq!(m.train_steps_taken(), 4);
}

#[test]
fn real_rollout_with_divided_and_spec() {
    let Some(m) = model() else { return };
    // 2 groups x 3 siblings with chunked slot leases + grouped SD.
    let mut requests = vec![];
    for group in 0..2u32 {
        for r in 0..3u32 {
            let prompt: Vec<u32> =
                (0..10).map(|i| 4 + group * 3 + (i + r) % 7).collect();
            requests.push(SeqRequest {
                group: GroupId(group),
                prompt,
                stop: StopRule::MaxTokens(20),
            });
        }
    }
    let report = RolloutSession::builder()
        .real(
            &m,
            RealRolloutConfig {
                use_spec: true,
                chunk_tokens: 8,
                context_aware: true,
                max_gen: 20,
                seed: 11,
                ..Default::default()
            },
        )
        .requests(requests)
        .run()
        .unwrap();
    assert_eq!(report.backend, "real");
    assert_eq!(report.sequences.len(), 6);
    for r in &report.sequences {
        assert_eq!(r.tokens.len(), 20);
        assert_eq!(r.gen_len, 20);
    }
    assert_eq!(report.metrics.tokens_generated, 120);
    assert_eq!(report.metrics.completions.len(), 6);
    assert!(report.metrics.engine_steps > 0);
    // Divided rollout actually parked/readmitted (6 requests, 4 slots).
    assert!(
        report.metrics.migrations > 0,
        "no slot migrations happened"
    );
    let seq_migrations: u64 =
        report.sequences.iter().map(|r| r.migrations as u64).sum();
    assert_eq!(seq_migrations, report.metrics.migrations);
}

#[test]
fn rollout_is_reproducible() {
    let Some(m) = model() else { return };
    let mk = || {
        vec![SeqRequest {
            group: GroupId(0),
            prompt: vec![5, 6, 7, 8],
            stop: StopRule::MaxTokens(12),
        }]
    };
    let run = |seed| {
        let report = RolloutSession::builder()
            .real(
                &m,
                RealRolloutConfig {
                    use_spec: false,
                    seed,
                    max_gen: 12,
                    ..Default::default()
                },
            )
            .requests(mk())
            .run()
            .unwrap();
        report.sequences[0].tokens.clone()
    };
    assert_eq!(run(1), run(1));
    assert_ne!(run(1), run(2));
}
