//! Cross-module integration tests: full rollouts on each preset under
//! each headline configuration, checking the paper's qualitative claims
//! hold at test scale. All rollouts go through the unified
//! `RolloutSession` API with registry policy names.

use seer::config::{SystemConfig, TaskPreset};
use seer::rl::phases::PhaseModel;
use seer::rollout::{RolloutReport, RolloutSession};
use seer::spec::simmodel::SdStrategy;

fn sys_for(cfg: &seer::config::WorkloadConfig) -> SystemConfig {
    SystemConfig {
        chunk_size: (cfg.avg_gen_len / 4).clamp(32, 2048),
        ..Default::default()
    }
}

fn rollout(preset: TaskPreset, scheduler: &str, sd: SdStrategy) -> RolloutReport {
    let cfg = preset.workload_for_test();
    let sys = sys_for(&cfg);
    RolloutSession::builder()
        .workload(cfg)
        .system(sys)
        .scheduler(scheduler)
        .sd_strategy(sd)
        .seed(42)
        .run()
        .expect("rollout session failed")
}

fn throughput(preset: TaskPreset, scheduler: &str, sd: SdStrategy) -> f64 {
    rollout(preset, scheduler, sd).metrics.throughput()
}

#[test]
fn seer_full_beats_verl_on_every_task() {
    for preset in seer::config::ALL_PRESETS {
        let verl = throughput(preset, "verl", SdStrategy::None);
        let seer = throughput(preset, "seer", SdStrategy::GroupedCst);
        assert!(
            seer > verl * 1.15,
            "{}: seer {seer:.0} vs verl {verl:.0}",
            preset.name()
        );
    }
}

#[test]
fn grouped_sd_beats_no_sd_on_seer() {
    for preset in seer::config::ALL_PRESETS {
        let none = throughput(preset, "seer", SdStrategy::None);
        let sd = throughput(preset, "seer", SdStrategy::GroupedCst);
        assert!(
            sd > none,
            "{}: sd {sd:.0} vs none {none:.0}",
            preset.name()
        );
    }
}

#[test]
fn seer_cuts_tail_time_on_memory_constrained_tasks() {
    for preset in [TaskPreset::Moonlight, TaskPreset::Qwen2Vl72b] {
        let verl = rollout(preset, "verl", SdStrategy::None);
        let seer = rollout(preset, "seer", SdStrategy::GroupedCst);
        let vt = verl.metrics.tail_time(0.10).as_secs_f64();
        let st = seer.metrics.tail_time(0.10).as_secs_f64();
        assert!(
            st < vt,
            "{}: seer tail {st:.1}s vs verl {vt:.1}s",
            preset.name()
        );
    }
}

#[test]
fn context_sched_close_to_oracle() {
    // Figure 10's headline: learned context reaches >=85% of oracle
    // throughput at test scale (paper: 96%).
    let learned = throughput(TaskPreset::Qwen2Vl72b, "seer", SdStrategy::None);
    let oracle = throughput(TaskPreset::Qwen2Vl72b, "oracle", SdStrategy::None);
    let ratio = learned / oracle;
    assert!(ratio > 0.85, "learned/oracle = {ratio:.2}");
}

#[test]
fn streamrl_oracle_between_verl_and_seer_on_constrained_tasks() {
    let verl = throughput(TaskPreset::Qwen2Vl72b, "verl", SdStrategy::None);
    let stream =
        throughput(TaskPreset::Qwen2Vl72b, "streamrl", SdStrategy::None);
    assert!(
        stream > verl * 0.9,
        "streamrl {stream:.0} unexpectedly catastrophic vs verl {verl:.0}"
    );
}

#[test]
fn rollout_dominates_iteration_time() {
    // Table 1's structural claim at test scale.
    for preset in seer::config::ALL_PRESETS {
        let cfg = preset.workload_for_test();
        let out = rollout(preset, "verl", SdStrategy::None);
        let model = PhaseModel::for_workload(&cfg);
        let split = model.split(
            out.metrics.makespan,
            out.metrics.tokens_generated,
        );
        let (r, _, u) = split.fractions();
        assert!(r > 0.5, "{}: rollout fraction {r:.2}", preset.name());
        assert!(u < 0.3, "{}: weight update fraction {u:.2}", preset.name());
    }
}

#[test]
fn load_samples_cover_run() {
    let cfg = TaskPreset::Moonlight.workload_for_test();
    let out = RolloutSession::builder()
        .workload(cfg)
        .scheduler("seer")
        .sd_strategy(SdStrategy::None)
        .seed(5)
        .sample_interval(seer::sim::clock::SimTime::from_millis(500))
        .run()
        .expect("rollout session failed");
    assert!(!out.metrics.load_samples.is_empty());
    let t_max = out
        .metrics
        .load_samples
        .iter()
        .map(|s| s.t)
        .max()
        .unwrap();
    // Samples span at least half the run.
    assert!(t_max.as_secs_f64() > 0.5 * out.metrics.makespan.as_secs_f64());
}
