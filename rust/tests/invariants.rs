//! Property-based invariant tests over the coordinator, scheduler and
//! simulation (DESIGN.md §6): no request lost, KV accounting conserved,
//! estimates monotone, determinism, MBA budget discipline — under
//! randomized workloads, every scheduling policy, and (ISSUE 3) seeded
//! random fault & elasticity scripts.

use std::cell::RefCell;
use std::rc::Rc;

use seer::config::{SystemConfig, TaskPreset, WorkloadConfig};
use seer::engine::cluster::{ClusterSim, RolloutOutcome};
use seer::metrics::EventCounts;
use seer::rollout::{ObserverHub, RolloutEvent, RolloutObserver};
use seer::scheduler::{
    ContextMode, RollPackerScheduler, Scheduler, SeerScheduler,
    StreamRlOracle, VerlScheduler,
};
use seer::sim::clock::SimTime;
use seer::sim::faults::FaultPlan;
use seer::spec::simmodel::SdStrategy;
use seer::sweep::SweepRunner;
use seer::util::prop::{case_params, check, panic_message, PropConfig};
use seer::workload::generate_iteration;

fn random_workload(rng: &mut seer::sim::Rng, size: usize) -> WorkloadConfig {
    let base = match rng.below(3) {
        0 => TaskPreset::Moonlight,
        1 => TaskPreset::Qwen2Vl72b,
        _ => TaskPreset::KimiK2,
    };
    let mut cfg = base.workload_for_test();
    cfg.reqs_per_iter = cfg.reqs_per_iter.min(32 + size * 4);
    cfg.reqs_per_iter =
        (cfg.reqs_per_iter / cfg.group_size).max(2) * cfg.group_size;
    cfg.n_instances = rng.range_usize(2, 4);
    cfg
}

fn random_scheduler(rng: &mut seer::sim::Rng) -> (Box<dyn Scheduler>, &'static str) {
    match rng.below(6) {
        0 => (Box::new(VerlScheduler::new()), "verl"),
        1 => (Box::new(StreamRlOracle::new()), "streamrl"),
        2 => (Box::new(SeerScheduler::new(ContextMode::None)), "no-context"),
        3 => (Box::new(SeerScheduler::new(ContextMode::Oracle)), "oracle"),
        4 => (Box::new(RollPackerScheduler::new()), "rollpacker"),
        _ => (Box::new(SeerScheduler::new(ContextMode::Learned)), "seer"),
    }
}

fn random_sd(rng: &mut seer::sim::Rng) -> SdStrategy {
    match rng.below(5) {
        0 => SdStrategy::None,
        1 => SdStrategy::GroupedCst,
        2 => SdStrategy::SuffixDecoding,
        3 => SdStrategy::DraftModel,
        _ => SdStrategy::Mtp,
    }
}

fn run_once(
    cfg: &WorkloadConfig,
    sched: Box<dyn Scheduler>,
    sd: SdStrategy,
    seed: u64,
) -> RolloutOutcome {
    let sys = SystemConfig {
        chunk_size: (cfg.avg_gen_len / 3).clamp(16, 2048),
        ..Default::default()
    };
    let w = generate_iteration(cfg, seed);
    ClusterSim::new(cfg.clone(), sys, w.groups, sched, sd)
        .sample_interval(SimTime::from_secs(5))
        .run()
}

#[test]
fn no_request_lost_any_policy() {
    check(
        "every request finishes exactly once",
        PropConfig {
            cases: 24,
            max_size: 40,
            ..Default::default()
        },
        |c| {
            let cfg = random_workload(c.rng, c.size);
            let (sched, name) = random_scheduler(c.rng);
            let sd = random_sd(c.rng);
            let seed = c.rng.next_u64();
            let out = run_once(&cfg, sched, sd, seed);
            assert_eq!(
                out.metrics.completions.len(),
                cfg.reqs_per_iter,
                "policy {name} lost requests"
            );
            out.buffer.check_invariants();
            let mut ids: Vec<u32> =
                out.metrics.completions.iter().map(|c| c.id.0).collect();
            ids.sort();
            ids.dedup();
            assert_eq!(ids.len(), cfg.reqs_per_iter);
        },
    );
}

#[test]
fn all_tokens_generated_exactly() {
    check(
        "tokens generated == workload total",
        PropConfig {
            cases: 16,
            max_size: 32,
            ..Default::default()
        },
        |c| {
            let cfg = random_workload(c.rng, c.size);
            let (sched, _) = random_scheduler(c.rng);
            let seed = c.rng.next_u64();
            let w = generate_iteration(&cfg, seed);
            let expected = w.total_gen_tokens();
            let sys = SystemConfig::default();
            let out = ClusterSim::new(
                cfg.clone(),
                sys,
                w.groups,
                sched,
                SdStrategy::None,
            )
            .run();
            assert_eq!(out.metrics.tokens_generated, expected);
            for cpl in &out.metrics.completions {
                let spec = &out.buffer.get(cpl.id).spec;
                assert_eq!(cpl.gen_len, spec.gen_len);
            }
        },
    );
}

#[test]
fn deterministic_event_traces() {
    check(
        "same seed -> identical outcome",
        PropConfig {
            cases: 8,
            max_size: 24,
            ..Default::default()
        },
        |c| {
            let cfg = random_workload(c.rng, c.size);
            let mode = if c.rng.bool(0.5) {
                ContextMode::Learned
            } else {
                ContextMode::None
            };
            let sd = random_sd(c.rng);
            let seed = c.rng.next_u64();
            let a = run_once(&cfg, Box::new(SeerScheduler::new(mode)), sd, seed);
            let b = run_once(&cfg, Box::new(SeerScheduler::new(mode)), sd, seed);
            assert_eq!(a.metrics.makespan, b.metrics.makespan);
            assert_eq!(a.metrics.preemptions, b.metrics.preemptions);
            assert_eq!(a.metrics.migrations, b.metrics.migrations);
            let fa: Vec<_> = a
                .metrics
                .completions
                .iter()
                .map(|x| (x.id, x.finished_at))
                .collect();
            let fb: Vec<_> = b
                .metrics
                .completions
                .iter()
                .map(|x| (x.id, x.finished_at))
                .collect();
            assert_eq!(fa, fb);
        },
    );
}

#[test]
fn seer_never_catastrophically_worse() {
    check(
        "seer no worse than baseline",
        PropConfig {
            cases: 10,
            max_size: 32,
            ..Default::default()
        },
        |c| {
            let cfg = random_workload(c.rng, c.size);
            let seed = c.rng.next_u64();
            let verl =
                run_once(&cfg, Box::new(VerlScheduler::new()), SdStrategy::None, seed);
            let seer = run_once(
                &cfg,
                Box::new(SeerScheduler::new(ContextMode::Learned)),
                SdStrategy::None,
                seed,
            );
            let v = verl.metrics.makespan.as_secs_f64();
            let s = seer.metrics.makespan.as_secs_f64();
            assert!(
                s <= v * 1.30 + 1.0,
                "seer {s:.1}s vs verl {v:.1}s on {}",
                cfg.name
            );
        },
    );
}

#[test]
fn oracle_lfs_at_least_as_good_as_no_context() {
    check(
        "oracle >= no-context (within tolerance)",
        PropConfig {
            cases: 8,
            max_size: 24,
            ..Default::default()
        },
        |c| {
            let cfg = random_workload(c.rng, c.size);
            let seed = c.rng.next_u64();
            let none = run_once(
                &cfg,
                Box::new(SeerScheduler::new(ContextMode::None)),
                SdStrategy::None,
                seed,
            );
            let oracle = run_once(
                &cfg,
                Box::new(SeerScheduler::new(ContextMode::Oracle)),
                SdStrategy::None,
                seed,
            );
            let n = none.metrics.makespan.as_secs_f64();
            let o = oracle.metrics.makespan.as_secs_f64();
            assert!(
                o <= n * 1.15 + 0.5,
                "oracle {o:.1}s vs no-context {n:.1}s"
            );
        },
    );
}

/// Observer asserting the event stream's virtual clock never runs
/// backwards.
#[derive(Default)]
struct MonotoneClock {
    last: SimTime,
    events: u64,
}

impl RolloutObserver for MonotoneClock {
    fn on_event(&mut self, ev: &RolloutEvent) {
        let now = ev.now();
        assert!(
            now >= self.last,
            "sim clock ran backwards: {now:?} after {:?}",
            self.last
        );
        self.last = now;
        self.events += 1;
    }
}

/// ISSUE 3 property sweep, driven through the parallel
/// [`SweepRunner`] since ISSUE 4: the same 50 seeded (workload, scale,
/// policy, fault-plan) combos as the old serial `check` loop — the
/// cases come from `util::prop::case_params`, the exact schedule
/// `check` drives — now executed by concurrent worker threads,
/// asserting the cross-cutting invariants *under concurrent execution*:
/// every request completes or is explicitly aborted (none silently
/// lost), the KV pool is never over-committed, per-instance concurrency
/// stays within the batch cap, the buffer's O(1) lifecycle counters
/// (`n_finished`/`n_running`/`n_aborted`, ISSUE 5) equal their full
/// phase scans (both checked inside the sim **at every telemetry
/// sample** via `with_invariant_checks` →
/// `RequestBuffer::check_invariants`), the sim clock is monotone over
/// the whole event stream, and the `EventCounts` observer tally agrees
/// with the driver-side `RolloutMetrics`. A failure panics with the
/// case's seed, like the serial harness.
#[test]
fn faulty_runs_conserve_requests_and_invariants() {
    let cases = case_params(&PropConfig {
        cases: 50,
        max_size: 36,
        ..Default::default()
    });
    SweepRunner::from_env().map(&cases, |i, &(case_seed, size)| {
        let run = || {
            let mut rng = seer::sim::Rng::new(case_seed);
            let cfg = random_workload(&mut rng, size);
            let (sched, name) = random_scheduler(&mut rng);
            let sd = random_sd(&mut rng);
            let seed = rng.next_u64();
            let w = generate_iteration(&cfg, seed);
            let n = w.n_requests();
            let plan = FaultPlan::random(
                rng.next_u64(),
                cfg.n_instances,
                n,
                rng.uniform(20.0, 240.0),
            );
            // Observers are thread-local to this worker: created,
            // driven, and read entirely inside one case.
            let counts = Rc::new(RefCell::new(EventCounts::default()));
            let clock = Rc::new(RefCell::new(MonotoneClock::default()));
            let mut hub = ObserverHub::new();
            hub.push(Box::new(counts.clone()));
            hub.push(Box::new(clock.clone()));
            let sys = SystemConfig {
                chunk_size: (cfg.avg_gen_len / 3).clamp(16, 2048),
                ..Default::default()
            };
            let out = ClusterSim::new(cfg.clone(), sys, w.groups, sched, sd)
                .with_faults(plan)
                .with_invariant_checks()
                .with_observers(hub)
                .sample_interval(SimTime::from_secs(2))
                .run();
            let m = &out.metrics;
            // Conservation: completed + aborted == issued, no dupes.
            assert_eq!(
                m.completions.len() + m.aborted as usize,
                n,
                "policy {name} lost requests under faults"
            );
            let mut ids: Vec<u32> =
                m.completions.iter().map(|c| c.id.0).collect();
            ids.sort();
            ids.dedup();
            assert_eq!(ids.len(), m.completions.len(), "{name} dup completion");
            out.buffer.check_invariants();
            assert_eq!(out.buffer.n_aborted() as u64, m.aborted);
            // End-of-run counter-vs-scan equality (also asserted at
            // every telemetry sample inside the run): the O(1) tallies
            // the event loop's done() check trusts match ground truth.
            assert_eq!(out.buffer.n_finished(), out.buffer.n_finished_scan());
            assert_eq!(out.buffer.n_aborted(), out.buffer.n_aborted_scan());
            assert_eq!(out.buffer.n_running(), 0, "{name} left runners");
            assert_eq!(out.buffer.n_running_scan(), 0);
            assert_eq!(
                out.buffer.n_finished(),
                n,
                "{name}: every request must end finished or aborted"
            );
            // Observer tally consistent with driver-side metrics.
            let ec = *counts.borrow();
            assert_eq!(ec.finished, m.completions.len() as u64);
            assert_eq!(ec.aborted, m.aborted);
            assert_eq!(ec.tokens, m.tokens_generated);
            assert_eq!(ec.preemptions, m.preemptions);
            assert_eq!(ec.migrations, m.migrations);
            assert_eq!(ec.instances_lost, m.instances_lost);
            assert_eq!(ec.rebalanced, m.fault_recovered);
            assert!(clock.borrow().events > 0);
        };
        if let Err(payload) =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(run))
        {
            panic!(
                "invariant sweep case {i} (seed {case_seed:#x}, size \
                 {size}): {}",
                panic_message(payload.as_ref())
            );
        }
    });
}

/// Satellite (ISSUE 7): rollpacker's stop-and-resume — general-lane
/// leases clamp at the tail threshold, the request re-enters the pool
/// and resumes packed onto a tail lane — must never double-count a
/// request, including under Partial-Rollout early stop where resumed
/// requests race the completion threshold.
#[test]
fn rollpacker_stop_and_resume_never_double_counts() {
    let cfg = TaskPreset::Moonlight.workload_for_test();
    let sys = SystemConfig {
        // Small chunks: every tail request crosses the threshold via at
        // least one clamped general-lane lease before being re-packed.
        chunk_size: 64,
        ..Default::default()
    };
    // Stop late enough that the long tail has crossed the threshold and
    // been re-packed (the divert-coverage assertion below keeps this
    // honest), yet early enough that resumed requests race the stop.
    let target = cfg.reqs_per_iter * 3 / 4;
    let w = generate_iteration(&cfg, 17);
    let out = ClusterSim::new(
        cfg.clone(),
        sys,
        w.groups,
        Box::new(RollPackerScheduler::new()),
        SdStrategy::GroupedCst,
    )
    .stop_after(target)
    .with_invariant_checks()
    .run();
    let m = &out.metrics;
    assert!(
        m.completions.len() >= target,
        "stopped short: {} < {target}",
        m.completions.len()
    );
    let mut ids: Vec<u32> = m.completions.iter().map(|c| c.id.0).collect();
    let n = ids.len();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), n, "a stop-and-resumed request completed twice");
    // The divert path really ran: requests were re-packed onto tail
    // lanes carrying the progress they had already generated — so the
    // uniqueness assertion above actually covered a resume.
    assert!(m.tail_packed >= 1, "no request was ever tail-packed");
    out.buffer.check_invariants();
}

#[test]
fn partial_rollout_biases_against_long_outputs() {
    // Statistical property: averaged over several seeds, the completed
    // set under 2x over-issue + early stop has a lower mean length than
    // the full synchronous completion set (Figure 12b). Individual seeds
    // can tie at test scale, so aggregate.
    let cfg = TaskPreset::Qwen2Vl72b.workload_for_test();
    let mut full_sum = 0.0;
    let mut part_sum = 0.0;
    for seed in 0..5u64 {
        let full = run_once(
            &cfg,
            Box::new(VerlScheduler::new()),
            SdStrategy::None,
            seed,
        );
        let mut big = cfg.clone();
        big.reqs_per_iter *= 2;
        let sys = SystemConfig::default();
        let w = generate_iteration(&big, seed);
        let partial = ClusterSim::new(
            big,
            sys,
            w.groups,
            Box::new(VerlScheduler::new()),
            SdStrategy::None,
        )
        .stop_after(cfg.reqs_per_iter)
        .run();
        let mean = |o: &RolloutOutcome| {
            o.metrics
                .completions
                .iter()
                .map(|c| c.gen_len as f64)
                .sum::<f64>()
                / o.metrics.completions.len() as f64
        };
        full_sum += mean(&full);
        part_sum += mean(&partial);
    }
    assert!(
        part_sum < full_sum * 0.98,
        "partial {part_sum:.0} vs full {full_sum:.0} (aggregated)"
    );
}
