//! Cross-policy scheduler conformance & property battery (ISSUE 7).
//!
//! Every test here is parametrized over the **builtin policy registry**
//! (`PolicyRegistry::builtin().scheduler_names()`), so a newly
//! registered scheduler inherits the whole battery for free — and the
//! pinned coverage list below fails loudly until the new policy's
//! expectations are reviewed and the list is updated. The battery pins
//! the contracts the driver relies on for *any* policy:
//!
//! - conservation under faults: completions + aborts == issued, no
//!   duplicate completions, buffer lifecycle counters equal their scans
//!   (checked at every telemetry sample via `with_invariant_checks`,
//!   which also asserts per-instance concurrency ≤ batch cap, KV-pool
//!   accounting, and that down instances hold no work);
//! - no starvation: every request finishes on a fault-free run;
//! - batch-cap respect directly at the `schedule` surface;
//! - `on_requeued` mirror integrity: a rejected or bounced assignment
//!   re-enters the policy's candidate order and is re-emitted — also
//!   when a bounce races a fault drain (satellite 3);
//! - warm-start determinism: same priors ⇒ byte-identical reports,
//!   cold == cold for history-free policies;
//! - byte-identical sweep reports across repeated runs and thread
//!   counts, for every registered policy in one grid.

use seer::config::{SystemConfig, TaskPreset, WorkloadConfig};
use seer::engine::cluster::ClusterSim;
use seer::iteration::ContextPriors;
use seer::rollout::{PolicyRegistry, RolloutReport, RolloutSession};
use seer::scheduler::{Assignment, InstanceView, SchedCtx, Scheduler};
use seer::sim::clock::SimTime;
use seer::sim::faults::{FaultEvent, FaultPlan};
use seer::spec::simmodel::SdStrategy;
use seer::sweep::{SweepRunner, SweepSpec};
use seer::util::json::Json;
use seer::workload::{generate_iteration, InstanceId, RequestId};

/// Policies this battery was last reviewed against. The companion test
/// pins it to the registry, so registering a fifth scheduler fails here
/// until its conformance expectations are (re)checked and the list is
/// extended — a policy can never ship with zero battery coverage.
const REVIEWED_POLICIES: &[&str] =
    &["no-context", "oracle", "rollpacker", "seer", "streamrl", "verl"];

fn registry_names() -> Vec<&'static str> {
    PolicyRegistry::builtin().scheduler_names()
}

fn test_cfg() -> WorkloadConfig {
    TaskPreset::Moonlight.workload_for_test()
}

fn test_sys() -> SystemConfig {
    SystemConfig {
        chunk_size: 128, // small chunks: divided rollout actually divides
        ..Default::default()
    }
}

/// The report JSON with the host-wall-clock field (the only
/// nondeterministic value) removed.
fn stripped_json(report: &RolloutReport) -> String {
    let mut j = report.to_json();
    if let Json::Obj(m) = &mut j {
        m.remove("wall_secs");
    }
    j.to_string()
}

fn run_session(scheduler: &str, seed: u64, plan: FaultPlan) -> RolloutReport {
    RolloutSession::builder()
        .workload(test_cfg())
        .system(test_sys())
        .scheduler(scheduler)
        .sd("grouped-cst")
        .seed(seed)
        .faults(plan)
        .run()
        .expect("rollout session failed")
}

/// A crash + elasticity script timed to fractions of this policy's own
/// clean makespan, so the scenario shape holds for every policy.
fn crash_and_scale(scheduler: &str, seed: u64) -> FaultPlan {
    let horizon = run_session(scheduler, seed, FaultPlan::new())
        .metrics
        .makespan
        .as_secs_f64();
    FaultPlan::new()
        .at(
            0.20 * horizon,
            FaultEvent::InstanceDown {
                instance: InstanceId(1),
            },
        )
        .at(0.35 * horizon, FaultEvent::ScaleUp { n: 1 })
        .at(0.55 * horizon, FaultEvent::ScaleDown { n: 1 })
        .at(
            0.70 * horizon,
            FaultEvent::InstanceRecover {
                instance: InstanceId(1),
            },
        )
        .sorted()
}

/// The pinned coverage list equals the registry: a fifth scheduler
/// cannot register without failing this test, forcing a review of the
/// battery's per-policy expectations (update `REVIEWED_POLICIES` once
/// done — every other test here enumerates the registry directly and
/// picks the newcomer up automatically).
#[test]
fn battery_covers_every_registered_policy() {
    assert_eq!(
        registry_names(),
        REVIEWED_POLICIES,
        "policy registry and conformance coverage list diverged; review \
         the new policy against this battery, then update \
         REVIEWED_POLICIES"
    );
}

/// Conservation under an identical crash/scale script, every policy:
/// completions + aborts == issued, no duplicate completions, lifecycle
/// counters equal their scans, and the in-sim invariant checker (KV
/// accounting, concurrency ≤ cap, down instances empty) passes at every
/// telemetry sample.
#[test]
fn conservation_under_faults_every_policy() {
    let reg = PolicyRegistry::builtin();
    for name in registry_names() {
        let cfg = test_cfg();
        let seed = 7;
        let plan = crash_and_scale(name, seed);
        let w = generate_iteration(&cfg, seed);
        let n = w.n_requests();
        let sched = reg.scheduler(name).unwrap();
        let out = ClusterSim::new(
            cfg.clone(),
            test_sys(),
            w.groups,
            sched,
            SdStrategy::GroupedCst,
        )
        .with_faults(plan)
        .with_invariant_checks()
        .sample_interval(SimTime::from_secs(2))
        .run();
        let m = &out.metrics;
        assert!(
            m.instances_lost >= 2,
            "{name}: fault script never fired ({} lost)",
            m.instances_lost
        );
        assert_eq!(
            m.completions.len() + m.aborted as usize,
            n,
            "{name}: lost requests under faults"
        );
        let mut ids: Vec<u32> =
            m.completions.iter().map(|c| c.id.0).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(
            ids.len(),
            m.completions.len(),
            "{name}: duplicate completion"
        );
        out.buffer.check_invariants();
        assert_eq!(out.buffer.n_finished(), out.buffer.n_finished_scan());
        assert_eq!(out.buffer.n_aborted(), out.buffer.n_aborted_scan());
        assert_eq!(out.buffer.n_running(), 0, "{name}: left runners");
    }
}

/// No starvation: on a fault-free run every request finishes and the
/// exact workload token total is generated — a policy whose candidate
/// mirror drops a request (or re-issues a finished one) fails here.
#[test]
fn every_policy_finishes_every_request() {
    for name in registry_names() {
        let cfg = test_cfg();
        let report = run_session(name, 11, FaultPlan::new());
        let m = &report.metrics;
        assert_eq!(
            m.completions.len(),
            cfg.reqs_per_iter,
            "{name}: starved requests"
        );
        assert_eq!(m.aborted, 0, "{name}: spurious aborts");
        let expected = generate_iteration(&cfg, 11).total_gen_tokens();
        assert_eq!(m.tokens_generated, expected, "{name}: token drift");
    }
}

/// Direct `schedule`-surface check: a policy must never assign onto a
/// view whose batch is full. (The driver only ever presents views of UP
/// instances, and `with_invariant_checks` above asserts down instances
/// stay empty in-sim; this pins the per-view cap at the unit surface.)
#[test]
fn no_policy_schedules_past_the_batch_cap() {
    let reg = PolicyRegistry::builtin();
    for name in registry_names() {
        let cfg = test_cfg();
        let w = generate_iteration(&cfg, 5);
        let buffer = seer::coordinator::RequestBuffer::from_groups(&w.groups);
        let mut s = reg.scheduler(name).unwrap();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        // Instance 0 is saturated; instance 1 has slots.
        let views = vec![
            InstanceView {
                id: InstanceId(0),
                free_kv_tokens: cfg.hw.kv_capacity_tokens,
                capacity_tokens: cfg.hw.kv_capacity_tokens,
                running: cfg.hw.max_batch,
                max_batch: cfg.hw.max_batch,
            },
            InstanceView {
                id: InstanceId(1),
                free_kv_tokens: cfg.hw.kv_capacity_tokens,
                capacity_tokens: cfg.hw.kv_capacity_tokens,
                running: 0,
                max_batch: cfg.hw.max_batch,
            },
        ];
        let ctx = SchedCtx {
            now: SimTime::ZERO,
            instances: &views,
            buffer: &buffer,
        };
        let mut out = Vec::new();
        s.schedule(&ctx, &mut out);
        assert!(!out.is_empty(), "{name}: scheduled nothing");
        let onto_full =
            out.iter().filter(|a| a.instance == InstanceId(0)).count();
        assert_eq!(onto_full, 0, "{name}: scheduled onto a full batch");
        assert!(
            out.iter()
                .filter(|a| a.instance == InstanceId(1))
                .count()
                <= cfg.hw.max_batch,
            "{name}: overfilled the open instance in one pass"
        );
    }
}

// ---------------------------------------------------------------------
// on_requeued mirror integrity (satellite 3): direct per-policy tests of
// the reject and arrival-bounce paths, plus the bounce-races-fault-drain
// interleaving. The driver's contract: an assignment it does not apply
// (instance rejected it, or the arrival was stale) comes back as
// `mark_waiting` + `on_requeued`; the policy must re-admit the request
// into its candidate order — losing it starves the run, double-admitting
// it double-schedules.
// ---------------------------------------------------------------------

fn init_policy(
    name: &str,
    seed: u64,
) -> (
    Box<dyn Scheduler>,
    seer::coordinator::RequestBuffer,
    Vec<InstanceView>,
    WorkloadConfig,
) {
    let cfg = test_cfg();
    let w = generate_iteration(&cfg, seed);
    let buffer = seer::coordinator::RequestBuffer::from_groups(&w.groups);
    let mut s = PolicyRegistry::builtin().scheduler(name).unwrap();
    s.init(&w.groups, &cfg, &SystemConfig::default());
    let views = (0..cfg.n_instances as u32)
        .map(|i| InstanceView {
            id: InstanceId(i),
            free_kv_tokens: cfg.hw.kv_capacity_tokens,
            capacity_tokens: cfg.hw.kv_capacity_tokens,
            running: 0,
            max_batch: cfg.hw.max_batch,
        })
        .collect();
    (s, buffer, views, cfg)
}

fn pass(
    s: &mut Box<dyn Scheduler>,
    buffer: &seer::coordinator::RequestBuffer,
    views: &[InstanceView],
) -> Vec<Assignment> {
    let ctx = SchedCtx {
        now: SimTime::ZERO,
        instances: views,
        buffer,
    };
    let mut out = Vec::new();
    s.schedule(&ctx, &mut out);
    out
}

fn emitted(out: &[Assignment], id: RequestId) -> usize {
    out.iter().filter(|a| a.req == id).count()
}

/// Reject path: an emitted-but-rejected assignment must be re-emitted
/// after `on_requeued`, and a request the driver *did* apply must not
/// be emitted again while it runs.
#[test]
fn requeued_rejects_reenter_every_policy() {
    for name in registry_names() {
        let (mut s, mut buffer, views, _cfg) = init_policy(name, 5);
        let first = pass(&mut s, &buffer, &views);
        assert!(!first.is_empty(), "{name}: empty first pass");
        // The driver applies the first assignment and rejects the rest.
        let applied = first[0].req;
        buffer.mark_scheduled(applied);
        let rejected: Vec<RequestId> =
            first[1..].iter().map(|a| a.req).collect();
        assert!(!rejected.is_empty(), "{name}: nothing to reject");
        for &id in &rejected {
            // Reject: never left Waiting; the driver still notifies.
            s.on_requeued(buffer.get(id));
        }
        let second = pass(&mut s, &buffer, &views);
        assert_eq!(
            emitted(&second, applied),
            0,
            "{name}: re-emitted a running request"
        );
        for &id in &rejected {
            assert_eq!(
                emitted(&second, id),
                1,
                "{name}: rejected request {} not re-emitted exactly once",
                id.0
            );
        }
    }
}

/// Arrival-bounce path: an applied assignment whose arrival the
/// instance bounces comes back through `mark_waiting` + `on_requeued`
/// (now from the Waiting phase, unlike the pure reject above) and must
/// re-enter the candidate order exactly once.
#[test]
fn arrival_bounce_reenters_every_policy() {
    for name in registry_names() {
        let (mut s, mut buffer, views, _cfg) = init_policy(name, 6);
        let first = pass(&mut s, &buffer, &views);
        assert!(!first.is_empty(), "{name}: empty first pass");
        let bounced = first[0].req;
        buffer.mark_scheduled(bounced);
        buffer.mark_waiting(bounced);
        s.on_requeued(buffer.get(bounced));
        let second = pass(&mut s, &buffer, &views);
        assert_eq!(
            emitted(&second, bounced),
            1,
            "{name}: bounced request not re-emitted exactly once"
        );
    }
}

/// Bounce racing a fault drain: request A is bounced back while request
/// B is simultaneously drained off a dying instance (the driver drains
/// via `mark_waiting` + `on_instance_lost`, which routes through the
/// chunk-end path). Both must be re-emitted exactly once — no policy's
/// mirror may lose or duplicate either. (Audit note: the driver guards
/// stale arrivals by phase + chunk sequence, and the policies' pop-time
/// stamp checks drop superseded entries, so no desync exists today;
/// this test pins that.)
#[test]
fn bounce_racing_fault_drain_keeps_mirror_consistent() {
    for name in registry_names() {
        let (mut s, mut buffer, views, _cfg) = init_policy(name, 9);
        let first = pass(&mut s, &buffer, &views);
        assert!(first.len() >= 2, "{name}: need two assignments");
        let bounced = first[0].req;
        let drained = first[1].req;
        buffer.mark_scheduled(bounced);
        buffer.mark_scheduled(drained);
        // The drained request made progress before the crash.
        buffer.get_mut(drained).generated = 64;
        // Crash drains B...
        buffer.mark_waiting(drained);
        let live: Vec<InstanceId> =
            views[1..].iter().map(|v| v.id).collect();
        s.on_instance_lost(views[0].id, &[drained], &live, &buffer);
        // ...while A's arrival bounces in the same driver step.
        buffer.mark_waiting(bounced);
        s.on_requeued(buffer.get(bounced));
        let second = pass(&mut s, &buffer, &views);
        for (label, id) in [("bounced", bounced), ("drained", drained)] {
            assert_eq!(
                emitted(&second, id),
                1,
                "{name}: {label} request {} emitted {} times after the \
                 race (want exactly 1)",
                id.0,
                emitted(&second, id)
            );
        }
    }
}

// ---------------------------------------------------------------------
// Determinism
// ---------------------------------------------------------------------

/// Warm-start determinism: the same priors produce byte-identical
/// stripped reports on repeated runs, for every policy — including the
/// history-free ones, whose `warm_start` returns false but which must
/// still run identically (and identical to their own cold run).
#[test]
fn warm_start_is_deterministic_every_policy() {
    let cfg = test_cfg();
    let w = generate_iteration(&cfg, 3);
    let priors = ContextPriors {
        estimates: w
            .groups
            .iter()
            .enumerate()
            .map(|(i, g)| (g.id, 32 + 16 * i as u32))
            .collect(),
        ..Default::default()
    };
    let run_warm = |name: &str| {
        RolloutSession::builder()
            .workload(cfg.clone())
            .system(test_sys())
            .scheduler(name)
            .sd("grouped-cst")
            .seed(3)
            .context_priors(priors.clone())
            .run()
            .expect("warm rollout failed")
    };
    for name in registry_names() {
        let a = run_warm(name);
        let b = run_warm(name);
        assert_eq!(
            stripped_json(&a),
            stripped_json(&b),
            "{name}: warm-started runs diverged"
        );
        assert_eq!(
            a.metrics.completions.len(),
            cfg.reqs_per_iter,
            "{name}: warm start starved requests"
        );
    }
}

/// Byte-identical sweep reports across repeated runs and thread counts,
/// with EVERY registered policy in one grid — the cross-policy
/// comparison surface (sweep, experiments, benches) rests on this.
#[test]
fn sweep_reports_byte_identical_across_thread_counts_all_policies() {
    let spec = SweepSpec::new(test_cfg())
        .schedulers(&registry_names())
        .seeds([1, 2]);
    let reference = SweepRunner::new(1)
        .run(&spec)
        .expect("serial sweep failed")
        .report
        .to_json()
        .to_string();
    assert!(!reference.is_empty());
    // Repeated run, same thread count: identical.
    let again = SweepRunner::new(1)
        .run(&spec)
        .unwrap()
        .report
        .to_json()
        .to_string();
    assert_eq!(again, reference, "repeated serial sweep diverged");
    // Parallel runs: identical to serial.
    for threads in [2, 4] {
        let json = SweepRunner::new(threads)
            .run(&spec)
            .unwrap()
            .report
            .to_json()
            .to_string();
        assert_eq!(
            json, reference,
            "thread count {threads} changed the report bytes"
        );
    }
}
