//! Deterministic chaos harness (PR 10): kill/restart the daemon
//! mid-train, corrupt the newest checkpoint generation between rounds,
//! and drop client connections mid-line — then assert recovery
//! converges to a final report byte-identical to the fault-free run.
//!
//! Determinism rules the harness relies on: reports carry no
//! wall-clock fields, the training driver replays identically from any
//! checkpointed epoch, and checkpoint recovery falls back to the
//! newest *valid* generation — so every schedule of kills and
//! corruptions that lets the job finish at all must land on the same
//! bytes. The second test pins the acceptance identity end to end:
//! `--mode async --lag 0` equals `--mode sync` under a trainer-side
//! fault plan.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use seer::config::TrainingMode;
use seer::iteration::TrainingDriver;
use seer::serve::api::train_report;
use seer::serve::{
    QuotaConfig, ServeConfig, Server, TrainCheckpoint, TrainParams,
};
use seer::sim::faults::{FaultEvent, FaultPlan};
use seer::util::json::Json;

fn start_server(state_dir: PathBuf) -> (String, JoinHandle<()>) {
    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 1,
        quota: QuotaConfig::default(),
        state_dir: Some(state_dir),
        ..ServeConfig::default()
    })
    .expect("bind");
    let addr = server.local_addr().expect("local addr").to_string();
    let handle = std::thread::spawn(move || server.run().expect("server run"));
    (addr, handle)
}

struct Client {
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: &str) -> Client {
        Client {
            reader: BufReader::new(TcpStream::connect(addr).expect("connect")),
        }
    }

    fn request(&mut self, line: &str) -> Json {
        let stream = self.reader.get_mut();
        stream.write_all(line.as_bytes()).expect("send");
        stream.write_all(b"\n").expect("send newline");
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply).expect("recv");
        assert!(n > 0, "server closed the connection unexpectedly");
        Json::parse(reply.trim_end()).expect("reply is valid JSON")
    }
}

fn ok(j: &Json) -> bool {
    j.get("ok").and_then(Json::as_bool) == Some(true)
}

fn wait_for(what: &str, mut probe: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(60);
    while !probe() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn iters_done(c: &mut Client, job: u64) -> u64 {
    c.request(&format!(r#"{{"verb":"status","job":{job}}}"#))
        .get("progress")
        .and_then(|p| p.get("iters_done"))
        .and_then(Json::as_u64)
        .unwrap_or(0)
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("seer-chaos-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Kill a round's daemon mid-job: first drop a raw connection mid-line
/// (the bounded reader must shrug it off), then abort-shutdown.
fn kill_round(addr: &str, c: &mut Client, handle: JoinHandle<()>) {
    {
        let mut raw = TcpStream::connect(addr).expect("raw connect");
        raw.write_all(br#"{"verb":"stat"#).expect("partial line");
    } // dropped here, mid-line — no newline ever arrives
    assert!(ok(&c.request(r#"{"verb":"shutdown","mode":"abort"}"#)));
    handle.join().unwrap();
}

#[test]
fn chaos_rounds_converge_to_the_fault_free_report() {
    let dir = temp_dir("rounds");
    let params = TrainParams {
        task: "moonlight".to_string(),
        scheduler: "seer".to_string(),
        sd: "grouped-cst".to_string(),
        iters: 4,
        seed: 11,
        drift: 0.1,
        mode: TrainingMode::Sync,
        cold: false,
        throttle_ms: 250,
        full: false,
        trainer_faults: FaultPlan::new(),
    };

    // The fault-free reference, straight on the driver.
    let mut driver = TrainingDriver::new(params.training_config().unwrap());
    for _ in 0..params.iters {
        driver.run_iteration(driver.next_epoch()).unwrap();
    }
    let expected = train_report(&params, driver.history()).to_string();

    // Round 1: run until two generations exist, then kill the daemon.
    let (addr, handle) = start_server(dir.clone());
    let mut c = Client::connect(&addr);
    let submitted = c.request(
        r#"{"verb":"submit","job":{"kind":"train","iters":4,"seed":11,"drift":0.1,"throttle_ms":250}}"#,
    );
    assert!(ok(&submitted), "{submitted}");
    let job = submitted.get("job").and_then(Json::as_u64).unwrap();
    wait_for("two checkpoint generations", || iters_done(&mut c, job) >= 2);
    kill_round(&addr, &mut c, handle);

    // Chaos 1: truncate the newest generation mid-record. Recovery must
    // fall back to the previous valid generation and redo the lost
    // iteration, not fail and not skip the job.
    let base = TrainCheckpoint::path_for(&dir, job);
    assert!(base.exists(), "abort shutdown must retain the checkpoint");
    let bytes = std::fs::read(&base).unwrap();
    std::fs::write(&base, &bytes[..bytes.len() / 2]).unwrap();

    // Round 2: resume from the torn state dir, make more progress.
    let (addr, handle) = start_server(dir.clone());
    let mut c = Client::connect(&addr);
    let status = c.request(&format!(r#"{{"verb":"status","job":{job}}}"#));
    assert_eq!(
        status.get("recovered").and_then(Json::as_bool),
        Some(true),
        "{status}"
    );
    wait_for("third iteration after fallback", || {
        iters_done(&mut c, job) >= 3
    });
    kill_round(&addr, &mut c, handle);

    // Chaos 2: flip the recorded checksum of the newest generation —
    // the record still parses, but verification must reject it.
    let text = std::fs::read_to_string(&base).unwrap();
    assert!(text.contains("\"crc\":\""), "v2 record carries a checksum");
    std::fs::write(&base, text.replacen("{\"crc\":\"", "{\"crc\":\"0", 1))
        .unwrap();

    // Round 3: final recovery runs the job to completion.
    let (addr, handle) = start_server(dir.clone());
    let mut c = Client::connect(&addr);
    let result = c.request(&format!(r#"{{"verb":"result","job":{job}}}"#));
    assert!(ok(&result), "{result}");
    assert_eq!(
        result.get("result").unwrap().to_string(),
        expected,
        "chaos-recovered final report differs from the fault-free run"
    );
    assert!(
        !base.exists(),
        "completed job must clean up all checkpoint generations"
    );
    assert!(ok(&c.request(r#"{"verb":"shutdown"}"#)));
    handle.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sync_and_lag_zero_reports_agree_under_trainer_chaos() {
    let plan = FaultPlan::new()
        .at(
            10.0,
            FaultEvent::TrainerSlowdown {
                factor: 2.0,
                from: 10.0,
                until: 120.0,
            },
        )
        .at(30.0, FaultEvent::TrainerStall { at: 30.0, secs: 15.0 })
        .at(0.0, FaultEvent::TrainerCrash { at_iter: 1 })
        .sorted();

    let report = |mode: TrainingMode| {
        let params = TrainParams {
            task: "moonlight".to_string(),
            scheduler: "seer".to_string(),
            sd: "grouped-cst".to_string(),
            iters: 3,
            seed: 7,
            drift: 0.05,
            mode,
            cold: false,
            throttle_ms: 0,
            full: false,
            trainer_faults: plan.clone(),
        };
        let mut driver =
            TrainingDriver::new(params.training_config().unwrap());
        for _ in 0..params.iters {
            driver.run_iteration(driver.next_epoch()).unwrap();
        }
        // Strip only the spec echo — it names the mode; every measured
        // byte must agree.
        let Json::Obj(mut o) = train_report(&params, driver.history())
        else {
            unreachable!()
        };
        o.remove("spec");
        Json::Obj(o).to_string()
    };

    let sync = report(TrainingMode::Sync);
    let lag0 = report(TrainingMode::Async { lag: 0 });
    assert_eq!(
        sync, lag0,
        "async --lag 0 must stay byte-identical to sync under trainer faults"
    );
    let parsed = Json::parse(&sync).unwrap();
    assert!(
        parsed
            .get("total_train_retries")
            .and_then(Json::as_u64)
            .unwrap()
            >= 1,
        "the crash event must cost at least one redone train step: {sync}"
    );
    assert!(
        parsed
            .get("total_trainer_fault_secs")
            .and_then(|v| v.as_f64())
            .unwrap()
            > 0.0,
        "slowdown/stall must surface as trainer fault seconds: {sync}"
    );
}
