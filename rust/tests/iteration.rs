//! Cross-iteration subsystem: store serialization round-trips, driver
//! determinism, and the warm-start long-tail win the subsystem exists
//! for.

use seer::config::TaskPreset;
use seer::iteration::{
    ContextStore, ContextStoreConfig, TrainingConfig, TrainingDriver,
};
use seer::util::json::Json;
use seer::workload::GroupId;

fn quick_cfg(warm: bool, iters: usize, seed: u64) -> TrainingConfig {
    TrainingConfig {
        iters,
        seed,
        warm_start: warm,
        ..TrainingConfig::new(TaskPreset::Moonlight.workload_for_test())
    }
}

fn tail_cfg(warm: bool, iters: usize, seed: u64) -> TrainingConfig {
    // The memory-constrained heavy-tail preset — where length context
    // buys the most (same regime the scheduler suite uses).
    TrainingConfig {
        iters,
        seed,
        warm_start: warm,
        ..TrainingConfig::new(TaskPreset::Qwen2Vl72b.workload_for_test())
    }
}

/// save → load through util::json reproduces identical priors.
#[test]
fn store_round_trips_through_json() {
    let mut driver = TrainingDriver::new(quick_cfg(true, 2, 7));
    driver.run().unwrap();
    let store = driver.into_store();
    assert!(!store.is_empty());

    let text = store.to_json().to_string();
    let back = ContextStore::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, store);
    assert_eq!(back.iterations(), store.iterations());

    // Identical priors, group by group.
    let (a, b) = (store.priors(), back.priors());
    assert_eq!(a.estimates, b.estimates);
    assert_eq!(a.warm_refs, b.warm_refs);
    assert_eq!(a.streams, b.streams);
    assert!(!a.estimates.is_empty());
}

#[test]
fn store_round_trips_through_disk() {
    let mut store = ContextStore::with_config(ContextStoreConfig {
        decay: 0.8,
        ..Default::default()
    });
    store.observe_group(GroupId(0), &[120, 480], &[&[5, 6, 7][..]]);
    store.observe_group(GroupId(2), &[64], &[]);
    let path = std::env::temp_dir().join(format!(
        "seer-ctx-store-{}.json",
        std::process::id()
    ));
    store.save(&path).unwrap();
    let back = ContextStore::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(back, store);
    assert_eq!(back.estimate(GroupId(0)), store.estimate(GroupId(0)));
}

/// Two same-seed driver runs produce identical per-iteration metrics.
#[test]
fn driver_is_deterministic() {
    let run = || {
        let mut d = TrainingDriver::new(quick_cfg(true, 3, 42));
        d.run().unwrap()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        // Bit-exact: the sim is deterministic and the store feeds back
        // deterministically.
        assert_eq!(x, y, "iteration {} diverged", x.iter);
    }
}

/// The acceptance demonstration: warm-started iterations beat both their
/// own cold first iteration and the cold baseline on long-tail latency
/// (p99 finish time). Fully deterministic, so these hold run-to-run; the
/// per-iteration bound carries a small tolerance (epoch drift re-samples
/// lengths, so an individual epoch can be intrinsically easier or
/// harder) while the aggregate win must be strict and measurable.
#[test]
fn warm_start_cuts_long_tail_latency() {
    let cold = TrainingDriver::new(tail_cfg(false, 3, 42)).run().unwrap();
    let warm = TrainingDriver::new(tail_cfg(true, 3, 42)).run().unwrap();
    // Iteration 1 consumed nothing in either run — identical workloads,
    // identical schedules.
    assert!(!warm[0].warm);
    assert_eq!(warm[0], cold[0]);
    for i in 1..3 {
        assert!(warm[i].warm);
        // No per-iteration regression beyond drift noise.
        assert!(
            warm[i].p99_finish_secs <= cold[i].p99_finish_secs * 1.02,
            "iter {}: warm p99 {:.2}s regressed vs cold p99 {:.2}s",
            i + 1,
            warm[i].p99_finish_secs,
            cold[i].p99_finish_secs
        );
    }
    // Aggregate over the warm iterations: measurably lower than the cold
    // baseline's matching iterations and than the cold first iteration.
    let p99_sum = |s: &[seer::iteration::IterationSummary]| {
        s[1..].iter().map(|x| x.p99_finish_secs).sum::<f64>()
    };
    let (warm_sum, cold_sum) = (p99_sum(&warm), p99_sum(&cold));
    assert!(
        warm_sum < cold_sum,
        "aggregate warm p99 {warm_sum:.2}s !< cold {cold_sum:.2}s"
    );
    let warm_mean = warm_sum / 2.0;
    assert!(
        warm_mean < warm[0].p99_finish_secs,
        "mean warm p99 {warm_mean:.2}s !< iteration-1 p99 {:.2}s",
        warm[0].p99_finish_secs
    );
}

/// `--save-ctx` / `--load-ctx` equivalence: a driver resumed from a
/// saved store behaves exactly like the driver that kept its store in
/// memory.
#[test]
fn saved_store_reproduces_warm_behavior() {
    // One continuous 3-iteration warm run...
    let mut continuous = TrainingDriver::new(quick_cfg(true, 3, 11));
    let cont = continuous.run().unwrap();

    // ...vs 2 iterations, save, load, then 1 more.
    let mut first = TrainingDriver::new(quick_cfg(true, 2, 11));
    first.run().unwrap();
    let path = std::env::temp_dir().join(format!(
        "seer-ctx-resume-{}.json",
        std::process::id()
    ));
    first.into_store().save(&path).unwrap();
    let loaded = ContextStore::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    // Total-count semantics: `iters: 3` with 2 epochs already in the
    // store runs exactly one more — the same accounting the serve
    // plane's train jobs use.
    let mut resumed =
        TrainingDriver::with_store(quick_cfg(true, 3, 11), loaded).unwrap();
    // The resumed driver continues the epoch sequence (epoch 2), it does
    // not replay epoch 0 into the decayed statistics.
    assert_eq!(resumed.next_epoch(), 2);
    let sums = resumed.run().unwrap();
    assert_eq!(sums.len(), 1, "iters is a total, not an increment");
    let s = sums[0];
    assert!(s.warm, "resumed run must start warm");
    assert_eq!(s, cont[2], "resumed iteration 3 must match continuous");
}
