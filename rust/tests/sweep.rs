//! Sweep-layer tests (ISSUE 4): parallel determinism — the same
//! `SweepSpec` must produce **byte-identical** aggregated JSON at every
//! thread count — serial-vs-parallel equivalence against direct
//! `RolloutSession` runs, and the golden key-schema snapshot of the
//! `seer sweep` JSON report.

mod common;

use seer::config::TaskPreset;
use seer::rollout::RolloutSession;
use seer::sim::faults::{FaultEvent, FaultPlan};
use seer::sweep::{SweepRunner, SweepSpec};
use seer::workload::{generate_epoch, InstanceId};

/// Makespan of a clean test-scale run, used to pin fault times to
/// fractions of the rollout so the crash reliably fires at any scale
/// (same approach as `tests/faults.rs`).
fn clean_horizon() -> f64 {
    RolloutSession::builder()
        .workload(TaskPreset::Moonlight.workload_for_test())
        .scheduler("seer")
        .sd("grouped-cst")
        .seed(1)
        .run()
        .expect("clean run failed")
        .metrics
        .makespan
        .as_secs_f64()
}

/// A crash-and-recover script timed well inside the rollout.
fn crash_plan(horizon: f64) -> FaultPlan {
    FaultPlan::new()
        .at(
            0.20 * horizon,
            FaultEvent::InstanceDown {
                instance: InstanceId(1),
            },
        )
        .at(
            0.55 * horizon,
            FaultEvent::InstanceRecover {
                instance: InstanceId(1),
            },
        )
        .sorted()
}

/// The full-dimensional test grid: 2 schedulers × 2 seeds × 2 fault
/// plans × 2 drifts = 16 cells.
fn full_spec() -> SweepSpec {
    SweepSpec::new(TaskPreset::Moonlight.workload_for_test())
        .schedulers(&["seer", "verl"])
        .seeds([1, 2])
        .fault_plan("none", FaultPlan::new())
        .fault_plan("crash", crash_plan(clean_horizon()))
        .drifts([0.0, 0.08])
}

/// Acceptance criterion: a parallel sweep of the same spec yields
/// byte-identical aggregated JSON for thread counts 1, 4 and 8 (the
/// report carries no host-dependent field; wall clock lives outside it
/// in `SweepOutcome`).
#[test]
fn parallel_sweep_is_byte_identical_across_thread_counts() {
    let spec = full_spec();
    let reference = SweepRunner::new(1)
        .run(&spec)
        .expect("serial sweep failed")
        .report
        .to_json()
        .to_string();
    assert!(!reference.is_empty());
    for threads in [4, 8] {
        let json = SweepRunner::new(threads)
            .run(&spec)
            .expect("parallel sweep failed")
            .report
            .to_json()
            .to_string();
        assert_eq!(
            json, reference,
            "thread count {threads} changed the report bytes"
        );
    }
}

/// Serial-vs-parallel equivalence against *direct* session runs: every
/// cell the parallel runner reports must match a `RolloutSession` built
/// by hand with the same parameters.
#[test]
fn parallel_cells_match_direct_session_runs() {
    let spec = SweepSpec::new(TaskPreset::Moonlight.workload_for_test())
        .schedulers(&["seer", "verl"])
        .seeds([3])
        .fault_plan("none", FaultPlan::new())
        .fault_plan("crash", crash_plan(clean_horizon()))
        .drifts([0.1]);
    let outcome = SweepRunner::new(4).run(&spec).unwrap();
    let cells = spec.expand();
    assert_eq!(outcome.report.cells.len(), cells.len());
    for (cell, got) in cells.iter().zip(&outcome.report.cells) {
        // Rebuild the session exactly as the sweep layer documents it.
        let mut builder = RolloutSession::builder()
            .workload(cell.workload.clone())
            .system(cell.system.clone())
            .scheduler(&cell.scheduler)
            .sd(&cell.sd)
            .seed(cell.seed)
            .n_instances(cell.n_instances);
        if cell.drift > 0.0 {
            let w = generate_epoch(&cell.workload, cell.seed, 1, cell.drift);
            builder = builder.groups(w.groups);
        }
        if !cell.faults.is_empty() {
            builder = builder.faults(cell.faults.clone());
        }
        let report = builder.run().expect("direct session failed");
        let m = &report.metrics;
        assert_eq!(got.scheduler, cell.scheduler);
        assert_eq!(got.seed, cell.seed);
        assert_eq!(got.makespan_secs, m.makespan.as_secs_f64(), "{cell:?}");
        assert_eq!(got.throughput_tok_s, m.throughput(), "{cell:?}");
        assert_eq!(got.tail_secs, m.tail_time(0.10).as_secs_f64());
        assert_eq!(got.p99_finish_secs, m.finish_percentile(99.0));
        assert_eq!(got.tokens, m.tokens_generated);
        assert_eq!(got.completions, m.completions.len());
        assert_eq!(got.migrations, m.migrations);
    }
    // The crash cells really exercised the fault layer somewhere.
    assert!(
        outcome
            .report
            .cells
            .iter()
            .any(|c| c.fault_name == "crash" && c.instances_lost > 0),
        "crash plan never fired — grid too small to mean anything"
    );
}

/// The aggregate/paired layers line up with the grid: one aggregate per
/// (scheduler, scale, fault, drift) group, one paired comparison per
/// non-baseline scheduler per point, n == seeds.
#[test]
fn report_aggregates_and_pairs_cover_the_grid() {
    let spec = full_spec();
    let report = SweepRunner::new(4).run(&spec).unwrap().report;
    assert_eq!(report.cells.len(), 16);
    assert_eq!(report.aggregates.len(), 8); // 2 sched × 2 fault × 2 drift
    assert_eq!(report.paired.len(), 4); // verl vs seer × 2 fault × 2 drift
    for a in &report.aggregates {
        assert_eq!(a.n_seeds, 2);
        assert!(a.mean_throughput_tok_s > 0.0);
        assert!(a.throughput_ci.lo <= a.mean_throughput_tok_s + 1e-9);
        assert!(a.throughput_ci.hi >= a.mean_throughput_tok_s - 1e-9);
    }
    for p in &report.paired {
        assert_eq!(p.baseline, "seer");
        assert_eq!(p.candidate, "verl");
        assert_eq!(p.speedup.n, 2);
        assert_eq!(p.tail_reduction.n, 2);
        assert!(p.speedup.mean > 0.0);
        assert!(p.speedup.ci.lo <= p.speedup.ci.hi);
    }
}

/// The mode dimension (PR 9) threads through the whole report: every
/// cell of a mode-bearing grid pipelines, aggregates and paired rows
/// carry the mode tag and lag, staleness shows up only in overlap
/// modes, and the paired layer pairs schedulers *within* a mode.
#[test]
fn mode_dimension_threads_through_aggregates_and_pairs() {
    use seer::config::TrainingMode;
    let spec = SweepSpec::new(TaskPreset::Moonlight.workload_for_test())
        .schedulers(&["seer", "verl"])
        .seeds([1, 2])
        .mode(TrainingMode::Sync)
        .mode(TrainingMode::Async { lag: 1 })
        .pipeline_iters(2);
    let report = SweepRunner::new(4).run(&spec).unwrap().report;
    assert_eq!(report.cells.len(), 8); // 2 sched × 2 modes × 2 seeds
    assert_eq!(report.aggregates.len(), 4);
    assert_eq!(report.paired.len(), 2); // verl vs seer, per mode
    for a in &report.aggregates {
        match a.mode.as_str() {
            "sync" => {
                assert_eq!(a.lag, 0);
                assert_eq!(a.mean_staleness, 0.0, "sync saw staleness");
            }
            "async:1" => assert_eq!(a.lag, 1),
            other => panic!("unexpected mode tag {other}"),
        }
    }
    let modes: Vec<&str> =
        report.paired.iter().map(|p| p.mode.as_str()).collect();
    assert_eq!(modes, ["sync", "async:1"]);
    for p in &report.paired {
        assert_eq!((p.baseline.as_str(), p.candidate.as_str()), ("seer", "verl"));
        assert_eq!(p.speedup.n, 2);
    }
    // Overlap actually overlapped: the async pipeline's span beats the
    // serialized sync pipeline for the same scheduler/seeds.
    let span = |mode: &str| {
        report
            .cells
            .iter()
            .filter(|c| c.scheduler == "seer" && c.mode == mode)
            .map(|c| c.makespan_secs)
            .sum::<f64>()
    };
    assert!(
        span("async:1") < span("sync"),
        "async:1 span {} !< sync span {}",
        span("async:1"),
        span("sync")
    );
    // The cell JSON exposes the new columns (PR 9 staleness, PR 10
    // trainer-fault accounting — zero here, but always present).
    let j = report.cells[0].to_json();
    for key in [
        "mode",
        "lag",
        "staleness_mean",
        "staleness_max",
        "stale_requests",
        "train_retries",
        "trainer_fault_secs",
    ] {
        assert!(j.get(key).is_some(), "cell JSON lost '{key}'");
    }
}

/// Golden snapshot of the `seer sweep` report schema: the set of key
/// paths (arrays descend into their first element as `[]`; see
/// `common::flatten_key_paths`) is pinned to a checked-in fixture so
/// report-shape regressions fail loudly. Values are covered by the
/// determinism tests above.
///
/// Regen path (same as `tests/faults.rs`):
/// `SEER_REGEN_GOLDEN=1 cargo test -q --test sweep sweep_report_schema`
/// rewrites `tests/fixtures/sweep_golden_keys.json` and passes; commit
/// the updated fixture.
/// Value-level golden (ISSUE 5, extended by ISSUE 7 with the rollpacker
/// tail-packing policy): the optimized schedulers — O(1) lifecycle
/// counters, incremental lazy-heap candidate ordering, dense side
/// tables — must produce byte-identical sweep report JSON to the
/// checked-in fixture for the same seeds, across all four comparison
/// policies.
///
/// Honest scope: the fixture freezes the report bytes **from the commit
/// that seeds it forward** — it is the standing tripwire that future
/// "mechanical sympathy" changes move no emitted number. Equivalence to
/// the *pre-overhaul* sort-based schedulers is established by
/// construction (identical iteration orders; lazy-heap pop order equals
/// the full sort under current keys — see ARCHITECTURE.md §Performance
/// model), and can be spot-checked by running this grid on the
/// overhaul's parent commit and diffing the JSON.
///
/// Seeding/regen: the fixture is written on first run (or with
/// `SEER_REGEN_GOLDEN=1`) — commit the generated
/// `tests/fixtures/sweep_golden_values.json`; any later divergence
/// fails. A fresh checkout without the committed fixture re-seeds
/// (loudly, on stderr) rather than failing, so the authoring
/// environment's missing toolchain cannot wedge CI — committing the
/// first CI run's fixture arms the test.
#[test]
fn sweep_report_bytes_match_golden_fixture() {
    let spec = SweepSpec::new(TaskPreset::Moonlight.workload_for_test())
        .schedulers(&["seer", "verl", "streamrl", "rollpacker"])
        .seeds([1, 2]);
    let json = SweepRunner::new(2)
        .run(&spec)
        .unwrap()
        .report
        .to_json()
        .to_string();
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/sweep_golden_values.json");
    common::check_golden_text(&json, &path);
}

#[test]
fn sweep_report_schema_matches_golden() {
    let spec = SweepSpec::new(TaskPreset::Moonlight.workload_for_test())
        .schedulers(&["seer", "verl"])
        .seeds([1, 2]);
    let report = SweepRunner::new(2).run(&spec).unwrap().report;
    let keys = common::flatten_key_paths(&report.to_json());
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/sweep_golden_keys.json");
    common::check_golden_keys(&keys, &path);
}
