//! End-to-end tests of the serve plane over real TCP sockets.
//!
//! Each test binds its own daemon on port 0, drives it with a plain
//! line-delimited JSON client, and shuts it down through the protocol —
//! the same path `seer serve` takes, minus argument parsing. The
//! recovery test additionally kills a daemon mid-train (abort shutdown)
//! and restarts it on the same state directory.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use seer::iteration::TrainingDriver;
use seer::rollout::{EventMux, MuxFrame};
use seer::serve::api::{train_report, MAX_LINE_BYTES};
use seer::serve::{
    QuotaConfig, RolloutParams, ServeConfig, Server, TrainCheckpoint,
    TrainParams,
};
use seer::util::json::Json;

/// Bind a daemon on a free port and run it on its own thread.
fn start_server(
    quota: QuotaConfig,
    workers: usize,
    state_dir: Option<PathBuf>,
) -> (String, JoinHandle<()>) {
    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers,
        quota,
        state_dir,
        ..ServeConfig::default()
    })
    .expect("bind");
    let addr = server.local_addr().expect("local addr").to_string();
    let handle = std::thread::spawn(move || server.run().expect("server run"));
    (addr, handle)
}

struct Client {
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: &str) -> Client {
        Client {
            reader: BufReader::new(
                TcpStream::connect(addr).expect("connect"),
            ),
        }
    }

    fn send(&mut self, line: &str) {
        let stream = self.reader.get_mut();
        stream.write_all(line.as_bytes()).expect("send");
        stream.write_all(b"\n").expect("send newline");
    }

    fn recv(&mut self) -> Json {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).expect("recv");
        assert!(n > 0, "server closed the connection unexpectedly");
        Json::parse(line.trim_end()).expect("reply is valid JSON")
    }

    fn request(&mut self, line: &str) -> Json {
        self.send(line);
        self.recv()
    }
}

fn ok(j: &Json) -> bool {
    j.get("ok").and_then(Json::as_bool) == Some(true)
}

fn code(j: &Json) -> Option<&str> {
    j.get("code").and_then(Json::as_str)
}

fn state_of(status: &Json) -> &str {
    status.get("state").and_then(Json::as_str).unwrap_or("?")
}

/// Poll `probe` every 10 ms until it returns true; panic after 60 s.
fn wait_for(what: &str, mut probe: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(60);
    while !probe() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("seer-serve-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn submit_subscribe_result_matches_direct_run() {
    let (addr, handle) = start_server(QuotaConfig::default(), 1, None);
    let mut c = Client::connect(&addr);

    let reply =
        c.request(r#"{"verb":"submit","job":{"kind":"rollout","seed":7}}"#);
    assert!(ok(&reply), "{reply}");
    let job = reply.get("job").and_then(Json::as_u64).unwrap();

    let result = c.request(&format!(r#"{{"verb":"result","job":{job}}}"#));
    assert!(ok(&result), "{result}");
    let report = result.get("result").unwrap();
    assert!(
        report.get("completions").and_then(Json::as_u64).unwrap() > 0,
        "{report}"
    );

    // Subscribing after completion replays the job's full event buffer.
    let sub = c.request(&format!(r#"{{"verb":"subscribe","job":{job}}}"#));
    assert!(ok(&sub), "{sub}");
    assert_eq!(sub.get("streaming").and_then(Json::as_bool), Some(true));
    let mut streamed = Vec::new();
    loop {
        let frame = c.recv();
        match frame.get("type").and_then(Json::as_str).unwrap() {
            "event" => {
                let Json::Obj(mut fields) = frame else { unreachable!() };
                fields.remove("type");
                streamed.push(Json::Obj(fields).to_string());
            }
            "end" => {
                assert_eq!(state_of(&frame), "done", "{frame}");
                break;
            }
            // Telemetry / truncation frames are not per-event payloads.
            _ => {}
        }
    }

    // The same job run directly, observed through the same mux type.
    let params = RolloutParams {
        task: "moonlight".to_string(),
        scheduler: "seer".to_string(),
        sd: "grouped-cst".to_string(),
        seed: 7,
        bubble: 0.0,
        full: false,
    };
    let mux = EventMux::new();
    let direct_report = params
        .session()
        .unwrap()
        .observer(Box::new(mux.clone()))
        .run()
        .unwrap();
    mux.close();
    let direct: Vec<String> = mux
        .subscribe()
        .iter()
        .filter_map(|f| match f {
            MuxFrame::Event(ev) => Some(ev.to_json().to_string()),
            _ => None,
        })
        .collect();

    assert!(!direct.is_empty());
    assert_eq!(streamed, direct, "streamed events != direct-run events");
    assert_eq!(
        report.get("tokens_generated").and_then(Json::as_u64),
        direct_report.to_json().get("tokens_generated").and_then(Json::as_u64),
    );

    assert!(ok(&c.request(r#"{"verb":"shutdown"}"#)));
    handle.join().unwrap();
}

#[test]
fn quota_one_each_runs_two_tenants_concurrently_third_queues() {
    let quota = QuotaConfig {
        max_per_tenant: 1,
        max_jobs: 64,
    };
    let (addr, handle) = start_server(quota, 2, None);
    let mut c = Client::connect(&addr);

    let train =
        r#"{"kind":"train","iters":3,"throttle_ms":150,"seed":5}"#.to_string();
    let a = c.request(&format!(
        r#"{{"verb":"submit","tenant":"a","job":{train}}}"#
    ));
    assert!(ok(&a), "{a}");

    // Tenant 'a' is at quota: a second submit is rejected with a reason.
    let again = c.request(&format!(
        r#"{{"verb":"submit","tenant":"a","job":{train}}}"#
    ));
    assert!(!ok(&again), "{again}");
    assert_eq!(code(&again), Some("quota"));
    assert!(
        again.get("error").and_then(Json::as_str).unwrap().contains("'a'"),
        "{again}"
    );

    let b = c.request(&format!(
        r#"{{"verb":"submit","tenant":"b","job":{train}}}"#
    ));
    assert!(ok(&b), "{b}");
    let third = c.request(
        r#"{"verb":"submit","tenant":"c","job":{"kind":"rollout"}}"#,
    );
    assert!(ok(&third), "{third}");
    let third_id = third.get("job").and_then(Json::as_u64).unwrap();

    // Both quota-1 tenants run at the same time on the 2 workers, while
    // the third admitted job waits for a free worker.
    wait_for("both tenants running concurrently", || {
        let s1 = c.request(r#"{"verb":"status","job":1}"#);
        let s2 = c.request(r#"{"verb":"status","job":2}"#);
        state_of(&s1) == "running" && state_of(&s2) == "running"
    });
    let queued = c.request(&format!(r#"{{"verb":"status","job":{third_id}}}"#));
    assert_eq!(state_of(&queued), "queued", "{queued}");

    // Once the trains drain, the queued job runs to completion.
    let done = c.request(&format!(r#"{{"verb":"result","job":{third_id}}}"#));
    assert!(ok(&done), "{done}");

    let summary = c.request(r#"{"verb":"status"}"#);
    assert_eq!(summary.get("jobs").and_then(Json::as_u64), Some(3));

    assert!(ok(&c.request(r#"{"verb":"shutdown"}"#)));
    handle.join().unwrap();
}

#[test]
fn cancel_hits_running_and_queued_jobs() {
    let (addr, handle) = start_server(QuotaConfig::default(), 1, None);
    let mut c = Client::connect(&addr);

    let long_train =
        r#"{"verb":"submit","job":{"kind":"train","iters":500,"throttle_ms":50}}"#;
    let first = c.request(long_train);
    assert!(ok(&first), "{first}");
    wait_for("job 1 running", || {
        state_of(&c.request(r#"{"verb":"status","job":1}"#)) == "running"
    });

    // The single worker is busy, so this one stays queued.
    let second =
        c.request(r#"{"verb":"submit","job":{"kind":"rollout"}}"#);
    assert!(ok(&second), "{second}");
    let cancelled_queued = c.request(r#"{"verb":"cancel","job":2}"#);
    assert!(ok(&cancelled_queued), "{cancelled_queued}");
    assert_eq!(state_of(&cancelled_queued), "cancelled");

    let cancelling = c.request(r#"{"verb":"cancel","job":1}"#);
    assert!(ok(&cancelling), "{cancelling}");
    assert_eq!(
        cancelling.get("cancelling").and_then(Json::as_bool),
        Some(true),
        "{cancelling}"
    );
    let r1 = c.request(r#"{"verb":"result","job":1}"#);
    assert_eq!(code(&r1), Some("cancelled"), "{r1}");
    let r2 = c.request(r#"{"verb":"result","job":2}"#);
    assert_eq!(code(&r2), Some("cancelled"), "{r2}");

    // Cancelling a terminal job is a no-op report, not an error.
    let again = c.request(r#"{"verb":"cancel","job":1}"#);
    assert!(ok(&again), "{again}");
    assert_eq!(state_of(&again), "cancelled");

    assert!(ok(&c.request(r#"{"verb":"shutdown"}"#)));
    handle.join().unwrap();
}

#[test]
fn malformed_requests_get_reasoned_errors() {
    let (addr, handle) = start_server(QuotaConfig::default(), 1, None);
    let mut c = Client::connect(&addr);

    for (line, needle) in [
        ("this is not json", "parse"),
        (r#"{"verb":"frobnicate"}"#, "unknown verb"),
        (r#"{"verb":"result"}"#, "missing field 'job'"),
        (r#"{"verb":"submit","job":{"kind":"rollout","task":"nope"}}"#, "unknown task"),
        (r#"{"verb":"submit","job":{"kind":"rollout","seed":"x"}}"#, "'seed'"),
    ] {
        let reply = c.request(line);
        assert!(!ok(&reply), "{line}: {reply}");
        assert_eq!(code(&reply), Some("bad-request"), "{line}: {reply}");
        let msg = reply.get("error").and_then(Json::as_str).unwrap();
        assert!(
            msg.to_lowercase().contains(&needle.to_lowercase()),
            "{line}: {msg}"
        );
    }

    // Unknown ids are addressed errors, not connection killers.
    for verb in ["status", "result", "cancel", "subscribe"] {
        let reply = c.request(&format!(r#"{{"verb":"{verb}","job":404}}"#));
        assert_eq!(code(&reply), Some("not-found"), "{verb}: {reply}");
    }

    // An over-long line gets a reply, then the connection is dropped.
    let mut flood = Client::connect(&addr);
    let huge = "a".repeat(MAX_LINE_BYTES + 10);
    flood.send(&huge);
    let reply = flood.recv();
    assert_eq!(code(&reply), Some("bad-request"), "{reply}");
    assert!(
        reply.get("error").and_then(Json::as_str).unwrap().contains("1 MiB"),
        "{reply}"
    );
    let mut rest = String::new();
    assert_eq!(flood.reader.read_line(&mut rest).expect("eof"), 0);

    // The first connection still works after all of the above.
    assert!(ok(&c.request(r#"{"verb":"status"}"#)));
    assert!(ok(&c.request(r#"{"verb":"shutdown"}"#)));
    handle.join().unwrap();
}

#[test]
fn train_job_killed_mid_run_resumes_byte_identically() {
    let dir = temp_dir("recover");
    let params = TrainParams {
        task: "moonlight".to_string(),
        scheduler: "seer".to_string(),
        sd: "grouped-cst".to_string(),
        iters: 3,
        seed: 11,
        drift: 0.1,
        mode: seer::config::TrainingMode::Sync,
        cold: false,
        throttle_ms: 300,
        full: false,
        trainer_faults: seer::sim::faults::FaultPlan::new(),
    };

    // Reference: the same job uninterrupted, straight on the driver.
    let mut driver = TrainingDriver::new(params.training_config().unwrap());
    for _ in 0..params.iters {
        driver.run_iteration(driver.next_epoch()).unwrap();
    }
    let expected = train_report(&params, driver.history()).to_string();

    // Round 1: run the job, then abort-kill the daemon mid-train.
    let (addr, handle) =
        start_server(QuotaConfig::default(), 1, Some(dir.clone()));
    let mut c = Client::connect(&addr);
    let submitted = c.request(
        r#"{"verb":"submit","tenant":"t","job":{"kind":"train","iters":3,"seed":11,"drift":0.1,"throttle_ms":300}}"#,
    );
    assert!(ok(&submitted), "{submitted}");
    let job = submitted.get("job").and_then(Json::as_u64).unwrap();
    wait_for("first iteration checkpointed", || {
        let s = c.request(&format!(r#"{{"verb":"status","job":{job}}}"#));
        s.get("progress")
            .and_then(|p| p.get("iters_done"))
            .and_then(Json::as_u64)
            .unwrap_or(0)
            >= 1
    });
    assert!(ok(&c.request(r#"{"verb":"shutdown","mode":"abort"}"#)));
    handle.join().unwrap();
    assert!(
        TrainCheckpoint::path_for(&dir, job).exists(),
        "abort shutdown must retain the train checkpoint"
    );

    // Round 2: a fresh daemon on the same state dir resumes the job.
    let (addr, handle) =
        start_server(QuotaConfig::default(), 1, Some(dir.clone()));
    let mut c = Client::connect(&addr);
    let status = c.request(&format!(r#"{{"verb":"status","job":{job}}}"#));
    assert!(ok(&status), "recovered job must exist: {status}");
    assert_eq!(
        status.get("recovered").and_then(Json::as_bool),
        Some(true),
        "{status}"
    );
    let result = c.request(&format!(r#"{{"verb":"result","job":{job}}}"#));
    assert!(ok(&result), "{result}");
    assert_eq!(
        result.get("result").unwrap().to_string(),
        expected,
        "resumed final report differs from the uninterrupted run"
    );
    assert!(
        !TrainCheckpoint::path_for(&dir, job).exists(),
        "completed job must clean up its checkpoint"
    );

    assert!(ok(&c.request(r#"{"verb":"shutdown"}"#)));
    handle.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn subscriber_dropped_mid_stream_never_blocks_the_job() {
    let (addr, handle) = start_server(QuotaConfig::default(), 1, None);
    let mut c = Client::connect(&addr);

    let submitted = c.request(
        r#"{"verb":"submit","job":{"kind":"train","iters":4,"throttle_ms":100,"seed":3}}"#,
    );
    assert!(ok(&submitted), "{submitted}");
    let job = submitted.get("job").and_then(Json::as_u64).unwrap();

    // A second client subscribes to the live stream, reads the ack and
    // a single frame, then drops its socket mid-NDJSON. The handler
    // thread must treat the dead peer as an unsubscribe, not an error.
    {
        let mut sub = Client::connect(&addr);
        let ack =
            sub.request(&format!(r#"{{"verb":"subscribe","job":{job}}}"#));
        assert!(ok(&ack), "{ack}");
        assert_eq!(ack.get("streaming").and_then(Json::as_bool), Some(true));
        let _half_read_frame = sub.recv();
    } // TcpStream dropped here, mid-stream.

    // The job still runs to completion — nothing blocked on the dead
    // subscriber's channel.
    let result = c.request(&format!(r#"{{"verb":"result","job":{job}}}"#));
    assert!(ok(&result), "{result}");
    assert_eq!(
        result.get("attempts").and_then(Json::as_u64),
        Some(1),
        "{result}"
    );

    // And the mux slot was pruned, not leaked: a fresh subscriber gets
    // the full replay with a clean terminal frame.
    let mut sub2 = Client::connect(&addr);
    let ack = sub2.request(&format!(r#"{{"verb":"subscribe","job":{job}}}"#));
    assert!(ok(&ack), "{ack}");
    loop {
        let frame = sub2.recv();
        if frame.get("type").and_then(Json::as_str) == Some("end") {
            assert_eq!(state_of(&frame), "done", "{frame}");
            break;
        }
    }

    assert!(ok(&c.request(r#"{"verb":"shutdown"}"#)));
    handle.join().unwrap();
}

#[test]
fn deadline_and_priority_ride_the_wire() {
    let quota = QuotaConfig {
        max_per_tenant: 8,
        max_jobs: 2,
    };
    let (addr, handle) = start_server(quota, 1, None);
    let mut c = Client::connect(&addr);

    // A deadline the long train cannot meet: typed terminal status.
    let doomed = c.request(
        r#"{"verb":"submit","job":{"kind":"train","iters":500,"throttle_ms":50,"deadline_secs":0.2}}"#,
    );
    assert!(ok(&doomed), "{doomed}");
    let doomed_id = doomed.get("job").and_then(Json::as_u64).unwrap();
    let r = c.request(&format!(r#"{{"verb":"result","job":{doomed_id}}}"#));
    assert_eq!(code(&r), Some("deadline-exceeded"), "{r}");
    let s = c.request(&format!(r#"{{"verb":"status","job":{doomed_id}}}"#));
    assert_eq!(state_of(&s), "deadline-exceeded", "{s}");

    // Overload shedding: fill the global cap with low-priority queued
    // work, then submit at a higher priority.
    let slow =
        r#"{"verb":"submit","job":{"kind":"train","iters":500,"throttle_ms":50}}"#;
    let running = c.request(slow);
    assert!(ok(&running), "{running}");
    let queued = c.request(slow);
    assert!(ok(&queued), "{queued}");
    let queued_id = queued.get("job").and_then(Json::as_u64).unwrap();
    wait_for("worker busy so the victim stays queued", || {
        state_of(&c.request(&format!(
            r#"{{"verb":"status","job":{}}}"#,
            running.get("job").and_then(Json::as_u64).unwrap()
        ))) == "running"
    });

    let urgent = c.request(
        r#"{"verb":"submit","job":{"kind":"rollout","priority":5}}"#,
    );
    assert!(ok(&urgent), "sheddable queue must admit priority: {urgent}");
    let shed = c.request(&format!(r#"{{"verb":"result","job":{queued_id}}}"#));
    assert_eq!(code(&shed), Some("shed"), "{shed}");

    assert!(ok(&c.request(r#"{"verb":"shutdown","mode":"abort"}"#)));
    handle.join().unwrap();
}
