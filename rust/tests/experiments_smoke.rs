//! Smoke: every experiment in the harness runs to completion at fast
//! scale (the content checks live in integration.rs and the experiment
//! modules' own assertions).

use seer::experiments;
use seer::util::cli::Args;

#[test]
fn every_experiment_runs() {
    let args = Args::parse(
        ["--fast".to_string(), "--iters".into(), "1".into()],
        &["fast"],
    );
    // table1/fig7/table4 run multiple full rollouts; keep to the fast
    // scale and a single iteration (still real runs).
    for id in experiments::ALL_IDS {
        experiments::run(id, &args)
            .unwrap_or_else(|e| panic!("experiment {id} failed: {e:#}"));
    }
}
