//! Helpers shared between the integration-test crates (included with
//! `mod common;` — the directory itself is not a test crate).

use std::path::Path;

use seer::util::json::Json;

/// Flatten a JSON value into its sorted, deduplicated key paths.
/// Objects nest with `.`; arrays descend into their *first* element as
/// `[]` (all elements of a report array share one schema), and an empty
/// array is the leaf `prefix[]`. Used by the golden key-schema
/// snapshots in `faults.rs` and `sweep.rs`.
pub fn flatten_key_paths(j: &Json) -> Vec<String> {
    fn rec(prefix: &str, j: &Json, out: &mut Vec<String>) {
        match j {
            Json::Obj(m) => {
                for (k, v) in m {
                    let path = if prefix.is_empty() {
                        k.clone()
                    } else {
                        format!("{prefix}.{k}")
                    };
                    rec(&path, v, out);
                }
            }
            Json::Arr(v) => {
                let path = format!("{prefix}[]");
                match v.first() {
                    Some(first) => rec(&path, first, out),
                    None => out.push(path),
                }
            }
            _ => out.push(prefix.to_string()),
        }
    }
    let mut out = Vec::new();
    rec("", j, &mut out);
    out.sort();
    out.dedup();
    out
}

/// Golden byte-for-byte value snapshot: compare `text` against the
/// fixture at `path`. With `SEER_REGEN_GOLDEN` set — or when the
/// fixture does not exist yet, in which case the first run seeds it —
/// write the current bytes and pass (commit the file). Used by the
/// sweep value-identity test pinning that scheduler optimizations never
/// change emitted report JSON.
#[allow(dead_code)] // each test crate compiles its own copy of common
pub fn check_golden_text(text: &str, path: &Path) {
    if std::env::var("SEER_REGEN_GOLDEN").is_ok() || !path.exists() {
        std::fs::write(path, text).unwrap();
        eprintln!("wrote golden fixture {path:?} ({} bytes)", text.len());
        return;
    }
    let golden = std::fs::read_to_string(path).unwrap();
    assert_eq!(
        text, golden,
        "report bytes drifted from the golden fixture {path:?}; a pure \
         mechanical-sympathy change must not alter emitted JSON — if the \
         change is intentional, regen with SEER_REGEN_GOLDEN=1"
    );
}

/// Self-describing header written into (and accepted from) key-schema
/// fixtures, so the regen path travels with the file instead of living
/// only in test docs.
const GOLDEN_KEYS_DOC: &str = "Golden JSON key-path schema. Regen: \
     SEER_REGEN_GOLDEN=1 cargo test -q (then commit this file). Arrays \
     descend into their first element as [].";

/// Golden key-schema check: compare `keys` against the fixture at
/// `path`, or — with `SEER_REGEN_GOLDEN` set — rewrite the fixture from
/// the current keys and pass (commit the updated file).
///
/// Fixture format: `{"_doc": <regen instructions>, "keys": [...]}` —
/// the header documents the `SEER_REGEN_GOLDEN` regen path inside the
/// fixture itself. A bare JSON array (the pre-ISSUE-7 format) is still
/// accepted on read; regeneration always writes the object form.
pub fn check_golden_keys(keys: &[String], path: &Path) {
    let arr = Json::Arr(keys.iter().map(|k| Json::Str(k.clone())).collect());
    if std::env::var("SEER_REGEN_GOLDEN").is_ok() {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("_doc".to_string(), Json::Str(GOLDEN_KEYS_DOC.to_string()));
        obj.insert("keys".to_string(), arr);
        std::fs::write(path, Json::Obj(obj).to_string()).unwrap();
        eprintln!("regenerated {path:?} ({} keys)", keys.len());
        return;
    }
    let golden_text = std::fs::read_to_string(path).unwrap();
    let parsed = Json::parse(&golden_text).unwrap();
    let golden_arr = match &parsed {
        Json::Obj(_) => parsed
            .get("keys")
            .expect("object-form golden fixture must have a 'keys' field"),
        _ => &parsed,
    };
    let golden: Vec<String> = golden_arr
        .as_arr()
        .expect("golden fixture keys must be a JSON array")
        .iter()
        .map(|j| j.as_str().unwrap().to_string())
        .collect();
    assert_eq!(
        keys, golden,
        "JSON key schema drifted from the golden fixture {path:?}; if \
         intentional, regen with SEER_REGEN_GOLDEN=1 (see test docs)"
    );
}
