//! The cross-iteration context store.
//!
//! Seer's core observation — requests sharing a prompt have correlated
//! lengths and token patterns — holds *across* RL iterations as well as
//! within one: synchronous GRPO revisits the same prompt set epoch after
//! epoch, so the length statistics and token patterns learned during
//! iteration *k* are a strong prior for iteration *k+1* (cf. RhymeRL's
//! "history rhymes" and RollPacker's historical-statistics schedulers).
//! The [`ContextStore`] persists exactly that signal between rollouts:
//!
//! * per-group finished-length statistics (decayed max / mean / sample
//!   weight) that seed the [`crate::coordinator::ContextManager`] with a
//!   *learned* estimate instead of the conservative generation-length
//!   upper bound — iteration ≥ 2 skips the cold-start probe tax;
//! * per-group reference-stream counts that warm the grouped-SD
//!   acceptance model (a CST that already holds last epoch's sibling
//!   streams accepts more from the first verify step);
//! * bounded per-group token-stream exemplars (real backend) that
//!   pre-populate the DGDS CSTs via [`crate::spec::dgds::DraftServer::warm_start`].
//!
//! Statistics blend with exponential decay so the store tracks policy
//! drift instead of averaging over stale epochs, and the whole store
//! serializes through [`crate::util::json`] (`seer train --save-ctx /
//! --load-ctx`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::rollout::session::RolloutReport;
use crate::util::json::Json;
use crate::workload::GroupId;

/// Serialization format version (bumped on breaking layout changes).
const FORMAT_VERSION: u64 = 1;

/// Tuning knobs for the store's decay and warm-start behaviour.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ContextStoreConfig {
    /// Per-iteration exponential-decay factor for historical statistics
    /// in `[0, 1)`: `stat ← decay · stat + (1 − decay) · fresh`. Higher
    /// keeps more history; lower tracks policy drift faster.
    pub decay: f64,
    /// Weight applied to historical reference streams when warming the
    /// grouped-SD acceptance context (history from an older policy is a
    /// weaker draft source than live siblings).
    pub warm_ref_weight: f64,
    /// Safety margin on length priors: the injected estimate is
    /// `max_len · prior_margin`, so a mild upward drift between epochs
    /// does not demote a genuinely long group in the LFS order.
    pub prior_margin: f64,
    /// Token-stream exemplars kept per group (real backend only).
    pub max_streams_per_group: usize,
    /// Suffix length kept per exemplar stream, in tokens.
    pub max_stream_tokens: usize,
}

impl Default for ContextStoreConfig {
    fn default() -> Self {
        ContextStoreConfig {
            decay: 0.6,
            warm_ref_weight: 0.5,
            prior_margin: 1.15,
            max_streams_per_group: 2,
            max_stream_tokens: 64,
        }
    }
}

/// Decayed per-group statistics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GroupRecord {
    /// Decayed maximum finished generation length (tokens).
    pub max_len: f64,
    /// Decayed mean finished generation length (tokens).
    pub mean_len: f64,
    /// Decayed observation weight (≈ how many recent iterations have
    /// contributed; 0 means the record is empty).
    pub weight: f64,
    /// Decayed count of completed sibling streams (the grouped-SD
    /// reference-count signal).
    pub refs: f64,
    /// Token-stream exemplars (suffixes) from the most recent iteration
    /// that produced real tokens; empty on the simulated backend.
    pub streams: Vec<Vec<u32>>,
}

/// Warm-start bundle extracted from a [`ContextStore`] for one rollout.
///
/// This is the currency the execution layers accept: the session builder
/// turns a store into priors
/// ([`crate::rollout::RolloutSessionBuilder::context_store`]), the
/// scheduler consumes `estimates`
/// ([`crate::scheduler::Scheduler::warm_start`]), the cluster simulator
/// consumes `warm_refs`, and the real engine feeds `streams` to the DGDS.
#[derive(Debug, Clone, Default)]
pub struct ContextPriors {
    /// Per-group length estimates (tokens) seeding the context manager.
    pub estimates: Vec<(GroupId, u32)>,
    /// Per-group historical reference-stream counts for the SD model.
    pub warm_refs: Vec<(GroupId, usize)>,
    /// Per-group token-stream exemplars for CST/DGDS warm starts.
    pub streams: Vec<(GroupId, Vec<Vec<u32>>)>,
}

impl ContextPriors {
    pub fn is_empty(&self) -> bool {
        self.estimates.is_empty() && self.warm_refs.is_empty() && self.streams.is_empty()
    }
}

/// Cross-iteration store of per-group rollout context.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ContextStore {
    cfg: ContextStoreConfig,
    /// Workload/task name the statistics were observed under (empty
    /// until the first observation). Group ids only name the same
    /// prompt for the same (task, seed, scale), so consumers must
    /// refuse priors from a store with a different fingerprint.
    task: String,
    /// Workload-generation seed the statistics were observed under
    /// (meaningful only once `task` is set).
    seed: u64,
    /// Iterations observed so far.
    iterations: u64,
    groups: BTreeMap<u32, GroupRecord>,
}

impl ContextStore {
    pub fn new() -> Self {
        Self::with_config(ContextStoreConfig::default())
    }

    pub fn with_config(cfg: ContextStoreConfig) -> Self {
        ContextStore {
            cfg,
            task: String::new(),
            seed: 0,
            iterations: 0,
            groups: BTreeMap::new(),
        }
    }

    pub fn config(&self) -> &ContextStoreConfig {
        &self.cfg
    }

    /// Task name the store's statistics belong to ("" = no observations
    /// yet).
    pub fn task(&self) -> &str {
        &self.task
    }

    /// Workload seed the store's statistics belong to (see
    /// [`task`](Self::task) for whether it is meaningful).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Record which (task, seed) the statistics describe (first writer
    /// wins — group ids are only meaningful within one workload's
    /// prompt set).
    pub fn set_fingerprint(&mut self, task: &str, seed: u64) {
        if self.task.is_empty() {
            self.task = task.to_string();
            self.seed = seed;
        }
    }

    /// Iterations folded into the store so far.
    pub fn iterations(&self) -> u64 {
        self.iterations
    }

    /// Number of groups with recorded statistics.
    pub fn len(&self) -> usize {
        self.groups.len()
    }

    pub fn is_empty(&self) -> bool {
        self.groups.is_empty()
    }

    pub fn group(&self, group: GroupId) -> Option<&GroupRecord> {
        self.groups.get(&group.0)
    }

    /// Fold one iteration's finished lengths (and token streams, when the
    /// backend produces them) into the store.
    pub fn observe_report(&mut self, report: &RolloutReport) {
        let mut lens: BTreeMap<u32, Vec<u32>> = BTreeMap::new();
        let mut streams: BTreeMap<u32, Vec<&[u32]>> = BTreeMap::new();
        for s in &report.sequences {
            lens.entry(s.group.0).or_default().push(s.gen_len);
            if !s.tokens.is_empty() {
                streams.entry(s.group.0).or_default().push(&s.tokens);
            }
        }
        for (g, ls) in &lens {
            let toks = streams.get(g).map(|v| v.as_slice()).unwrap_or(&[]);
            self.observe_group(GroupId(*g), ls, toks);
        }
        self.iterations += 1;
    }

    /// Fold one group's finished lengths (and optional token streams)
    /// into its decayed record.
    pub fn observe_group(&mut self, group: GroupId, lens: &[u32], streams: &[&[u32]]) {
        if lens.is_empty() {
            return;
        }
        let fresh_max = *lens.iter().max().unwrap() as f64;
        let fresh_mean =
            lens.iter().map(|&l| l as f64).sum::<f64>() / lens.len() as f64;
        let d = self.cfg.decay;
        let r = self.groups.entry(group.0).or_default();
        if r.weight == 0.0 {
            r.max_len = fresh_max;
            r.mean_len = fresh_mean;
            r.refs = lens.len() as f64;
        } else {
            r.max_len = d * r.max_len + (1.0 - d) * fresh_max;
            r.mean_len = d * r.mean_len + (1.0 - d) * fresh_mean;
            // Blended like the lengths so the steady state stays at one
            // epoch's completed-stream count — warm_refs must never claim
            // more reference streams than a group physically produces.
            r.refs = d * r.refs + (1.0 - d) * lens.len() as f64;
        }
        r.weight = d * r.weight + 1.0;
        if !streams.is_empty() {
            r.streams = streams
                .iter()
                .take(self.cfg.max_streams_per_group)
                .map(|s| {
                    let keep = s.len().min(self.cfg.max_stream_tokens);
                    s[s.len() - keep..].to_vec()
                })
                .collect();
        }
    }

    /// Length prior for a group (tokens), with the configured safety
    /// margin applied; `None` when the store has no signal for it.
    pub fn estimate(&self, group: GroupId) -> Option<u32> {
        let r = self.groups.get(&group.0)?;
        if r.weight <= 0.0 {
            return None;
        }
        Some((r.max_len * self.cfg.prior_margin).ceil().max(1.0) as u32)
    }

    /// Historical reference-stream count for the grouped-SD model,
    /// already scaled by `warm_ref_weight`.
    pub fn warm_refs(&self, group: GroupId) -> usize {
        self.groups
            .get(&group.0)
            .map(|r| (r.refs * self.cfg.warm_ref_weight).floor() as usize)
            .unwrap_or(0)
            .min(32)
    }

    /// Extract the warm-start bundle for one rollout.
    pub fn priors(&self) -> ContextPriors {
        let mut p = ContextPriors::default();
        for (&g, r) in &self.groups {
            let id = GroupId(g);
            if let Some(est) = self.estimate(id) {
                p.estimates.push((id, est));
            }
            let refs = self.warm_refs(id);
            if refs > 0 {
                p.warm_refs.push((id, refs));
            }
            if !r.streams.is_empty() {
                p.streams.push((id, r.streams.clone()));
            }
        }
        p
    }

    // -- serialization ----------------------------------------------------

    /// Serialize the full store (config + statistics) to JSON.
    pub fn to_json(&self) -> Json {
        let mut cfg = BTreeMap::new();
        cfg.insert("decay".to_string(), Json::Num(self.cfg.decay));
        cfg.insert(
            "warm_ref_weight".to_string(),
            Json::Num(self.cfg.warm_ref_weight),
        );
        cfg.insert(
            "prior_margin".to_string(),
            Json::Num(self.cfg.prior_margin),
        );
        cfg.insert(
            "max_streams_per_group".to_string(),
            Json::Num(self.cfg.max_streams_per_group as f64),
        );
        cfg.insert(
            "max_stream_tokens".to_string(),
            Json::Num(self.cfg.max_stream_tokens as f64),
        );
        let mut groups = BTreeMap::new();
        for (g, r) in &self.groups {
            let mut o = BTreeMap::new();
            o.insert("max_len".to_string(), Json::Num(r.max_len));
            o.insert("mean_len".to_string(), Json::Num(r.mean_len));
            o.insert("weight".to_string(), Json::Num(r.weight));
            o.insert("refs".to_string(), Json::Num(r.refs));
            o.insert(
                "streams".to_string(),
                Json::Arr(
                    r.streams
                        .iter()
                        .map(|s| {
                            Json::Arr(
                                s.iter().map(|&t| Json::Num(t as f64)).collect(),
                            )
                        })
                        .collect(),
                ),
            );
            groups.insert(g.to_string(), Json::Obj(o));
        }
        let mut top = BTreeMap::new();
        top.insert("version".to_string(), Json::Num(FORMAT_VERSION as f64));
        top.insert("task".to_string(), Json::Str(self.task.clone()));
        // As a string: Json numbers are f64 and would corrupt u64 seeds
        // above 2^53.
        top.insert("seed".to_string(), Json::Str(self.seed.to_string()));
        top.insert("iterations".to_string(), Json::Num(self.iterations as f64));
        top.insert("config".to_string(), Json::Obj(cfg));
        top.insert("groups".to_string(), Json::Obj(groups));
        Json::Obj(top)
    }

    /// Rebuild a store from [`ContextStore::to_json`] output.
    pub fn from_json(j: &Json) -> Result<Self> {
        let version = j
            .get("version")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("context store: missing version"))?;
        if version != FORMAT_VERSION {
            return Err(anyhow!(
                "context store: unsupported version {version} (expected {FORMAT_VERSION})"
            ));
        }
        let c = j
            .get("config")
            .ok_or_else(|| anyhow!("context store: missing config"))?;
        let f = |key: &str| -> Result<f64> {
            c.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("context store: missing config.{key}"))
        };
        let cfg = ContextStoreConfig {
            decay: f("decay")?,
            warm_ref_weight: f("warm_ref_weight")?,
            prior_margin: f("prior_margin")?,
            max_streams_per_group: f("max_streams_per_group")? as usize,
            max_stream_tokens: f("max_stream_tokens")? as usize,
        };
        let mut store = ContextStore::with_config(cfg);
        // Fingerprint fields are as load-bearing as the statistics (they
        // gate every warm-start safety check), so a store missing them
        // is rejected rather than loaded as fingerprint-less.
        store.task = j
            .get("task")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("context store: missing task"))?
            .to_string();
        store.seed = j
            .get("seed")
            .and_then(Json::as_str)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow!("context store: missing/bad seed"))?;
        store.iterations = j
            .get("iterations")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("context store: missing iterations"))?;
        let groups = j
            .get("groups")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("context store: missing groups"))?;
        for (g, rec) in groups {
            let gid: u32 = g
                .parse()
                .map_err(|_| anyhow!("context store: bad group key '{g}'"))?;
            let num = |key: &str| -> Result<f64> {
                rec.get(key)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("context store: group {g} missing {key}"))
            };
            let mut streams = Vec::new();
            for s in rec.get("streams").and_then(Json::as_arr).unwrap_or(&[]) {
                let toks = s
                    .as_arr()
                    .ok_or_else(|| anyhow!("context store: bad stream in group {g}"))?;
                let mut stream = Vec::with_capacity(toks.len());
                for t in toks {
                    let tok = t.as_u64().ok_or_else(|| {
                        anyhow!("context store: bad token in group {g} stream")
                    })?;
                    stream.push(tok as u32);
                }
                streams.push(stream);
            }
            store.groups.insert(
                gid,
                GroupRecord {
                    max_len: num("max_len")?,
                    mean_len: num("mean_len")?,
                    weight: num("weight")?,
                    refs: num("refs")?,
                    streams,
                },
            );
        }
        Ok(store)
    }

    /// Save the store to a JSON file.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("saving context store to {path:?}"))
    }

    /// Load a store saved with [`ContextStore::save`].
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("loading context store from {path:?}"))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("context store {path:?}: {e}"))?;
        Self::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_store_has_no_priors() {
        let s = ContextStore::new();
        assert!(s.is_empty());
        assert_eq!(s.estimate(GroupId(0)), None);
        assert_eq!(s.warm_refs(GroupId(0)), 0);
        assert!(s.priors().is_empty());
    }

    #[test]
    fn first_observation_sets_stats_directly() {
        let mut s = ContextStore::new();
        s.observe_group(GroupId(3), &[100, 300, 200], &[]);
        let r = s.group(GroupId(3)).unwrap();
        assert_eq!(r.max_len, 300.0);
        assert_eq!(r.mean_len, 200.0);
        assert_eq!(r.weight, 1.0);
        assert_eq!(r.refs, 3.0);
        // Estimate carries the configured safety margin.
        let est = s.estimate(GroupId(3)).unwrap();
        assert_eq!(est, (300.0 * s.config().prior_margin).ceil() as u32);
    }

    #[test]
    fn decay_blends_toward_fresh_observations() {
        let mut s = ContextStore::with_config(ContextStoreConfig {
            decay: 0.5,
            ..Default::default()
        });
        s.observe_group(GroupId(0), &[1000], &[]);
        s.observe_group(GroupId(0), &[200], &[]);
        let r = s.group(GroupId(0)).unwrap();
        assert_eq!(r.max_len, 600.0); // 0.5·1000 + 0.5·200
        // Repeated short epochs pull a stale long estimate down.
        for _ in 0..10 {
            s.observe_group(GroupId(0), &[200], &[]);
        }
        assert!(s.group(GroupId(0)).unwrap().max_len < 210.0);
    }

    #[test]
    fn streams_are_bounded_suffixes() {
        let mut s = ContextStore::with_config(ContextStoreConfig {
            max_streams_per_group: 2,
            max_stream_tokens: 4,
            ..Default::default()
        });
        let a: Vec<u32> = (0..10).collect();
        let b = vec![7, 8];
        let c = vec![9];
        s.observe_group(GroupId(1), &[10, 2, 1], &[&a, &b, &c]);
        let r = s.group(GroupId(1)).unwrap();
        assert_eq!(r.streams.len(), 2);
        assert_eq!(r.streams[0], vec![6, 7, 8, 9]); // 4-token suffix
        assert_eq!(r.streams[1], vec![7, 8]);
    }

    #[test]
    fn warm_refs_scale_and_cap() {
        let mut s = ContextStore::new();
        s.observe_group(GroupId(0), &[10; 8], &[]);
        // 8 refs × 0.5 weight = 4.
        assert_eq!(s.warm_refs(GroupId(0)), 4);
    }

    #[test]
    fn fingerprint_first_writer_wins_and_round_trips() {
        let mut s = ContextStore::new();
        assert_eq!(s.task(), "");
        s.set_fingerprint("moonlight", 42);
        s.set_fingerprint("qwen", 7); // ignored: stats stay moonlight@42
        assert_eq!(s.task(), "moonlight");
        assert_eq!(s.seed(), 42);
        let back = ContextStore::from_json(
            &Json::parse(&s.to_json().to_string()).unwrap(),
        )
        .unwrap();
        assert_eq!(back.task(), "moonlight");
        assert_eq!(back.seed(), 42);
    }

    #[test]
    fn json_round_trip_is_identical() {
        let mut s = ContextStore::with_config(ContextStoreConfig {
            decay: 0.7,
            ..Default::default()
        });
        s.set_fingerprint("moonlight", 42);
        s.observe_group(GroupId(0), &[100, 350], &[&[1, 2, 3][..]]);
        s.observe_group(GroupId(5), &[40], &[]);
        s.observe_group(GroupId(0), &[90, 120], &[]);
        let j = s.to_json();
        let back = ContextStore::from_json(&Json::parse(&j.to_string()).unwrap())
            .unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn from_json_rejects_bad_versions() {
        let j = Json::parse(r#"{"version": 99, "config": {}, "groups": {}}"#)
            .unwrap();
        assert!(ContextStore::from_json(&j).is_err());
    }

    #[test]
    fn from_json_rejects_missing_fingerprint() {
        let s = ContextStore::new();
        let text = s.to_json().to_string().replace("\"task\":\"\",", "");
        let e = ContextStore::from_json(&Json::parse(&text).unwrap())
            .unwrap_err()
            .to_string();
        assert!(e.contains("missing task"), "{e}");
    }

    #[test]
    fn from_json_rejects_malformed_documents() {
        // The `--load-ctx` load path (and the serve checkpoint path
        // behind it) must turn every malformed document into an Err,
        // never a panic.
        let mut s = ContextStore::new();
        s.set_fingerprint("moonlight", 42);
        s.observe_group(GroupId(0), &[10, 20], &[&[1, 2][..]]);
        let full = s.to_json().to_string();
        for cut in 1..full.len() {
            assert!(
                Json::parse(&full[..cut]).is_err(),
                "truncated at {cut} parsed"
            );
        }
        let deep = format!("{}1{}", "[".repeat(50_000), "]".repeat(50_000));
        let e = Json::parse(&deep).unwrap_err();
        assert!(e.msg.contains("nesting too deep"), "{e}");
        // Type confusion at every schema level.
        for bad in [
            r#"[]"#,
            r#"{"version": "one"}"#,
            r#"{"version": 1, "task": 3, "seed": "42", "iterations": 1, "config": {}, "groups": {}}"#,
            r#"{"version": 1, "task": "m", "seed": 42, "iterations": 1, "config": {}, "groups": {}}"#,
            r#"{"version": 1, "task": "m", "seed": "42", "iterations": 1, "config": [], "groups": {}}"#,
            r#"{"version": 1, "task": "m", "seed": "42", "iterations": 1, "config": {"decay": 0.5, "warm_ref_weight": 1, "prior_margin": 1, "max_streams_per_group": 1, "max_stream_tokens": 1}, "groups": []}"#,
            r#"{"version": 1, "task": "m", "seed": "42", "iterations": 1, "config": {"decay": 0.5, "warm_ref_weight": 1, "prior_margin": 1, "max_streams_per_group": 1, "max_stream_tokens": 1}, "groups": {"x": {}}}"#,
        ] {
            assert!(
                ContextStore::from_json(&Json::parse(bad).unwrap()).is_err(),
                "accepted {bad}"
            );
        }
    }

    #[test]
    fn from_json_rejects_malformed_streams() {
        // Valid store, then corrupt one stream token into a string.
        let mut s = ContextStore::new();
        s.observe_group(GroupId(0), &[10], &[&[1, 2][..]]);
        let text = s
            .to_json()
            .to_string()
            .replace("\"streams\":[[1,2]]", "\"streams\":[[1,\"x\"]]");
        let j = Json::parse(&text).unwrap();
        let e = ContextStore::from_json(&j).unwrap_err().to_string();
        assert!(e.contains("bad token"), "{e}");
    }
}
