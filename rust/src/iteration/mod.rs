//! Cross-iteration context: persist what one rollout learned for the
//! next.
//!
//! Synchronous RL rebuilds its rollout state from scratch every
//! iteration, so every epoch re-pays the cold-start cost Seer's online
//! context learning exists to amortize: the context manager probes every
//! group before it can order by length, and the grouped-SD CSTs start
//! empty. But the prompt set is *the same* across GRPO epochs, and
//! lengths/token patterns drift slowly with the policy — history rhymes.
//! This module closes the loop:
//!
//! * [`ContextStore`] — decayed per-group length statistics, SD reference
//!   counts, and bounded token-stream exemplars, serializable through
//!   [`crate::util::json`] (`seer train --save-ctx / --load-ctx`);
//! * [`ContextPriors`] — the warm-start bundle a store hands to one
//!   rollout (consumed by
//!   [`crate::rollout::RolloutSessionBuilder::context_store`], the
//!   scheduler's [`crate::scheduler::Scheduler::warm_start`], the cluster
//!   simulator, and the real engine's DGDS);
//! * [`TrainingDriver`] — runs N GRPO iterations through
//!   [`crate::rollout::RolloutSession`], re-sampling each epoch with
//!   drift ([`crate::workload::generate_epoch`]) and feeding finished
//!   lengths back into the store.
//!
//! `experiments::multi_iter` (CLI: `seer experiment multi-iter`)
//! measures the effect: with the store, iteration ≥ 2 long-tail latency
//! drops below both iteration 1 and the cold-start baseline.

pub mod driver;
pub mod store;

pub use driver::{IterationSummary, TrainingConfig, TrainingDriver};
pub use store::{ContextPriors, ContextStore, ContextStoreConfig, GroupRecord};
