//! The multi-iteration training driver.
//!
//! [`TrainingDriver`] turns the single-shot rollout simulator into a
//! multi-epoch synchronous-RL system: each iteration re-samples the same
//! prompt set with configurable length drift
//! ([`crate::workload::generate_epoch`]), runs it through one
//! [`crate::rollout::RolloutSession`], folds the finished lengths back
//! into the [`ContextStore`], and — when warm starting is enabled —
//! seeds the next iteration's context manager and grouped-SD state from
//! the store. Training and weight-update phase times come from the
//! calibrated [`crate::rl::PhaseModel`], so each
//! [`IterationSummary`] reports the full iteration wall, not just the
//! rollout.
//!
//! Everything is deterministic in the config: two drivers with the same
//! [`TrainingConfig`] produce bit-identical summaries.
//!
//! # Training modes
//!
//! [`TrainingMode`] selects how epoch *k+1*'s rollout overlaps epoch
//! *k*'s training/weight-update phases on the pipeline clock:
//!
//! * `Sync` — strictly serial (today's default): rollout *k+1* starts
//!   only after update *k* lands. Single-shot session path.
//! * `Hybrid` — one-step overlap: rollout *k+1* runs concurrently with
//!   training *k* (off-policy lag ≤ 1). Laminar-style.
//! * `Async { lag }` — bounded staleness: rollout *k* may start as soon
//!   as update *k−1−lag* has landed; updates land mid-rollout and bump
//!   the stamped policy version via
//!   [`crate::rollout::RolloutStream::set_policy_version`]. `lag = 0`
//!   reproduces `Sync` byte-identically (pinned by test).
//!
//! The rollout start `S_k`, finish `R_k = S_k + makespan`, and update
//! landing `U_k` follow the recurrence `S_k = max(R_{k-1}, U_{k-1-lag})`
//! and `U_k = max(R_k, U_{k-1}) + train_k + weight_update_k` with
//! `U_j = 0` for `j < 0`; per-completion staleness is folded into the
//! epoch metrics by [`crate::metrics::RolloutMetrics::apply_staleness`].

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::config::{SystemConfig, TrainingMode, WorkloadConfig};
use crate::rl::PhaseModel;
use crate::rollout::session::RolloutReport;
use crate::rollout::{RolloutObserver, RolloutSession};
use crate::sim::clock::SimTime;
use crate::sim::faults::{trainer_step, FaultPlan};
use crate::util::json::Json;
use crate::workload::generate_epoch;

use super::store::{ContextStore, ContextStoreConfig};

/// Configuration of one multi-iteration training run.
#[derive(Debug, Clone)]
pub struct TrainingConfig {
    pub workload: WorkloadConfig,
    pub system: SystemConfig,
    /// Registry name of the scheduling policy (e.g. `"seer"`).
    pub scheduler: String,
    /// Registry name of the SD strategy (e.g. `"grouped-cst"`).
    pub sd: String,
    /// GRPO iterations (epochs) to run.
    pub iters: usize,
    pub seed: u64,
    /// Per-epoch length drift (log-normal sigma); 0 = identical epochs.
    pub drift: f64,
    /// Consume the context store's priors from iteration 2 on. The store
    /// *learns* either way; cold runs just never read it back.
    pub warm_start: bool,
    /// Rollout/training overlap discipline (see the module docs).
    /// `Sync` (the default) is today's strictly serial pipeline.
    pub mode: TrainingMode,
    /// Trainer-side fault script replayed into the `U_k` recurrence by
    /// [`crate::sim::faults::trainer_step`]: slowdowns/stalls inflate
    /// the train step, crashes redo it from the last checkpoint
    /// (`train_retries`). Cluster-side events in the plan are ignored
    /// here — this driver's rollouts are fault-free. An empty plan
    /// leaves every summary byte-identical to pre-fault behavior.
    pub trainer_faults: FaultPlan,
    pub store: ContextStoreConfig,
}

impl TrainingConfig {
    pub fn new(workload: WorkloadConfig) -> Self {
        TrainingConfig {
            workload,
            system: SystemConfig::default(),
            scheduler: "seer".to_string(),
            sd: "grouped-cst".to_string(),
            iters: 3,
            seed: 42,
            drift: 0.05,
            warm_start: true,
            mode: TrainingMode::Sync,
            trainer_faults: FaultPlan::new(),
            store: ContextStoreConfig::default(),
        }
    }
}

/// Per-iteration metrics of one training run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IterationSummary {
    pub iter: usize,
    /// Whether this iteration consumed warm priors from the store.
    pub warm: bool,
    pub makespan_secs: f64,
    /// p99 request finish time within the iteration — the long-tail
    /// latency metric the cross-iteration store targets.
    pub p99_finish_secs: f64,
    /// Time spent solely on the last 10% of requests (paper §4.2.2).
    pub tail_secs: f64,
    pub throughput_tok_s: f64,
    pub tokens: u64,
    pub preemptions: u64,
    pub migrations: u64,
    /// Modeled training / weight-update phase times (Table 1 model).
    pub train_secs: f64,
    pub weight_update_secs: f64,
    /// Full iteration wall: rollout + training + weight update.
    pub iter_total_secs: f64,
    /// Pipeline-clock time this epoch's rollout started (`S_k`, seconds
    /// since the pipeline began). Equals the previous update's landing
    /// time under `Sync`; earlier under overlap modes.
    pub rollout_start_secs: f64,
    /// Pipeline-clock time this epoch's trained update lands (`U_k`).
    pub update_land_secs: f64,
    /// Mean per-completion policy-version lag of this epoch's data
    /// (0 under `Sync` and `Async { lag: 0 }`).
    pub staleness_mean: f64,
    /// Largest per-completion policy-version lag.
    pub staleness_max: u64,
    /// Completions generated under an older policy version than the one
    /// training consumed them at.
    pub stale_requests: u64,
    /// Train-step redos forced by scripted `TrainerCrash` events at this
    /// iteration (0 on a fault-free run).
    pub train_retries: u64,
    /// Seconds trainer-side faults (slowdown, stall, crash redo) added
    /// to this iteration's update landing over the fault-free recurrence.
    pub trainer_fault_secs: f64,
}

impl IterationSummary {
    /// Serialize as one JSON object. Floats print in shortest-roundtrip
    /// form and counters fit f64's 2^53 integer range at any simulated
    /// scale, so [`IterationSummary::from_json`] recovers an *equal*
    /// summary — the serve plane's checkpoint/resume path depends on
    /// this exactness for byte-identical resumed reports.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        let mut put = |k: &str, v: Json| {
            o.insert(k.to_string(), v);
        };
        put("iter", Json::Num(self.iter as f64));
        put("warm", Json::Bool(self.warm));
        put("makespan_secs", Json::Num(self.makespan_secs));
        put("p99_finish_secs", Json::Num(self.p99_finish_secs));
        put("tail_secs", Json::Num(self.tail_secs));
        put("throughput_tok_s", Json::Num(self.throughput_tok_s));
        put("tokens", Json::Num(self.tokens as f64));
        put("preemptions", Json::Num(self.preemptions as f64));
        put("migrations", Json::Num(self.migrations as f64));
        put("train_secs", Json::Num(self.train_secs));
        put("weight_update_secs", Json::Num(self.weight_update_secs));
        put("iter_total_secs", Json::Num(self.iter_total_secs));
        put("rollout_start_secs", Json::Num(self.rollout_start_secs));
        put("update_land_secs", Json::Num(self.update_land_secs));
        put("staleness_mean", Json::Num(self.staleness_mean));
        put("staleness_max", Json::Num(self.staleness_max as f64));
        put("stale_requests", Json::Num(self.stale_requests as f64));
        put("train_retries", Json::Num(self.train_retries as f64));
        put("trainer_fault_secs", Json::Num(self.trainer_fault_secs));
        Json::Obj(o)
    }

    /// Inverse of [`IterationSummary::to_json`]; every missing or
    /// type-confused field is a named error (checkpoints are read back
    /// from disk, which may have been truncated or hand-edited).
    pub fn from_json(j: &Json) -> Result<Self> {
        let f = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .with_context(|| format!("iteration summary: bad '{k}'"))
        };
        let u = |k: &str| -> Result<u64> {
            j.get(k)
                .and_then(Json::as_u64)
                .with_context(|| format!("iteration summary: bad '{k}'"))
        };
        Ok(IterationSummary {
            iter: u("iter")? as usize,
            warm: j
                .get("warm")
                .and_then(Json::as_bool)
                .context("iteration summary: bad 'warm'")?,
            makespan_secs: f("makespan_secs")?,
            p99_finish_secs: f("p99_finish_secs")?,
            tail_secs: f("tail_secs")?,
            throughput_tok_s: f("throughput_tok_s")?,
            tokens: u("tokens")?,
            preemptions: u("preemptions")?,
            migrations: u("migrations")?,
            train_secs: f("train_secs")?,
            weight_update_secs: f("weight_update_secs")?,
            iter_total_secs: f("iter_total_secs")?,
            rollout_start_secs: f("rollout_start_secs")?,
            update_land_secs: f("update_land_secs")?,
            staleness_mean: f("staleness_mean")?,
            staleness_max: u("staleness_max")?,
            stale_requests: u("stale_requests")?,
            train_retries: u("train_retries")?,
            trainer_fault_secs: f("trainer_fault_secs")?,
        })
    }
}

/// Drives N GRPO iterations through the session layer, threading the
/// cross-iteration [`ContextStore`] between them.
pub struct TrainingDriver {
    cfg: TrainingConfig,
    store: ContextStore,
    history: Vec<IterationSummary>,
    /// Epoch index the next [`run_iteration`](Self::run_iteration) via
    /// [`run`](Self::run) will use. Starts at `store.iterations()` so a
    /// resumed driver *continues* the drift sequence instead of
    /// replaying already-observed epochs into the decayed statistics.
    next_epoch: usize,
    /// Pipeline clock: `R_{k-1}` — when the previous epoch's rollout
    /// finished, in seconds since the pipeline started. Reconstructed
    /// from `history` on [`with_resume`](Self::with_resume), so a
    /// resumed overlap run continues the recurrence exactly.
    pipe_r_prev: f64,
    /// Pipeline clock: `U_j` — when each completed training step's
    /// update landed, indexed by *pipeline-relative* epoch (0 = the
    /// first epoch this pipeline ran). A store-only resume
    /// ([`with_store`](Self::with_store)) restarts the pipeline clock
    /// at 0 while epoch numbering continues.
    pipe_u: Vec<f64>,
}

impl TrainingDriver {
    pub fn new(cfg: TrainingConfig) -> Self {
        let store = ContextStore::with_config(cfg.store);
        Self::build(cfg, store)
    }

    /// Resume from a previously saved store (`seer train --load-ctx`):
    /// the first iteration already runs warm, and epoch numbering
    /// continues from where the saved run stopped. Errors when the
    /// store's fingerprint (task, seed, group count) does not match the
    /// config — group ids only name the same prompt for the same
    /// workload, so mismatched priors would be silently wrong.
    pub fn with_store(cfg: TrainingConfig, store: ContextStore) -> Result<Self> {
        if !store.task().is_empty() {
            if store.task() != cfg.workload.name || store.seed() != cfg.seed {
                anyhow::bail!(
                    "context store fingerprint (task '{}', seed {}) does \
                     not match the training config (task '{}', seed {})",
                    store.task(),
                    store.seed(),
                    cfg.workload.name,
                    cfg.seed
                );
            }
            if store.len() != cfg.workload.n_groups() {
                anyhow::bail!(
                    "context store has {} groups but the workload has {} \
                     (different scale?)",
                    store.len(),
                    cfg.workload.n_groups()
                );
            }
        }
        Ok(Self::build(cfg, store))
    }

    /// Resume an *interrupted* run from checkpointed state: the store
    /// plus the summaries of the iterations already completed. Beyond
    /// the [`with_store`](Self::with_store) fingerprint checks, the
    /// history length must equal the store's observed iteration count —
    /// they are written atomically together by the serve plane's
    /// checkpointer, so a mismatch means a corrupt or mixed-up file.
    /// The resumed driver continues the epoch sequence and appends to
    /// `history`, so its final history is identical to an uninterrupted
    /// run's.
    pub fn with_resume(
        cfg: TrainingConfig,
        store: ContextStore,
        history: Vec<IterationSummary>,
    ) -> Result<Self> {
        if history.len() as u64 != store.iterations() {
            bail!(
                "resume history has {} summaries but the store observed {} \
                 iterations",
                history.len(),
                store.iterations()
            );
        }
        let mut d = Self::with_store(cfg, store)?;
        d.pipe_u = history.iter().map(|s| s.update_land_secs).collect();
        d.pipe_r_prev = history
            .last()
            .map(|s| s.rollout_start_secs + s.makespan_secs)
            .unwrap_or(0.0);
        d.history = history;
        Ok(d)
    }

    fn build(cfg: TrainingConfig, store: ContextStore) -> Self {
        TrainingDriver {
            cfg,
            next_epoch: store.iterations() as usize,
            store,
            history: Vec::new(),
            pipe_r_prev: 0.0,
            pipe_u: Vec::new(),
        }
    }

    /// Epoch index the next driven iteration will run.
    pub fn next_epoch(&self) -> usize {
        self.next_epoch
    }

    pub fn store(&self) -> &ContextStore {
        &self.store
    }

    /// Consume the driver, handing back the store (for `--save-ctx`).
    pub fn into_store(self) -> ContextStore {
        self.store
    }

    pub fn history(&self) -> &[IterationSummary] {
        &self.history
    }

    /// Run one iteration (epoch `iter`), returning its summary.
    pub fn run_iteration(&mut self, iter: usize) -> Result<IterationSummary> {
        self.run_iteration_observed(iter, None)
    }

    /// [`run_iteration`](Self::run_iteration) with an optional event
    /// observer attached to the epoch's rollout session — the serve
    /// plane threads its fan-out mux through here so `subscribe` streams
    /// a train job's events live. Observation never changes the result:
    /// summaries are identical with and without an observer.
    pub fn run_iteration_observed(
        &mut self,
        iter: usize,
        observer: Option<Box<dyn RolloutObserver>>,
    ) -> Result<IterationSummary> {
        let cfg = &self.cfg;
        // Pipeline-relative epoch index and the staleness gate: rollout
        // may start once the cluster is free (R_{k-1}) AND version
        // k-lag exists (update k-1-lag landed).
        let rel = self.pipe_u.len();
        let lag = cfg.mode.lag() as usize;
        let gate = if rel > lag { self.pipe_u[rel - 1 - lag] } else { 0.0 };
        let start_at = self.pipe_r_prev.max(gate);

        let w = generate_epoch(&cfg.workload, cfg.seed, iter as u64, cfg.drift);
        let mut builder = RolloutSession::builder()
            .workload(cfg.workload.clone())
            .system(cfg.system.clone())
            .scheduler(&cfg.scheduler)
            .sd(&cfg.sd)
            .seed(cfg.seed)
            .groups(w.groups);
        let warm = cfg.warm_start && !self.store.is_empty();
        if warm {
            // The store's streams are one epoch old, so the policy has
            // drifted by exactly the per-epoch sigma since they were
            // recorded — the SD model discounts warm references by it.
            builder = builder
                .context_store(&self.store)
                .warm_drift(cfg.drift);
        }
        if let Some(obs) = observer {
            builder = builder.observer(obs);
        }
        let report = if cfg.mode.is_pipelined() {
            self.run_epoch_pipelined(builder, rel, start_at)?
        } else {
            builder.run()?
        };
        let summary = self.summarize(iter, warm, start_at, &report);
        self.store
            .set_fingerprint(self.cfg.workload.name, self.cfg.seed);
        self.store.observe_report(&report);
        self.history.push(summary);
        self.next_epoch = iter + 1;
        self.pipe_r_prev = summary.rollout_start_secs + summary.makespan_secs;
        self.pipe_u.push(summary.update_land_secs);
        Ok(summary)
    }

    /// Run one overlap-mode epoch through the suspendable
    /// [`crate::rollout::RolloutStream`]: park the stream across the
    /// staleness-gate wait, then advance it in segments, bumping the
    /// stamped policy version as earlier epochs' trained updates land
    /// mid-rollout, and fold per-completion lag into the metrics.
    fn run_epoch_pipelined(
        &self,
        builder: crate::rollout::RolloutSessionBuilder<'static>,
        rel: usize,
        start_at: f64,
    ) -> Result<RolloutReport> {
        let mut stream = builder.start_stream()?;
        if start_at > self.pipe_r_prev {
            // The cluster sits idle from R_{k-1} until the bounding
            // version lands — model the wait as a suspend/resume pair
            // (virtual time inside the rollout is unaffected).
            stream.suspend()?;
            stream.resume()?;
        }
        // Versions landed before the rollout started…
        let landed = self.pipe_u.iter().filter(|&&u| u <= start_at).count();
        stream.set_policy_version(landed as u64);
        // …and those landing mid-rollout, at sim-relative deadlines.
        for j in landed..rel {
            stream.run_until(SimTime::from_secs_f64(self.pipe_u[j] - start_at))?;
            stream.set_policy_version((j + 1) as u64);
        }
        stream.run_until(SimTime::FAR_FUTURE)?;
        let mut report = stream.finish()?;
        // Training consumes this data at version `rel` — the version a
        // synchronous run would have generated it under.
        report.metrics.apply_staleness(rel as u64);
        Ok(report)
    }

    /// Run all configured iterations:
    /// [`run_to`](Self::run_to)`(cfg.iters)`. On a fresh driver that is
    /// `cfg.iters` epochs; on a resumed one it *completes* the run to
    /// the configured total, matching the serve plane's accounting.
    pub fn run(&mut self) -> Result<Vec<IterationSummary>> {
        self.run_to(self.cfg.iters)
    }

    /// Run iterations until `total` epochs have completed overall
    /// (total-count semantics: a driver resumed past `total` runs
    /// nothing). Returns the summaries this call produced. Gates on the
    /// epoch counter, not the in-memory history, so a store-only resume
    /// (`--load-ctx`, which starts with an empty history but a non-zero
    /// epoch) still counts the already-observed epochs toward `total`.
    pub fn run_to(&mut self, total: usize) -> Result<Vec<IterationSummary>> {
        let start = self.history.len();
        while self.next_epoch < total {
            self.run_iteration(self.next_epoch)?;
        }
        Ok(self.history[start..].to_vec())
    }

    fn summarize(
        &self,
        iter: usize,
        warm: bool,
        start_at: f64,
        report: &RolloutReport,
    ) -> IterationSummary {
        let m = &report.metrics;
        let phases = PhaseModel::for_workload(&self.cfg.workload)
            .split(m.makespan, m.tokens_generated);
        // U_k = max(R_k, U_{k-1}) + T_k: training starts when its data
        // is ready and the trainer finished the previous step.
        let rollout_end = start_at + m.makespan.as_secs_f64();
        let u_prev = self.pipe_u.last().copied().unwrap_or(0.0);
        let train_start = rollout_end.max(u_prev);
        // With a trainer-fault script, the step walks through
        // `trainer_step` (the one shared implementation — the sweep cell
        // recurrence uses it too, keeping sync ≡ async-lag-0 under any
        // plan). The empty-plan path keeps the exact historical float
        // expression so fault-free runs stay byte-identical.
        let (update_land, train_retries, trainer_fault_secs) =
            if self.cfg.trainer_faults.is_empty() {
                (
                    train_start
                        + phases.training.as_secs_f64()
                        + phases.weight_update.as_secs_f64(),
                    0,
                    0.0,
                )
            } else {
                let base = phases.training.as_secs_f64()
                    + phases.weight_update.as_secs_f64();
                let step = trainer_step(
                    &self.cfg.trainer_faults,
                    iter,
                    train_start,
                    base,
                );
                (step.end_secs, step.retries, step.fault_secs)
            };
        IterationSummary {
            iter,
            warm,
            makespan_secs: m.makespan.as_secs_f64(),
            p99_finish_secs: m.finish_percentile(99.0),
            tail_secs: m.tail_time(0.10).as_secs_f64(),
            throughput_tok_s: m.throughput(),
            tokens: m.tokens_generated,
            preemptions: m.preemptions,
            migrations: m.migrations,
            train_secs: phases.training.as_secs_f64(),
            weight_update_secs: phases.weight_update.as_secs_f64(),
            iter_total_secs: phases.total().as_secs_f64()
                + trainer_fault_secs,
            rollout_start_secs: start_at,
            update_land_secs: update_land,
            staleness_mean: m.staleness_mean(),
            staleness_max: m.staleness_max,
            stale_requests: m.stale_requests,
            train_retries,
            trainer_fault_secs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;

    fn quick_cfg(warm: bool, iters: usize) -> TrainingConfig {
        TrainingConfig {
            iters,
            warm_start: warm,
            ..TrainingConfig::new(TaskPreset::Moonlight.workload_for_test())
        }
    }

    #[test]
    fn runs_iterations_and_learns() {
        let mut d = TrainingDriver::new(quick_cfg(true, 2));
        let sums = d.run().unwrap();
        assert_eq!(sums.len(), 2);
        // Iteration 0 is necessarily cold; iteration 1 consumes priors.
        assert!(!sums[0].warm);
        assert!(sums[1].warm);
        assert!(d.store().iterations() >= 2);
        assert_eq!(d.store().len(), d.cfg.workload.n_groups());
        assert_eq!(d.store().task(), d.cfg.workload.name);
        assert!(sums.iter().all(|s| s.tokens > 0));
        // The phase model adds training/update time on top of rollout.
        assert!(sums[0].iter_total_secs > sums[0].makespan_secs);
    }

    #[test]
    fn cold_runs_never_consume_the_store() {
        let mut d = TrainingDriver::new(quick_cfg(false, 2));
        let sums = d.run().unwrap();
        assert!(sums.iter().all(|s| !s.warm));
        // ...but the store still learned (for --save-ctx).
        assert!(!d.store().is_empty());
    }

    #[test]
    fn preloaded_store_warms_iteration_one_and_continues_epochs() {
        let mut cold = TrainingDriver::new(quick_cfg(true, 1));
        cold.run().unwrap();
        let store = cold.into_store();
        // Total-count semantics: the store already observed 1 epoch, so
        // `iters: 2` runs exactly one more (epoch 1).
        let mut d =
            TrainingDriver::with_store(quick_cfg(true, 2), store).unwrap();
        assert_eq!(d.next_epoch(), 1, "resume must not replay epoch 0");
        let sums = d.run().unwrap();
        assert_eq!(sums.len(), 1);
        assert!(sums[0].warm, "loaded store must warm the first iteration");
        assert_eq!(sums[0].iter, 1);
    }

    #[test]
    fn run_counts_total_epochs_not_additional_ones() {
        let mut d = TrainingDriver::new(quick_cfg(true, 2));
        d.run().unwrap();
        assert_eq!(d.history().len(), 2);
        // Already at the configured total: run() is a no-op…
        assert!(d.run().unwrap().is_empty());
        assert_eq!(d.history().len(), 2);
        // …and run_to past it continues the epoch sequence.
        let more = d.run_to(3).unwrap();
        assert_eq!(more.len(), 1);
        assert_eq!(more[0].iter, 2);
    }

    #[test]
    fn async_lag_zero_matches_sync_history_byte_for_byte() {
        let history_json = |mode: TrainingMode| {
            let cfg = TrainingConfig {
                mode,
                ..quick_cfg(true, 3)
            };
            let mut d = TrainingDriver::new(cfg);
            d.run().unwrap();
            Json::Arr(d.history().iter().map(|s| s.to_json()).collect())
                .to_string()
        };
        assert_eq!(
            history_json(TrainingMode::Sync),
            history_json(TrainingMode::Async { lag: 0 }),
            "lag 0 must reproduce the synchronous pipeline byte-identically"
        );
    }

    #[test]
    fn overlap_modes_pipeline_epochs_and_bound_staleness() {
        let run = |mode: TrainingMode| {
            let cfg = TrainingConfig {
                mode,
                ..quick_cfg(true, 3)
            };
            let mut d = TrainingDriver::new(cfg);
            d.run().unwrap()
        };
        let sync = run(TrainingMode::Sync);
        let hybrid = run(TrainingMode::Hybrid);
        let deep = run(TrainingMode::Async { lag: 2 });
        for k in 1..3 {
            // Overlap starts rollouts before the previous update lands…
            assert!(
                hybrid[k].rollout_start_secs < sync[k].rollout_start_secs,
                "epoch {k} must start early under hybrid overlap"
            );
            // …with off-policy lag bounded by the mode.
            assert!(hybrid[k].staleness_max <= 1);
            assert!(deep[k].staleness_max <= 2);
        }
        // Version stamping never perturbs rollout dynamics: per-epoch
        // makespans are identical, overlap only shifts them earlier on
        // the pipeline clock, so the pipeline finishes strictly sooner.
        assert_eq!(sync[2].makespan_secs, hybrid[2].makespan_secs);
        assert!(hybrid[2].update_land_secs < sync[2].update_land_secs);
        assert!(
            hybrid.iter().map(|s| s.stale_requests).sum::<u64>() > 0,
            "overlapped rollouts must see mid-stream version bumps"
        );
        assert!(sync.iter().all(|s| s.stale_requests == 0));
    }

    #[test]
    fn trainer_faults_shift_update_landings_and_count_retries() {
        use crate::sim::faults::FaultEvent;
        let base = {
            let mut d = TrainingDriver::new(quick_cfg(true, 3));
            d.run().unwrap()
        };
        // Script against the fault-free pipeline clock: a stall inside
        // iteration 1's train step and a crash redoing iteration 2's.
        let stall_at = base[1].update_land_secs - 0.5 * base[1].train_secs;
        let plan = FaultPlan::new()
            .at(
                stall_at,
                FaultEvent::TrainerStall {
                    at: stall_at,
                    secs: 30.0,
                },
            )
            .at(2.0, FaultEvent::TrainerCrash { at_iter: 2 })
            .sorted();
        let cfg = TrainingConfig {
            trainer_faults: plan,
            ..quick_cfg(true, 3)
        };
        let mut d = TrainingDriver::new(cfg);
        let faulted = d.run().unwrap();
        // Rollouts are untouched (sync: faults only delay the trainer)…
        for k in 0..3 {
            assert_eq!(faulted[k].makespan_secs, base[k].makespan_secs);
        }
        // …iteration 1 absorbs the stall (up to walker float
        // reassociation)…
        assert!((faulted[1].trainer_fault_secs - 30.0).abs() < 1e-6);
        assert!(
            (faulted[1].update_land_secs
                - (base[1].update_land_secs + 30.0))
                .abs()
                < 1e-6
        );
        assert!(faulted[1].iter_total_secs > base[1].iter_total_secs);
        // …and iteration 2 redoes its full train step once, on top of
        // the 30s the pipeline is already running late.
        assert_eq!(faulted[2].train_retries, 1);
        let redo = faulted[2].train_secs + faulted[2].weight_update_secs;
        assert!((faulted[2].trainer_fault_secs - redo).abs() < 1e-6);
        assert_eq!(faulted[0].train_retries, 0);
        assert_eq!(faulted[0].trainer_fault_secs, 0.0);
    }

    #[test]
    fn trainer_faults_preserve_lag_zero_sync_identity() {
        use crate::sim::faults::FaultEvent;
        let plan = FaultPlan::new()
            .at(
                0.0,
                FaultEvent::TrainerSlowdown {
                    factor: 3.0,
                    from: 0.0,
                    until: 1.0e9,
                },
            )
            .at(1.0, FaultEvent::TrainerCrash { at_iter: 1 })
            .sorted();
        let history_json = |mode: TrainingMode| {
            let cfg = TrainingConfig {
                mode,
                trainer_faults: plan.clone(),
                ..quick_cfg(true, 3)
            };
            let mut d = TrainingDriver::new(cfg);
            d.run().unwrap();
            Json::Arr(d.history().iter().map(|s| s.to_json()).collect())
                .to_string()
        };
        assert_eq!(
            history_json(TrainingMode::Sync),
            history_json(TrainingMode::Async { lag: 0 }),
            "lag 0 must stay byte-identical to sync under trainer faults"
        );
    }

    #[test]
    fn overlap_hides_trainer_hiccups_that_stall_sync() {
        use crate::sim::faults::FaultEvent;
        // A stall early in iteration 0's train step: sync serializes the
        // delay into every later epoch's start; hybrid keeps rolling out
        // epoch 1 while the stalled trainer catches up.
        let probe = {
            let mut d = TrainingDriver::new(quick_cfg(true, 1));
            d.run().unwrap()
        };
        let at = probe[0].rollout_start_secs
            + probe[0].makespan_secs
            + 0.25 * probe[0].train_secs;
        let plan = FaultPlan::new()
            .at(at, FaultEvent::TrainerStall { at, secs: 40.0 })
            .sorted();
        let run = |mode: TrainingMode| {
            let cfg = TrainingConfig {
                mode,
                trainer_faults: plan.clone(),
                ..quick_cfg(true, 2)
            };
            let mut d = TrainingDriver::new(cfg);
            d.run().unwrap()
        };
        let sync = run(TrainingMode::Sync);
        let hybrid = run(TrainingMode::Hybrid);
        assert!((sync[0].trainer_fault_secs - 40.0).abs() < 1e-6);
        // Sync pushes epoch 1's rollout start out by the stall; hybrid
        // started it before the stalled update landed.
        assert!(
            hybrid[1].rollout_start_secs
                < sync[1].rollout_start_secs,
            "hybrid must start epoch 1 before sync's stalled update lands"
        );
        assert!(hybrid[1].update_land_secs < sync[1].update_land_secs);
    }

    #[test]
    fn summary_json_round_trips_exactly() {
        let mut d = TrainingDriver::new(quick_cfg(true, 2));
        for s in d.run().unwrap() {
            let j = s.to_json();
            let back = IterationSummary::from_json(
                &Json::parse(&j.to_string()).unwrap(),
            )
            .unwrap();
            // Exact equality (floats included): shortest-roundtrip
            // printing makes the JSON hop lossless.
            assert_eq!(back, s);
        }
    }

    #[test]
    fn summary_from_json_rejects_bad_fields() {
        let s = TrainingDriver::new(quick_cfg(true, 1))
            .run_iteration(0)
            .unwrap();
        let Json::Obj(o) = s.to_json() else { unreachable!() };
        for key in o.keys() {
            let mut broken = o.clone();
            broken.insert(key.clone(), Json::Null);
            let e = IterationSummary::from_json(&Json::Obj(broken))
                .unwrap_err()
                .to_string();
            assert!(e.contains(key.as_str()), "{key}: {e}");
        }
        assert!(IterationSummary::from_json(&Json::Arr(vec![])).is_err());
    }

    #[test]
    fn resumed_run_matches_uninterrupted_history_exactly() {
        let cfg = TrainingConfig {
            drift: 0.1,
            ..quick_cfg(true, 4)
        };
        let mut full = TrainingDriver::new(cfg.clone());
        full.run().unwrap();

        // Interrupt after 2 iterations; round-trip state through JSON
        // the way a checkpoint does.
        let mut part = TrainingDriver::new(cfg.clone());
        part.run_iteration(0).unwrap();
        part.run_iteration(1).unwrap();
        let history: Vec<IterationSummary> = part
            .history()
            .iter()
            .map(|s| {
                IterationSummary::from_json(
                    &Json::parse(&s.to_json().to_string()).unwrap(),
                )
                .unwrap()
            })
            .collect();
        let store = crate::iteration::ContextStore::from_json(
            &Json::parse(&part.into_store().to_json().to_string()).unwrap(),
        )
        .unwrap();

        let mut resumed =
            TrainingDriver::with_resume(cfg, store, history).unwrap();
        assert_eq!(resumed.next_epoch(), 2);
        resumed.run_iteration(2).unwrap();
        resumed.run_iteration(3).unwrap();
        assert_eq!(resumed.history(), full.history());
    }

    #[test]
    fn with_resume_rejects_inconsistent_history() {
        let mut d = TrainingDriver::new(quick_cfg(true, 2));
        let sums = d.run().unwrap();
        let store = d.into_store();
        // One summary short of the store's two observed iterations.
        let e = TrainingDriver::with_resume(
            quick_cfg(true, 2),
            store,
            sums[..1].to_vec(),
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("summaries"), "{e}");
    }

    #[test]
    fn observer_does_not_change_the_summary() {
        let mut plain = TrainingDriver::new(quick_cfg(true, 1));
        let a = plain.run_iteration(0).unwrap();
        let mut observed = TrainingDriver::new(quick_cfg(true, 1));
        let mux = crate::rollout::EventMux::new();
        let b = observed
            .run_iteration_observed(0, Some(Box::new(mux.clone())))
            .unwrap();
        assert_eq!(a, b);
        assert_eq!(mux.counts().tokens, b.tokens);
    }

    #[test]
    fn with_store_rejects_mismatched_fingerprints() {
        let mut d = TrainingDriver::new(quick_cfg(true, 1));
        d.run().unwrap();
        let store = d.into_store();
        // Different seed → different prompt identity per group id.
        let other = TrainingConfig {
            seed: 7,
            ..quick_cfg(true, 1)
        };
        let e = TrainingDriver::with_store(other, store)
            .err()
            .expect("mismatched seed must be rejected")
            .to_string();
        assert!(e.contains("fingerprint"), "{e}");
    }
}
