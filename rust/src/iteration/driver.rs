//! The multi-iteration training driver.
//!
//! [`TrainingDriver`] turns the single-shot rollout simulator into a
//! multi-epoch synchronous-RL system: each iteration re-samples the same
//! prompt set with configurable length drift
//! ([`crate::workload::generate_epoch`]), runs it through one
//! [`crate::rollout::RolloutSession`], folds the finished lengths back
//! into the [`ContextStore`], and — when warm starting is enabled —
//! seeds the next iteration's context manager and grouped-SD state from
//! the store. Training and weight-update phase times come from the
//! calibrated [`crate::rl::PhaseModel`], so each
//! [`IterationSummary`] reports the full iteration wall, not just the
//! rollout.
//!
//! Everything is deterministic in the config: two drivers with the same
//! [`TrainingConfig`] produce bit-identical summaries.

use anyhow::Result;

use crate::config::{SystemConfig, WorkloadConfig};
use crate::rl::PhaseModel;
use crate::rollout::session::RolloutReport;
use crate::rollout::RolloutSession;
use crate::workload::generate_epoch;

use super::store::{ContextStore, ContextStoreConfig};

/// Configuration of one multi-iteration training run.
#[derive(Debug, Clone)]
pub struct TrainingConfig {
    pub workload: WorkloadConfig,
    pub system: SystemConfig,
    /// Registry name of the scheduling policy (e.g. `"seer"`).
    pub scheduler: String,
    /// Registry name of the SD strategy (e.g. `"grouped-cst"`).
    pub sd: String,
    /// GRPO iterations (epochs) to run.
    pub iters: usize,
    pub seed: u64,
    /// Per-epoch length drift (log-normal sigma); 0 = identical epochs.
    pub drift: f64,
    /// Consume the context store's priors from iteration 2 on. The store
    /// *learns* either way; cold runs just never read it back.
    pub warm_start: bool,
    pub store: ContextStoreConfig,
}

impl TrainingConfig {
    pub fn new(workload: WorkloadConfig) -> Self {
        TrainingConfig {
            workload,
            system: SystemConfig::default(),
            scheduler: "seer".to_string(),
            sd: "grouped-cst".to_string(),
            iters: 3,
            seed: 42,
            drift: 0.05,
            warm_start: true,
            store: ContextStoreConfig::default(),
        }
    }
}

/// Per-iteration metrics of one training run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IterationSummary {
    pub iter: usize,
    /// Whether this iteration consumed warm priors from the store.
    pub warm: bool,
    pub makespan_secs: f64,
    /// p99 request finish time within the iteration — the long-tail
    /// latency metric the cross-iteration store targets.
    pub p99_finish_secs: f64,
    /// Time spent solely on the last 10% of requests (paper §4.2.2).
    pub tail_secs: f64,
    pub throughput_tok_s: f64,
    pub tokens: u64,
    pub preemptions: u64,
    pub migrations: u64,
    /// Modeled training / weight-update phase times (Table 1 model).
    pub train_secs: f64,
    pub weight_update_secs: f64,
    /// Full iteration wall: rollout + training + weight update.
    pub iter_total_secs: f64,
}

/// Drives N GRPO iterations through the session layer, threading the
/// cross-iteration [`ContextStore`] between them.
pub struct TrainingDriver {
    cfg: TrainingConfig,
    store: ContextStore,
    history: Vec<IterationSummary>,
    /// Epoch index the next [`run_iteration`](Self::run_iteration) via
    /// [`run`](Self::run) will use. Starts at `store.iterations()` so a
    /// resumed driver *continues* the drift sequence instead of
    /// replaying already-observed epochs into the decayed statistics.
    next_epoch: usize,
}

impl TrainingDriver {
    pub fn new(cfg: TrainingConfig) -> Self {
        let store = ContextStore::with_config(cfg.store);
        Self::build(cfg, store)
    }

    /// Resume from a previously saved store (`seer train --load-ctx`):
    /// the first iteration already runs warm, and epoch numbering
    /// continues from where the saved run stopped. Errors when the
    /// store's fingerprint (task, seed, group count) does not match the
    /// config — group ids only name the same prompt for the same
    /// workload, so mismatched priors would be silently wrong.
    pub fn with_store(cfg: TrainingConfig, store: ContextStore) -> Result<Self> {
        if !store.task().is_empty() {
            if store.task() != cfg.workload.name || store.seed() != cfg.seed {
                anyhow::bail!(
                    "context store fingerprint (task '{}', seed {}) does \
                     not match the training config (task '{}', seed {})",
                    store.task(),
                    store.seed(),
                    cfg.workload.name,
                    cfg.seed
                );
            }
            if store.len() != cfg.workload.n_groups() {
                anyhow::bail!(
                    "context store has {} groups but the workload has {} \
                     (different scale?)",
                    store.len(),
                    cfg.workload.n_groups()
                );
            }
        }
        Ok(Self::build(cfg, store))
    }

    fn build(cfg: TrainingConfig, store: ContextStore) -> Self {
        TrainingDriver {
            cfg,
            next_epoch: store.iterations() as usize,
            store,
            history: Vec::new(),
        }
    }

    /// Epoch index the next driven iteration will run.
    pub fn next_epoch(&self) -> usize {
        self.next_epoch
    }

    pub fn store(&self) -> &ContextStore {
        &self.store
    }

    /// Consume the driver, handing back the store (for `--save-ctx`).
    pub fn into_store(self) -> ContextStore {
        self.store
    }

    pub fn history(&self) -> &[IterationSummary] {
        &self.history
    }

    /// Run one iteration (epoch `iter`), returning its summary.
    pub fn run_iteration(&mut self, iter: usize) -> Result<IterationSummary> {
        let cfg = &self.cfg;
        let w = generate_epoch(&cfg.workload, cfg.seed, iter as u64, cfg.drift);
        let mut builder = RolloutSession::builder()
            .workload(cfg.workload.clone())
            .system(cfg.system.clone())
            .scheduler(&cfg.scheduler)
            .sd(&cfg.sd)
            .seed(cfg.seed)
            .groups(w.groups);
        let warm = cfg.warm_start && !self.store.is_empty();
        if warm {
            builder = builder.context_store(&self.store);
        }
        let report = builder.run()?;
        let summary = self.summarize(iter, warm, &report);
        self.store
            .set_fingerprint(self.cfg.workload.name, self.cfg.seed);
        self.store.observe_report(&report);
        self.history.push(summary);
        self.next_epoch = iter + 1;
        Ok(summary)
    }

    /// Run all configured iterations, continuing the epoch sequence.
    pub fn run(&mut self) -> Result<Vec<IterationSummary>> {
        let start = self.history.len();
        for _ in 0..self.cfg.iters {
            self.run_iteration(self.next_epoch)?;
        }
        Ok(self.history[start..].to_vec())
    }

    fn summarize(
        &self,
        iter: usize,
        warm: bool,
        report: &RolloutReport,
    ) -> IterationSummary {
        let m = &report.metrics;
        let phases = PhaseModel::for_workload(&self.cfg.workload)
            .split(m.makespan, m.tokens_generated);
        IterationSummary {
            iter,
            warm,
            makespan_secs: m.makespan.as_secs_f64(),
            p99_finish_secs: m.finish_percentile(99.0),
            tail_secs: m.tail_time(0.10).as_secs_f64(),
            throughput_tok_s: m.throughput(),
            tokens: m.tokens_generated,
            preemptions: m.preemptions,
            migrations: m.migrations,
            train_secs: phases.training.as_secs_f64(),
            weight_update_secs: phases.weight_update.as_secs_f64(),
            iter_total_secs: phases.total().as_secs_f64(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;

    fn quick_cfg(warm: bool, iters: usize) -> TrainingConfig {
        TrainingConfig {
            iters,
            warm_start: warm,
            ..TrainingConfig::new(TaskPreset::Moonlight.workload_for_test())
        }
    }

    #[test]
    fn runs_iterations_and_learns() {
        let mut d = TrainingDriver::new(quick_cfg(true, 2));
        let sums = d.run().unwrap();
        assert_eq!(sums.len(), 2);
        // Iteration 0 is necessarily cold; iteration 1 consumes priors.
        assert!(!sums[0].warm);
        assert!(sums[1].warm);
        assert!(d.store().iterations() >= 2);
        assert_eq!(d.store().len(), d.cfg.workload.n_groups());
        assert_eq!(d.store().task(), d.cfg.workload.name);
        assert!(sums.iter().all(|s| s.tokens > 0));
        // The phase model adds training/update time on top of rollout.
        assert!(sums[0].iter_total_secs > sums[0].makespan_secs);
    }

    #[test]
    fn cold_runs_never_consume_the_store() {
        let mut d = TrainingDriver::new(quick_cfg(false, 2));
        let sums = d.run().unwrap();
        assert!(sums.iter().all(|s| !s.warm));
        // ...but the store still learned (for --save-ctx).
        assert!(!d.store().is_empty());
    }

    #[test]
    fn preloaded_store_warms_iteration_one_and_continues_epochs() {
        let mut cold = TrainingDriver::new(quick_cfg(true, 1));
        cold.run().unwrap();
        let store = cold.into_store();
        let mut d =
            TrainingDriver::with_store(quick_cfg(true, 1), store).unwrap();
        assert_eq!(d.next_epoch(), 1, "resume must not replay epoch 0");
        let sums = d.run().unwrap();
        assert!(sums[0].warm, "loaded store must warm the first iteration");
        assert_eq!(sums[0].iter, 1);
    }

    #[test]
    fn with_store_rejects_mismatched_fingerprints() {
        let mut d = TrainingDriver::new(quick_cfg(true, 1));
        d.run().unwrap();
        let store = d.into_store();
        // Different seed → different prompt identity per group id.
        let other = TrainingConfig {
            seed: 7,
            ..quick_cfg(true, 1)
        };
        let e = TrainingDriver::with_store(other, store)
            .err()
            .expect("mismatched seed must be rejected")
            .to_string();
        assert!(e.contains("fingerprint"), "{e}");
    }
}
