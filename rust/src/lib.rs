//! # seer — synchronous LLM RL rollout with online context learning
//!
//! Reproduction of *"Seer: Online Context Learning for Fast Synchronous LLM
//! Reinforcement Learning"* (CS.DC 2025) as a three-layer Rust + JAX +
//! Pallas stack. This crate is layer 3: the coordinator that owns the
//! rollout event loop, request/group/chunk state, the global KVCache pool,
//! context-aware scheduling, and the distributed grouped draft server
//! (DGDS). Layers 2 (JAX model) and 1 (Pallas kernels) are AOT-compiled to
//! HLO-text artifacts at build time and executed through [`runtime`];
//! Python never runs on the request path.
//!
//! The front door is the unified session layer — one builder in front of
//! both execution substrates:
//!
//! ```
//! use seer::config::TaskPreset;
//! use seer::rollout::RolloutSession;
//!
//! # fn main() -> anyhow::Result<()> {
//! let report = RolloutSession::builder()
//!     .workload(TaskPreset::Moonlight.workload_for_test())
//!     .scheduler("seer")          // resolved via the policy registry
//!     .sd("grouped-cst")          // grouped speculative decoding
//!     .seed(42)
//!     .run()?;
//! assert!(report.metrics.throughput() > 0.0);
//! println!(
//!     "{} requests, {:.0} tok/s",
//!     report.sequences.len(),
//!     report.metrics.throughput()
//! );
//! # Ok(())
//! # }
//! ```
//!
//! Multi-iteration training threads a cross-iteration
//! [`iteration::ContextStore`] between rollouts via
//! [`iteration::TrainingDriver`] (CLI: `seer train`), so iteration ≥ 2
//! warm-starts the context manager and grouped-SD state instead of
//! re-paying the cold-start probe tax (see ARCHITECTURE.md).
//!
//! Module map (see ARCHITECTURE.md at the repository root for the full
//! inventory and the event flow of one divided-rollout chunk):
//!
//! * [`rollout`] — **the front door**: the unified session layer.
//!   [`rollout::RolloutSession`] is a builder over the
//!   [`rollout::RolloutBackend`] trait, implemented by both execution
//!   substrates — the discrete-event cluster simulator and the
//!   real-model engine — and every run yields one unified
//!   [`rollout::RolloutReport`]. Policies resolve by name through
//!   [`rollout::PolicyRegistry`]; request lifecycle streams to
//!   [`rollout::RolloutObserver`]s. The CLI, experiments, benches, and
//!   the RL loop all construct rollouts here and nowhere else.
//! * [`sim`] — deterministic discrete-event core (clock, event queue,
//!   RNG, and [`sim::faults`] fault & elasticity scripts: instance
//!   crashes, stragglers, recoveries, scale events and request aborts
//!   replayed at exact virtual timestamps).
//! * [`util`] — in-tree substrates for the offline environment: JSON
//!   parser/serializer, CLI, stats helpers, property-test harness.
//! * [`config`] — system/workload configuration and the paper's Table 3
//!   task presets.
//! * [`workload`] — group-correlated length mixtures and token streams,
//!   plus the id types (`RequestId`/`GroupId`/`InstanceId`) every layer
//!   speaks.
//! * [`kvcache`] — paged per-instance allocator + Mooncake-like global pool.
//! * [`engine`] — the simulated substrate: vLLM-like inference instances
//!   with continuous batching, preemption and a calibrated step-time cost
//!   model, driven by `engine::cluster::ClusterSim`.
//! * [`coordinator`] — request buffer, context manager, divided rollout.
//! * [`scheduler`] — pluggable policies: Seer (paper Alg. 2) and baselines
//!   (veRL group-RR, StreamRL-Oracle, Partial Rollout, No-Context,
//!   Oracle); constructed by registry name.
//! * [`spec`] — CST (suffix-automaton implementation), DGDS, MBA adaptive
//!   speculation (paper Alg. 1), multi-path drafting, vanilla SD baselines.
//! * [`metrics`] — timelines, histograms, tail-time accounting; consumes
//!   the session event stream as an ordinary observer
//!   ([`metrics::EventCounts`]).
//! * [`runtime`] — PJRT artifact loading/execution via the `xla` crate.
//! * [`rl`] — the synchronous GRPO loop: rollout (through a real-backend
//!   session) → reward → advantage → train_step → weight update.
//! * [`iteration`] — cross-iteration context: the [`iteration::ContextStore`]
//!   (decayed per-group length/token statistics, JSON-serializable) and
//!   the [`iteration::TrainingDriver`] multi-epoch loop that warm-starts
//!   every layer above from it.
//! * [`sweep`] — the parallel deterministic study layer:
//!   [`sweep::SweepSpec`] grids (scheduler × seed × scale × fault plan ×
//!   drift) executed by [`sweep::SweepRunner`] across std worker threads
//!   with order-independent aggregation (same spec ⇒ byte-identical
//!   report JSON at any thread count), paired per-seed statistics with
//!   seeded-bootstrap CIs, and the `BENCH_rollout.json` perf baselines.
//! * [`experiments`] — regenerates every table and figure of the paper's
//!   evaluation section, measuring through sessions (multi-run
//!   experiments fan out through the sweep runner).
//! * [`serve`] — the persistent control plane (`seer serve`): a TCP
//!   daemon with a job API over line-delimited JSON, per-tenant
//!   admission control, live NDJSON event streaming through
//!   [`rollout::EventMux`], and crash-durable train-job checkpoints
//!   that a restarted daemon resumes byte-identically.

pub mod config;
pub mod coordinator;
pub mod engine;
pub mod experiments;
pub mod iteration;
pub mod kvcache;
pub mod metrics;
pub mod rl;
pub mod rollout;
pub mod runtime;
pub mod scheduler;
pub mod serve;
pub mod sim;
pub mod spec;
pub mod sweep;
pub mod util;
pub mod workload;

pub use config::{SystemConfig, TaskPreset, WorkloadConfig};
pub use sim::clock::SimTime;
