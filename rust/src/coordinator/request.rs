//! Request lifecycle state.
//!
//! Divided rollout (paper §3.2) makes the schedulable unit a *chunk*: a
//! bounded lease of generation progress on one instance. A request cycles
//! Waiting → Running(chunk on instance i) → Paused (KV parked in the
//! global pool) → Running(chunk on instance j) → ... → Finished. Systems
//! without divided rollout (veRL/StreamRL baselines) simply use one
//! whole-request chunk and never enter Paused except via preemption.

use crate::sim::clock::SimTime;
use crate::workload::{GroupId, InstanceId, RequestId, RequestSpec};

/// Where a request's KVCache currently lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvLocation {
    /// Nothing materialized (fresh request, or dropped by preemption).
    Nowhere,
    /// Resident on an instance's HBM.
    Instance(InstanceId),
    /// Parked in the global Mooncake-like pool.
    Pool,
}

/// Scheduling phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// In the request buffer, never run or between chunks.
    Waiting,
    /// Actively generating on an instance.
    Running(InstanceId),
    /// Done (reached its true generation length).
    Finished,
}

/// Full per-request coordinator state.
#[derive(Debug, Clone)]
pub struct ReqState {
    pub spec: RequestSpec,
    pub phase: Phase,
    /// Tokens generated so far.
    pub generated: u32,
    /// KV tokens currently materialized somewhere (prompt + generated, or
    /// 0 after a preemption drop).
    pub kv_tokens: u64,
    pub kv_location: KvLocation,
    /// True if the next time this request runs it must recompute its KV
    /// from scratch (it was preempted without pool backing).
    pub needs_reprefill: bool,
    /// Tokens still allowed in the current chunk lease (Running only).
    pub chunk_remaining: u32,
    /// Designated speculative probe of its group (paper §3.3).
    pub is_probe: bool,
    /// Terminated by a fault-script abort rather than by reaching its
    /// true length. Aborted requests sit in `Phase::Finished` (the
    /// lifecycle is over) but are excluded from completion accounting.
    pub aborted: bool,
    pub first_scheduled: Option<SimTime>,
    pub finished_at: Option<SimTime>,
    /// Number of chunks this request has been scheduled as.
    pub chunks_run: u32,
    /// Number of times preempted.
    pub preemptions: u32,
    /// Times its KV moved through the pool to a different instance.
    pub migrations: u32,
}

impl ReqState {
    pub fn new(spec: RequestSpec, is_probe: bool) -> Self {
        ReqState {
            spec,
            phase: Phase::Waiting,
            generated: 0,
            kv_tokens: 0,
            kv_location: KvLocation::Nowhere,
            needs_reprefill: true,
            chunk_remaining: 0,
            is_probe,
            aborted: false,
            first_scheduled: None,
            finished_at: None,
            chunks_run: 0,
            preemptions: 0,
            migrations: 0,
        }
    }

    pub fn id(&self) -> RequestId {
        self.spec.id
    }

    pub fn group(&self) -> GroupId {
        self.spec.group
    }

    /// Tokens left to generate (ground truth — only the engine may call
    /// this; schedulers other than Oracle must not).
    pub fn remaining_true(&self) -> u32 {
        self.spec.gen_len.saturating_sub(self.generated)
    }

    pub fn is_finished(&self) -> bool {
        matches!(self.phase, Phase::Finished)
    }

    pub fn is_running(&self) -> bool {
        matches!(self.phase, Phase::Running(_))
    }

    /// KV tokens the request will need on an instance to run a chunk of
    /// `chunk` tokens: existing KV plus new growth (and prompt, if the KV
    /// must be rebuilt).
    pub fn kv_demand(&self, chunk: u32) -> u64 {
        let base = if self.needs_reprefill {
            self.spec.prompt_len as u64 + self.generated as u64
        } else {
            self.kv_tokens
        };
        base + chunk as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> RequestSpec {
        RequestSpec {
            id: RequestId(1),
            group: GroupId(0),
            prompt_len: 100,
            gen_len: 1000,
        }
    }

    #[test]
    fn new_request_needs_prefill() {
        let r = ReqState::new(spec(), true);
        assert!(r.needs_reprefill);
        assert_eq!(r.kv_location, KvLocation::Nowhere);
        assert_eq!(r.remaining_true(), 1000);
        assert!(r.is_probe);
    }

    #[test]
    fn kv_demand_accounts_for_reprefill() {
        let mut r = ReqState::new(spec(), false);
        r.generated = 400;
        // Preempted state: KV dropped, must rebuild prompt+generated.
        r.needs_reprefill = true;
        r.kv_tokens = 0;
        assert_eq!(r.kv_demand(256), 100 + 400 + 256);
        // Paused-with-pool state: KV intact.
        r.needs_reprefill = false;
        r.kv_tokens = 500;
        assert_eq!(r.kv_demand(256), 756);
    }
}
