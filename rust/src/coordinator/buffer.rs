//! The global request buffer (paper Fig. 5): the coordinator's single
//! source of truth for every request in the iteration, with index
//! structures for the waiting set.
//!
//! Hot-path accounting is O(1): the waiting set is a dense bitset over
//! the contiguous id space (ascending iteration order, same as the
//! ordered set it replaced) and the lifecycle tallies (`n_finished`,
//! `n_running`, `n_aborted`) are counters maintained at the mark-
//! transitions — the event loop's `done()` check reads them every event,
//! so they must never fall back to an O(n) scan. The scan versions
//! survive as `*_scan` cross-checks, asserted against the counters in
//! [`RequestBuffer::check_invariants`] (the property harness runs that
//! at every telemetry sample).

use crate::util::idset::IdBitSet;
use crate::workload::{GroupSpec, InstanceId, RequestId};

use super::request::{Phase, ReqState};

/// All requests of one rollout iteration, indexed by `RequestId`
/// (contiguous from 0), plus the waiting set.
#[derive(Debug, Default)]
pub struct RequestBuffer {
    reqs: Vec<ReqState>,
    waiting: IdBitSet,
    /// Requests in `Phase::Running` (counter; see module docs).
    n_running: usize,
    /// Requests in `Phase::Finished`, aborted included (counter).
    n_finished: usize,
    /// Requests terminated by a scripted abort (counter; subset of
    /// `n_finished`).
    n_aborted: usize,
}

impl RequestBuffer {
    /// Build from the iteration's groups. The *first* request of each
    /// group is designated its speculative probe (paper §3.3).
    pub fn from_groups(groups: &[GroupSpec]) -> Self {
        let mut reqs: Vec<ReqState> = Vec::new();
        for g in groups {
            for (i, r) in g.requests.iter().enumerate() {
                debug_assert_eq!(
                    r.id.0 as usize,
                    reqs.len(),
                    "request ids must be contiguous"
                );
                reqs.push(ReqState::new(r.clone(), i == 0));
            }
        }
        let mut waiting = IdBitSet::with_capacity(reqs.len());
        for r in &reqs {
            waiting.insert(r.id().0);
        }
        RequestBuffer {
            reqs,
            waiting,
            n_running: 0,
            n_finished: 0,
            n_aborted: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.reqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.reqs.is_empty()
    }

    pub fn get(&self, id: RequestId) -> &ReqState {
        &self.reqs[id.0 as usize]
    }

    pub fn get_mut(&mut self, id: RequestId) -> &mut ReqState {
        &mut self.reqs[id.0 as usize]
    }

    pub fn all(&self) -> &[ReqState] {
        &self.reqs
    }

    /// Waiting requests in ascending id order (the order every
    /// policy's FCFS tie-breaks are defined over).
    pub fn waiting(&self) -> impl Iterator<Item = RequestId> + '_ {
        self.waiting.iter().map(RequestId)
    }

    pub fn n_waiting(&self) -> usize {
        self.waiting.len()
    }

    /// Finished requests, aborted included — O(1).
    pub fn n_finished(&self) -> usize {
        self.n_finished
    }

    /// Requests currently in `Phase::Running` — O(1).
    pub fn n_running(&self) -> usize {
        self.n_running
    }

    /// Requests terminated by a scripted abort — O(1).
    pub fn n_aborted(&self) -> usize {
        self.n_aborted
    }

    /// True when nothing is waiting and nothing is running — the event
    /// loop's per-event termination check, O(1).
    pub fn all_finished(&self) -> bool {
        self.waiting.is_empty() && self.n_running == 0
    }

    /// Scan-based `n_finished` (cross-check / bench reference only; the
    /// hot path must use the counter).
    pub fn n_finished_scan(&self) -> usize {
        self.reqs.iter().filter(|r| r.is_finished()).count()
    }

    /// Scan-based `n_running` (cross-check only).
    pub fn n_running_scan(&self) -> usize {
        self.reqs.iter().filter(|r| r.is_running()).count()
    }

    /// Scan-based `n_aborted` (cross-check only).
    pub fn n_aborted_scan(&self) -> usize {
        self.reqs.iter().filter(|r| r.aborted).count()
    }

    /// Transition a request out of the waiting set (being scheduled)
    /// without touching its phase. The driver uses
    /// [`mark_running`](Self::mark_running); this entry point exists for
    /// tests and benches that churn the waiting set directly.
    pub fn mark_scheduled(&mut self, id: RequestId) {
        let present = self.waiting.remove(id.0);
        debug_assert!(present, "scheduling non-waiting request {id:?}");
    }

    /// Waiting → Running(instance): leave the waiting set and take a
    /// placement. The counter-maintaining twin of the driver's old
    /// `phase = Running` + `mark_scheduled` pair — all phase writes go
    /// through the buffer so the O(1) tallies can't drift.
    pub fn mark_running(&mut self, id: RequestId, instance: InstanceId) {
        let r = &mut self.reqs[id.0 as usize];
        debug_assert!(
            matches!(r.phase, Phase::Waiting),
            "mark_running on non-waiting request {id:?}"
        );
        r.phase = Phase::Running(instance);
        self.n_running += 1;
        let present = self.waiting.remove(id.0);
        debug_assert!(present, "running non-waiting request {id:?}");
    }

    /// Return a request to the waiting set (chunk ended / preempted /
    /// drained by a fault).
    pub fn mark_waiting(&mut self, id: RequestId) {
        let r = &mut self.reqs[id.0 as usize];
        debug_assert!(!r.is_finished());
        if r.is_running() {
            self.n_running -= 1;
        }
        r.phase = Phase::Waiting;
        r.chunk_remaining = 0;
        self.waiting.insert(id.0);
    }

    /// Finalize a request.
    pub fn mark_finished(&mut self, id: RequestId) {
        let r = &mut self.reqs[id.0 as usize];
        // Hard assert (kept in release): double-finishing corrupts GRPO
        // group accounting downstream.
        assert!(!r.is_finished(), "double finish {id:?}");
        if r.is_running() {
            self.n_running -= 1;
        }
        r.phase = Phase::Finished;
        self.n_finished += 1;
        self.waiting.remove(id.0);
    }

    /// Terminate a request as *aborted* (fault script): the lifecycle
    /// ends like `mark_finished`, but the request is flagged so
    /// completion accounting excludes it.
    pub fn mark_aborted(&mut self, id: RequestId) {
        let r = &mut self.reqs[id.0 as usize];
        assert!(!r.is_finished(), "aborting finished request {id:?}");
        if r.is_running() {
            self.n_running -= 1;
        }
        r.phase = Phase::Finished;
        r.aborted = true;
        self.n_finished += 1;
        self.n_aborted += 1;
        self.waiting.remove(id.0);
    }

    /// Consistency check for the invariant tests: every request is in
    /// exactly one of {waiting set, running, finished}, and the O(1)
    /// lifecycle counters agree with a full phase scan.
    pub fn check_invariants(&self) {
        for r in &self.reqs {
            let in_waiting = self.waiting.contains(r.id().0);
            match r.phase {
                Phase::Waiting => {
                    assert!(in_waiting, "{:?} Waiting but not in set", r.id())
                }
                Phase::Running(_) | Phase::Finished => assert!(
                    !in_waiting,
                    "{:?} {:?} but still in waiting set",
                    r.id(),
                    r.phase
                ),
            }
            assert!(r.generated <= r.spec.gen_len, "overran true length");
            if r.aborted {
                assert!(
                    r.is_finished(),
                    "{:?} aborted but still live",
                    r.id()
                );
            }
        }
        // Counter-vs-scan equality: the O(1) tallies the event loop
        // trusts must match ground truth at all times.
        assert_eq!(
            self.n_finished,
            self.n_finished_scan(),
            "n_finished counter drifted from phase scan"
        );
        assert_eq!(
            self.n_running,
            self.n_running_scan(),
            "n_running counter drifted from phase scan"
        );
        assert_eq!(
            self.n_aborted,
            self.n_aborted_scan(),
            "n_aborted counter drifted from abort scan"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;
    use crate::workload::generate_iteration;

    fn buffer() -> RequestBuffer {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 1);
        RequestBuffer::from_groups(&w.groups)
    }

    #[test]
    fn probes_are_first_of_each_group() {
        let b = buffer();
        let probes: Vec<_> =
            b.all().iter().filter(|r| r.is_probe).collect();
        let cfg = TaskPreset::Moonlight.workload_for_test();
        assert_eq!(probes.len(), cfg.n_groups());
        // Exactly one probe per group.
        let mut groups: Vec<u32> = probes.iter().map(|r| r.group().0).collect();
        groups.dedup();
        assert_eq!(groups.len(), cfg.n_groups());
    }

    #[test]
    fn lifecycle_transitions() {
        let mut b = buffer();
        let id = b.all()[0].id();
        assert_eq!(b.n_waiting(), b.len());
        b.mark_scheduled(id);
        assert_eq!(b.n_waiting(), b.len() - 1);
        b.mark_waiting(id);
        assert_eq!(b.n_waiting(), b.len());
        b.mark_scheduled(id);
        b.mark_finished(id);
        assert_eq!(b.n_finished(), 1);
        b.check_invariants();
    }

    #[test]
    fn running_counter_follows_placements() {
        let mut b = buffer();
        let (a, c) = (b.all()[0].id(), b.all()[1].id());
        assert_eq!(b.n_running(), 0);
        assert!(!b.all_finished());
        b.mark_running(a, crate::workload::InstanceId(0));
        b.mark_running(c, crate::workload::InstanceId(1));
        assert_eq!(b.n_running(), 2);
        b.mark_waiting(a);
        assert_eq!(b.n_running(), 1);
        b.mark_finished(c);
        assert_eq!(b.n_running(), 0);
        assert_eq!(b.n_finished(), 1);
        b.check_invariants();
    }

    #[test]
    fn all_finished_is_counter_driven() {
        let cfg = crate::config::TaskPreset::Moonlight.workload_for_test();
        let mut small = cfg;
        small.reqs_per_iter = small.group_size;
        let w = generate_iteration(&small, 1);
        let mut b = RequestBuffer::from_groups(&w.groups);
        let ids: Vec<_> = b.all().iter().map(|r| r.id()).collect();
        for &id in &ids {
            b.mark_running(id, crate::workload::InstanceId(0));
        }
        assert!(!b.all_finished(), "running requests must block done()");
        for &id in &ids {
            b.mark_finished(id);
        }
        assert!(b.all_finished());
        b.check_invariants();
    }

    #[test]
    fn abort_lifecycle() {
        let mut b = buffer();
        let id = b.all()[0].id();
        b.mark_aborted(id);
        assert_eq!(b.n_waiting(), b.len() - 1);
        assert_eq!(b.n_aborted(), 1);
        // Aborted counts as phase-finished (the lifecycle is over)...
        assert_eq!(b.n_finished(), 1);
        // ...and is terminal.
        b.check_invariants();
    }

    #[test]
    #[should_panic(expected = "aborting finished request")]
    fn abort_after_finish_panics() {
        let mut b = buffer();
        let id = b.all()[0].id();
        b.mark_scheduled(id);
        b.mark_finished(id);
        b.mark_aborted(id);
    }

    #[test]
    #[should_panic(expected = "double finish")]
    fn double_finish_panics() {
        let mut b = buffer();
        let id = b.all()[0].id();
        b.mark_scheduled(id);
        b.mark_finished(id);
        b.mark_finished(id);
    }
}
