//! The global request buffer (paper Fig. 5): the coordinator's single
//! source of truth for every request in the iteration, with index
//! structures for the waiting set.

use std::collections::BTreeSet;

use crate::workload::{GroupSpec, RequestId};

use super::request::{Phase, ReqState};

/// All requests of one rollout iteration, indexed by `RequestId`
/// (contiguous from 0), plus the waiting set.
#[derive(Debug, Default)]
pub struct RequestBuffer {
    reqs: Vec<ReqState>,
    waiting: BTreeSet<RequestId>,
}

impl RequestBuffer {
    /// Build from the iteration's groups. The *first* request of each
    /// group is designated its speculative probe (paper §3.3).
    pub fn from_groups(groups: &[GroupSpec]) -> Self {
        let mut reqs: Vec<ReqState> = Vec::new();
        for g in groups {
            for (i, r) in g.requests.iter().enumerate() {
                debug_assert_eq!(
                    r.id.0 as usize,
                    reqs.len(),
                    "request ids must be contiguous"
                );
                reqs.push(ReqState::new(r.clone(), i == 0));
            }
        }
        let waiting = reqs.iter().map(|r| r.id()).collect();
        RequestBuffer { reqs, waiting }
    }

    pub fn len(&self) -> usize {
        self.reqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.reqs.is_empty()
    }

    pub fn get(&self, id: RequestId) -> &ReqState {
        &self.reqs[id.0 as usize]
    }

    pub fn get_mut(&mut self, id: RequestId) -> &mut ReqState {
        &mut self.reqs[id.0 as usize]
    }

    pub fn all(&self) -> &[ReqState] {
        &self.reqs
    }

    pub fn waiting(&self) -> impl Iterator<Item = RequestId> + '_ {
        self.waiting.iter().copied()
    }

    pub fn n_waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn n_finished(&self) -> usize {
        self.reqs.iter().filter(|r| r.is_finished()).count()
    }

    pub fn all_finished(&self) -> bool {
        self.waiting.is_empty() && self.reqs.iter().all(|r| !r.is_running())
    }

    /// Transition a request out of the waiting set (being scheduled).
    pub fn mark_scheduled(&mut self, id: RequestId) {
        let present = self.waiting.remove(&id);
        debug_assert!(present, "scheduling non-waiting request {id:?}");
    }

    /// Return a request to the waiting set (chunk ended / preempted).
    pub fn mark_waiting(&mut self, id: RequestId) {
        let r = self.get_mut(id);
        debug_assert!(!r.is_finished());
        r.phase = Phase::Waiting;
        r.chunk_remaining = 0;
        self.waiting.insert(id);
    }

    /// Finalize a request.
    pub fn mark_finished(&mut self, id: RequestId) {
        let r = self.get_mut(id);
        // Hard assert (kept in release): double-finishing corrupts GRPO
        // group accounting downstream.
        assert!(!r.is_finished(), "double finish {id:?}");
        r.phase = Phase::Finished;
        self.waiting.remove(&id);
    }

    /// Terminate a request as *aborted* (fault script): the lifecycle
    /// ends like `mark_finished`, but the request is flagged so
    /// completion accounting excludes it.
    pub fn mark_aborted(&mut self, id: RequestId) {
        let r = self.get_mut(id);
        assert!(!r.is_finished(), "aborting finished request {id:?}");
        r.phase = Phase::Finished;
        r.aborted = true;
        self.waiting.remove(&id);
    }

    pub fn n_aborted(&self) -> usize {
        self.reqs.iter().filter(|r| r.aborted).count()
    }

    /// Consistency check for the invariant tests: every request is in
    /// exactly one of {waiting set, running, finished}.
    pub fn check_invariants(&self) {
        for r in &self.reqs {
            let in_waiting = self.waiting.contains(&r.id());
            match r.phase {
                Phase::Waiting => {
                    assert!(in_waiting, "{:?} Waiting but not in set", r.id())
                }
                Phase::Running(_) | Phase::Finished => assert!(
                    !in_waiting,
                    "{:?} {:?} but still in waiting set",
                    r.id(),
                    r.phase
                ),
            }
            assert!(r.generated <= r.spec.gen_len, "overran true length");
            if r.aborted {
                assert!(
                    r.is_finished(),
                    "{:?} aborted but still live",
                    r.id()
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;
    use crate::workload::generate_iteration;

    fn buffer() -> RequestBuffer {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 1);
        RequestBuffer::from_groups(&w.groups)
    }

    #[test]
    fn probes_are_first_of_each_group() {
        let b = buffer();
        let probes: Vec<_> =
            b.all().iter().filter(|r| r.is_probe).collect();
        let cfg = TaskPreset::Moonlight.workload_for_test();
        assert_eq!(probes.len(), cfg.n_groups());
        // Exactly one probe per group.
        let mut groups: Vec<u32> = probes.iter().map(|r| r.group().0).collect();
        groups.dedup();
        assert_eq!(groups.len(), cfg.n_groups());
    }

    #[test]
    fn lifecycle_transitions() {
        let mut b = buffer();
        let id = b.all()[0].id();
        assert_eq!(b.n_waiting(), b.len());
        b.mark_scheduled(id);
        assert_eq!(b.n_waiting(), b.len() - 1);
        b.mark_waiting(id);
        assert_eq!(b.n_waiting(), b.len());
        b.mark_scheduled(id);
        b.mark_finished(id);
        assert_eq!(b.n_finished(), 1);
        b.check_invariants();
    }

    #[test]
    fn abort_lifecycle() {
        let mut b = buffer();
        let id = b.all()[0].id();
        b.mark_aborted(id);
        assert_eq!(b.n_waiting(), b.len() - 1);
        assert_eq!(b.n_aborted(), 1);
        // Aborted counts as phase-finished (the lifecycle is over)...
        assert_eq!(b.n_finished(), 1);
        // ...and is terminal.
        b.check_invariants();
    }

    #[test]
    #[should_panic(expected = "aborting finished request")]
    fn abort_after_finish_panics() {
        let mut b = buffer();
        let id = b.all()[0].id();
        b.mark_scheduled(id);
        b.mark_finished(id);
        b.mark_aborted(id);
    }

    #[test]
    #[should_panic(expected = "double finish")]
    fn double_finish_panics() {
        let mut b = buffer();
        let id = b.all()[0].id();
        b.mark_scheduled(id);
        b.mark_finished(id);
        b.mark_finished(id);
    }
}
