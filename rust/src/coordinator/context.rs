//! Context manager (paper §3.3): learns per-group output-length estimates
//! online from the speculative probe requests and finished siblings.
//!
//! Estimate semantics follow the paper exactly: a group with no finished
//! request is conservatively assumed to be a potential long-tail case
//! (estimate = generation-length upper bound); once requests finish, the
//! estimate is the maximum observed finished length, which converges to
//! the true group maximum from above-or-below as more siblings finish.

use std::collections::BTreeMap;

use crate::workload::{GroupId, GroupSpec};

#[derive(Debug, Clone, Copy)]
struct GroupCtx {
    /// Current length estimate (tokens).
    estimate: u32,
    /// Finished request count.
    finished: usize,
    /// Total requests in the group.
    size: usize,
    /// Scheduling credits served (for the starvation guard).
    served_chunks: u64,
}

/// Online group-length estimator.
#[derive(Debug, Default)]
pub struct ContextManager {
    groups: BTreeMap<GroupId, GroupCtx>,
    upper_bound: u32,
}

impl ContextManager {
    pub fn new(upper_bound: u32) -> Self {
        ContextManager {
            groups: BTreeMap::new(),
            upper_bound,
        }
    }

    pub fn init_groups(&mut self, groups: &[GroupSpec]) {
        self.groups.clear();
        for g in groups {
            self.groups.insert(
                g.id,
                GroupCtx {
                    estimate: self.upper_bound,
                    finished: 0,
                    size: g.requests.len(),
                    served_chunks: 0,
                },
            );
        }
    }

    /// UPDATEESTIMATE (paper Alg. 2 line 3): a request of `group`
    /// finished at `len` tokens.
    pub fn on_finished(&mut self, group: GroupId, len: u32) {
        let g = self
            .groups
            .get_mut(&group)
            .expect("finished request from unknown group");
        if g.finished == 0 {
            // First completion replaces the conservative upper bound.
            g.estimate = len;
        } else {
            g.estimate = g.estimate.max(len);
        }
        g.finished += 1;
        debug_assert!(g.finished <= g.size);
    }

    /// Current length estimate for LFS ordering.
    pub fn estimate(&self, group: GroupId) -> u32 {
        self.groups
            .get(&group)
            .map(|g| g.estimate)
            .unwrap_or(self.upper_bound)
    }

    /// True once at least one sibling finished (the estimate is "learned"
    /// rather than the conservative bound).
    pub fn has_signal(&self, group: GroupId) -> bool {
        self.groups.map_or_false(group, |g| g.finished > 0)
    }

    pub fn finished_count(&self, group: GroupId) -> usize {
        self.groups.get(&group).map(|g| g.finished).unwrap_or(0)
    }

    /// Record that a chunk of this group was scheduled (starvation guard
    /// bookkeeping).
    pub fn on_scheduled(&mut self, group: GroupId) {
        if let Some(g) = self.groups.get_mut(&group) {
            g.served_chunks += 1;
        }
    }

    /// The group with the fewest served chunks (ties by id) — the
    /// anti-starvation candidate.
    pub fn most_underserved(
        &self,
        candidates: impl Iterator<Item = GroupId>,
    ) -> Option<GroupId> {
        candidates.min_by_key(|g| {
            (
                self.groups.get(g).map(|c| c.served_chunks).unwrap_or(0),
                g.0,
            )
        })
    }
}

trait MapExt<K, V> {
    fn map_or_false(&self, k: K, f: impl Fn(&V) -> bool) -> bool;
}

impl<K: Ord, V> MapExt<K, V> for BTreeMap<K, V> {
    fn map_or_false(&self, k: K, f: impl Fn(&V) -> bool) -> bool {
        self.get(&k).map(f).unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{GroupSpec, RequestId, RequestSpec};

    fn group(id: u32, lens: &[u32]) -> GroupSpec {
        GroupSpec {
            id: GroupId(id),
            prompt_len: 10,
            requests: lens
                .iter()
                .enumerate()
                .map(|(i, &l)| RequestSpec {
                    id: RequestId(id * 100 + i as u32),
                    group: GroupId(id),
                    prompt_len: 10,
                    gen_len: l,
                })
                .collect(),
        }
    }

    #[test]
    fn starts_at_upper_bound() {
        let mut cm = ContextManager::new(65536);
        cm.init_groups(&[group(0, &[100, 200])]);
        assert_eq!(cm.estimate(GroupId(0)), 65536);
        assert!(!cm.has_signal(GroupId(0)));
    }

    #[test]
    fn first_finish_replaces_bound_then_max() {
        let mut cm = ContextManager::new(65536);
        cm.init_groups(&[group(0, &[100, 200, 300])]);
        cm.on_finished(GroupId(0), 100);
        assert_eq!(cm.estimate(GroupId(0)), 100);
        cm.on_finished(GroupId(0), 300);
        assert_eq!(cm.estimate(GroupId(0)), 300);
        cm.on_finished(GroupId(0), 200);
        assert_eq!(cm.estimate(GroupId(0)), 300); // monotone max
        assert_eq!(cm.finished_count(GroupId(0)), 3);
    }

    #[test]
    fn underserved_picks_least_scheduled() {
        let mut cm = ContextManager::new(1000);
        cm.init_groups(&[group(0, &[1]), group(1, &[1]), group(2, &[1])]);
        cm.on_scheduled(GroupId(0));
        cm.on_scheduled(GroupId(0));
        cm.on_scheduled(GroupId(2));
        let candidates = [GroupId(0), GroupId(1), GroupId(2)];
        assert_eq!(
            cm.most_underserved(candidates.iter().copied()),
            Some(GroupId(1))
        );
    }

    #[test]
    fn unknown_group_falls_back_to_bound() {
        let cm = ContextManager::new(4242);
        assert_eq!(cm.estimate(GroupId(9)), 4242);
    }
}
