//! Context manager (paper §3.3): learns per-group output-length estimates
//! online from the speculative probe requests and finished siblings.
//!
//! Estimate semantics follow the paper, extended with two sources of
//! signal beyond it:
//!
//! * **Cold start** — a group with no finished request and no history is
//!   conservatively assumed to be a potential long-tail case
//!   (estimate = generation-length upper bound).
//! * **Warm priors** — the cross-iteration
//!   [`crate::iteration::ContextStore`] can inject last epoch's learned
//!   estimate via [`ContextManager::with_priors`] /
//!   [`ContextManager::inject_priors`]; such groups start from the prior
//!   instead of the upper bound and report [`has_prior`], which lets the
//!   scheduler skip the probe tax for them.
//! * **Learned** — once requests finish, the estimate is the maximum
//!   observed finished length, which converges to the true group maximum
//!   as more siblings finish.
//!
//! In every mode, in-flight progress reported through
//! [`ContextManager::on_progress`] (a chunk lease ended and the request
//! migrated back into the queue) raises a learned or prior estimate that
//! turned out stale: a sibling that already generated `g` tokens proves
//! the group maximum is at least `g`.
//!
//! [`has_prior`]: ContextManager::has_prior

use std::collections::BTreeMap;

use crate::workload::{GroupId, GroupSpec};

#[derive(Debug, Clone, Copy)]
struct GroupCtx {
    /// Current length estimate (tokens), excluding the progress floor.
    estimate: u32,
    /// Maximum generated-token count observed on an in-flight sibling
    /// (the chunk-end/migration update path).
    progress: u32,
    /// The estimate came from an injected cross-iteration prior and no
    /// request has finished yet.
    from_prior: bool,
    /// Finished request count.
    finished: usize,
    /// Total requests in the group.
    size: usize,
    /// Scheduling credits served (for the starvation guard).
    served_chunks: u64,
}

impl GroupCtx {
    fn current_estimate(&self, upper_bound: u32) -> u32 {
        if self.finished == 0 && !self.from_prior {
            // Conservative bound: progress is always below it.
            upper_bound
        } else {
            // Learned or prior estimate, floored by observed in-flight
            // progress (the missed-update fix: a migrated sibling that
            // generated more than the estimate proves it stale).
            self.estimate.max(self.progress)
        }
    }
}

/// Online group-length estimator.
#[derive(Debug, Default)]
pub struct ContextManager {
    groups: BTreeMap<GroupId, GroupCtx>,
    priors: BTreeMap<GroupId, u32>,
    upper_bound: u32,
}

impl ContextManager {
    pub fn new(upper_bound: u32) -> Self {
        ContextManager {
            groups: BTreeMap::new(),
            priors: BTreeMap::new(),
            upper_bound,
        }
    }

    /// Prior-injection constructor: groups named in `priors` start from
    /// the given estimate (clamped to the upper bound) instead of the
    /// conservative bound. Priors apply to groups registered by a later
    /// [`init_groups`](Self::init_groups) call too.
    pub fn with_priors(
        upper_bound: u32,
        priors: impl IntoIterator<Item = (GroupId, u32)>,
    ) -> Self {
        let mut cm = Self::new(upper_bound);
        cm.inject_priors(priors);
        cm
    }

    /// Inject cross-iteration priors, updating already-registered groups
    /// that have no online signal yet. Called by the scheduler's
    /// warm-start path; safe in either order relative to `init_groups`.
    pub fn inject_priors(
        &mut self,
        priors: impl IntoIterator<Item = (GroupId, u32)>,
    ) {
        for (g, est) in priors {
            let est = est.min(self.upper_bound).max(1);
            self.priors.insert(g, est);
            if let Some(ctx) = self.groups.get_mut(&g) {
                if ctx.finished == 0 {
                    ctx.estimate = est;
                    ctx.from_prior = true;
                }
            }
        }
    }

    pub fn init_groups(&mut self, groups: &[GroupSpec]) {
        self.groups.clear();
        for g in groups {
            let prior = self.priors.get(&g.id).copied();
            self.groups.insert(
                g.id,
                GroupCtx {
                    estimate: prior.unwrap_or(self.upper_bound),
                    progress: 0,
                    from_prior: prior.is_some(),
                    finished: 0,
                    size: g.requests.len(),
                    served_chunks: 0,
                },
            );
        }
    }

    /// UPDATEESTIMATE (paper Alg. 2 line 3): a request of `group`
    /// finished at `len` tokens.
    pub fn on_finished(&mut self, group: GroupId, len: u32) {
        let g = self
            .groups
            .get_mut(&group)
            .expect("finished request from unknown group");
        if g.finished == 0 {
            // First completion replaces the conservative bound or prior.
            g.estimate = len;
            g.from_prior = false;
        } else {
            g.estimate = g.estimate.max(len);
        }
        g.finished += 1;
        debug_assert!(g.finished <= g.size);
    }

    /// A chunk lease ended with the request unfinished at `generated`
    /// tokens (it migrates back into the waiting queue). Records the
    /// in-flight progress so stale learned/prior estimates can't demote
    /// a demonstrably long group in the LFS order.
    pub fn on_progress(&mut self, group: GroupId, generated: u32) {
        if let Some(g) = self.groups.get_mut(&group) {
            g.progress = g.progress.max(generated);
        }
    }

    /// Current length estimate for LFS ordering.
    pub fn estimate(&self, group: GroupId) -> u32 {
        self.groups
            .get(&group)
            .map(|g| g.current_estimate(self.upper_bound))
            .unwrap_or_else(|| {
                self.priors
                    .get(&group)
                    .copied()
                    .unwrap_or(self.upper_bound)
            })
    }

    /// True once at least one sibling finished (the estimate is "learned"
    /// rather than the conservative bound or an injected prior).
    pub fn has_signal(&self, group: GroupId) -> bool {
        self.groups.map_or_false(group, |g| g.finished > 0)
    }

    /// True while the group's estimate comes from an injected
    /// cross-iteration prior (no online completion yet).
    pub fn has_prior(&self, group: GroupId) -> bool {
        self.groups.map_or_false(group, |g| g.from_prior)
    }

    /// True when the scheduler has *any* length context for the group —
    /// online signal or a warm prior. Probe requests only need the
    /// high-priority path while this is false.
    pub fn has_context(&self, group: GroupId) -> bool {
        self.groups
            .map_or_false(group, |g| g.finished > 0 || g.from_prior)
    }

    pub fn finished_count(&self, group: GroupId) -> usize {
        self.groups.get(&group).map(|g| g.finished).unwrap_or(0)
    }

    /// Record that a chunk of this group was scheduled (starvation guard
    /// bookkeeping).
    pub fn on_scheduled(&mut self, group: GroupId) {
        if let Some(g) = self.groups.get_mut(&group) {
            g.served_chunks += 1;
        }
    }

    /// The group with the fewest served chunks (ties by id) — the
    /// anti-starvation candidate.
    pub fn most_underserved(
        &self,
        candidates: impl Iterator<Item = GroupId>,
    ) -> Option<GroupId> {
        candidates.min_by_key(|g| {
            (
                self.groups.get(g).map(|c| c.served_chunks).unwrap_or(0),
                g.0,
            )
        })
    }
}

trait MapExt<K, V> {
    fn map_or_false(&self, k: K, f: impl Fn(&V) -> bool) -> bool;
}

impl<K: Ord, V> MapExt<K, V> for BTreeMap<K, V> {
    fn map_or_false(&self, k: K, f: impl Fn(&V) -> bool) -> bool {
        self.get(&k).map(f).unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{GroupSpec, RequestId, RequestSpec};

    fn group(id: u32, lens: &[u32]) -> GroupSpec {
        GroupSpec {
            id: GroupId(id),
            prompt_len: 10,
            requests: lens
                .iter()
                .enumerate()
                .map(|(i, &l)| RequestSpec {
                    id: RequestId(id * 100 + i as u32),
                    group: GroupId(id),
                    prompt_len: 10,
                    gen_len: l,
                })
                .collect(),
        }
    }

    #[test]
    fn starts_at_upper_bound() {
        let mut cm = ContextManager::new(65536);
        cm.init_groups(&[group(0, &[100, 200])]);
        assert_eq!(cm.estimate(GroupId(0)), 65536);
        assert!(!cm.has_signal(GroupId(0)));
        assert!(!cm.has_context(GroupId(0)));
    }

    #[test]
    fn first_finish_replaces_bound_then_max() {
        let mut cm = ContextManager::new(65536);
        cm.init_groups(&[group(0, &[100, 200, 300])]);
        cm.on_finished(GroupId(0), 100);
        assert_eq!(cm.estimate(GroupId(0)), 100);
        cm.on_finished(GroupId(0), 300);
        assert_eq!(cm.estimate(GroupId(0)), 300);
        cm.on_finished(GroupId(0), 200);
        assert_eq!(cm.estimate(GroupId(0)), 300); // monotone max
        assert_eq!(cm.finished_count(GroupId(0)), 3);
    }

    #[test]
    fn underserved_picks_least_scheduled() {
        let mut cm = ContextManager::new(1000);
        cm.init_groups(&[group(0, &[1]), group(1, &[1]), group(2, &[1])]);
        cm.on_scheduled(GroupId(0));
        cm.on_scheduled(GroupId(0));
        cm.on_scheduled(GroupId(2));
        let candidates = [GroupId(0), GroupId(1), GroupId(2)];
        assert_eq!(
            cm.most_underserved(candidates.iter().copied()),
            Some(GroupId(1))
        );
    }

    #[test]
    fn unknown_group_falls_back_to_bound() {
        let cm = ContextManager::new(4242);
        assert_eq!(cm.estimate(GroupId(9)), 4242);
    }

    #[test]
    fn priors_replace_bound_until_first_finish() {
        let mut cm = ContextManager::with_priors(65536, [(GroupId(0), 500)]);
        cm.init_groups(&[group(0, &[100, 200]), group(1, &[100, 200])]);
        assert_eq!(cm.estimate(GroupId(0)), 500);
        assert!(cm.has_prior(GroupId(0)));
        assert!(cm.has_context(GroupId(0)));
        assert!(!cm.has_signal(GroupId(0)));
        // Un-prior'd sibling group keeps the conservative bound.
        assert_eq!(cm.estimate(GroupId(1)), 65536);
        // First real finish replaces the prior with online signal.
        cm.on_finished(GroupId(0), 123);
        assert_eq!(cm.estimate(GroupId(0)), 123);
        assert!(!cm.has_prior(GroupId(0)));
        assert!(cm.has_signal(GroupId(0)));
    }

    #[test]
    fn inject_after_init_updates_unfinished_groups_only() {
        let mut cm = ContextManager::new(65536);
        cm.init_groups(&[group(0, &[100]), group(1, &[100])]);
        cm.on_finished(GroupId(1), 77);
        cm.inject_priors([(GroupId(0), 900), (GroupId(1), 900)]);
        assert_eq!(cm.estimate(GroupId(0)), 900);
        // Online signal wins over a late prior.
        assert_eq!(cm.estimate(GroupId(1)), 77);
    }

    #[test]
    fn priors_clamp_to_upper_bound() {
        let mut cm = ContextManager::with_priors(1000, [(GroupId(0), 9999)]);
        cm.init_groups(&[group(0, &[1])]);
        assert_eq!(cm.estimate(GroupId(0)), 1000);
    }

    /// Regression (cross-iteration PR): a probe that migrates and
    /// re-enters the queue used to leave no trace in the context manager.
    /// If a short sibling then finished first, the group estimate
    /// collapsed to the short length even though the migrated probe had
    /// *already generated more* — demoting a demonstrably long group in
    /// the LFS order. The `on_progress` path keeps the estimate at the
    /// observed in-flight maximum.
    #[test]
    fn migrated_probe_progress_floors_stale_estimates() {
        let mut cm = ContextManager::new(65536);
        cm.init_groups(&[group(0, &[600, 100])]);
        // Probe runs a 500-token chunk, lease ends, request migrates.
        cm.on_progress(GroupId(0), 500);
        // No finish yet: still the conservative bound.
        assert_eq!(cm.estimate(GroupId(0)), 65536);
        // The short sibling finishes first.
        cm.on_finished(GroupId(0), 100);
        // Stale pre-fix behaviour was estimate == 100.
        assert_eq!(cm.estimate(GroupId(0)), 500);
        // And a finish above the progress floor still raises it.
        cm.on_finished(GroupId(0), 620);
        assert_eq!(cm.estimate(GroupId(0)), 620);
    }

    #[test]
    fn progress_floors_stale_priors_too() {
        let mut cm = ContextManager::with_priors(65536, [(GroupId(0), 200)]);
        cm.init_groups(&[group(0, &[600, 100])]);
        assert_eq!(cm.estimate(GroupId(0)), 200);
        // The probe outran the historical prior before migrating.
        cm.on_progress(GroupId(0), 450);
        assert_eq!(cm.estimate(GroupId(0)), 450);
    }
}
