//! The rollout coordinator: request/chunk state machine, the global
//! request buffer, and the context manager that learns group-level length
//! estimates online (the paper's "Group-Aware Context Learning").

pub mod buffer;
pub mod context;
pub mod request;

pub use buffer::RequestBuffer;
pub use context::ContextManager;
pub use request::{KvLocation, Phase, ReqState};
