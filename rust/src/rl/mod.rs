//! The synchronous GRPO loop: rollout → reward → group-normalized
//! advantages → train_step → (in-place) weight update. Strictly on-policy:
//! every training sequence comes from the current parameters.

pub mod grpo;
pub mod phases;
pub mod task;

pub use grpo::{GrpoConfig, GrpoTrainer, IterStats};
pub use phases::{PhaseModel, PhaseSplit};
pub use task::CopyTask;

/// Group-normalized GRPO advantages: (r - mean_g) / (std_g + eps).
pub fn grpo_advantages(rewards: &[f32], group_of: &[usize]) -> Vec<f32> {
    assert_eq!(rewards.len(), group_of.len());
    let n_groups = group_of.iter().copied().max().map(|m| m + 1).unwrap_or(0);
    let mut sum = vec![0f64; n_groups];
    let mut cnt = vec![0usize; n_groups];
    for (&r, &g) in rewards.iter().zip(group_of) {
        sum[g] += r as f64;
        cnt[g] += 1;
    }
    let mean: Vec<f64> = sum
        .iter()
        .zip(&cnt)
        .map(|(s, &c)| if c > 0 { s / c as f64 } else { 0.0 })
        .collect();
    let mut var = vec![0f64; n_groups];
    for (&r, &g) in rewards.iter().zip(group_of) {
        let d = r as f64 - mean[g];
        var[g] += d * d;
    }
    let std: Vec<f64> = var
        .iter()
        .zip(&cnt)
        .map(|(v, &c)| if c > 0 { (v / c as f64).sqrt() } else { 0.0 })
        .collect();
    rewards
        .iter()
        .zip(group_of)
        .map(|(&r, &g)| ((r as f64 - mean[g]) / (std[g] + 1e-6)) as f32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advantages_are_group_centered() {
        let rewards = [1.0f32, 0.0, 1.0, 1.0];
        let groups = [0usize, 0, 1, 1];
        let adv = grpo_advantages(&rewards, &groups);
        // Group 0: mean 0.5, std 0.5 -> ±1.
        assert!((adv[0] - 1.0).abs() < 1e-3);
        assert!((adv[1] + 1.0).abs() < 1e-3);
        // Group 1: zero variance -> ~0 advantages.
        assert!(adv[2].abs() < 1e-3 && adv[3].abs() < 1e-3);
    }

    #[test]
    fn group_sums_to_zero() {
        let rewards = [0.2f32, 0.9, 0.5, 0.1, 0.7, 0.7];
        let groups = [0usize, 0, 0, 1, 1, 1];
        let adv = grpo_advantages(&rewards, &groups);
        let s0: f32 = adv[..3].iter().sum();
        let s1: f32 = adv[3..].iter().sum();
        assert!(s0.abs() < 1e-4 && s1.abs() < 1e-4);
    }
}
