//! RL iteration phase model (Table 1): rollout / training / weight-update
//! time split. Rollout time comes from the cluster simulation; training
//! and weight-update are modeled from the workload's scale (the paper's
//! point is precisely that these phases are small and well-optimized
//! already — veRL colocation, checkpoint-engine distribution).

use crate::config::WorkloadConfig;
use crate::sim::clock::SimTime;

#[derive(Debug, Clone, Copy)]
pub struct PhaseSplit {
    pub rollout: SimTime,
    pub training: SimTime,
    pub weight_update: SimTime,
}

impl PhaseSplit {
    pub fn total(&self) -> SimTime {
        self.rollout + self.training + self.weight_update
    }

    pub fn fractions(&self) -> (f64, f64, f64) {
        let t = self.total().as_secs_f64().max(1e-9);
        (
            self.rollout.as_secs_f64() / t,
            self.training.as_secs_f64() / t,
            self.weight_update.as_secs_f64() / t,
        )
    }
}

/// Calibrated per-task phase model.
#[derive(Debug, Clone)]
pub struct PhaseModel {
    /// Training FLOPs per generated token ≈ 3 × forward (fwd + bwd), with
    /// the trainer's efficiency factor folded in.
    pub train_flops_per_token: f64,
    /// Aggregate training compute across the cluster (FLOP/s).
    pub train_flops: f64,
    /// Weight bytes to broadcast and the fabric bandwidth.
    pub weight_bytes: u64,
    pub broadcast_bw: f64,
    /// Fixed overheads (checkpoint conversion, optimizer sync).
    pub train_overhead: SimTime,
    pub update_overhead: SimTime,
}

impl PhaseModel {
    pub fn for_workload(cfg: &WorkloadConfig) -> Self {
        let total_gpus = (cfg.n_instances * cfg.gpus_per_instance) as f64;
        // Model size proxy: kv_bytes_per_token correlates poorly with
        // weights; use flops_per_token (≈ 2 x active params) instead and
        // a dense-equivalent factor for MoE total weights.
        let active_params = cfg.hw.flops_per_token / 2.0;
        let weight_bytes = match cfg.name {
            "moonlight" => 32u64 << 30,
            "qwen2-vl-72b" => 146u64 << 30,
            "kimi-k2" => 1u64 << 40,
            _ => (active_params * 2.0) as u64,
        };
        // Fixed overheads (checkpoint conversion, optimizer sync, dataset
        // shuffling) scale with iteration size so that scaled-down test
        // workloads keep the paper's phase *fractions*.
        let rel = (cfg.reqs_per_iter as f64 * cfg.avg_gen_len as f64)
            / (3200.0 * 22386.0);
        let rel = rel.clamp(0.005, 2.0);
        PhaseModel {
            train_flops_per_token: 6.0 * active_params, // fwd+bwd ≈ 3 x 2P
            train_flops: total_gpus * 700e12 * 0.35,
            weight_bytes: ((weight_bytes as f64) * rel.min(1.0)) as u64,
            broadcast_bw: total_gpus / 8.0 * 50e9, // NICs per node
            train_overhead: SimTime::from_secs_f64(20.0 * rel),
            update_overhead: SimTime::from_secs_f64(5.0 * rel),
        }
    }

    /// Phase split for one iteration that generated `tokens` tokens with
    /// the given rollout makespan.
    pub fn split(&self, rollout: SimTime, tokens: u64) -> PhaseSplit {
        let train = tokens as f64 * self.train_flops_per_token / self.train_flops;
        let update = self.weight_bytes as f64 / self.broadcast_bw;
        PhaseSplit {
            rollout,
            training: self.train_overhead + SimTime::from_secs_f64(train),
            weight_update: self.update_overhead
                + SimTime::from_secs_f64(update),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;

    #[test]
    fn rollout_dominates_when_long() {
        let cfg = TaskPreset::Moonlight.workload();
        let m = PhaseModel::for_workload(&cfg);
        let tokens = cfg.reqs_per_iter as u64 * cfg.avg_gen_len as u64;
        let split = m.split(SimTime::from_secs(3000), tokens);
        let (r, t, u) = split.fractions();
        assert!(r > 0.6, "rollout frac {r}");
        assert!(t < 0.4 && u < 0.1);
        assert!((r + t + u - 1.0).abs() < 1e-9);
    }

    #[test]
    fn training_scales_with_tokens() {
        let cfg = TaskPreset::Qwen2Vl72b.workload();
        let m = PhaseModel::for_workload(&cfg);
        let a = m.split(SimTime::from_secs(100), 1_000_000);
        let b = m.split(SimTime::from_secs(100), 100_000_000);
        assert!(b.training > a.training);
    }
}
