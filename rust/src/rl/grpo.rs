//! The end-to-end synchronous GRPO trainer over the real model runtime.
//!
//! One iteration = rollout (real tokens through the coordinator-driven
//! slot engine) → programmatic reward → group-normalized advantages →
//! `train_step` HLO (loss + Adam update, parameters replaced in place =
//! the weight-update phase) → next iteration rolls out with the new
//! weights. Strictly on-policy, matching the paper's synchronous setting.

use std::time::Instant;

use anyhow::Result;

use crate::rollout::engine::{RealRolloutConfig, SeqRequest, StopRule};
use crate::rollout::session::RolloutSession;
use crate::runtime::ModelRuntime;
use crate::sim::Rng;
use crate::workload::GroupId;

use super::grpo_advantages;
use super::task::CopyTask;

#[derive(Debug, Clone)]
pub struct GrpoConfig {
    /// Prompts per iteration; each expands into `group_size` requests.
    pub prompts_per_iter: usize,
    pub group_size: usize,
    pub max_gen: usize,
    pub temperature: f64,
    pub use_spec: bool,
    pub context_aware: bool,
    pub chunk_tokens: usize,
    pub seed: u64,
}

impl Default for GrpoConfig {
    fn default() -> Self {
        GrpoConfig {
            prompts_per_iter: 4,
            group_size: 4,
            max_gen: 24,
            temperature: 1.0,
            use_spec: false,
            context_aware: true,
            chunk_tokens: 0,
            seed: 0,
        }
    }
}

/// Per-iteration training statistics.
#[derive(Debug, Clone, Copy)]
pub struct IterStats {
    pub iter: usize,
    pub mean_reward: f32,
    /// Strict (unshaped) accuracy — the evaluation metric.
    pub mean_accuracy: f32,
    pub mean_loss: f32,
    pub tokens: u64,
    pub rollout_secs: f64,
    pub train_secs: f64,
}

pub struct GrpoTrainer {
    pub model: ModelRuntime,
    pub task: CopyTask,
    pub cfg: GrpoConfig,
    pub rng: Rng,
    pub history: Vec<IterStats>,
}

impl GrpoTrainer {
    pub fn new(model: ModelRuntime, cfg: GrpoConfig) -> Self {
        let rng = Rng::new(cfg.seed ^ 0x62F0);
        GrpoTrainer {
            model,
            task: CopyTask::default(),
            cfg,
            rng,
            history: vec![],
        }
    }

    /// One synchronous RL iteration: rollout → reward → train.
    pub fn run_iteration(&mut self, iter: usize) -> Result<IterStats> {
        // ---- rollout (current policy) --------------------------------
        let mut prompts = Vec::new();
        let mut patterns = Vec::new();
        for _ in 0..self.cfg.prompts_per_iter {
            let (p, pat) = self.task.sample_prompt(&mut self.rng);
            prompts.push(p);
            patterns.push(pat);
        }
        let mut requests = Vec::new();
        for (gi, p) in prompts.iter().enumerate() {
            for _ in 0..self.cfg.group_size {
                requests.push(SeqRequest {
                    group: GroupId(gi as u32),
                    prompt: p.clone(),
                    stop: StopRule::MaxTokens(self.cfg.max_gen),
                });
            }
        }
        let t0 = Instant::now();
        let builder = RolloutSession::builder()
            .real(
                &self.model,
                RealRolloutConfig {
                    temperature: self.cfg.temperature,
                    use_spec: self.cfg.use_spec,
                    chunk_tokens: self.cfg.chunk_tokens,
                    context_aware: self.cfg.context_aware,
                    seed: self.cfg.seed ^ (iter as u64) << 16,
                    max_gen: self.cfg.max_gen,
                },
            )
            .requests(requests);
        // No cross-iteration ContextStore here: warm start is only sound
        // when group g names the same prompt every epoch, which holds for
        // the sim TrainingDriver (generate_epoch keeps prompt slots) but
        // not for this task sampler — it draws fresh prompts per
        // iteration, so per-GroupId history would describe no prompt.
        let report = builder.run()?;
        let rollout_secs = t0.elapsed().as_secs_f64();

        // ---- rewards + advantages ------------------------------------
        let mut rewards = Vec::with_capacity(report.sequences.len());
        let mut groups = Vec::with_capacity(report.sequences.len());
        let mut acc_sum = 0f32;
        for r in &report.sequences {
            let gi = r.group.0 as usize;
            rewards.push(self.task.reward(&patterns[gi], &r.tokens));
            acc_sum += self.task.accuracy(&patterns[gi], &r.tokens);
            groups.push(gi);
        }
        let mean_accuracy = acc_sum / report.sequences.len().max(1) as f32;
        let advantages = grpo_advantages(&rewards, &groups);
        let mean_reward =
            rewards.iter().sum::<f32>() / rewards.len().max(1) as f32;

        // ---- training (experience → train_step batches) ---------------
        let t1 = Instant::now();
        let d = self.model.manifest.dims;
        let (bsz, tlen) = (d.batch, d.train_len);
        let mut loss_sum = 0f32;
        let mut n_batches = 0usize;
        let results = &report.sequences;
        let idx_chunks: Vec<Vec<usize>> = (0..results.len())
            .collect::<Vec<_>>()
            .chunks(bsz)
            .map(|c| c.to_vec())
            .collect();
        for chunk in idx_chunks {
            // Short final chunks leave zero-advantage padding rows, which
            // contribute nothing to the policy gradient.
            let mut tokens = vec![0i32; bsz * tlen];
            let mut mask = vec![0i32; bsz * tlen];
            let mut adv = vec![0f32; bsz];
            for (row, &ri) in chunk.iter().enumerate() {
                let r = &results[ri];
                let full: Vec<u32> = {
                    let p = &prompts[r.group.0 as usize];
                    p.iter().chain(r.tokens.iter()).copied().collect()
                };
                for (t, &tok) in full.iter().take(tlen).enumerate() {
                    tokens[row * tlen + t] = tok as i32;
                }
                let gen_start = r.prompt_len as usize;
                let gen_end =
                    (r.prompt_len as usize + r.tokens.len()).min(tlen);
                for t in gen_start..gen_end {
                    mask[row * tlen + t] = 1;
                }
                adv[row] = advantages[ri];
            }
            let loss = self.model.train(&tokens, &mask, &adv)?;
            loss_sum += loss;
            n_batches += 1;
        }
        let train_secs = t1.elapsed().as_secs_f64();

        let stats = IterStats {
            iter,
            mean_reward,
            mean_accuracy,
            mean_loss: loss_sum / n_batches.max(1) as f32,
            tokens: report.metrics.tokens_generated,
            rollout_secs,
            train_secs,
        };
        self.history.push(stats);
        Ok(stats)
    }
}

