//! Synthetic RL task with a programmatic reward: pattern continuation.
//!
//! Prompt: `[BOS, p_1..p_k, SEP]` with a random pattern over a small
//! alphabet. The "correct" continuation repeats the pattern cyclically.
//! Reward = fraction of generated tokens matching the target continuation.
//! Policy-gradient learning on this task is easy enough for a ~0.5–4M
//! parameter model to show a rising reward curve within a few hundred
//! steps, which is what the end-to-end example (EXPERIMENTS.md §E2E)
//! records.

use crate::sim::Rng;

pub const BOS: u32 = 2;
pub const SEP: u32 = 3;
/// Pattern alphabet starts here (avoids BOS/SEP/PAD collisions).
pub const ALPHA0: u32 = 8;

#[derive(Debug, Clone)]
pub struct CopyTask {
    /// Pattern length range (inclusive).
    pub k_min: usize,
    pub k_max: usize,
    /// Alphabet size (tokens ALPHA0 .. ALPHA0+alphabet).
    pub alphabet: u32,
}

impl Default for CopyTask {
    fn default() -> Self {
        CopyTask {
            k_min: 3,
            k_max: 6,
            alphabet: 12,
        }
    }
}

impl CopyTask {
    /// Sample a prompt. Returns (prompt tokens, pattern).
    pub fn sample_prompt(&self, rng: &mut Rng) -> (Vec<u32>, Vec<u32>) {
        let k = rng.range_usize(self.k_min, self.k_max);
        let pattern: Vec<u32> = (0..k)
            .map(|_| ALPHA0 + rng.below(self.alphabet as u64) as u32)
            .collect();
        let mut prompt = Vec::with_capacity(k + 2);
        prompt.push(BOS);
        prompt.extend_from_slice(&pattern);
        prompt.push(SEP);
        (prompt, pattern)
    }

    /// Target continuation of length `n`: the pattern repeated.
    pub fn target(&self, pattern: &[u32], n: usize) -> Vec<u32> {
        (0..n).map(|i| pattern[i % pattern.len()]).collect()
    }

    /// Shaped reward in [0, 1]: full credit for exactly matching the
    /// cyclic target, partial credit (0.25) for emitting *some* pattern
    /// token — the graded signal policy gradient needs to climb out of a
    /// random-init policy over a large vocabulary (without shaping, early
    /// groups are all-zero and GRPO advantages vanish).
    pub fn reward(&self, pattern: &[u32], generated: &[u32]) -> f32 {
        if generated.is_empty() {
            return 0.0;
        }
        let target = self.target(pattern, generated.len());
        let mut score = 0f32;
        for (g, t) in generated.iter().zip(&target) {
            if g == t {
                score += 1.0;
            } else if pattern.contains(g) {
                score += 0.25;
            }
        }
        score / generated.len() as f32
    }

    /// Strict accuracy (no shaping): the evaluation metric the e2e
    /// example reports alongside the shaped training reward.
    pub fn accuracy(&self, pattern: &[u32], generated: &[u32]) -> f32 {
        if generated.is_empty() {
            return 0.0;
        }
        let target = self.target(pattern, generated.len());
        let hits = generated
            .iter()
            .zip(&target)
            .filter(|(a, b)| a == b)
            .count();
        hits as f32 / generated.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompt_shape() {
        let t = CopyTask::default();
        let mut rng = Rng::new(1);
        let (prompt, pattern) = t.sample_prompt(&mut rng);
        assert_eq!(prompt[0], BOS);
        assert_eq!(*prompt.last().unwrap(), SEP);
        assert_eq!(prompt.len(), pattern.len() + 2);
        assert!(pattern.iter().all(|&p| p >= ALPHA0));
    }

    #[test]
    fn reward_perfect_and_zero() {
        let t = CopyTask::default();
        let pattern = vec![10, 11, 12];
        let perfect = t.target(&pattern, 7);
        assert_eq!(t.reward(&pattern, &perfect), 1.0);
        let wrong = vec![9; 7];
        assert_eq!(t.reward(&pattern, &wrong), 0.0);
        assert_eq!(t.reward(&pattern, &[]), 0.0);
    }

    #[test]
    fn reward_partial() {
        let t = CopyTask::default();
        let pattern = vec![10, 11];
        // Target for 4: [10, 11, 10, 11]; match half.
        let gen = vec![10, 9, 10, 9];
        assert!((t.reward(&pattern, &gen) - 0.5).abs() < 1e-6);
    }
}
