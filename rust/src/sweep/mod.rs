//! Parallel deterministic sweeps over the rollout simulator.
//!
//! Seer's claims are comparative (2.04× throughput, 72–94% tail
//! reduction vs synchronous baselines), so the experiment harness needs
//! to run *grids* of rollouts — scheduler policy × seed × cluster scale
//! × fault plan × drift — and report paired statistics rather than
//! single-run point estimates. This module is that layer:
//!
//! * [`SweepSpec`] describes the grid and expands it into independent
//!   [`SweepCell`]s in a documented stable order.
//! * [`SweepRunner`] executes cells across std worker threads (no tokio;
//!   the `spec::dgds` thread/channel idiom) and restores input order
//!   before aggregating, so the same spec + seeds produce **byte
//!   identical** [`SweepReport`] JSON at any thread count — pinned by
//!   `rust/tests/sweep.rs`.
//! * Aggregation reports per-group means with seeded-bootstrap
//!   percentile CIs and per-seed paired speedup / tail-reduction against
//!   the baseline scheduler ([`crate::util::stats`]).
//! * [`rollout_bench_suite`] wraps [`crate::util::bench`] to write the
//!   `BENCH_rollout.json` baselines for the sim hot path.
//!
//! ```
//! use seer::config::TaskPreset;
//! use seer::sweep::{SweepRunner, SweepSpec};
//!
//! # fn main() -> anyhow::Result<()> {
//! let spec = SweepSpec::new(TaskPreset::Moonlight.workload_for_test())
//!     .schedulers(&["seer", "verl"])
//!     .seeds([1, 2]);
//! let outcome = SweepRunner::new(2).run(&spec)?;
//! assert_eq!(outcome.report.cells.len(), 4);
//! // Paired per-seed speedup of every scheduler vs the baseline:
//! assert_eq!(outcome.report.paired[0].speedup.n, 2);
//! # Ok(())
//! # }
//! ```
//!
//! The CLI front end is `seer sweep` (see `main.rs`); the experiment
//! harness (`fig7`, `fig8`, `faults`, `multi-iter`) fans its
//! measurements out through [`SweepRunner::map`].

pub mod runner;
pub mod spec;

pub use runner::{
    Aggregate, CancelToken, PairedComparison, SweepOutcome, SweepReport,
    SweepRunner,
};
pub use spec::{CellResult, SweepCell, SweepSpec};

use anyhow::Result;

use crate::rollout::RolloutSession;
use crate::util::bench::BenchSuite;

/// Benchmark the sim hot path — one full rollout session per scheduler
/// at test scale, plus the lifecycle-accounting micro pair — into a
/// [`BenchSuite`] ready to be written as `BENCH_rollout.json`. Honors
/// `SEER_BENCH_MS` (0 = single-iteration CI smoke mode).
///
/// The `accounting_*` pair is an in-binary before/after of the O(1)
/// lifecycle-counter overhaul: `scan_before` measures the retained
/// `n_finished_scan` cross-check (the per-event cost the event loop's
/// `done()` used to pay once the waiting set drained), `counter_after`
/// the O(1) counters it pays now. End-to-end `rollout_*` numbers are
/// compared against the checked-in `BENCH_rollout.json` baseline by the
/// CI perf guard (>2x regression fails the job).
pub fn rollout_bench_suite<S: AsRef<str>>(schedulers: &[S]) -> Result<BenchSuite> {
    let cfg = crate::config::TaskPreset::Moonlight.workload_for_test();
    let mut suite = BenchSuite::new("rollout");
    for s in schedulers {
        let name = s.as_ref();
        // Validate the name once up front so a typo is an error, not a
        // panic inside the bench closure.
        RolloutSession::builder()
            .workload(cfg.clone())
            .scheduler(name)
            .sd("grouped-cst")
            .build()?;
        suite.run(&format!("rollout_{name}"), || {
            let report = RolloutSession::builder()
                .workload(cfg.clone())
                .scheduler(name)
                .sd("grouped-cst")
                .seed(42)
                .run()
                .expect("bench rollout failed");
            std::hint::black_box(report.metrics.tokens_generated);
        });
    }
    // Lifecycle-accounting pair over a paper-scale buffer (full-scale
    // request count, so the scan cost is what a real tail phase paid).
    let full = crate::config::TaskPreset::Moonlight.workload();
    let w = crate::workload::generate_iteration(&full, 1);
    let buffer = crate::coordinator::RequestBuffer::from_groups(&w.groups);
    suite.run("accounting_done_scan_before", || {
        std::hint::black_box(buffer.n_finished_scan());
    });
    suite.run("accounting_done_counter_after", || {
        std::hint::black_box((buffer.all_finished(), buffer.n_finished()));
    });
    Ok(suite)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_suite_runs_in_smoke_mode() {
        // Single-iteration smoke so the test stays fast; also exercises
        // the SEER_BENCH_MS=0 path end to end.
        let _guard = crate::util::bench::env_lock();
        std::env::set_var("SEER_BENCH_MS", "0");
        let suite = rollout_bench_suite(&["seer"]).unwrap();
        std::env::remove_var("SEER_BENCH_MS");
        let j = suite.to_json();
        assert!(j
            .expect("benches")
            .expect("rollout_seer")
            .expect("iters")
            .as_u64()
            .unwrap()
            >= 1);
    }

    #[test]
    fn bench_suite_rejects_unknown_scheduler() {
        assert!(rollout_bench_suite(&["not-a-policy"]).is_err());
    }
}
