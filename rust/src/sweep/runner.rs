//! The parallel deterministic sweep runner.
//!
//! [`SweepRunner`] fans independent work items out across std worker
//! threads (the repo is tokio-free; this reuses the `spec::dgds`
//! thread/channel idiom) and restores input order before anything is
//! aggregated, so results are a pure function of the work items — the
//! same spec and seeds produce byte-identical reports at every thread
//! count. The primitive is [`SweepRunner::map`]: an order-preserving
//! parallel map over a shared atomic work cursor. [`SweepRunner::run`]
//! builds on it to execute a whole [`SweepSpec`] grid and aggregate the
//! results into a [`SweepReport`] with seeded-bootstrap CIs and paired
//! per-seed comparisons against the baseline scheduler.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;
use crate::util::stats::{
    bootstrap_mean_ci, paired_speedup, paired_tail_reduction, Ci, Paired,
    BOOTSTRAP_LEVEL, BOOTSTRAP_RESAMPLES,
};

use super::spec::{CellResult, SweepSpec};

/// Base seed for the report's bootstrap resampling; each aggregate group
/// and paired comparison offsets it by its stable group ordinal, so the
/// report is deterministic in the spec alone.
const BOOT_SEED: u64 = 0x5EE2_B007;

/// A shared cooperative-cancellation flag checked at work-item
/// granularity: [`SweepRunner::run_with_cancel`] consults it before each
/// cell, and the serve plane's job executors consult it between train
/// iterations. Cloning shares the flag; cancelling is idempotent and
/// sticky (there is no un-cancel).
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    /// Request cancellation. Work already in flight finishes its current
    /// item; nothing new starts.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

/// Executes sweep cells across worker threads.
#[derive(Debug, Clone, Copy)]
pub struct SweepRunner {
    threads: usize,
}

impl SweepRunner {
    /// A runner with exactly `threads` workers (clamped to ≥ 1).
    pub fn new(threads: usize) -> Self {
        SweepRunner {
            threads: threads.max(1),
        }
    }

    /// One worker per available core, capped at 8 (sweep cells are
    /// CPU-bound; beyond the cap coordination costs dominate at our
    /// cell sizes).
    pub fn auto() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        SweepRunner::new(n.min(8))
    }

    /// `SEER_SWEEP_THREADS` override, else [`SweepRunner::auto`]. The
    /// experiment harness and CLI default to this.
    pub fn from_env() -> Self {
        match std::env::var("SEER_SWEEP_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
        {
            Some(n) if n >= 1 => SweepRunner::new(n),
            _ => SweepRunner::auto(),
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Order-preserving parallel map: applies `f` to every item and
    /// returns results in *input* order, regardless of which worker
    /// finished first. With one thread (or one item) this degenerates to
    /// a plain serial loop — the reference the equivalence tests compare
    /// against. A panic in `f` propagates to the caller with its
    /// *original payload* (workers are joined explicitly and the first
    /// panic is resumed), so a failing property assertion inside `f`
    /// reads like an ordinary test failure — reproduction seed and all.
    pub fn map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        let n = items.len();
        let threads = self.threads.min(n);
        if threads <= 1 {
            return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        let cursor = AtomicUsize::new(0);
        let (tx, rx) = channel::<(usize, R)>();
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..threads)
                .map(|_| {
                    let tx = tx.clone();
                    let cursor = &cursor;
                    let f = &f;
                    s.spawn(move || loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let r = f(i, &items[i]);
                        if tx.send((i, r)).is_err() {
                            break;
                        }
                    })
                })
                .collect();
            // Join explicitly so a worker panic keeps its payload
            // (letting `scope` auto-join would replace it with the
            // generic "a scoped thread panicked").
            let mut first_panic = None;
            for h in handles {
                if let Err(payload) = h.join() {
                    first_panic.get_or_insert(payload);
                }
            }
            if let Some(payload) = first_panic {
                std::panic::resume_unwind(payload);
            }
        });
        drop(tx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx.iter() {
            slots[i] = Some(r);
        }
        slots
            .into_iter()
            .map(|s| s.expect("every item mapped exactly once"))
            .collect()
    }

    /// [`map`](Self::map) for fallible work: runs everything, then
    /// returns the first error (by item order) if any.
    pub fn try_map<T, R, F>(&self, items: &[T], f: F) -> Result<Vec<R>>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> Result<R> + Sync,
    {
        self.map(items, f).into_iter().collect()
    }

    /// Expand and execute the whole grid, then aggregate. The report is
    /// deterministic in the spec; only [`SweepOutcome::wall_secs`]
    /// (kept outside the report) depends on the host. Rejects specs
    /// whose dimension values would mislabel report rows
    /// ([`SweepSpec::validate`]).
    pub fn run(&self, spec: &SweepSpec) -> Result<SweepOutcome> {
        self.run_with_cancel(spec, &CancelToken::new())
    }

    /// [`run`](Self::run) with a job-granular cancellation hook: the
    /// token is checked before each cell starts, so cancelling stops the
    /// sweep at cell boundaries (in-flight cells complete). A cancelled
    /// sweep returns an error mentioning "cancelled" rather than a
    /// partial report — partial grids would aggregate misleadingly.
    pub fn run_with_cancel(
        &self,
        spec: &SweepSpec,
        cancel: &CancelToken,
    ) -> Result<SweepOutcome> {
        let start = Instant::now();
        spec.validate()?;
        let cells = spec.expand();
        let results = self
            .try_map(&cells, |_, cell| {
                if cancel.is_cancelled() {
                    bail!("sweep cancelled before cell {}", cell.index);
                }
                cell.run().with_context(|| {
                    format!(
                        "sweep cell {} ({} mode {} seed {} scale {} fault {} drift {})",
                        cell.index,
                        cell.scheduler,
                        cell.mode.tag(),
                        cell.seed,
                        cell.n_instances,
                        cell.fault_name,
                        cell.drift
                    )
                })
            })?;
        let report = SweepReport::aggregate(spec, results);
        Ok(SweepOutcome {
            report,
            wall_secs: start.elapsed().as_secs_f64(),
        })
    }

    /// Spawn this runner's worker pool as long-lived scoped threads: one
    /// call to `worker(i)` per worker, each expected to loop until its
    /// work source drains (the serve plane's job-queue loop lives in the
    /// closure). The threads are owned by `scope`, so the caller's
    /// `thread::scope` block joins them — same lifetime discipline as
    /// [`SweepRunner::map`], but for open-ended queue service instead of
    /// a fixed item list.
    pub fn spawn_workers<'scope, 'env, F>(
        &self,
        scope: &'scope std::thread::Scope<'scope, 'env>,
        worker: &'scope F,
    ) where
        F: Fn(usize) + Sync,
    {
        for i in 0..self.threads {
            scope.spawn(move || worker(i));
        }
    }
}

impl Default for SweepRunner {
    fn default() -> Self {
        SweepRunner::from_env()
    }
}

/// Per-group (scheduler, mode, scale, fault, drift) aggregate across
/// seeds.
#[derive(Debug, Clone, PartialEq)]
pub struct Aggregate {
    pub scheduler: String,
    /// Training-mode tag (`sync`, `hybrid`, `async:N`) of this group.
    pub mode: String,
    /// Staleness bound the mode permits (`0` for sync).
    pub lag: u64,
    pub n_instances: usize,
    pub fault_name: String,
    pub drift: f64,
    pub n_seeds: usize,
    pub mean_makespan_secs: f64,
    pub mean_throughput_tok_s: f64,
    pub mean_tail_secs: f64,
    pub mean_p99_finish_secs: f64,
    /// Mean per-request policy-version staleness across the group's
    /// seeds (zero everywhere for sync groups).
    pub mean_staleness: f64,
    /// Seeded-bootstrap CI over the per-seed throughputs.
    pub throughput_ci: Ci,
}

/// Paired per-seed comparison of one scheduler against the baseline
/// (`spec.schedulers[0]`) at the same mode/scale/fault/drift point.
#[derive(Debug, Clone, PartialEq)]
pub struct PairedComparison {
    pub baseline: String,
    pub candidate: String,
    /// Training-mode tag shared by both sides of the pairing.
    pub mode: String,
    pub lag: u64,
    pub n_instances: usize,
    pub fault_name: String,
    pub drift: f64,
    /// Makespan speedup `baseline / candidate` per seed.
    pub speedup: Paired,
    /// Tail-time reduction `1 - candidate / baseline` per seed.
    pub tail_reduction: Paired,
}

/// The deterministic result of one sweep: per-cell results in grid
/// order, per-group aggregates, and paired comparisons. Contains no
/// host-dependent field, so [`SweepReport::to_json`] is byte-identical
/// across thread counts and hosts.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub spec_json: Json,
    pub cells: Vec<CellResult>,
    pub aggregates: Vec<Aggregate>,
    pub paired: Vec<PairedComparison>,
}

/// A finished sweep: the deterministic report plus the host wall clock
/// (reported separately — e.g. on stderr — precisely so the JSON stays
/// comparable across machines and thread counts).
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    pub report: SweepReport,
    pub wall_secs: f64,
}

impl SweepReport {
    /// Fold ordered cell results into aggregates and paired stats.
    /// Relies on the expansion contract: results arrive in grid order
    /// and each aggregate group is one contiguous run of `k` seeds.
    fn aggregate(spec: &SweepSpec, cells: Vec<CellResult>) -> SweepReport {
        let (schedulers, modes, scales, faults, drifts, seeds) = spec.dims();
        let k = seeds.len();
        let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len().max(1) as f64;
        let mut aggregates = Vec::new();
        for (g, group) in cells.chunks(k).enumerate() {
            let first = &group[0];
            let throughputs: Vec<f64> =
                group.iter().map(|c| c.throughput_tok_s).collect();
            aggregates.push(Aggregate {
                scheduler: first.scheduler.clone(),
                mode: first.mode.clone(),
                lag: first.lag,
                n_instances: first.n_instances,
                fault_name: first.fault_name.clone(),
                drift: first.drift,
                n_seeds: group.len(),
                mean_makespan_secs: mean(
                    &group.iter().map(|c| c.makespan_secs).collect::<Vec<_>>(),
                ),
                mean_throughput_tok_s: mean(&throughputs),
                mean_tail_secs: mean(
                    &group.iter().map(|c| c.tail_secs).collect::<Vec<_>>(),
                ),
                mean_p99_finish_secs: mean(
                    &group
                        .iter()
                        .map(|c| c.p99_finish_secs)
                        .collect::<Vec<_>>(),
                ),
                mean_staleness: mean(
                    &group
                        .iter()
                        .map(|c| c.staleness_mean)
                        .collect::<Vec<_>>(),
                ),
                throughput_ci: bootstrap_mean_ci(
                    &throughputs,
                    BOOTSTRAP_LEVEL,
                    BOOTSTRAP_RESAMPLES,
                    BOOT_SEED.wrapping_add(g as u64),
                ),
            });
        }
        // Paired layer: scheduler s > 0 vs scheduler 0 at the same
        // (mode, scale, fault, drift) point. With the scheduler
        // dimension outermost, scheduler s's groups sit at ordinal
        // s*per + p.
        let per = modes.len() * scales.len() * faults.len() * drifts.len();
        let mut paired = Vec::new();
        for s in 1..schedulers.len() {
            for p in 0..per {
                let base = &cells[p * k..(p + 1) * k];
                let cand_lo = (s * per + p) * k;
                let cand = &cells[cand_lo..cand_lo + k];
                let makespans = |xs: &[CellResult]| {
                    xs.iter().map(|c| c.makespan_secs).collect::<Vec<_>>()
                };
                let tails = |xs: &[CellResult]| {
                    xs.iter().map(|c| c.tail_secs).collect::<Vec<_>>()
                };
                let ordinal = (s * per + p) as u64;
                paired.push(PairedComparison {
                    baseline: schedulers[0].clone(),
                    candidate: schedulers[s].clone(),
                    mode: base[0].mode.clone(),
                    lag: base[0].lag,
                    n_instances: base[0].n_instances,
                    fault_name: base[0].fault_name.clone(),
                    drift: base[0].drift,
                    speedup: paired_speedup(
                        &makespans(base),
                        &makespans(cand),
                        BOOT_SEED ^ (ordinal << 1),
                    ),
                    tail_reduction: paired_tail_reduction(
                        &tails(base),
                        &tails(cand),
                        BOOT_SEED ^ ((ordinal << 1) | 1),
                    ),
                });
            }
        }
        SweepReport {
            spec_json: spec.to_json(),
            cells,
            aggregates,
            paired,
        }
    }

    /// Serialize the full report. Key order is BTreeMap-stable and every
    /// value is virtual-time-deterministic, so equal specs print equal
    /// bytes (pinned by `tests/sweep.rs`).
    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("spec".to_string(), self.spec_json.clone());
        o.insert(
            "n_cells".to_string(),
            Json::Num(self.cells.len() as f64),
        );
        o.insert(
            "cells".to_string(),
            Json::Arr(self.cells.iter().map(|c| c.to_json()).collect()),
        );
        o.insert(
            "aggregates".to_string(),
            Json::Arr(self.aggregates.iter().map(agg_json).collect()),
        );
        o.insert(
            "paired".to_string(),
            Json::Arr(self.paired.iter().map(paired_json).collect()),
        );
        Json::Obj(o)
    }
}

fn ci_json(ci: &Ci) -> Json {
    let mut o = std::collections::BTreeMap::new();
    o.insert("lo".to_string(), Json::Num(ci.lo));
    o.insert("hi".to_string(), Json::Num(ci.hi));
    o.insert("level".to_string(), Json::Num(ci.level));
    Json::Obj(o)
}

fn paired_stat_json(p: &Paired) -> Json {
    let mut o = std::collections::BTreeMap::new();
    o.insert("mean".to_string(), Json::Num(p.mean));
    o.insert("wins".to_string(), Json::Num(p.wins as f64));
    o.insert("ci".to_string(), ci_json(&p.ci));
    Json::Obj(o)
}

fn agg_json(a: &Aggregate) -> Json {
    let mut o = std::collections::BTreeMap::new();
    o.insert("scheduler".to_string(), Json::Str(a.scheduler.clone()));
    o.insert("mode".to_string(), Json::Str(a.mode.clone()));
    o.insert("lag".to_string(), Json::Num(a.lag as f64));
    o.insert("n_instances".to_string(), Json::Num(a.n_instances as f64));
    o.insert("fault".to_string(), Json::Str(a.fault_name.clone()));
    o.insert("drift".to_string(), Json::Num(a.drift));
    o.insert("n_seeds".to_string(), Json::Num(a.n_seeds as f64));
    o.insert(
        "mean_makespan_secs".to_string(),
        Json::Num(a.mean_makespan_secs),
    );
    o.insert(
        "mean_throughput_tok_s".to_string(),
        Json::Num(a.mean_throughput_tok_s),
    );
    o.insert("mean_tail_secs".to_string(), Json::Num(a.mean_tail_secs));
    o.insert(
        "mean_p99_finish_secs".to_string(),
        Json::Num(a.mean_p99_finish_secs),
    );
    o.insert("mean_staleness".to_string(), Json::Num(a.mean_staleness));
    o.insert("throughput_ci".to_string(), ci_json(&a.throughput_ci));
    Json::Obj(o)
}

fn paired_json(p: &PairedComparison) -> Json {
    let mut o = std::collections::BTreeMap::new();
    o.insert("baseline".to_string(), Json::Str(p.baseline.clone()));
    o.insert("candidate".to_string(), Json::Str(p.candidate.clone()));
    o.insert("mode".to_string(), Json::Str(p.mode.clone()));
    o.insert("lag".to_string(), Json::Num(p.lag as f64));
    o.insert("n_instances".to_string(), Json::Num(p.n_instances as f64));
    o.insert("fault".to_string(), Json::Str(p.fault_name.clone()));
    o.insert("drift".to_string(), Json::Num(p.drift));
    o.insert("n_seeds".to_string(), Json::Num(p.speedup.n as f64));
    o.insert("speedup".to_string(), paired_stat_json(&p.speedup));
    o.insert(
        "tail_reduction".to_string(),
        paired_stat_json(&p.tail_reduction),
    );
    Json::Obj(o)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_input_order() {
        let items: Vec<usize> = (0..37).collect();
        for threads in [1, 2, 8] {
            let out = SweepRunner::new(threads)
                .map(&items, |i, &x| (i, x * x));
            assert_eq!(out.len(), items.len());
            for (i, (idx, sq)) in out.iter().enumerate() {
                assert_eq!(*idx, i);
                assert_eq!(*sq, i * i);
            }
        }
    }

    #[test]
    fn map_handles_empty_and_fewer_items_than_threads() {
        let r = SweepRunner::new(8);
        let empty: Vec<u32> = vec![];
        assert!(r.map(&empty, |_, &x| x).is_empty());
        assert_eq!(r.map(&[5u32], |_, &x| x + 1), vec![6]);
    }

    #[test]
    fn try_map_surfaces_first_error_by_item_order() {
        let items: Vec<usize> = (0..16).collect();
        let r = SweepRunner::new(4).try_map(&items, |_, &x| {
            if x % 2 == 1 {
                anyhow::bail!("odd {x}")
            }
            Ok(x)
        });
        assert_eq!(r.unwrap_err().to_string(), "odd 1");
    }

    #[test]
    fn map_propagates_worker_panics_with_payload() {
        let items: Vec<usize> = (0..8).collect();
        let res = std::panic::catch_unwind(|| {
            SweepRunner::new(4).map(&items, |_, &x| {
                assert!(x != 5, "boom at {x}");
                x
            })
        });
        // The worker's own message survives — not scope's generic
        // "a scoped thread panicked".
        let payload = res.unwrap_err();
        let msg = payload
            .downcast_ref::<String>()
            .map(String::as_str)
            .unwrap_or("");
        assert!(msg.contains("boom at 5"), "payload lost: {msg:?}");
    }

    #[test]
    fn run_rejects_invalid_dimensions() {
        use crate::config::TaskPreset;
        let spec = SweepSpec::new(TaskPreset::Moonlight.workload_for_test())
            .drifts([-0.5]);
        let e = SweepRunner::new(1).run(&spec).unwrap_err();
        assert!(e.to_string().contains("drift"), "{e}");
    }

    #[test]
    fn runner_clamps_threads() {
        assert_eq!(SweepRunner::new(0).threads(), 1);
        assert!(SweepRunner::auto().threads() >= 1);
    }

    #[test]
    fn cancel_token_is_shared_and_sticky() {
        let t = CancelToken::new();
        let clone = t.clone();
        assert!(!t.is_cancelled());
        clone.cancel();
        clone.cancel(); // idempotent
        assert!(t.is_cancelled());
    }

    #[test]
    fn run_with_cancel_stops_before_any_cell() {
        use crate::config::TaskPreset;
        let spec =
            SweepSpec::new(TaskPreset::Moonlight.workload_for_test());
        let cancel = CancelToken::new();
        cancel.cancel();
        let e = SweepRunner::new(1)
            .run_with_cancel(&spec, &cancel)
            .unwrap_err();
        assert!(e.to_string().contains("cancelled"), "{e}");
    }

    #[test]
    fn spawn_workers_runs_each_worker_once() {
        let hits = AtomicUsize::new(0);
        let runner = SweepRunner::new(3);
        let worker = |_i: usize| {
            hits.fetch_add(1, Ordering::SeqCst);
        };
        std::thread::scope(|s| {
            runner.spawn_workers(s, &worker);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 3);
    }
}
