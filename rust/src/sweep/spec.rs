//! The sweep grid: [`SweepSpec`] describes a study as the cross product
//! of scheduler policy × seed × cluster scale × fault plan × drift, and
//! expands it into independent, self-contained [`SweepCell`]s.
//!
//! Expansion order is part of the spec's contract (tests pin it):
//! scheduler is the outermost dimension, then cluster scale, fault plan,
//! drift, and finally seed — so the cells belonging to one aggregate
//! group (same scheduler/scale/fault/drift, varying seed) are contiguous
//! and the runner can aggregate by index arithmetic without ever
//! depending on completion order.

use anyhow::{bail, Result};

use crate::config::{SystemConfig, WorkloadConfig};
use crate::rollout::RolloutSession;
use crate::sim::faults::FaultPlan;
use crate::util::json::Json;
use crate::workload::generate_epoch;

/// The effective dimension vectors of a spec, in expansion order:
/// `(schedulers, scales, fault_plans, drifts, seeds)`.
pub type SweepDims = (
    Vec<String>,
    Vec<usize>,
    Vec<(String, FaultPlan)>,
    Vec<f64>,
    Vec<u64>,
);

/// A parameter grid over independent rollout runs.
///
/// Empty dimension vectors mean "the single default value" (the base
/// workload's instance count, no faults, no drift), so a spec is usable
/// straight from [`SweepSpec::new`].
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Base workload; the scale dimension overrides `n_instances`.
    pub workload: WorkloadConfig,
    pub system: SystemConfig,
    /// Registry names; `schedulers[0]` is the baseline every other
    /// policy is paired against.
    pub schedulers: Vec<String>,
    /// SD strategy registry name, shared by every cell.
    pub sd: String,
    /// Workload-generation seeds (the paired-statistics axis).
    pub seeds: Vec<u64>,
    /// Cluster scales (`n_instances` values). Empty ⇒ the base workload's.
    pub scales: Vec<usize>,
    /// Named fault scripts. Empty ⇒ one healthy plan named `"none"`.
    pub fault_plans: Vec<(String, FaultPlan)>,
    /// Epoch-drift sigmas, each ≥ 0 (0.0 = the base iteration
    /// workload; cells only apply drift when it is > 0, so negative
    /// values would run the base workload under a misleading label —
    /// the CLI rejects them).
    pub drifts: Vec<f64>,
}

impl SweepSpec {
    pub fn new(workload: WorkloadConfig) -> Self {
        SweepSpec {
            workload,
            system: SystemConfig::default(),
            schedulers: vec!["seer".to_string()],
            sd: "grouped-cst".to_string(),
            seeds: vec![42],
            scales: Vec::new(),
            fault_plans: Vec::new(),
            drifts: Vec::new(),
        }
    }

    pub fn system(mut self, system: SystemConfig) -> Self {
        self.system = system;
        self
    }

    pub fn schedulers<S: AsRef<str>>(mut self, names: &[S]) -> Self {
        self.schedulers = names.iter().map(|s| s.as_ref().to_string()).collect();
        self
    }

    pub fn sd(mut self, name: &str) -> Self {
        self.sd = name.to_string();
        self
    }

    pub fn seeds(mut self, seeds: impl IntoIterator<Item = u64>) -> Self {
        self.seeds = seeds.into_iter().collect();
        self
    }

    pub fn scales(mut self, scales: impl IntoIterator<Item = usize>) -> Self {
        self.scales = scales.into_iter().collect();
        self
    }

    pub fn fault_plan(mut self, name: &str, plan: FaultPlan) -> Self {
        self.fault_plans.push((name.to_string(), plan));
        self
    }

    pub fn drifts(mut self, drifts: impl IntoIterator<Item = f64>) -> Self {
        self.drifts = drifts.into_iter().collect();
        self
    }

    /// Effective dimension values after filling empty dimensions with
    /// their defaults, in expansion order:
    /// `(schedulers, scales, fault_plans, drifts, seeds)`.
    pub fn dims(&self) -> SweepDims {
        let schedulers = if self.schedulers.is_empty() {
            vec!["seer".to_string()]
        } else {
            self.schedulers.clone()
        };
        let scales = if self.scales.is_empty() {
            vec![self.workload.n_instances]
        } else {
            self.scales.clone()
        };
        let faults = if self.fault_plans.is_empty() {
            vec![("none".to_string(), FaultPlan::new())]
        } else {
            self.fault_plans.clone()
        };
        let drifts = if self.drifts.is_empty() {
            vec![0.0]
        } else {
            self.drifts.clone()
        };
        let seeds = if self.seeds.is_empty() {
            vec![42]
        } else {
            self.seeds.clone()
        };
        (schedulers, scales, faults, drifts, seeds)
    }

    /// Reject dimension values the execution layer would otherwise
    /// silently clamp or ignore, mislabeling report rows: a scale of 0
    /// (the simulator clamps to 1 while the report would echo 0) and
    /// non-finite or negative drifts (cells only apply drift > 0, so
    /// such cells would be base runs under a misleading label).
    /// [`crate::sweep::SweepRunner::run`] calls this before expanding,
    /// covering every entry point, not just the CLI.
    pub fn validate(&self) -> Result<()> {
        if self.scales.contains(&0) {
            bail!("sweep scale 0 invalid: n_instances must be >= 1");
        }
        if let Some(d) =
            self.drifts.iter().find(|d| !d.is_finite() || **d < 0.0)
        {
            bail!("sweep drift {d} invalid: must be finite and >= 0");
        }
        Ok(())
    }

    /// Number of cells the spec expands to (the dimension product).
    pub fn cardinality(&self) -> usize {
        let (sc, s, f, d, k) = self.dims();
        sc.len() * s.len() * f.len() * d.len() * k.len()
    }

    /// Seeds per aggregate group — the innermost dimension's length.
    pub fn seeds_per_group(&self) -> usize {
        self.dims().4.len()
    }

    /// Expand the grid into independent session configs, in the
    /// documented stable order. `cell.index == position` always holds.
    pub fn expand(&self) -> Vec<SweepCell> {
        let (schedulers, scales, faults, drifts, seeds) = self.dims();
        let cap = schedulers.len()
            * scales.len()
            * faults.len()
            * drifts.len()
            * seeds.len();
        let mut cells = Vec::with_capacity(cap);
        for scheduler in &schedulers {
            for &n_instances in &scales {
                for (fault_name, plan) in &faults {
                    for &drift in &drifts {
                        for &seed in &seeds {
                            cells.push(SweepCell {
                                index: cells.len(),
                                scheduler: scheduler.clone(),
                                sd: self.sd.clone(),
                                seed,
                                n_instances,
                                fault_name: fault_name.clone(),
                                faults: plan.clone(),
                                drift,
                                workload: self.workload.clone(),
                                system: self.system.clone(),
                            });
                        }
                    }
                }
            }
        }
        cells
    }

    /// Spec echo for the report JSON (fault plans by name only — the
    /// scripts themselves live in their own files).
    pub fn to_json(&self) -> Json {
        let (schedulers, scales, faults, drifts, seeds) = self.dims();
        let mut o = std::collections::BTreeMap::new();
        o.insert("task".to_string(), Json::Str(self.workload.name.to_string()));
        o.insert(
            "reqs_per_iter".to_string(),
            Json::Num(self.workload.reqs_per_iter as f64),
        );
        o.insert(
            "group_size".to_string(),
            Json::Num(self.workload.group_size as f64),
        );
        o.insert(
            "schedulers".to_string(),
            Json::Arr(schedulers.into_iter().map(Json::Str).collect()),
        );
        o.insert("sd".to_string(), Json::Str(self.sd.clone()));
        // Seeds are serialized as strings: u64 seeds (e.g. hashed ones)
        // can exceed 2^53 and would be silently rounded by a JSON
        // number, breaking replay-from-report.
        o.insert(
            "seeds".to_string(),
            Json::Arr(seeds.iter().map(|s| Json::Str(s.to_string())).collect()),
        );
        o.insert(
            "scales".to_string(),
            Json::Arr(scales.iter().map(|&s| Json::Num(s as f64)).collect()),
        );
        o.insert(
            "fault_plans".to_string(),
            Json::Arr(faults.into_iter().map(|(n, _)| Json::Str(n)).collect()),
        );
        o.insert(
            "drifts".to_string(),
            Json::Arr(drifts.iter().map(|&d| Json::Num(d)).collect()),
        );
        Json::Obj(o)
    }
}

/// One fully-specified point of the grid: everything a worker thread
/// needs to build and run a [`RolloutSession`], as plain data (nothing
/// non-`Send` crosses threads — each worker constructs its own session).
#[derive(Debug, Clone)]
pub struct SweepCell {
    /// Position in the expanded grid (stable across thread counts).
    pub index: usize,
    pub scheduler: String,
    pub sd: String,
    pub seed: u64,
    pub n_instances: usize,
    pub fault_name: String,
    pub faults: FaultPlan,
    /// Epoch-drift sigma; > 0 runs epoch 1 of the drifted sequence
    /// instead of the base iteration (see [`generate_epoch`]).
    pub drift: f64,
    pub workload: WorkloadConfig,
    pub system: SystemConfig,
}

impl SweepCell {
    /// Build and run this cell's rollout session, returning its
    /// deterministic (virtual-time only) result.
    pub fn run(&self) -> Result<CellResult> {
        let mut builder = RolloutSession::builder()
            .workload(self.workload.clone())
            .system(self.system.clone())
            .scheduler(&self.scheduler)
            .sd(&self.sd)
            .seed(self.seed)
            .n_instances(self.n_instances);
        if self.drift > 0.0 {
            // Workload generation is scale-independent, so the drifted
            // epoch is the same whatever `n_instances` the cell runs at.
            let w = generate_epoch(&self.workload, self.seed, 1, self.drift);
            builder = builder.groups(w.groups);
        }
        if !self.faults.is_empty() {
            builder = builder.faults(self.faults.clone());
        }
        let report = builder.run()?;
        let m = &report.metrics;
        Ok(CellResult {
            index: self.index,
            scheduler: self.scheduler.clone(),
            seed: self.seed,
            n_instances: self.n_instances,
            fault_name: self.fault_name.clone(),
            drift: self.drift,
            makespan_secs: m.makespan.as_secs_f64(),
            throughput_tok_s: m.throughput(),
            tail_secs: m.tail_time(0.10).as_secs_f64(),
            p99_finish_secs: m.finish_percentile(99.0),
            tail_packed: m.tail_packed,
            tail_resume_tokens: m.tail_resume_tokens,
            bubble_draft_secs: m.bubble_draft_time.as_secs_f64(),
            bubble_accept_tokens: m.bubble_accept_tokens,
            tokens: m.tokens_generated,
            completions: m.completions.len(),
            preemptions: m.preemptions,
            migrations: m.migrations,
            aborted: m.aborted,
            instances_lost: m.instances_lost,
        })
    }
}

/// One cell's outcome: the cell's identity plus virtual-time metrics.
/// Deliberately contains no host wall-clock field — cell results are
/// byte-identical however many threads ran them.
#[derive(Debug, Clone, PartialEq)]
pub struct CellResult {
    pub index: usize,
    pub scheduler: String,
    pub seed: u64,
    pub n_instances: usize,
    pub fault_name: String,
    pub drift: f64,
    pub makespan_secs: f64,
    pub throughput_tok_s: f64,
    pub tail_secs: f64,
    pub p99_finish_secs: f64,
    /// Tail-packing telemetry (zero for policies without tail lanes).
    pub tail_packed: u64,
    pub tail_resume_tokens: u64,
    /// Bubble-drafting telemetry (zero with `bubble_draft_frac` 0).
    pub bubble_draft_secs: f64,
    pub bubble_accept_tokens: u64,
    pub tokens: u64,
    pub completions: usize,
    pub preemptions: u64,
    pub migrations: u64,
    pub aborted: u64,
    pub instances_lost: u64,
}

impl CellResult {
    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        let mut put = |k: &str, v: Json| {
            o.insert(k.to_string(), v);
        };
        put("scheduler", Json::Str(self.scheduler.clone()));
        // String, not number: u64 seeds can exceed 2^53 (see spec echo).
        put("seed", Json::Str(self.seed.to_string()));
        put("n_instances", Json::Num(self.n_instances as f64));
        put("fault", Json::Str(self.fault_name.clone()));
        put("drift", Json::Num(self.drift));
        put("makespan_secs", Json::Num(self.makespan_secs));
        put("throughput_tok_s", Json::Num(self.throughput_tok_s));
        put("tail_secs", Json::Num(self.tail_secs));
        put("p99_finish_secs", Json::Num(self.p99_finish_secs));
        put("tail_packed", Json::Num(self.tail_packed as f64));
        put(
            "tail_resume_tokens",
            Json::Num(self.tail_resume_tokens as f64),
        );
        put(
            "bubble_draft_secs",
            Json::Num(self.bubble_draft_secs),
        );
        put(
            "bubble_accept_tokens",
            Json::Num(self.bubble_accept_tokens as f64),
        );
        put("tokens", Json::Num(self.tokens as f64));
        put("completions", Json::Num(self.completions as f64));
        put("preemptions", Json::Num(self.preemptions as f64));
        put("migrations", Json::Num(self.migrations as f64));
        put("aborted", Json::Num(self.aborted as f64));
        put("instances_lost", Json::Num(self.instances_lost as f64));
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;
    use crate::sim::faults::FaultEvent;
    use crate::workload::InstanceId;

    fn spec() -> SweepSpec {
        SweepSpec::new(TaskPreset::Moonlight.workload_for_test())
            .schedulers(&["seer", "verl"])
            .seeds([1, 2, 3])
            .scales([2, 3])
            .drifts([0.0, 0.1])
    }

    #[test]
    fn cardinality_is_dimension_product() {
        let s = spec();
        assert_eq!(s.cardinality(), 2 * 2 * 1 * 2 * 3);
        assert_eq!(s.expand().len(), s.cardinality());
        assert_eq!(s.seeds_per_group(), 3);
        // A fault dimension multiplies in.
        let s = s.fault_plan("none", FaultPlan::new()).fault_plan(
            "crash1",
            FaultPlan::new().at(
                10.0,
                FaultEvent::InstanceDown {
                    instance: InstanceId(0),
                },
            ),
        );
        assert_eq!(s.cardinality(), 2 * 2 * 2 * 2 * 3);
    }

    #[test]
    fn defaults_fill_empty_dimensions() {
        let base = TaskPreset::Moonlight.workload_for_test();
        let n = base.n_instances;
        let s = SweepSpec::new(base);
        assert_eq!(s.cardinality(), 1);
        let cells = s.expand();
        assert_eq!(cells[0].scheduler, "seer");
        assert_eq!(cells[0].n_instances, n);
        assert_eq!(cells[0].fault_name, "none");
        assert_eq!(cells[0].drift, 0.0);
        assert_eq!(cells[0].seed, 42);
    }

    #[test]
    fn expansion_order_is_stable_and_seed_innermost() {
        let s = spec();
        let a = s.expand();
        let b = s.expand();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.index, y.index);
            assert_eq!(x.scheduler, y.scheduler);
            assert_eq!((x.seed, x.n_instances, x.drift), (y.seed, y.n_instances, y.drift));
        }
        // index == position, scheduler outermost, seed innermost.
        for (i, c) in a.iter().enumerate() {
            assert_eq!(c.index, i);
        }
        assert_eq!(a[0].scheduler, "seer");
        assert_eq!(a[0].seed, 1);
        assert_eq!(a[1].seed, 2);
        assert_eq!(a[2].seed, 3);
        assert_eq!(a[3].seed, 1, "drift advances after seeds exhaust");
        assert_ne!(a[0].drift, a[3].drift);
        let half = a.len() / 2;
        assert_eq!(a[half - 1].scheduler, "seer");
        assert_eq!(a[half].scheduler, "verl");
        // Cells of one aggregate group are contiguous.
        let k = s.seeds_per_group();
        for group in a.chunks(k) {
            assert!(group.windows(2).all(|w| {
                w[0].scheduler == w[1].scheduler
                    && w[0].n_instances == w[1].n_instances
                    && w[0].fault_name == w[1].fault_name
                    && w[0].drift == w[1].drift
            }));
        }
    }

    #[test]
    fn validate_rejects_clamped_or_ignored_dimensions() {
        let base = TaskPreset::Moonlight.workload_for_test();
        assert!(SweepSpec::new(base.clone()).validate().is_ok());
        let e = SweepSpec::new(base.clone())
            .scales([2, 0])
            .validate()
            .unwrap_err();
        assert!(e.to_string().contains("scale 0"), "{e}");
        for bad in [-0.1, f64::NAN, f64::INFINITY] {
            let e = SweepSpec::new(base.clone())
                .drifts([bad])
                .validate()
                .unwrap_err();
            assert!(e.to_string().contains("drift"), "{e}");
        }
    }

    #[test]
    fn spec_json_echoes_dimensions() {
        let j = spec().to_json();
        assert_eq!(j.expect("task").as_str(), Some("moonlight"));
        assert_eq!(j.expect("schedulers").as_arr().unwrap().len(), 2);
        assert_eq!(j.expect("seeds").as_arr().unwrap().len(), 3);
        assert_eq!(j.expect("fault_plans").as_arr().unwrap().len(), 1);
        assert_eq!(
            j.expect("fault_plans").as_arr().unwrap()[0].as_str(),
            Some("none")
        );
    }
}
