//! The sweep grid: [`SweepSpec`] describes a study as the cross product
//! of scheduler policy × training mode × seed × cluster scale × fault
//! plan × drift, and expands it into independent, self-contained
//! [`SweepCell`]s.
//!
//! Expansion order is part of the spec's contract (tests pin it):
//! scheduler is the outermost dimension, then training mode, cluster
//! scale, fault plan, drift, and finally seed — so the cells belonging
//! to one aggregate group (same scheduler/mode/scale/fault/drift,
//! varying seed) are contiguous and the runner can aggregate by index
//! arithmetic without ever depending on completion order.
//!
//! With no explicit mode dimension a cell is one synchronous rollout
//! (today's behavior, byte-identical). Listing modes via
//! [`SweepSpec::mode`] switches *every* cell — `sync` included — to a
//! [`pipeline_iters`](SweepSpec::pipeline_iters)-epoch training
//! pipeline through the suspendable [`crate::rollout::RolloutStream`],
//! so mode rows compare the same amount of work: the cell's makespan
//! becomes the pipeline span (rollout overlap included) and the
//! staleness aggregates are folded per completion.

use anyhow::{bail, Result};

use crate::config::{SystemConfig, TrainingMode, WorkloadConfig};
use crate::rollout::RolloutSession;
use crate::sim::faults::FaultPlan;
use crate::util::json::Json;
use crate::workload::generate_epoch;

/// The effective dimension vectors of a spec, in expansion order:
/// `(schedulers, modes, scales, fault_plans, drifts, seeds)`.
pub type SweepDims = (
    Vec<String>,
    Vec<TrainingMode>,
    Vec<usize>,
    Vec<(String, FaultPlan)>,
    Vec<f64>,
    Vec<u64>,
);

/// A parameter grid over independent rollout runs.
///
/// Empty dimension vectors mean "the single default value" (the base
/// workload's instance count, no faults, no drift), so a spec is usable
/// straight from [`SweepSpec::new`].
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Base workload; the scale dimension overrides `n_instances`.
    pub workload: WorkloadConfig,
    pub system: SystemConfig,
    /// Registry names; `schedulers[0]` is the baseline every other
    /// policy is paired against.
    pub schedulers: Vec<String>,
    /// SD strategy registry name, shared by every cell.
    pub sd: String,
    /// Workload-generation seeds (the paired-statistics axis).
    pub seeds: Vec<u64>,
    /// Cluster scales (`n_instances` values). Empty ⇒ the base workload's.
    pub scales: Vec<usize>,
    /// Named fault scripts. Empty ⇒ one healthy plan named `"none"`.
    pub fault_plans: Vec<(String, FaultPlan)>,
    /// Epoch-drift sigmas, each ≥ 0 (0.0 = the base iteration
    /// workload; cells only apply drift when it is > 0, so negative
    /// values would run the base workload under a misleading label —
    /// the CLI rejects them).
    pub drifts: Vec<f64>,
    /// Training-mode dimension. Empty ⇒ single-rollout synchronous
    /// cells (today's behavior). Non-empty ⇒ every cell runs a
    /// [`pipeline_iters`](Self::pipeline_iters)-epoch training pipeline
    /// under its mode, `sync` included, for like-for-like rows.
    pub modes: Vec<TrainingMode>,
    /// Epochs each pipelined cell runs (only consulted when `modes` is
    /// non-empty); ≥ 1, default 2 — the smallest pipeline that shows
    /// rollout/training overlap.
    pub pipeline_iters: usize,
}

impl SweepSpec {
    pub fn new(workload: WorkloadConfig) -> Self {
        SweepSpec {
            workload,
            system: SystemConfig::default(),
            schedulers: vec!["seer".to_string()],
            sd: "grouped-cst".to_string(),
            seeds: vec![42],
            scales: Vec::new(),
            fault_plans: Vec::new(),
            drifts: Vec::new(),
            modes: Vec::new(),
            pipeline_iters: 2,
        }
    }

    pub fn system(mut self, system: SystemConfig) -> Self {
        self.system = system;
        self
    }

    pub fn schedulers<S: AsRef<str>>(mut self, names: &[S]) -> Self {
        self.schedulers = names.iter().map(|s| s.as_ref().to_string()).collect();
        self
    }

    pub fn sd(mut self, name: &str) -> Self {
        self.sd = name.to_string();
        self
    }

    pub fn seeds(mut self, seeds: impl IntoIterator<Item = u64>) -> Self {
        self.seeds = seeds.into_iter().collect();
        self
    }

    pub fn scales(mut self, scales: impl IntoIterator<Item = usize>) -> Self {
        self.scales = scales.into_iter().collect();
        self
    }

    pub fn fault_plan(mut self, name: &str, plan: FaultPlan) -> Self {
        self.fault_plans.push((name.to_string(), plan));
        self
    }

    pub fn drifts(mut self, drifts: impl IntoIterator<Item = f64>) -> Self {
        self.drifts = drifts.into_iter().collect();
        self
    }

    /// Add a training-mode dimension value (see the field docs: any
    /// explicit mode switches all cells to the multi-epoch pipeline).
    pub fn mode(mut self, mode: TrainingMode) -> Self {
        self.modes.push(mode);
        self
    }

    /// Epochs per pipelined cell (used only with an explicit mode
    /// dimension).
    pub fn pipeline_iters(mut self, n: usize) -> Self {
        self.pipeline_iters = n;
        self
    }

    /// Effective dimension values after filling empty dimensions with
    /// their defaults, in expansion order:
    /// `(schedulers, modes, scales, fault_plans, drifts, seeds)`.
    pub fn dims(&self) -> SweepDims {
        let schedulers = if self.schedulers.is_empty() {
            vec!["seer".to_string()]
        } else {
            self.schedulers.clone()
        };
        let modes = if self.modes.is_empty() {
            vec![TrainingMode::Sync]
        } else {
            self.modes.clone()
        };
        let scales = if self.scales.is_empty() {
            vec![self.workload.n_instances]
        } else {
            self.scales.clone()
        };
        let faults = if self.fault_plans.is_empty() {
            vec![("none".to_string(), FaultPlan::new())]
        } else {
            self.fault_plans.clone()
        };
        let drifts = if self.drifts.is_empty() {
            vec![0.0]
        } else {
            self.drifts.clone()
        };
        let seeds = if self.seeds.is_empty() {
            vec![42]
        } else {
            self.seeds.clone()
        };
        (schedulers, modes, scales, faults, drifts, seeds)
    }

    /// Reject dimension values the execution layer would otherwise
    /// silently clamp or ignore, mislabeling report rows: a scale of 0
    /// (the simulator clamps to 1 while the report would echo 0) and
    /// non-finite or negative drifts (cells only apply drift > 0, so
    /// such cells would be base runs under a misleading label).
    /// [`crate::sweep::SweepRunner::run`] calls this before expanding,
    /// covering every entry point, not just the CLI.
    pub fn validate(&self) -> Result<()> {
        if self.scales.contains(&0) {
            bail!("sweep scale 0 invalid: n_instances must be >= 1");
        }
        if let Some(d) =
            self.drifts.iter().find(|d| !d.is_finite() || **d < 0.0)
        {
            bail!("sweep drift {d} invalid: must be finite and >= 0");
        }
        if self.pipeline_iters == 0 {
            bail!("sweep pipeline_iters 0 invalid: must be >= 1");
        }
        Ok(())
    }

    /// Number of cells the spec expands to (the dimension product).
    pub fn cardinality(&self) -> usize {
        let (sc, m, s, f, d, k) = self.dims();
        sc.len() * m.len() * s.len() * f.len() * d.len() * k.len()
    }

    /// Seeds per aggregate group — the innermost dimension's length.
    pub fn seeds_per_group(&self) -> usize {
        self.dims().5.len()
    }

    /// Expand the grid into independent session configs, in the
    /// documented stable order. `cell.index == position` always holds.
    pub fn expand(&self) -> Vec<SweepCell> {
        let (schedulers, modes, scales, faults, drifts, seeds) = self.dims();
        let cap = schedulers.len()
            * modes.len()
            * scales.len()
            * faults.len()
            * drifts.len()
            * seeds.len();
        // An explicit mode dimension pipelines every cell; the default
        // dimension keeps the legacy single-rollout cell.
        let pipeline_iters = if self.modes.is_empty() {
            1
        } else {
            self.pipeline_iters.max(1)
        };
        let mut cells = Vec::with_capacity(cap);
        for scheduler in &schedulers {
            for &mode in &modes {
                for &n_instances in &scales {
                    for (fault_name, plan) in &faults {
                        for &drift in &drifts {
                            for &seed in &seeds {
                                cells.push(SweepCell {
                                    index: cells.len(),
                                    scheduler: scheduler.clone(),
                                    sd: self.sd.clone(),
                                    mode,
                                    pipeline_iters,
                                    seed,
                                    n_instances,
                                    fault_name: fault_name.clone(),
                                    faults: plan.clone(),
                                    drift,
                                    workload: self.workload.clone(),
                                    system: self.system.clone(),
                                });
                            }
                        }
                    }
                }
            }
        }
        cells
    }

    /// Spec echo for the report JSON (fault plans by name only — the
    /// scripts themselves live in their own files).
    pub fn to_json(&self) -> Json {
        let (schedulers, modes, scales, faults, drifts, seeds) = self.dims();
        let mut o = std::collections::BTreeMap::new();
        o.insert("task".to_string(), Json::Str(self.workload.name.to_string()));
        o.insert(
            "reqs_per_iter".to_string(),
            Json::Num(self.workload.reqs_per_iter as f64),
        );
        o.insert(
            "group_size".to_string(),
            Json::Num(self.workload.group_size as f64),
        );
        o.insert(
            "schedulers".to_string(),
            Json::Arr(schedulers.into_iter().map(Json::Str).collect()),
        );
        o.insert("sd".to_string(), Json::Str(self.sd.clone()));
        // Seeds are serialized as strings: u64 seeds (e.g. hashed ones)
        // can exceed 2^53 and would be silently rounded by a JSON
        // number, breaking replay-from-report.
        o.insert(
            "seeds".to_string(),
            Json::Arr(seeds.iter().map(|s| Json::Str(s.to_string())).collect()),
        );
        o.insert(
            "scales".to_string(),
            Json::Arr(scales.iter().map(|&s| Json::Num(s as f64)).collect()),
        );
        o.insert(
            "fault_plans".to_string(),
            Json::Arr(faults.into_iter().map(|(n, _)| Json::Str(n)).collect()),
        );
        o.insert(
            "drifts".to_string(),
            Json::Arr(drifts.iter().map(|&d| Json::Num(d)).collect()),
        );
        o.insert(
            "modes".to_string(),
            Json::Arr(modes.iter().map(|m| Json::Str(m.tag())).collect()),
        );
        o.insert(
            "pipeline_iters".to_string(),
            Json::Num(self.pipeline_iters as f64),
        );
        Json::Obj(o)
    }
}

/// One fully-specified point of the grid: everything a worker thread
/// needs to build and run a [`RolloutSession`], as plain data (nothing
/// non-`Send` crosses threads — each worker constructs its own session).
#[derive(Debug, Clone)]
pub struct SweepCell {
    /// Position in the expanded grid (stable across thread counts).
    pub index: usize,
    pub scheduler: String,
    pub sd: String,
    /// Training-mode dimension value.
    pub mode: TrainingMode,
    /// Epochs this cell runs; 1 ⇒ the legacy single-rollout cell, > 1 ⇒
    /// a multi-epoch training pipeline under `mode`.
    pub pipeline_iters: usize,
    pub seed: u64,
    pub n_instances: usize,
    pub fault_name: String,
    pub faults: FaultPlan,
    /// Epoch-drift sigma; > 0 runs epoch 1 of the drifted sequence
    /// instead of the base iteration (see [`generate_epoch`]).
    pub drift: f64,
    pub workload: WorkloadConfig,
    pub system: SystemConfig,
}

impl SweepCell {
    /// Build and run this cell, returning its deterministic
    /// (virtual-time only) result. A single-epoch `sync` cell runs the
    /// legacy single-shot session; anything else runs the multi-epoch
    /// pipeline (see [`SweepSpec::mode`]).
    pub fn run(&self) -> Result<CellResult> {
        if self.pipeline_iters > 1 || self.mode.is_pipelined() {
            return self.run_pipelined();
        }
        let mut builder = self.session_builder();
        if self.drift > 0.0 {
            // Workload generation is scale-independent, so the drifted
            // epoch is the same whatever `n_instances` the cell runs at.
            let w = generate_epoch(&self.workload, self.seed, 1, self.drift);
            builder = builder.groups(w.groups);
        }
        let report = builder.run()?;
        let m = &report.metrics;
        Ok(CellResult {
            index: self.index,
            scheduler: self.scheduler.clone(),
            mode: self.mode.tag(),
            lag: self.mode.lag() as u64,
            seed: self.seed,
            n_instances: self.n_instances,
            fault_name: self.fault_name.clone(),
            drift: self.drift,
            makespan_secs: m.makespan.as_secs_f64(),
            throughput_tok_s: m.throughput(),
            tail_secs: m.tail_time(0.10).as_secs_f64(),
            p99_finish_secs: m.finish_percentile(99.0),
            tail_packed: m.tail_packed,
            tail_resume_tokens: m.tail_resume_tokens,
            bubble_draft_secs: m.bubble_draft_time.as_secs_f64(),
            bubble_accept_tokens: m.bubble_accept_tokens,
            tokens: m.tokens_generated,
            completions: m.completions.len(),
            preemptions: m.preemptions,
            migrations: m.migrations,
            aborted: m.aborted,
            instances_lost: m.instances_lost,
            staleness_mean: 0.0,
            staleness_max: 0,
            stale_requests: 0,
            train_retries: 0,
            trainer_fault_secs: 0.0,
        })
    }

    fn session_builder(&self) -> crate::rollout::RolloutSessionBuilder<'static> {
        let mut builder = RolloutSession::builder()
            .workload(self.workload.clone())
            .system(self.system.clone())
            .scheduler(&self.scheduler)
            .sd(&self.sd)
            .seed(self.seed)
            .n_instances(self.n_instances);
        // Only the cluster half of the script reaches the rollout
        // engine; trainer-side events replay into the pipeline
        // recurrence (`run_pipelined`) instead.
        let (cluster, _) = self.faults.partition();
        if !cluster.is_empty() {
            builder = builder.faults(cluster);
        }
        builder
    }

    /// Multi-epoch pipelined cell: `pipeline_iters` cold epochs through
    /// the suspendable stream under the cell's mode, using the same
    /// `S_k = max(R_{k-1}, U_{k-1-lag})` recurrence as
    /// [`crate::iteration::TrainingDriver`]. The cell's makespan is the
    /// *pipeline span* (through the last update landing), throughput is
    /// total tokens over that span, tail/p99 come from the final epoch,
    /// and counters are summed. The fault script replays against every
    /// epoch's rollout.
    fn run_pipelined(&self) -> Result<CellResult> {
        use crate::rl::PhaseModel;
        use crate::sim::clock::SimTime;
        let lag = self.mode.lag() as usize;
        let epochs = self.pipeline_iters.max(1);
        let phase = PhaseModel::for_workload(&self.workload);
        // Trainer half of the cell's fault script, replayed into the
        // U_k recurrence through the same `trainer_step` walker the
        // training driver uses (sync ≡ async-lag-0 by construction).
        let (_, trainer) = self.faults.partition();
        let mut train_retries = 0u64;
        let mut trainer_fault_secs = 0.0f64;
        let mut r_prev = 0.0f64;
        let mut u: Vec<f64> = Vec::with_capacity(epochs);
        let (mut tokens, mut completions) = (0u64, 0usize);
        let (mut preempt, mut migr, mut aborted, mut lost) =
            (0u64, 0u64, 0u64, 0u64);
        let (mut tail_packed, mut tail_resume, mut bubble_tok) =
            (0u64, 0u64, 0u64);
        let mut bubble_secs = 0.0f64;
        let (mut stal_sum, mut stal_max, mut stale_reqs) = (0u64, 0u64, 0u64);
        let (mut tail_secs, mut p99) = (0.0f64, 0.0f64);
        for e in 0..epochs {
            let gate = if e > lag { u[e - 1 - lag] } else { 0.0 };
            let s_k = r_prev.max(gate);
            let mut builder = self.session_builder();
            if self.drift > 0.0 {
                // Continue the legacy cell's convention: drifted cells
                // run the drifted sequence starting at epoch 1.
                let w = generate_epoch(
                    &self.workload,
                    self.seed,
                    (e + 1) as u64,
                    self.drift,
                );
                builder = builder.groups(w.groups);
            }
            let mut stream = builder.start_stream()?;
            let landed = u.iter().filter(|&&t| t <= s_k).count();
            stream.set_policy_version(landed as u64);
            for j in landed..e {
                stream.run_until(SimTime::from_secs_f64(u[j] - s_k))?;
                stream.set_policy_version((j + 1) as u64);
            }
            stream.run_until(SimTime::FAR_FUTURE)?;
            let mut report = stream.finish()?;
            report.metrics.apply_staleness(e as u64);
            let m = &report.metrics;
            let split = phase.split(m.makespan, m.tokens_generated);
            let r_k = s_k + m.makespan.as_secs_f64();
            let u_prev = u.last().copied().unwrap_or(0.0);
            let train_start = r_k.max(u_prev);
            // Empty trainer plan keeps the exact historical float
            // expression (byte-identity with pre-fault reports).
            if trainer.is_empty() {
                u.push(
                    train_start
                        + split.training.as_secs_f64()
                        + split.weight_update.as_secs_f64(),
                );
            } else {
                let step = crate::sim::faults::trainer_step(
                    &trainer,
                    e,
                    train_start,
                    split.training.as_secs_f64()
                        + split.weight_update.as_secs_f64(),
                );
                u.push(step.end_secs);
                train_retries += step.retries;
                trainer_fault_secs += step.fault_secs;
            }
            r_prev = r_k;
            tokens += m.tokens_generated;
            completions += m.completions.len();
            preempt += m.preemptions;
            migr += m.migrations;
            aborted += m.aborted;
            lost += m.instances_lost;
            tail_packed += m.tail_packed;
            tail_resume += m.tail_resume_tokens;
            bubble_secs += m.bubble_draft_time.as_secs_f64();
            bubble_tok += m.bubble_accept_tokens;
            stal_sum += m.staleness_sum;
            stal_max = stal_max.max(m.staleness_max);
            stale_reqs += m.stale_requests;
            tail_secs = m.tail_time(0.10).as_secs_f64();
            p99 = m.finish_percentile(99.0);
        }
        let span = u.last().copied().unwrap_or(0.0);
        Ok(CellResult {
            index: self.index,
            scheduler: self.scheduler.clone(),
            mode: self.mode.tag(),
            lag: lag as u64,
            seed: self.seed,
            n_instances: self.n_instances,
            fault_name: self.fault_name.clone(),
            drift: self.drift,
            makespan_secs: span,
            throughput_tok_s: if span > 0.0 {
                tokens as f64 / span
            } else {
                0.0
            },
            tail_secs,
            p99_finish_secs: p99,
            tail_packed,
            tail_resume_tokens: tail_resume,
            bubble_draft_secs: bubble_secs,
            bubble_accept_tokens: bubble_tok,
            tokens,
            completions,
            preemptions: preempt,
            migrations: migr,
            aborted,
            instances_lost: lost,
            staleness_mean: if completions > 0 {
                stal_sum as f64 / completions as f64
            } else {
                0.0
            },
            staleness_max: stal_max,
            stale_requests: stale_reqs,
            train_retries,
            trainer_fault_secs,
        })
    }
}

/// One cell's outcome: the cell's identity plus virtual-time metrics.
/// Deliberately contains no host wall-clock field — cell results are
/// byte-identical however many threads ran them.
#[derive(Debug, Clone, PartialEq)]
pub struct CellResult {
    pub index: usize,
    pub scheduler: String,
    /// Training-mode tag (`"sync"`, `"hybrid"`, `"async:N"`).
    pub mode: String,
    /// Off-policy lag bound of the mode (0 for sync/legacy cells).
    pub lag: u64,
    pub seed: u64,
    pub n_instances: usize,
    pub fault_name: String,
    pub drift: f64,
    pub makespan_secs: f64,
    pub throughput_tok_s: f64,
    pub tail_secs: f64,
    pub p99_finish_secs: f64,
    /// Tail-packing telemetry (zero for policies without tail lanes).
    pub tail_packed: u64,
    pub tail_resume_tokens: u64,
    /// Bubble-drafting telemetry (zero with `bubble_draft_frac` 0).
    pub bubble_draft_secs: f64,
    pub bubble_accept_tokens: u64,
    pub tokens: u64,
    pub completions: usize,
    pub preemptions: u64,
    pub migrations: u64,
    pub aborted: u64,
    pub instances_lost: u64,
    /// Policy-version staleness aggregates (all zero for sync and
    /// legacy cells).
    pub staleness_mean: f64,
    pub staleness_max: u64,
    pub stale_requests: u64,
    /// Trainer-side fault replay totals across the cell's pipeline
    /// (zero for legacy cells and trainer-fault-free plans).
    pub train_retries: u64,
    pub trainer_fault_secs: f64,
}

impl CellResult {
    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        let mut put = |k: &str, v: Json| {
            o.insert(k.to_string(), v);
        };
        put("scheduler", Json::Str(self.scheduler.clone()));
        put("mode", Json::Str(self.mode.clone()));
        put("lag", Json::Num(self.lag as f64));
        // String, not number: u64 seeds can exceed 2^53 (see spec echo).
        put("seed", Json::Str(self.seed.to_string()));
        put("n_instances", Json::Num(self.n_instances as f64));
        put("fault", Json::Str(self.fault_name.clone()));
        put("drift", Json::Num(self.drift));
        put("makespan_secs", Json::Num(self.makespan_secs));
        put("throughput_tok_s", Json::Num(self.throughput_tok_s));
        put("tail_secs", Json::Num(self.tail_secs));
        put("p99_finish_secs", Json::Num(self.p99_finish_secs));
        put("tail_packed", Json::Num(self.tail_packed as f64));
        put(
            "tail_resume_tokens",
            Json::Num(self.tail_resume_tokens as f64),
        );
        put(
            "bubble_draft_secs",
            Json::Num(self.bubble_draft_secs),
        );
        put(
            "bubble_accept_tokens",
            Json::Num(self.bubble_accept_tokens as f64),
        );
        put("tokens", Json::Num(self.tokens as f64));
        put("completions", Json::Num(self.completions as f64));
        put("preemptions", Json::Num(self.preemptions as f64));
        put("migrations", Json::Num(self.migrations as f64));
        put("aborted", Json::Num(self.aborted as f64));
        put("instances_lost", Json::Num(self.instances_lost as f64));
        put("staleness_mean", Json::Num(self.staleness_mean));
        put("staleness_max", Json::Num(self.staleness_max as f64));
        put("stale_requests", Json::Num(self.stale_requests as f64));
        put("train_retries", Json::Num(self.train_retries as f64));
        put(
            "trainer_fault_secs",
            Json::Num(self.trainer_fault_secs),
        );
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;
    use crate::sim::faults::FaultEvent;
    use crate::workload::InstanceId;

    fn spec() -> SweepSpec {
        SweepSpec::new(TaskPreset::Moonlight.workload_for_test())
            .schedulers(&["seer", "verl"])
            .seeds([1, 2, 3])
            .scales([2, 3])
            .drifts([0.0, 0.1])
    }

    #[test]
    fn cardinality_is_dimension_product() {
        let s = spec();
        assert_eq!(s.cardinality(), 2 * 2 * 1 * 2 * 3);
        assert_eq!(s.expand().len(), s.cardinality());
        assert_eq!(s.seeds_per_group(), 3);
        // A fault dimension multiplies in.
        let s = s.fault_plan("none", FaultPlan::new()).fault_plan(
            "crash1",
            FaultPlan::new().at(
                10.0,
                FaultEvent::InstanceDown {
                    instance: InstanceId(0),
                },
            ),
        );
        assert_eq!(s.cardinality(), 2 * 2 * 2 * 2 * 3);
    }

    #[test]
    fn mode_dimension_multiplies_and_pipelines_cells() {
        let s = spec()
            .mode(TrainingMode::Sync)
            .mode(TrainingMode::Async { lag: 1 });
        assert_eq!(s.cardinality(), 2 * 2 * 2 * 2 * 3);
        let cells = s.expand();
        // Every cell of an explicit-mode spec pipelines, sync included.
        assert!(cells.iter().all(|c| c.pipeline_iters == 2));
        // Mode sits between scheduler (outermost) and scale.
        assert_eq!(cells[0].mode, TrainingMode::Sync);
        let per_mode = cells.len() / 4; // 2 schedulers × 2 modes
        assert_eq!(cells[per_mode].mode, TrainingMode::Async { lag: 1 });
        assert_eq!(cells[per_mode].scheduler, "seer");
        // Default spec keeps the legacy single-rollout cell.
        assert!(spec().expand().iter().all(|c| c.pipeline_iters == 1
            && c.mode == TrainingMode::Sync));
    }

    #[test]
    fn pipelined_async_lag_zero_cell_matches_sync_cell() {
        let run = |mode: TrainingMode| {
            let s = SweepSpec::new(TaskPreset::Moonlight.workload_for_test())
                .seeds([7])
                .mode(mode);
            s.expand()[0].run().unwrap()
        };
        let sync = run(TrainingMode::Sync);
        let lag0 = run(TrainingMode::Async { lag: 0 });
        // Identical pipeline numbers; only the labels differ.
        assert_eq!(sync.makespan_secs, lag0.makespan_secs);
        assert_eq!(sync.throughput_tok_s, lag0.throughput_tok_s);
        assert_eq!(sync.tokens, lag0.tokens);
        assert_eq!(sync.stale_requests, 0);
        assert_eq!(lag0.stale_requests, 0);
        assert_eq!(sync.mode, "sync");
        assert_eq!(lag0.mode, "async:0");
        // A real lag overlaps: strictly shorter pipeline span, bounded
        // staleness.
        let lag1 = run(TrainingMode::Async { lag: 1 });
        assert!(lag1.makespan_secs < sync.makespan_secs);
        assert!(lag1.staleness_max <= 1);
        assert!(lag1.tokens == sync.tokens);
    }

    #[test]
    fn trainer_fault_cells_pipeline_the_walker_and_stay_lag0_identical() {
        let plan = FaultPlan::new()
            .at(
                0.0,
                FaultEvent::TrainerSlowdown {
                    factor: 2.0,
                    from: 0.0,
                    until: 1.0e9,
                },
            )
            .at(0.0, FaultEvent::TrainerCrash { at_iter: 1 })
            .sorted();
        let run = |mode: TrainingMode| {
            let s = SweepSpec::new(TaskPreset::Moonlight.workload_for_test())
                .seeds([7])
                .fault_plan("trainer-chaos", plan.clone())
                .mode(mode);
            s.expand()[0].run().unwrap()
        };
        let sync = run(TrainingMode::Sync);
        let lag0 = run(TrainingMode::Async { lag: 0 });
        // The acceptance identity, at the cell layer: lag 0 under a
        // trainer plan is byte-equal to sync under the same plan.
        assert_eq!(
            {
                let mut j = sync.to_json();
                if let Json::Obj(o) = &mut j {
                    o.remove("mode");
                    o.remove("lag");
                }
                j.to_string()
            },
            {
                let mut j = lag0.to_json();
                if let Json::Obj(o) = &mut j {
                    o.remove("mode");
                    o.remove("lag");
                }
                j.to_string()
            }
        );
        assert_eq!(sync.train_retries, 1);
        assert!(sync.trainer_fault_secs > 0.0);
        // The healthy twin of the same cell reports zeros.
        let healthy = SweepSpec::new(
            TaskPreset::Moonlight.workload_for_test(),
        )
        .seeds([7])
        .mode(TrainingMode::Sync)
        .expand()[0]
            .run()
            .unwrap();
        assert_eq!(healthy.train_retries, 0);
        assert_eq!(healthy.trainer_fault_secs, 0.0);
        // Trainer events never perturb the rollouts themselves.
        assert_eq!(healthy.tokens, sync.tokens);
        assert!(sync.makespan_secs > healthy.makespan_secs);
    }

    #[test]
    fn defaults_fill_empty_dimensions() {
        let base = TaskPreset::Moonlight.workload_for_test();
        let n = base.n_instances;
        let s = SweepSpec::new(base);
        assert_eq!(s.cardinality(), 1);
        let cells = s.expand();
        assert_eq!(cells[0].scheduler, "seer");
        assert_eq!(cells[0].n_instances, n);
        assert_eq!(cells[0].fault_name, "none");
        assert_eq!(cells[0].drift, 0.0);
        assert_eq!(cells[0].seed, 42);
    }

    #[test]
    fn expansion_order_is_stable_and_seed_innermost() {
        let s = spec();
        let a = s.expand();
        let b = s.expand();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.index, y.index);
            assert_eq!(x.scheduler, y.scheduler);
            assert_eq!((x.seed, x.n_instances, x.drift), (y.seed, y.n_instances, y.drift));
        }
        // index == position, scheduler outermost, seed innermost.
        for (i, c) in a.iter().enumerate() {
            assert_eq!(c.index, i);
        }
        assert_eq!(a[0].scheduler, "seer");
        assert_eq!(a[0].seed, 1);
        assert_eq!(a[1].seed, 2);
        assert_eq!(a[2].seed, 3);
        assert_eq!(a[3].seed, 1, "drift advances after seeds exhaust");
        assert_ne!(a[0].drift, a[3].drift);
        let half = a.len() / 2;
        assert_eq!(a[half - 1].scheduler, "seer");
        assert_eq!(a[half].scheduler, "verl");
        // Cells of one aggregate group are contiguous.
        let k = s.seeds_per_group();
        for group in a.chunks(k) {
            assert!(group.windows(2).all(|w| {
                w[0].scheduler == w[1].scheduler
                    && w[0].n_instances == w[1].n_instances
                    && w[0].fault_name == w[1].fault_name
                    && w[0].drift == w[1].drift
            }));
        }
    }

    #[test]
    fn validate_rejects_clamped_or_ignored_dimensions() {
        let base = TaskPreset::Moonlight.workload_for_test();
        assert!(SweepSpec::new(base.clone()).validate().is_ok());
        let e = SweepSpec::new(base.clone())
            .scales([2, 0])
            .validate()
            .unwrap_err();
        assert!(e.to_string().contains("scale 0"), "{e}");
        for bad in [-0.1, f64::NAN, f64::INFINITY] {
            let e = SweepSpec::new(base.clone())
                .drifts([bad])
                .validate()
                .unwrap_err();
            assert!(e.to_string().contains("drift"), "{e}");
        }
    }

    #[test]
    fn spec_json_echoes_dimensions() {
        let j = spec().to_json();
        assert_eq!(j.expect("task").as_str(), Some("moonlight"));
        assert_eq!(j.expect("schedulers").as_arr().unwrap().len(), 2);
        assert_eq!(j.expect("seeds").as_arr().unwrap().len(), 3);
        assert_eq!(j.expect("fault_plans").as_arr().unwrap().len(), 1);
        assert_eq!(
            j.expect("fault_plans").as_arr().unwrap()[0].as_str(),
            Some("none")
        );
    }
}
