//! Summary statistics and histograms used by metrics and experiments.

/// Online summary of a sample set, plus exact percentiles via a retained
/// (sorted-on-demand) sample vector.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        debug_assert!(x.is_finite(), "non-finite sample {x}");
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn extend(&mut self, xs: impl IntoIterator<Item = f64>) {
        for x in xs {
            self.add(x);
        }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.sum() / self.samples.len() as f64
    }

    pub fn var(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples
            .iter()
            .cloned()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
            self.sorted = true;
        }
    }

    /// Exact percentile (nearest-rank). `p` in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        assert!(!self.samples.is_empty(), "percentile of empty summary");
        self.ensure_sorted();
        let n = self.samples.len();
        let rank = ((p / 100.0) * n as f64).ceil().max(1.0) as usize;
        self.samples[rank.min(n) - 1]
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// Fixed-bin histogram over [lo, hi); values outside clamp to edge bins.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram {
            lo,
            hi,
            counts: vec![0; bins],
        }
    }

    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let idx = if x <= self.lo {
            0
        } else if x >= self.hi {
            bins - 1
        } else {
            (((x - self.lo) / (self.hi - self.lo)) * bins as f64) as usize
        };
        self.counts[idx.min(bins - 1)] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Bin center values, for plotting/printing.
    pub fn centers(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        (0..self.counts.len())
            .map(|i| self.lo + w * (i as f64 + 0.5))
            .collect()
    }

    /// Render a one-line-per-bin ASCII bar chart (experiment harness
    /// output for the paper's distribution figures).
    pub fn render(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let centers = self.centers();
        let mut out = String::new();
        for (c, n) in centers.iter().zip(&self.counts) {
            let bar = "#".repeat((n * width as u64 / max) as usize);
            out.push_str(&format!("{c:>12.0} | {bar} {n}\n"));
        }
        out
    }
}

/// Weighted mean helper for throughput-style ratios.
pub fn weighted_mean(pairs: &[(f64, f64)]) -> f64 {
    let (num, den) = pairs
        .iter()
        .fold((0.0, 0.0), |(n, d), (v, w)| (n + v * w, d + w));
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        s.extend([1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.median(), 2.0);
        assert_eq!(s.percentile(100.0), 4.0);
        assert_eq!(s.percentile(25.0), 1.0);
    }

    #[test]
    fn summary_var() {
        let mut s = Summary::new();
        s.extend([2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.var() - 4.0).abs() < 1e-12);
        assert!((s.std() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_after_add_resorts() {
        let mut s = Summary::new();
        s.extend([5.0, 1.0]);
        assert_eq!(s.median(), 1.0);
        s.add(0.5);
        assert_eq!(s.median(), 1.0);
        s.add(0.1);
        assert_eq!(s.percentile(25.0), 0.1);
    }

    #[test]
    fn histogram_bins_and_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(0.5);
        h.add(9.99);
        h.add(-5.0); // clamps to bin 0
        h.add(50.0); // clamps to last bin
        assert_eq!(h.counts[0], 2);
        assert_eq!(h.counts[9], 2);
        assert_eq!(h.total(), 4);
        assert_eq!(h.centers()[0], 0.5);
    }

    #[test]
    fn weighted_mean_works() {
        assert_eq!(weighted_mean(&[(2.0, 1.0), (4.0, 3.0)]), 3.5);
        assert_eq!(weighted_mean(&[]), 0.0);
    }
}
