//! Summary statistics and histograms used by metrics and experiments,
//! plus the paired-comparison layer the sweep runner reports through:
//! seeded-bootstrap percentile confidence intervals and per-seed paired
//! speedup / tail-reduction between two policies
//! ([`bootstrap_mean_ci`], [`paired_speedup`], [`paired_tail_reduction`]).
//! Everything is deterministic in its `seed` argument (the resampler is
//! the in-tree [`crate::sim::Rng`]), so sweep reports are byte-identical
//! across runs and thread counts.

use crate::sim::Rng;

/// Online summary of a sample set, plus exact percentiles via a retained
/// (sorted-on-demand) sample vector.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        debug_assert!(x.is_finite(), "non-finite sample {x}");
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn extend(&mut self, xs: impl IntoIterator<Item = f64>) {
        for x in xs {
            self.add(x);
        }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.sum() / self.samples.len() as f64
    }

    pub fn var(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples
            .iter()
            .cloned()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
            self.sorted = true;
        }
    }

    /// Exact percentile (nearest-rank). `p` in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        assert!(!self.samples.is_empty(), "percentile of empty summary");
        self.ensure_sorted();
        let n = self.samples.len();
        let rank = ((p / 100.0) * n as f64).ceil().max(1.0) as usize;
        self.samples[rank.min(n) - 1]
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// Fixed-bin histogram over [lo, hi); values outside clamp to edge bins.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram {
            lo,
            hi,
            counts: vec![0; bins],
        }
    }

    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let idx = if x <= self.lo {
            0
        } else if x >= self.hi {
            bins - 1
        } else {
            (((x - self.lo) / (self.hi - self.lo)) * bins as f64) as usize
        };
        self.counts[idx.min(bins - 1)] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Bin center values, for plotting/printing.
    pub fn centers(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        (0..self.counts.len())
            .map(|i| self.lo + w * (i as f64 + 0.5))
            .collect()
    }

    /// Render a one-line-per-bin ASCII bar chart (experiment harness
    /// output for the paper's distribution figures).
    pub fn render(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let centers = self.centers();
        let mut out = String::new();
        for (c, n) in centers.iter().zip(&self.counts) {
            let bar = "#".repeat((n * width as u64 / max) as usize);
            out.push_str(&format!("{c:>12.0} | {bar} {n}\n"));
        }
        out
    }
}

/// Weighted mean helper for throughput-style ratios.
pub fn weighted_mean(pairs: &[(f64, f64)]) -> f64 {
    let (num, den) = pairs
        .iter()
        .fold((0.0, 0.0), |(n, d), (v, w)| (n + v * w, d + w));
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

// ---------------------------------------------------------------------
// Paired statistics (sweep layer).
// ---------------------------------------------------------------------

/// Default bootstrap resample count for the sweep report.
pub const BOOTSTRAP_RESAMPLES: usize = 1000;
/// Default confidence level for the sweep report's intervals.
pub const BOOTSTRAP_LEVEL: f64 = 0.95;

/// A percentile confidence interval.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Ci {
    pub lo: f64,
    pub hi: f64,
    /// Confidence level the bounds were computed at (e.g. 0.95).
    pub level: f64,
}

/// Seeded-bootstrap percentile CI for the mean of `xs`.
///
/// Resamples `xs` with replacement `resamples` times using the
/// deterministic in-tree RNG, takes the mean of each resample, and
/// returns the `[(1-level)/2, 1-(1-level)/2]` percentiles of that
/// bootstrap distribution (nearest-rank, via [`Summary::percentile`]).
/// Fewer than two samples give the degenerate interval `[mean, mean]` —
/// there is nothing to resample. Deterministic in `(xs, level,
/// resamples, seed)`.
pub fn bootstrap_mean_ci(
    xs: &[f64],
    level: f64,
    resamples: usize,
    seed: u64,
) -> Ci {
    assert!((0.0..1.0).contains(&level) && level > 0.0, "level {level}");
    let n = xs.len();
    if n < 2 {
        let m = if n == 1 { xs[0] } else { 0.0 };
        return Ci { lo: m, hi: m, level };
    }
    let mut rng = Rng::new(seed);
    let mut means = Summary::new();
    for _ in 0..resamples.max(1) {
        let mut sum = 0.0;
        for _ in 0..n {
            sum += xs[rng.below(n as u64) as usize];
        }
        means.add(sum / n as f64);
    }
    let alpha = (1.0 - level) / 2.0;
    Ci {
        lo: means.percentile(100.0 * alpha),
        hi: means.percentile(100.0 * (1.0 - alpha)),
        level,
    }
}

/// A paired per-seed comparison between a baseline and a candidate
/// policy: the mean of the per-seed statistic, its seeded-bootstrap CI,
/// and how many seeds favour the candidate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Paired {
    /// Number of paired observations (seeds).
    pub n: usize,
    /// Mean of the per-seed statistic (ratio or reduction).
    pub mean: f64,
    pub ci: Ci,
    /// Seeds where the candidate beat the baseline (ratio > 1 for
    /// speedups, reduction > 0 for tail reductions).
    pub wins: usize,
}

fn paired_from(stats: Vec<f64>, win: impl Fn(f64) -> bool, seed: u64) -> Paired {
    let n = stats.len();
    let mean = if n == 0 {
        0.0
    } else {
        stats.iter().sum::<f64>() / n as f64
    };
    let wins = stats.iter().filter(|&&s| win(s)).count();
    let ci = bootstrap_mean_ci(&stats, BOOTSTRAP_LEVEL, BOOTSTRAP_RESAMPLES, seed);
    Paired { n, mean, ci, wins }
}

/// Per-seed paired speedup of `candidate` over `baseline`: the mean of
/// `baseline[i] / candidate[i]` (makespan-style — smaller candidate
/// values are speedups > 1), with a seeded-bootstrap CI. The two slices
/// must be seed-aligned and equally long.
pub fn paired_speedup(baseline: &[f64], candidate: &[f64], seed: u64) -> Paired {
    assert_eq!(
        baseline.len(),
        candidate.len(),
        "paired comparison needs seed-aligned samples"
    );
    let ratios: Vec<f64> = baseline
        .iter()
        .zip(candidate)
        .map(|(&b, &c)| b / c.max(1e-12))
        .collect();
    paired_from(ratios, |r| r > 1.0, seed)
}

/// Per-seed paired tail reduction of `candidate` vs `baseline`: the mean
/// of `1 - candidate[i] / baseline[i]` (the paper's 72–94% framing —
/// positive means the candidate's tail is shorter), with a
/// seeded-bootstrap CI.
pub fn paired_tail_reduction(
    baseline: &[f64],
    candidate: &[f64],
    seed: u64,
) -> Paired {
    assert_eq!(
        baseline.len(),
        candidate.len(),
        "paired comparison needs seed-aligned samples"
    );
    let reductions: Vec<f64> = baseline
        .iter()
        .zip(candidate)
        .map(|(&b, &c)| 1.0 - c / b.max(1e-12))
        .collect();
    paired_from(reductions, |d| d > 0.0, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        s.extend([1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.median(), 2.0);
        assert_eq!(s.percentile(100.0), 4.0);
        assert_eq!(s.percentile(25.0), 1.0);
    }

    #[test]
    fn summary_var() {
        let mut s = Summary::new();
        s.extend([2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.var() - 4.0).abs() < 1e-12);
        assert!((s.std() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_after_add_resorts() {
        let mut s = Summary::new();
        s.extend([5.0, 1.0]);
        assert_eq!(s.median(), 1.0);
        s.add(0.5);
        assert_eq!(s.median(), 1.0);
        s.add(0.1);
        assert_eq!(s.percentile(25.0), 0.1);
    }

    #[test]
    fn histogram_bins_and_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(0.5);
        h.add(9.99);
        h.add(-5.0); // clamps to bin 0
        h.add(50.0); // clamps to last bin
        assert_eq!(h.counts[0], 2);
        assert_eq!(h.counts[9], 2);
        assert_eq!(h.total(), 4);
        assert_eq!(h.centers()[0], 0.5);
    }

    #[test]
    fn weighted_mean_works() {
        assert_eq!(weighted_mean(&[(2.0, 1.0), (4.0, 3.0)]), 3.5);
        assert_eq!(weighted_mean(&[]), 0.0);
    }

    #[test]
    fn bootstrap_constant_samples_collapse_exactly() {
        // Every resample of a constant sample has the same mean, so the
        // percentile interval is exactly [c, c] whatever the seed.
        let ci = bootstrap_mean_ci(&[3.5; 8], 0.95, 200, 17);
        assert_eq!(ci.lo, 3.5);
        assert_eq!(ci.hi, 3.5);
        assert_eq!(ci.level, 0.95);
    }

    #[test]
    fn bootstrap_degenerate_sizes() {
        let ci = bootstrap_mean_ci(&[], 0.9, 100, 1);
        assert_eq!((ci.lo, ci.hi), (0.0, 0.0));
        let ci = bootstrap_mean_ci(&[7.0], 0.9, 100, 1);
        assert_eq!((ci.lo, ci.hi), (7.0, 7.0));
    }

    #[test]
    fn bootstrap_is_seeded_and_ordered() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let a = bootstrap_mean_ci(&xs, 0.95, 500, 42);
        let b = bootstrap_mean_ci(&xs, 0.95, 500, 42);
        // Exactly reproducible from the seed.
        assert_eq!(a, b);
        let c = bootstrap_mean_ci(&xs, 0.95, 500, 43);
        assert_ne!(a, c, "different seed must resample differently");
        // The interval brackets the sample mean and is sane.
        let mean = 4.5;
        assert!(a.lo <= mean && mean <= a.hi, "{a:?}");
        assert!(a.lo >= 1.0 && a.hi <= 8.0);
        // Wider confidence ⇒ interval at least as wide.
        let w = bootstrap_mean_ci(&xs, 0.99, 500, 42);
        assert!(w.lo <= a.lo && w.hi >= a.hi, "{w:?} vs {a:?}");
    }

    #[test]
    fn paired_speedup_exact_values() {
        // Ratios are [2, 2]: exact mean, exact degenerate CI, both wins.
        let p = paired_speedup(&[2.0, 4.0], &[1.0, 2.0], 7);
        assert_eq!(p.n, 2);
        assert_eq!(p.mean, 2.0);
        assert_eq!(p.wins, 2);
        assert_eq!((p.ci.lo, p.ci.hi), (2.0, 2.0));
        // A mixed outcome: ratios [2.0, 0.5] ⇒ mean 1.25, one win.
        let p = paired_speedup(&[2.0, 1.0], &[1.0, 2.0], 7);
        assert_eq!(p.mean, 1.25);
        assert_eq!(p.wins, 1);
    }

    #[test]
    fn paired_tail_reduction_exact_values() {
        // Reductions are [0.8, 0.5] ⇒ mean 0.65 exactly, both wins.
        let p = paired_tail_reduction(&[10.0, 10.0], &[2.0, 5.0], 9);
        assert_eq!(p.n, 2);
        assert_eq!(p.mean, 0.65);
        assert_eq!(p.wins, 2);
        // A regression (candidate tail longer) is a negative reduction.
        let p = paired_tail_reduction(&[10.0], &[15.0], 9);
        assert_eq!(p.mean, -0.5);
        assert_eq!(p.wins, 0);
    }

    #[test]
    fn paired_is_deterministic_in_seed() {
        let base = [10.0, 12.0, 9.0, 14.0];
        let cand = [6.0, 7.0, 8.0, 6.5];
        assert_eq!(
            paired_speedup(&base, &cand, 11),
            paired_speedup(&base, &cand, 11)
        );
        assert_eq!(
            paired_tail_reduction(&base, &cand, 11),
            paired_tail_reduction(&base, &cand, 11)
        );
    }

    #[test]
    #[should_panic(expected = "seed-aligned")]
    fn paired_rejects_mismatched_lengths() {
        paired_speedup(&[1.0, 2.0], &[1.0], 0);
    }
}
