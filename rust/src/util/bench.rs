//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! `bench` runs a closure until both a minimum iteration count and a
//! minimum wall time are reached, then reports mean/min ns per iteration.
//! Results are printed in a stable, greppable format plus a
//! machine-readable JSON line for trajectory tooling:
//!
//! ```text
//! bench <name>: mean 123.4ns min 110.0ns (n=10000)
//! bench_json {"iters":10000,"mean_ns":123.4,"min_ns":110,"name":"<name>"}
//! ```
//!
//! Both lines go to **stderr**, so a program that benches mid-run keeps
//! its stdout machine-parseable (`seer sweep --bench-out` emits pure
//! report JSON on stdout while the suite narrates on stderr).
//!
//! `SEER_BENCH_MS` controls the minimum wall time per bench; the special
//! value `0` is a CI smoke mode — no warmup and exactly one timed
//! iteration, so a bench suite completes in one pass. [`BenchSuite`]
//! collects named results and writes them as one JSON document (the
//! `BENCH_*.json` baseline files).

use std::path::Path;
use std::time::Instant;

use crate::util::json::Json;

#[derive(Debug, Clone, Copy)]
pub struct BenchResult {
    pub mean_ns: f64,
    pub min_ns: f64,
    pub iters: u64,
}

impl BenchResult {
    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("mean_ns".to_string(), Json::Num(self.mean_ns));
        o.insert("min_ns".to_string(), Json::Num(self.min_ns));
        o.insert("iters".to_string(), Json::Num(self.iters as f64));
        Json::Obj(o)
    }
}

/// Benchmark `f`, returning per-iteration statistics.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    let ms: Option<u64> = std::env::var("SEER_BENCH_MS")
        .ok()
        .and_then(|s| s.parse().ok());
    let r = if ms == Some(0) {
        // CI smoke mode: exactly one timed iteration, no warmup. The old
        // behaviour ran the timing loop zero times (0/0 statistics);
        // falling back to 300 ms would defeat the point of the knob.
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_nanos() as f64;
        BenchResult {
            mean_ns: dt,
            min_ns: dt,
            iters: 1,
        }
    } else {
        // Warmup.
        for _ in 0..3 {
            f();
        }
        let min_time = std::time::Duration::from_millis(ms.unwrap_or(300));
        let mut iters = 0u64;
        let mut min_ns = f64::INFINITY;
        let start = Instant::now();
        // Batched timing: measure in growing batches to amortize clock
        // reads.
        let mut batch = 1u64;
        while start.elapsed() < min_time {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            let dt = t0.elapsed().as_nanos() as f64 / batch as f64;
            min_ns = min_ns.min(dt);
            iters += batch;
            if batch < 1024 {
                batch *= 2;
            }
        }
        BenchResult {
            mean_ns: start.elapsed().as_nanos() as f64 / iters as f64,
            min_ns,
            iters,
        }
    };
    eprintln!(
        "bench {name}: mean {} min {} (n={})",
        fmt_ns(r.mean_ns),
        fmt_ns(r.min_ns),
        r.iters
    );
    let mut o = match r.to_json() {
        Json::Obj(o) => o,
        _ => unreachable!(),
    };
    o.insert("name".to_string(), Json::Str(name.to_string()));
    eprintln!("bench_json {}", Json::Obj(o));
    r
}

/// Benchmark returning a value (prevents dead-code elimination).
pub fn bench_val<T, F: FnMut() -> T>(name: &str, mut f: F) -> BenchResult {
    bench(name, || {
        std::hint::black_box(f());
    })
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.2}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2}µs", ns / 1e3)
    } else {
        format!("{ns:.1}ns")
    }
}

/// A named collection of bench results, written as one JSON baseline
/// file (the repo's `BENCH_*.json` perf trajectory). The sim hot path's
/// suite is built by [`crate::sweep::rollout_bench_suite`] and emitted
/// by `seer sweep --bench-out`.
#[derive(Debug, Clone, Default)]
pub struct BenchSuite {
    name: String,
    results: Vec<(String, BenchResult)>,
}

impl BenchSuite {
    pub fn new(name: &str) -> Self {
        BenchSuite {
            name: name.to_string(),
            results: Vec::new(),
        }
    }

    /// Run `f` under [`bench`] and record the result under `name`.
    pub fn run<F: FnMut()>(&mut self, name: &str, f: F) -> BenchResult {
        let r = bench(name, f);
        self.record(name, r);
        r
    }

    /// Record an externally produced result.
    pub fn record(&mut self, name: &str, r: BenchResult) {
        self.results.push((name.to_string(), r));
    }

    pub fn len(&self) -> usize {
        self.results.len()
    }

    pub fn is_empty(&self) -> bool {
        self.results.is_empty()
    }

    /// `{"suite": <name>, "benches": {<name>: {iters, mean_ns, min_ns}}}`
    pub fn to_json(&self) -> Json {
        let mut benches = std::collections::BTreeMap::new();
        for (name, r) in &self.results {
            benches.insert(name.clone(), r.to_json());
        }
        let mut o = std::collections::BTreeMap::new();
        o.insert("suite".to_string(), Json::Str(self.name.clone()));
        o.insert("benches".to_string(), Json::Obj(benches));
        Json::Obj(o)
    }

    /// Write the suite as a JSON baseline file.
    pub fn write(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .map_err(|e| anyhow::anyhow!("writing {path:?}: {e}"))
    }
}

/// Serializes tests (and in-crate callers) that mutate `SEER_BENCH_MS` —
/// the environment is process-global and `cargo test` runs in parallel.
#[cfg(test)]
pub fn env_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reports_reasonable_numbers() {
        let _guard = env_lock();
        std::env::set_var("SEER_BENCH_MS", "10");
        let r = bench("noop", || {});
        std::env::remove_var("SEER_BENCH_MS");
        assert!(r.iters > 0);
        assert!(r.min_ns >= 0.0 && r.mean_ns >= r.min_ns * 0.01);
    }

    #[test]
    fn bench_ms_zero_is_single_iteration_smoke() {
        let _guard = env_lock();
        std::env::set_var("SEER_BENCH_MS", "0");
        let mut calls = 0u64;
        let r = bench("smoke", || calls += 1);
        std::env::remove_var("SEER_BENCH_MS");
        // No warmup, exactly one timed call, sane statistics.
        assert_eq!(calls, 1);
        assert_eq!(r.iters, 1);
        assert!(r.mean_ns.is_finite() && r.mean_ns >= 0.0);
        assert_eq!(r.mean_ns, r.min_ns);
    }

    #[test]
    fn result_json_shape() {
        let r = BenchResult {
            mean_ns: 12.5,
            min_ns: 10.0,
            iters: 4,
        };
        assert_eq!(
            r.to_json().to_string(),
            r#"{"iters":4,"mean_ns":12.5,"min_ns":10}"#
        );
    }

    #[test]
    fn suite_collects_and_serializes() {
        let _guard = env_lock();
        std::env::set_var("SEER_BENCH_MS", "0");
        let mut s = BenchSuite::new("demo");
        s.run("a", || {});
        s.record(
            "b",
            BenchResult {
                mean_ns: 1.0,
                min_ns: 1.0,
                iters: 1,
            },
        );
        std::env::remove_var("SEER_BENCH_MS");
        assert_eq!(s.len(), 2);
        let j = s.to_json();
        assert_eq!(j.expect("suite").as_str(), Some("demo"));
        assert!(j.expect("benches").expect("a").expect("iters").as_u64() == Some(1));
        assert!(j.expect("benches").get("b").is_some());
        // Round-trips through the parser.
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn suite_writes_file() {
        let _guard = env_lock();
        std::env::set_var("SEER_BENCH_MS", "0");
        let mut s = BenchSuite::new("io");
        s.run("noop", || {});
        std::env::remove_var("SEER_BENCH_MS");
        let path = std::env::temp_dir().join("seer_bench_suite_test.json");
        s.write(&path).unwrap();
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back.expect("suite").as_str(), Some("io"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(12.3), "12.3ns");
        assert_eq!(fmt_ns(1234.0), "1.23µs");
        assert_eq!(fmt_ns(1.5e6), "1.50ms");
        assert_eq!(fmt_ns(2.5e9), "2.50s");
    }
}
