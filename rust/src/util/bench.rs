//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! `bench` runs a closure until both a minimum iteration count and a
//! minimum wall time are reached, then reports mean/min ns per iteration.
//! Results are printed in a stable, greppable format:
//!
//! ```text
//! bench <name>: mean 123.4ns min 110.0ns (n=10000)
//! ```

use std::time::Instant;

#[derive(Debug, Clone, Copy)]
pub struct BenchResult {
    pub mean_ns: f64,
    pub min_ns: f64,
    pub iters: u64,
}

/// Benchmark `f`, returning per-iteration statistics.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    // Warmup.
    for _ in 0..3 {
        f();
    }
    let min_time = std::time::Duration::from_millis(
        std::env::var("SEER_BENCH_MS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(300),
    );
    let mut iters = 0u64;
    let mut min_ns = f64::INFINITY;
    let start = Instant::now();
    // Batched timing: measure in growing batches to amortize clock reads.
    let mut batch = 1u64;
    while start.elapsed() < min_time {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed().as_nanos() as f64 / batch as f64;
        min_ns = min_ns.min(dt);
        iters += batch;
        if batch < 1024 {
            batch *= 2;
        }
    }
    let mean_ns = start.elapsed().as_nanos() as f64 / iters as f64;
    let r = BenchResult {
        mean_ns,
        min_ns,
        iters,
    };
    println!(
        "bench {name}: mean {} min {} (n={iters})",
        fmt_ns(mean_ns),
        fmt_ns(min_ns)
    );
    r
}

/// Benchmark returning a value (prevents dead-code elimination).
pub fn bench_val<T, F: FnMut() -> T>(name: &str, mut f: F) -> BenchResult {
    bench(name, || {
        std::hint::black_box(f());
    })
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.2}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2}µs", ns / 1e3)
    } else {
        format!("{ns:.1}ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reports_reasonable_numbers() {
        std::env::set_var("SEER_BENCH_MS", "10");
        let r = bench("noop", || {});
        assert!(r.iters > 0);
        assert!(r.min_ns >= 0.0 && r.mean_ns >= r.min_ns * 0.01);
        std::env::remove_var("SEER_BENCH_MS");
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(12.3), "12.3ns");
        assert_eq!(fmt_ns(1234.0), "1.23µs");
        assert_eq!(fmt_ns(1.5e6), "1.50ms");
        assert_eq!(fmt_ns(2.5e9), "2.50s");
    }
}
