//! A dense bitset over contiguous `u32` ids.
//!
//! The coordinator's waiting set used to be a `BTreeSet<RequestId>`;
//! request ids are contiguous from 0 by construction
//! (`RequestBuffer::from_groups` asserts it), so a fixed-capacity bitset
//! gives O(1) insert/remove/contains and word-at-a-time iteration while
//! preserving the property the rest of the system relies on: **iteration
//! yields ids in ascending order**, exactly like the ordered set it
//! replaces. Schedulers and the event loop depend on that order for
//! byte-identical reports — do not swap this for a hash set.

/// Fixed-capacity set of `u32` ids in `0..capacity`.
#[derive(Debug, Clone, Default)]
pub struct IdBitSet {
    words: Vec<u64>,
    len: usize,
}

impl IdBitSet {
    /// An empty set able to hold ids `0..capacity`.
    pub fn with_capacity(capacity: usize) -> Self {
        IdBitSet {
            words: vec![0; capacity.div_ceil(64)],
            len: 0,
        }
    }

    /// Number of ids currently in the set (O(1)).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn contains(&self, id: u32) -> bool {
        let (w, b) = (id as usize / 64, id as usize % 64);
        self.words.get(w).is_some_and(|word| word & (1 << b) != 0)
    }

    /// Insert `id`; returns whether it was newly inserted.
    pub fn insert(&mut self, id: u32) -> bool {
        let (w, b) = (id as usize / 64, id as usize % 64);
        let word = &mut self.words[w];
        let mask = 1u64 << b;
        if *word & mask != 0 {
            return false;
        }
        *word |= mask;
        self.len += 1;
        true
    }

    /// Remove `id`; returns whether it was present.
    pub fn remove(&mut self, id: u32) -> bool {
        let (w, b) = (id as usize / 64, id as usize % 64);
        let Some(word) = self.words.get_mut(w) else {
            return false;
        };
        let mask = 1u64 << b;
        if *word & mask == 0 {
            return false;
        }
        *word &= !mask;
        self.len -= 1;
        true
    }

    /// Iterate the set ids in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = u32> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &word)| {
            let mut w = word;
            std::iter::from_fn(move || {
                if w == 0 {
                    return None;
                }
                let b = w.trailing_zeros();
                w &= w - 1;
                Some(wi as u32 * 64 + b)
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_remove_contains_len() {
        let mut s = IdBitSet::with_capacity(200);
        assert!(s.is_empty());
        assert!(s.insert(0));
        assert!(s.insert(63));
        assert!(s.insert(64));
        assert!(s.insert(199));
        assert!(!s.insert(63), "double insert must report false");
        assert_eq!(s.len(), 4);
        assert!(s.contains(64));
        assert!(!s.contains(65));
        assert!(s.remove(64));
        assert!(!s.remove(64), "double remove must report false");
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn iterates_in_ascending_order() {
        let mut s = IdBitSet::with_capacity(300);
        for id in [250u32, 3, 64, 0, 127, 128, 65] {
            s.insert(id);
        }
        let got: Vec<u32> = s.iter().collect();
        assert_eq!(got, vec![0, 3, 64, 65, 127, 128, 250]);
    }

    #[test]
    fn matches_btreeset_on_random_churn() {
        use std::collections::BTreeSet;
        let mut rng = crate::sim::Rng::new(0xB17);
        let mut s = IdBitSet::with_capacity(512);
        let mut reference: BTreeSet<u32> = BTreeSet::new();
        for _ in 0..4000 {
            let id = rng.below(512) as u32;
            if rng.bool(0.5) {
                assert_eq!(s.insert(id), reference.insert(id));
            } else {
                assert_eq!(s.remove(id), reference.remove(&id));
            }
        }
        assert_eq!(s.len(), reference.len());
        let got: Vec<u32> = s.iter().collect();
        let want: Vec<u32> = reference.iter().copied().collect();
        assert_eq!(got, want);
    }
}
