//! ASCII table rendering for the experiment harness, matching the
//! rows/columns the paper's tables and figures report.

/// A simple column-aligned table with a title and optional footnote.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub note: Option<String>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
            note: None,
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn note(&mut self, note: &str) -> &mut Self {
        self.note = Some(note.to_string());
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncols)
                .map(|i| format!(" {:<w$} ", cells[i], w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        if let Some(n) = &self.note {
            out.push_str(&format!("note: {n}\n"));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a ratio like the paper's "1.42x".
pub fn fmt_x(v: f64) -> String {
    format!("{v:.2}x")
}

/// Format a percentage.
pub fn fmt_pct(v: f64) -> String {
    format!("{:.0}%", v * 100.0)
}

/// Format seconds human-readably.
pub fn fmt_secs(s: f64) -> String {
    if s >= 3600.0 {
        format!("{:.2}h", s / 3600.0)
    } else if s >= 60.0 {
        format!("{:.1}m", s / 60.0)
    } else {
        format!("{s:.1}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["Method", "Speedup"]);
        t.row_strs(&["Baseline", "1.00x"]);
        t.row_strs(&["+ Divided Rollout", "1.41x"]);
        let r = t.render();
        assert!(r.contains("## Demo"));
        assert!(r.contains("+ Divided Rollout"));
        // every data line has the same total width
        let lines: Vec<&str> =
            r.lines().filter(|l| l.contains('|')).collect();
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("X", &["a", "b"]);
        t.row_strs(&["only one"]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_x(1.4142), "1.41x");
        assert_eq!(fmt_pct(0.72), "72%");
        assert_eq!(fmt_secs(12.3), "12.3s");
        assert_eq!(fmt_secs(120.0), "2.0m");
        assert_eq!(fmt_secs(7200.0), "2.00h");
    }
}
