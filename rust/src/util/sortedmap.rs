//! A dense ordered map over small key sets: a sorted `Vec` of
//! `(key, value)` pairs with binary-search lookup.
//!
//! This is the engine's slot-table replacement for `BTreeMap` on the
//! event-loop hot path (per-instance `running`/`pending` sets). Batch
//! sizes are bounded by the instance batch cap, so a contiguous sorted
//! vector beats a node-based tree on every operation that matters here:
//! lookups are a cache-friendly binary search, iteration is a linear
//! scan over one allocation, and inserts/removes are a short `memmove`.
//!
//! **Iteration order is ascending key order and is load-bearing**: the
//! cluster driver iterates these tables to build commit/finish event
//! sequences, and the determinism (and byte-identity) of report JSON
//! depends on visiting requests in ascending `RequestId` order — exactly
//! the order the previous `BTreeMap` representation produced. Do not
//! replace this with a hash map or an insertion-ordered table.

/// A map from `K` to `V` stored as a sorted vector of pairs.
#[derive(Debug, Clone, Default)]
pub struct SortedVecMap<K: Ord + Copy, V> {
    entries: Vec<(K, V)>,
}

impl<K: Ord + Copy, V> SortedVecMap<K, V> {
    pub fn new() -> Self {
        SortedVecMap { entries: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        SortedVecMap {
            entries: Vec::with_capacity(n),
        }
    }

    fn pos(&self, k: &K) -> Result<usize, usize> {
        self.entries.binary_search_by(|(ek, _)| ek.cmp(k))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn clear(&mut self) {
        self.entries.clear();
    }

    pub fn contains_key(&self, k: &K) -> bool {
        self.pos(k).is_ok()
    }

    pub fn get(&self, k: &K) -> Option<&V> {
        self.pos(k).ok().map(|i| &self.entries[i].1)
    }

    pub fn get_mut(&mut self, k: &K) -> Option<&mut V> {
        match self.pos(k) {
            Ok(i) => Some(&mut self.entries[i].1),
            Err(_) => None,
        }
    }

    /// Insert `v` under `k`, returning the previous value if any
    /// (`BTreeMap::insert` semantics).
    pub fn insert(&mut self, k: K, v: V) -> Option<V> {
        match self.pos(&k) {
            Ok(i) => Some(std::mem::replace(&mut self.entries[i].1, v)),
            Err(i) => {
                self.entries.insert(i, (k, v));
                None
            }
        }
    }

    /// Remove the entry under `k`, returning its value if present.
    pub fn remove(&mut self, k: &K) -> Option<V> {
        match self.pos(k) {
            Ok(i) => Some(self.entries.remove(i).1),
            Err(_) => None,
        }
    }

    /// Keys in ascending order.
    pub fn keys(&self) -> impl Iterator<Item = &K> + '_ {
        self.entries.iter().map(|(k, _)| k)
    }

    /// Values in ascending key order.
    pub fn values(&self) -> impl Iterator<Item = &V> + '_ {
        self.entries.iter().map(|(_, v)| v)
    }

    /// `(key, value)` pairs in ascending key order.
    pub fn iter(&self) -> impl Iterator<Item = (&K, &V)> + '_ {
        self.entries.iter().map(|(k, v)| (k, v))
    }

    /// Mutable `(key, value)` pairs in ascending key order.
    pub fn iter_mut(&mut self) -> impl Iterator<Item = (&K, &mut V)> + '_ {
        self.entries.iter_mut().map(|(k, v)| (&*k, v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut m: SortedVecMap<u32, &str> = SortedVecMap::new();
        assert!(m.is_empty());
        assert_eq!(m.insert(5, "five"), None);
        assert_eq!(m.insert(1, "one"), None);
        assert_eq!(m.insert(3, "three"), None);
        assert_eq!(m.len(), 3);
        assert_eq!(m.get(&3), Some(&"three"));
        assert_eq!(m.insert(3, "drei"), Some("three"));
        assert_eq!(m.remove(&3), Some("drei"));
        assert_eq!(m.remove(&3), None);
        assert!(!m.contains_key(&3));
        assert!(m.contains_key(&1));
    }

    #[test]
    fn iteration_is_ascending_key_order() {
        let mut m: SortedVecMap<u32, u32> = SortedVecMap::new();
        for k in [9u32, 2, 7, 4, 0] {
            m.insert(k, k * 10);
        }
        let keys: Vec<u32> = m.keys().copied().collect();
        assert_eq!(keys, vec![0, 2, 4, 7, 9]);
        let pairs: Vec<(u32, u32)> = m.iter().map(|(k, v)| (*k, *v)).collect();
        assert_eq!(pairs, vec![(0, 0), (2, 20), (4, 40), (7, 70), (9, 90)]);
    }

    #[test]
    fn get_mut_and_iter_mut_mutate_in_place() {
        let mut m: SortedVecMap<u32, u32> = SortedVecMap::new();
        m.insert(1, 10);
        m.insert(2, 20);
        *m.get_mut(&1).unwrap() += 5;
        for (_, v) in m.iter_mut() {
            *v += 1;
        }
        assert_eq!(m.get(&1), Some(&16));
        assert_eq!(m.get(&2), Some(&21));
    }
}
