//! Minimal JSON parser and serializer — enough for the AOT artifact
//! manifest, the `seer rollout --json` report output, and the `seer
//! serve` wire protocol.
//!
//! Supports the full JSON value grammar (objects, arrays, strings with
//! escapes, numbers, booleans, null). Does not aim for serde performance;
//! manifests are tens of KB and parsed once at startup. Serialization
//! (`Display`) is compact (no whitespace) and round-trips through
//! [`Json::parse`]; non-finite numbers serialize as `null` since JSON
//! has no representation for them.
//!
//! The parser is hardened for untrusted input (the serve plane feeds it
//! raw socket bytes): nesting depth is bounded by [`MAX_DEPTH`] so a
//! `[[[[…` bomb returns a positioned [`ParseError`] instead of
//! overflowing the stack, and every malformed, truncated, or
//! type-confused document is a positioned `Err` — the parser never
//! panics on any byte sequence.

use std::collections::BTreeMap;
use std::fmt;

/// Maximum container nesting depth the parser accepts. Real documents in
/// this repo nest a handful of levels; 128 leaves generous headroom
/// while keeping worst-case recursion far below stack limits.
pub const MAX_DEPTH: usize = 128;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // --- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Panicking accessor for required manifest fields (error messages
    /// name the missing key).
    pub fn expect(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("manifest missing key '{key}'"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => f.write_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    f.write_str("null")
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                f.write_str("[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{x}")?;
                }
                f.write_str("]")
            }
            Json::Obj(m) => {
                f.write_str("{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    /// Current container nesting depth (bounded by [`MAX_DEPTH`]).
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    /// Enter one container level; errors once [`MAX_DEPTH`] is exceeded
    /// so adversarially deep documents fail fast instead of recursing
    /// toward a stack overflow.
    fn descend(&mut self) -> Result<(), ParseError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.depth += 1;
        Ok(())
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, b: u8) -> Result<(), ParseError> {
        match self.bump() {
            Some(got) if got == b => Ok(()),
            Some(_) => {
                // Undo the bump so the error points at the bad byte.
                self.pos -= 1;
                Err(self.err(&format!("expected '{}'", b as char)))
            }
            None => Err(self.err(&format!("expected '{}', got end", b as char))),
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect_byte(b'{')?;
        self.descend()?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => {
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect_byte(b'[')?;
        self.descend()?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => {
                    self.depth -= 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect_byte(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| {
                                self.err("truncated \\u escape")
                            })?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(
                                    || self.err("bad hex in \\u escape"),
                                )?;
                        }
                        // Surrogate pairs: combine if a high surrogate.
                        if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\')
                                || self.bump() != Some(b'u')
                            {
                                return Err(self.err("lone high surrogate"));
                            }
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let c = self.bump().ok_or_else(|| {
                                    self.err("truncated \\u escape")
                                })?;
                                low = low * 16
                                    + (c as char).to_digit(16).ok_or_else(
                                        || self.err("bad hex"),
                                    )?;
                            }
                            code = 0x10000
                                + ((code - 0xD800) << 10)
                                + (low - 0xDC00);
                        }
                        s.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequence.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let chunk =
                        std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("bad utf-8"))?;
                    let ch = chunk.chars().next().unwrap();
                    s.push(ch);
                    self.pos = start + ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number bytes"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse(r#""a\nb""#).unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(
            r#"{"entries": {"decode": {"shape": [4, 256], "ok": true}}, "n": 3}"#,
        )
        .unwrap();
        let shape = j
            .expect("entries")
            .expect("decode")
            .expect("shape")
            .as_arr()
            .unwrap();
        assert_eq!(shape[1].as_usize(), Some(256));
        assert_eq!(j.expect("n").as_u64(), Some(3));
    }

    #[test]
    fn parses_unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
    }

    #[test]
    fn parses_utf8_passthrough() {
        assert_eq!(
            Json::parse("\"héllo — ✓\"").unwrap(),
            Json::Str("héllo — ✓".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn rejects_truncated_documents_with_position() {
        // Every truncation point of a valid document must be a
        // positioned Err, never a panic (the serve plane feeds the
        // parser raw socket bytes).
        let full = r#"{"a": [1, {"b": "xA", "c": -2.5e3}], "d": null}"#;
        for cut in 1..full.len() {
            if !full.is_char_boundary(cut) {
                continue;
            }
            let doc = &full[..cut];
            match Json::parse(doc) {
                Ok(_) => panic!("truncated '{doc}' parsed"),
                Err(e) => assert!(e.pos <= doc.len(), "{e}"),
            }
        }
    }

    #[test]
    fn rejects_over_deep_documents_without_overflow() {
        // A nesting bomb must fail fast at MAX_DEPTH, not recurse
        // toward a stack overflow.
        for (open, close) in [("[", "]"), ("{\"k\":", "}")] {
            let deep =
                format!("{}1{}", open.repeat(100_000), close.repeat(100_000));
            let e = Json::parse(&deep).unwrap_err();
            assert!(e.msg.contains("nesting too deep"), "{e}");
        }
        // Depth within the limit still parses.
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH - 1), "]".repeat(MAX_DEPTH - 1));
        assert!(Json::parse(&ok).is_ok());
        // Sibling containers do not accumulate depth.
        let wide = format!("[{}]", vec!["[0]"; 1000].join(","));
        assert!(Json::parse(&wide).is_ok());
    }

    #[test]
    fn type_confused_accessors_return_none() {
        // Typed accessors on the wrong variant are None, so load paths
        // built on them surface Err instead of panicking.
        let j = Json::parse(r#"{"s": "x", "n": 3, "a": [1], "o": {}}"#).unwrap();
        assert_eq!(j.expect("s").as_f64(), None);
        assert_eq!(j.expect("n").as_str(), None);
        assert_eq!(j.expect("a").as_obj(), None);
        assert_eq!(j.expect("o").as_arr(), None);
        assert_eq!(j.expect("n").as_bool(), None);
        assert_eq!(Json::Null.get("k"), None);
        // Negative / huge numbers saturate through the integer casts
        // rather than wrapping or panicking.
        assert_eq!(Json::Num(-4.0).as_u64(), Some(0));
        assert_eq!(Json::Num(1e300).as_usize(), Some(usize::MAX));
    }

    #[test]
    fn bad_escape_and_surrogate_inputs_error() {
        assert!(Json::parse(r#""\q""#).is_err());
        assert!(Json::parse(r#""\u12"#).is_err());
        assert!(Json::parse(r#""\ud800""#).is_err()); // lone high surrogate
        assert!(Json::parse(r#""\udfff\udfff""#).is_err()); // bad codepoint
        assert!(Json::parse("-").is_err());
        assert!(Json::parse("1e").is_err());
    }

    #[test]
    fn display_round_trips() {
        let cases = [
            r#"{"a":[1,2.5,-3],"b":"x\"y\n","c":true,"d":null}"#,
            "[]",
            "{}",
            r#"{"nested":{"k":[{"v":1e300}]}}"#,
        ];
        for text in cases {
            let v = Json::parse(text).unwrap();
            let printed = v.to_string();
            assert_eq!(Json::parse(&printed).unwrap(), v, "{text}");
        }
    }

    #[test]
    fn display_integers_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(-1.5).to_string(), "-1.5");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn display_escapes_control_chars() {
        let v = Json::Str("a\u{1}\t\"\\".into());
        let printed = v.to_string();
        assert_eq!(printed, "\"a\\u0001\\t\\\"\\\\\"");
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn parses_real_manifest() {
        // Shape mirrors aot.py's output.
        let text = r#"{
  "preset": "tiny",
  "config": {"vocab": 256, "d_model": 128, "kv_block": 64},
  "use_pallas": true,
  "entries": {
    "decode_step": {
      "name": "decode_step",
      "args": [{"shape": [256, 128], "dtype": "float32"}],
      "results": [{"shape": [4, 256], "dtype": "float32"}],
      "file": "tiny.decode_step.hlo.txt"
    }
  },
  "param_layout": [{"name": "tok_emb", "shape": [256, 128], "dtype": "float32"}],
  "n_params": 484608
}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.expect("preset").as_str(), Some("tiny"));
        assert_eq!(
            j.expect("config").expect("kv_block").as_usize(),
            Some(64)
        );
        let entry = j.expect("entries").expect("decode_step");
        assert_eq!(
            entry.expect("file").as_str(),
            Some("tiny.decode_step.hlo.txt")
        );
    }
}
