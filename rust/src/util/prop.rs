//! Minimal property-testing harness (proptest is unavailable offline).
//!
//! `check` runs a property over many deterministic seeds and, on failure,
//! reports the seed so the case is exactly reproducible:
//!
//! ```text
//! property failed (seed 17, case 3): <message>
//! ```
//!
//! Shrinking is replaced by seed reporting plus caller-controlled size
//! scaling: generators receive a `size` hint that grows over the run, so
//! early failures are usually already small.

use crate::sim::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub base_seed: u64,
    pub min_size: usize,
    pub max_size: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig {
            cases: 64,
            base_seed: 0x5EE2,
            min_size: 1,
            max_size: 64,
        }
    }
}

/// Context handed to each property case.
pub struct Case<'a> {
    pub rng: &'a mut Rng,
    /// Size hint in [min_size, max_size]; grows roughly linearly over the
    /// run so early cases are small.
    pub size: usize,
    pub index: usize,
}

/// The deterministic per-case `(seed, size)` schedule [`check`] drives
/// its cases with — base seed plus a golden-ratio stride, size ramping
/// linearly over the run. Exposed so external harnesses (e.g. the
/// parallel invariant sweep in `rust/tests/invariants.rs`) can
/// reproduce the exact same cases without duplicating the formula.
pub fn case_params(cfg: &PropConfig) -> Vec<(u64, usize)> {
    (0..cfg.cases)
        .map(|i| {
            let seed = cfg
                .base_seed
                .wrapping_add((i as u64).wrapping_mul(0x9E3779B97F4A7C15));
            let frac = if cfg.cases <= 1 {
                1.0
            } else {
                i as f64 / (cfg.cases - 1) as f64
            };
            let size = cfg.min_size
                + ((cfg.max_size - cfg.min_size) as f64 * frac).round()
                    as usize;
            (seed, size)
        })
        .collect()
}

/// Best-effort human-readable message from a caught panic payload.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "<non-string panic>".to_string())
}

/// Run `prop` over `cfg.cases` cases. Panics with seed info on failure
/// (assert inside the property as usual).
pub fn check<F: FnMut(&mut Case)>(name: &str, cfg: PropConfig, mut prop: F) {
    for (i, (seed, size)) in case_params(&cfg).into_iter().enumerate() {
        let mut rng = Rng::new(seed);
        let mut case = Case {
            rng: &mut rng,
            size,
            index: i,
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || prop(&mut case),
        ));
        if let Err(payload) = result {
            let msg = panic_message(payload.as_ref());
            panic!(
                "property '{name}' failed (case {i}, seed {seed:#x}, size {size}): {msg}"
            );
        }
    }
}

/// Shorthand with the default config.
pub fn quick<F: FnMut(&mut Case)>(name: &str, prop: F) {
    check(name, PropConfig::default(), prop);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        quick("reverse twice", |c| {
            let n = c.rng.range_usize(0, c.size);
            let xs: Vec<u64> = (0..n).map(|_| c.rng.next_u64()).collect();
            let mut ys = xs.clone();
            ys.reverse();
            ys.reverse();
            assert_eq!(xs, ys);
        });
    }

    #[test]
    fn reports_failing_seed() {
        let result = std::panic::catch_unwind(|| {
            check(
                "always fails at size > 10",
                PropConfig {
                    cases: 16,
                    ..Default::default()
                },
                |c| {
                    assert!(c.size <= 10, "size was {}", c.size);
                },
            );
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("seed"), "message: {msg}");
        assert!(msg.contains("always fails"), "message: {msg}");
    }

    #[test]
    fn case_params_match_check_schedule() {
        let cfg = PropConfig {
            cases: 50,
            max_size: 36,
            ..Default::default()
        };
        let params = case_params(&cfg);
        assert_eq!(params.len(), 50);
        assert_eq!(params[0], (0x5EE2, 1));
        assert_eq!(params[49].1, 36, "last case runs at max_size");
        // Seeds are all distinct (golden-ratio stride).
        let mut seeds: Vec<u64> = params.iter().map(|p| p.0).collect();
        seeds.sort();
        seeds.dedup();
        assert_eq!(seeds.len(), 50);
        // The schedule is what `check` actually drives.
        let mut seen = vec![];
        check("collect schedule", cfg, |c| {
            seen.push(c.size);
        });
        assert_eq!(
            seen,
            params.iter().map(|p| p.1).collect::<Vec<_>>()
        );
    }

    #[test]
    fn panic_message_downcasts() {
        let boxed: Box<dyn std::any::Any + Send> = Box::new("static str");
        assert_eq!(panic_message(boxed.as_ref()), "static str");
        let boxed: Box<dyn std::any::Any + Send> =
            Box::new(String::from("owned"));
        assert_eq!(panic_message(boxed.as_ref()), "owned");
        let boxed: Box<dyn std::any::Any + Send> = Box::new(42u32);
        assert_eq!(panic_message(boxed.as_ref()), "<non-string panic>");
    }

    #[test]
    fn sizes_span_range() {
        let mut sizes = vec![];
        check(
            "collect sizes",
            PropConfig {
                cases: 8,
                min_size: 2,
                max_size: 30,
                ..Default::default()
            },
            |c| sizes.push(c.size),
        );
        assert_eq!(sizes.first(), Some(&2));
        assert_eq!(sizes.last(), Some(&30));
    }
}
