//! In-tree substrates for the offline build environment.
//!
//! The usual ecosystem crates (serde_json, clap, criterion, proptest, rand)
//! are unavailable offline, so this module implements the minimal pieces
//! the system needs, from scratch, with tests: a JSON parser for the
//! artifact manifest, a CLI argument parser, summary statistics, a tiny
//! property-testing harness, and table rendering for the experiment
//! harness output.

pub mod bench;
pub mod cli;
pub mod idset;
pub mod json;
pub mod prop;
pub mod sortedmap;
pub mod stats;
pub mod table;

pub use json::Json;
pub use stats::Summary;
