//! Tiny CLI argument parser: `--key value`, `--flag`, and positionals.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positionals: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    /// `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        flag_names: &[&str],
    ) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&name) {
                    out.flags.push(name.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        out.flags.push(name.to_string());
                    } else {
                        out.options.insert(name.to_string(), it.next().unwrap());
                    }
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positionals.push(a);
            }
        }
        out
    }

    pub fn from_env(flag_names: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), flag_names)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{key} not an integer: {s}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{key} not an integer: {s}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{key} not a number: {s}")))
            .unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(
            sv(&["run", "--task", "moonlight", "--fast", "--n=3", "out"]),
            &["fast"],
        );
        assert_eq!(a.positionals, sv(&["run", "out"]));
        assert_eq!(a.get("task"), Some("moonlight"));
        assert_eq!(a.get_usize("n", 0), 3);
        assert!(a.has_flag("fast"));
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(sv(&["--verbose"]), &[]);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn flag_followed_by_option() {
        let a = Args::parse(sv(&["--dry", "--seed", "7"]), &[]);
        assert!(a.has_flag("dry"));
        assert_eq!(a.get_u64("seed", 0), 7);
    }

    #[test]
    fn defaults() {
        let a = Args::parse(sv(&[]), &[]);
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_f64("y", 1.5), 1.5);
    }
}
