//! PJRT runtime: loads the AOT'd HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client via
//! the `xla` crate. This is the only place the Rust side touches model
//! compute; Python never runs at request time.
//!
//! Pattern (from /opt/xla-example/load_hlo): HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`.

pub mod manifest;
pub mod model;

pub use manifest::{EntrySpec, Manifest, TensorSpec};
pub use model::ModelRuntime;

use anyhow::{Context, Result};
use std::path::Path;

/// Thin wrapper over the PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile one HLO-text artifact.
    pub fn load_hlo(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {path:?}: {e:?}"))
            .context("artifact compilation failed")
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}
