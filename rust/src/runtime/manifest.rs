//! Artifact manifest: the JSON sidecar `aot.py` writes next to the HLO
//! text files, describing every entry point's flattened argument/result
//! layout and the parameter ordering.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .expect("shape")
            .as_arr()
            .ok_or_else(|| anyhow!("shape not an array"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .expect("dtype")
            .as_str()
            .ok_or_else(|| anyhow!("dtype not a string"))?
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub file: String,
    pub args: Vec<TensorSpec>,
    pub results: Vec<TensorSpec>,
}

/// Static model dimensions baked into the artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    pub batch: usize,
    pub prefill_len: usize,
    pub train_len: usize,
    pub draft_width: usize,
    pub kv_block: usize,
}

#[derive(Debug)]
pub struct Manifest {
    pub preset: String,
    pub dims: ModelDims,
    pub use_pallas: bool,
    pub entries: BTreeMap<String, EntrySpec>,
    /// (name, spec) in the canonical flattening order.
    pub param_layout: Vec<(String, TensorSpec)>,
    pub n_params: usize,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/<preset>.manifest.json`.
    pub fn load(dir: &Path, preset: &str) -> Result<Manifest> {
        let path = dir.join(format!("{preset}.manifest.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {path:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;

        let cfg = j.expect("config");
        let dim = |k: &str| -> Result<usize> {
            cfg.expect(k)
                .as_usize()
                .ok_or_else(|| anyhow!("config.{k} not an int"))
        };
        let dims = ModelDims {
            vocab: dim("vocab")?,
            d_model: dim("d_model")?,
            n_layers: dim("n_layers")?,
            n_heads: dim("n_heads")?,
            head_dim: dim("head_dim")?,
            max_seq: dim("max_seq")?,
            batch: dim("batch")?,
            prefill_len: dim("prefill_len")?,
            train_len: dim("train_len")?,
            draft_width: dim("draft_width")?,
            kv_block: dim("kv_block")?,
        };

        let mut entries = BTreeMap::new();
        for (name, spec) in j
            .expect("entries")
            .as_obj()
            .ok_or_else(|| anyhow!("entries not an object"))?
        {
            let parse_list = |key: &str| -> Result<Vec<TensorSpec>> {
                spec.expect(key)
                    .as_arr()
                    .ok_or_else(|| anyhow!("{key} not an array"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            entries.insert(
                name.clone(),
                EntrySpec {
                    name: name.clone(),
                    file: spec
                        .expect("file")
                        .as_str()
                        .ok_or_else(|| anyhow!("file not a string"))?
                        .to_string(),
                    args: parse_list("args")?,
                    results: parse_list("results")?,
                },
            );
        }

        let param_layout = j
            .expect("param_layout")
            .as_arr()
            .ok_or_else(|| anyhow!("param_layout not an array"))?
            .iter()
            .map(|e| {
                let name = e
                    .expect("name")
                    .as_str()
                    .ok_or_else(|| anyhow!("param name"))?
                    .to_string();
                Ok((name, TensorSpec::from_json(e)?))
            })
            .collect::<Result<Vec<_>>>()?;

        let n_params = j
            .expect("n_params")
            .as_usize()
            .ok_or_else(|| anyhow!("n_params"))?;
        let total: usize = param_layout.iter().map(|(_, s)| s.elements()).sum();
        if total != n_params {
            bail!("param layout totals {total}, manifest says {n_params}");
        }

        Ok(Manifest {
            preset: j
                .expect("preset")
                .as_str()
                .ok_or_else(|| anyhow!("preset"))?
                .to_string(),
            dims,
            use_pallas: j
                .expect("use_pallas")
                .as_bool()
                .unwrap_or(true),
            entries,
            param_layout,
            n_params,
            dir: dir.to_path_buf(),
        })
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("no entry '{name}' in manifest"))
    }

    pub fn hlo_path(&self, entry: &EntrySpec) -> PathBuf {
        self.dir.join(&entry.file)
    }

    pub fn params_path(&self) -> PathBuf {
        self.dir.join(format!("{}.params.bin", self.preset))
    }

    /// Load the initial parameter blob as per-leaf f32 vectors.
    pub fn load_params(&self) -> Result<Vec<Vec<f32>>> {
        let path = self.params_path();
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {path:?}"))?;
        if bytes.len() != 4 * self.n_params {
            bail!(
                "params blob is {} bytes, expected {}",
                bytes.len(),
                4 * self.n_params
            );
        }
        let mut out = Vec::with_capacity(self.param_layout.len());
        let mut off = 0usize;
        for (_, spec) in &self.param_layout {
            let n = spec.elements();
            let mut v = Vec::with_capacity(n);
            for i in 0..n {
                let b = &bytes[off + 4 * i..off + 4 * i + 4];
                v.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            }
            off += 4 * n;
            out.push(v);
        }
        Ok(out)
    }
}

/// Default artifact directory: `$SEER_ARTIFACTS` or `artifacts/` relative
/// to the crate root.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(d) = std::env::var("SEER_ARTIFACTS") {
        return PathBuf::from(d);
    }
    let manifest_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    manifest_dir.join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        default_artifact_dir().join("tiny.manifest.json").exists()
    }

    #[test]
    fn loads_tiny_manifest() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&default_artifact_dir(), "tiny").unwrap();
        assert_eq!(m.preset, "tiny");
        assert!(m.entries.contains_key("decode_step"));
        assert!(m.entries.contains_key("train_step"));
        let d = m.entry("decode_step").unwrap();
        // params + (tokens, cache_lens, k_cache, v_cache)
        assert_eq!(d.args.len(), m.param_layout.len() + 4);
        assert_eq!(d.results.len(), 3);
        // logits (B, V)
        assert_eq!(d.results[0].shape, vec![m.dims.batch, m.dims.vocab]);
    }

    #[test]
    fn loads_param_blob() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&default_artifact_dir(), "tiny").unwrap();
        let params = m.load_params().unwrap();
        assert_eq!(params.len(), m.param_layout.len());
        let total: usize = params.iter().map(|p| p.len()).sum();
        assert_eq!(total, m.n_params);
        // Embeddings should be small random values, not zeros.
        let emb = &params[params.len() - 1]; // tok_emb sorts last
        assert!(emb.iter().any(|&x| x != 0.0));
        assert!(emb.iter().all(|&x| x.abs() < 1.0));
    }
}
