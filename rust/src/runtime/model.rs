//! Typed wrappers over the model's AOT entry points, holding the live
//! parameter/optimizer state as XLA literals.
//!
//! The weight-update phase of the synchronous RL loop is "free" here: the
//! train_step artifact returns the new parameter leaves, which replace the
//! in-memory list used by the very next rollout step — the same
//! checkpoint-engine semantics the paper's pipeline relies on, minus the
//! multi-node broadcast.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use xla::Literal;

use super::manifest::{Manifest, TensorSpec};
use super::Runtime;

pub struct ModelRuntime {
    rt: Runtime,
    pub manifest: Manifest,
    exes: BTreeMap<String, xla::PjRtLoadedExecutable>,
    /// Parameter leaves in manifest order, resident on device.
    ///
    /// Perf + correctness note (EXPERIMENTS.md §Perf): all executions go
    /// through `execute_b` with buffers this struct uploads and drops
    /// explicitly. The crate's literal-based `execute` leaks its internal
    /// literal→buffer conversions (~3.5 MB per decode call, OOM within
    /// ~100 training iterations) and re-uploads the parameters on every
    /// call; device-resident parameter buffers fix both.
    params: Vec<xla::PjRtBuffer>,
    opt_m: Vec<xla::PjRtBuffer>,
    opt_v: Vec<xla::PjRtBuffer>,
    step: i32,
}

fn dims_i64(shape: &[usize]) -> Vec<i64> {
    shape.iter().map(|&d| d as i64).collect()
}

/// Build an f32 literal of the given shape.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("shape {shape:?} needs {n} elements, got {}", data.len());
    }
    Literal::vec1(data)
        .reshape(&dims_i64(shape))
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Build an i32 literal of the given shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("shape {shape:?} needs {n} elements, got {}", data.len());
    }
    Literal::vec1(data)
        .reshape(&dims_i64(shape))
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Upload raw f32 data to a device buffer (single host→device copy).
fn upload_f32(rt: &Runtime, data: &[f32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
    rt.client()
        .buffer_from_host_buffer(data, shape, None)
        .map_err(|e| anyhow!("upload: {e:?}"))
}

/// Upload a host literal to a device buffer.
fn upload_literal(rt: &Runtime, lit: &Literal) -> Result<xla::PjRtBuffer> {
    rt.client()
        .buffer_from_host_literal(None, lit)
        .map_err(|e| anyhow!("upload literal: {e:?}"))
}

impl ModelRuntime {
    /// Load + compile every entry of `<dir>/<preset>.*` and initialize
    /// parameters from the emitted blob.
    pub fn load(dir: &Path, preset: &str) -> Result<Self> {
        let rt = Runtime::cpu()?;
        let manifest = Manifest::load(dir, preset)?;
        let mut exes = BTreeMap::new();
        for (name, entry) in &manifest.entries {
            let exe = rt
                .load_hlo(&manifest.hlo_path(entry))
                .with_context(|| format!("loading entry '{name}'"))?;
            exes.insert(name.clone(), exe);
        }
        let raw = manifest.load_params()?;
        let mut params = Vec::with_capacity(raw.len());
        let mut opt_m = Vec::with_capacity(raw.len());
        let mut opt_v = Vec::with_capacity(raw.len());
        for ((_, spec), leaf) in manifest.param_layout.iter().zip(&raw) {
            params.push(upload_f32(&rt, leaf, &spec.shape)?);
            let zeros = vec![0f32; leaf.len()];
            opt_m.push(upload_f32(&rt, &zeros, &spec.shape)?);
            opt_v.push(upload_f32(&rt, &zeros, &spec.shape)?);
        }
        Ok(ModelRuntime {
            rt,
            manifest,
            exes,
            params,
            opt_m,
            opt_v,
            step: 0,
        })
    }

    pub fn platform(&self) -> String {
        self.rt.platform()
    }

    pub fn n_param_leaves(&self) -> usize {
        self.params.len()
    }

    /// Execute `entry` with `extra` inputs appended after the parameter
    /// leaves, returning the flattened result literals.
    fn call(&self, entry: &str, extra: &[&Literal]) -> Result<Vec<Literal>> {
        self.call_with_prefix(entry, &[], extra)
    }

    /// Execute a parameter-less entry (cache plumbing like slot_update).
    fn call_raw(&self, entry: &str, args: &[&Literal]) -> Result<Vec<Literal>> {
        let spec = self.manifest.entry(entry)?;
        let exe = self
            .exes
            .get(entry)
            .ok_or_else(|| anyhow!("entry '{entry}' not compiled"))?;
        if args.len() != spec.args.len() {
            bail!(
                "entry '{entry}' wants {} args, got {}",
                spec.args.len(),
                args.len()
            );
        }
        let uploaded: Vec<xla::PjRtBuffer> = args
            .iter()
            .map(|l| upload_literal(&self.rt, l))
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::PjRtBuffer> = uploaded.iter().collect();
        let outputs = exe
            .execute_b::<&xla::PjRtBuffer>(&refs)
            .map_err(|e| anyhow!("execute {entry}: {e:?}"))?;
        self.collect_results(entry, outputs)
    }

    /// Execute with device-resident `mid` buffers between the parameter
    /// leaves and the host `extra` literals: arguments are
    /// params ++ mid ++ extra (train_step passes the optimizer state as
    /// `mid`; inference entries pass none).
    fn call_with_prefix(
        &self,
        entry: &str,
        mid: &[&xla::PjRtBuffer],
        extra: &[&Literal],
    ) -> Result<Vec<Literal>> {
        let spec = self.manifest.entry(entry)?;
        let exe = self
            .exes
            .get(entry)
            .ok_or_else(|| anyhow!("entry '{entry}' not compiled"))?;
        // Upload the host-literal inputs; params and `mid` are already
        // device-resident.
        let uploaded: Vec<xla::PjRtBuffer> = extra
            .iter()
            .map(|l| upload_literal(&self.rt, l))
            .collect::<Result<_>>()?;
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(spec.args.len());
        args.extend(self.params.iter());
        args.extend_from_slice(mid);
        args.extend(uploaded.iter());
        if args.len() != spec.args.len() {
            bail!(
                "entry '{entry}' wants {} args, got {}",
                spec.args.len(),
                args.len()
            );
        }
        let outputs = exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .map_err(|e| anyhow!("execute {entry}: {e:?}"))?;
        self.collect_results(entry, outputs)
    }

    fn collect_results(
        &self,
        entry: &str,
        outputs: Vec<Vec<xla::PjRtBuffer>>,
    ) -> Result<Vec<Literal>> {
        let spec = self.manifest.entry(entry)?;
        let row = outputs
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("no output replica"))?;
        let mut lits = Vec::new();
        for buf in row {
            let lit = buf
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            lits.push(lit);
        }
        // jax lowers with return_tuple=True: a single tuple literal holds
        // all results. Untuple if so.
        if lits.len() == 1 {
            let mut only = lits.pop().unwrap();
            match only.decompose_tuple() {
                Ok(parts) if !parts.is_empty() => lits = parts,
                _ => lits.push(only),
            }
        }
        if lits.len() != spec.results.len() {
            bail!(
                "entry '{entry}' returned {} literals, manifest says {}",
                lits.len(),
                spec.results.len()
            );
        }
        Ok(lits)
    }

    // ------------------------------------------------------------------
    // Entry points.
    // ------------------------------------------------------------------

    /// Prefill the whole batch. `tokens`: B×P row-major; `seq_lens`: B.
    /// Returns (last-token logits B×V, k_cache, v_cache).
    pub fn prefill(
        &self,
        tokens: &[i32],
        seq_lens: &[i32],
    ) -> Result<(Vec<f32>, Literal, Literal)> {
        let d = &self.manifest.dims;
        let t = lit_i32(tokens, &[d.batch, d.prefill_len])?;
        let l = lit_i32(seq_lens, &[d.batch])?;
        let mut out = self.call("prefill", &[&t, &l])?;
        let vc = out.pop().unwrap();
        let kc = out.pop().unwrap();
        let logits = out.pop().unwrap().to_vec::<f32>()
            .map_err(|e| anyhow!("logits: {e:?}"))?;
        Ok((logits, kc, vc))
    }

    /// Prefill one sequence (B=1 entry). Returns (logits V, kc1, vc1).
    pub fn prefill_one(
        &self,
        tokens: &[i32],
        seq_len: i32,
    ) -> Result<(Vec<f32>, Literal, Literal)> {
        let d = &self.manifest.dims;
        let t = lit_i32(tokens, &[1, d.prefill_len])?;
        let l = lit_i32(&[seq_len], &[1])?;
        let mut out = self.call("prefill_one", &[&t, &l])?;
        let vc = out.pop().unwrap();
        let kc = out.pop().unwrap();
        let logits = out.pop().unwrap().to_vec::<f32>()
            .map_err(|e| anyhow!("logits: {e:?}"))?;
        Ok((logits, kc, vc))
    }

    /// Insert a single-sequence cache (from `prefill_one` or
    /// `slot_extract`) into batch slot `slot` of (k_cache, v_cache).
    pub fn slot_update(
        &self,
        kc: &Literal,
        vc: &Literal,
        kc1: &Literal,
        vc1: &Literal,
        slot: i32,
    ) -> Result<(Literal, Literal)> {
        let s = Literal::scalar(slot);
        let mut out = self.call_raw("slot_update", &[kc, vc, kc1, vc1, &s])?;
        let vc = out.pop().unwrap();
        let kc = out.pop().unwrap();
        Ok((kc, vc))
    }

    /// Extract one slot's cache pair (L, 1, H, S, Dh) — parked in the
    /// host-side KV pool between chunk leases (divided rollout).
    pub fn slot_extract(
        &self,
        kc: &Literal,
        vc: &Literal,
        slot: i32,
    ) -> Result<(Literal, Literal)> {
        let s = Literal::scalar(slot);
        let mut out = self.call_raw("slot_extract", &[kc, vc, &s])?;
        let vc = out.pop().unwrap();
        let kc = out.pop().unwrap();
        Ok((kc, vc))
    }

    /// One decode step. Returns (logits B×V, k_cache, v_cache).
    pub fn decode(
        &self,
        tokens: &[i32],
        cache_lens: &[i32],
        kc: &Literal,
        vc: &Literal,
    ) -> Result<(Vec<f32>, Literal, Literal)> {
        let d = &self.manifest.dims;
        let t = lit_i32(tokens, &[d.batch])?;
        let l = lit_i32(cache_lens, &[d.batch])?;
        let mut out = self.call("decode_step", &[&t, &l, kc, vc])?;
        let vc_o = out.pop().unwrap();
        let kc_o = out.pop().unwrap();
        let logits = out.pop().unwrap().to_vec::<f32>()
            .map_err(|e| anyhow!("logits: {e:?}"))?;
        Ok((logits, kc_o, vc_o))
    }

    /// Verify G draft positions per sequence. `draft_tokens`: B×G
    /// row-major (position 0 = last accepted token). Returns
    /// (logits B×G×V, k_cache, v_cache).
    pub fn verify(
        &self,
        draft_tokens: &[i32],
        cache_lens: &[i32],
        kc: &Literal,
        vc: &Literal,
    ) -> Result<(Vec<f32>, Literal, Literal)> {
        let d = &self.manifest.dims;
        let t = lit_i32(draft_tokens, &[d.batch, d.draft_width])?;
        let l = lit_i32(cache_lens, &[d.batch])?;
        let mut out = self.call("verify_step", &[&t, &l, kc, vc])?;
        let vc_o = out.pop().unwrap();
        let kc_o = out.pop().unwrap();
        let logits = out.pop().unwrap().to_vec::<f32>()
            .map_err(|e| anyhow!("logits: {e:?}"))?;
        Ok((logits, kc_o, vc_o))
    }

    /// One GRPO training step over a B×T window; updates parameters and
    /// optimizer state in place and returns the loss.
    pub fn train(
        &mut self,
        tokens: &[i32],
        loss_mask: &[i32],
        advantages: &[f32],
    ) -> Result<f32> {
        let d = &self.manifest.dims;
        let t = lit_i32(tokens, &[d.batch, d.train_len])?;
        let m = lit_i32(loss_mask, &[d.batch, d.train_len])?;
        let a = lit_f32(advantages, &[d.batch])?;
        let step = Literal::scalar(self.step);
        let mid: Vec<&xla::PjRtBuffer> = self
            .opt_m
            .iter()
            .chain(self.opt_v.iter())
            .collect();
        let out = self.call_with_prefix(
            "train_step",
            &mid,
            &[&step, &t, &m, &a],
        )?;
        let n = self.params.len();
        if out.len() != 3 * n + 1 {
            bail!("train_step returned {} results, want {}", out.len(), 3 * n + 1);
        }
        // Re-upload the updated weights/optimizer state as the new
        // device-resident buffers (the in-place weight update of the
        // synchronous loop). Round-trip through raw f32 host data:
        // literals decomposed out of an execution's result tuple are not
        // accepted by buffer_from_host_literal (xla_extension asserts on
        // their size metadata), while raw uploads are always safe.
        let mut it = out.into_iter();
        let reupload = |rt: &Runtime,
                        lits: &mut dyn Iterator<Item = Literal>,
                        layout: &[(String, TensorSpec)]|
         -> Result<Vec<xla::PjRtBuffer>> {
            let mut bufs = Vec::with_capacity(layout.len());
            for (lit, (_, spec)) in lits.take(layout.len()).zip(layout) {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("download leaf: {e:?}"))?;
                bufs.push(upload_f32(rt, &data, &spec.shape)?);
            }
            Ok(bufs)
        };
        let layout = self.manifest.param_layout.clone();
        let new_params = reupload(&self.rt, &mut it, &layout)?;
        let new_m = reupload(&self.rt, &mut it, &layout)?;
        let new_v = reupload(&self.rt, &mut it, &layout)?;
        let loss = it
            .next()
            .unwrap()
            .to_vec::<f32>()
            .map_err(|e| anyhow!("loss: {e:?}"))?[0];
        self.params = new_params;
        self.opt_m = new_m;
        self.opt_v = new_v;
        self.step += 1;
        Ok(loss)
    }

    pub fn train_steps_taken(&self) -> i32 {
        self.step
    }

    /// Read a parameter leaf back to host (tests / checkpointing).
    pub fn param_leaf(&self, idx: usize) -> Result<Vec<f32>> {
        self.params[idx]
            .to_literal_sync()
            .map_err(|e| anyhow!("param leaf download: {e:?}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("param leaf: {e:?}"))
    }

    pub fn param_spec(&self, idx: usize) -> &(String, TensorSpec) {
        &self.manifest.param_layout[idx]
    }
}
