//! Pluggable rollout scheduling policies.
//!
//! The cluster driver asks the active policy for assignments whenever
//! capacity frees up; the policy sees per-instance KV telemetry and the
//! request buffer and returns (request, instance, chunk) triples. The
//! driver re-validates every assignment against the allocator before
//! acting (defense in depth: a buggy policy cannot corrupt accounting).

pub mod lazyheap;
pub mod rollpacker;
pub mod seer;
pub mod streamrl;
pub mod verl;

pub use rollpacker::RollPackerScheduler;
pub use seer::{ContextMode, SeerScheduler};
pub use streamrl::StreamRlOracle;
pub use verl::VerlScheduler;

use crate::config::{SystemConfig, WorkloadConfig};
use crate::coordinator::{ReqState, RequestBuffer};
use crate::sim::clock::SimTime;
use crate::workload::{GroupSpec, InstanceId, RequestId};

/// One instance's load snapshot, as the scheduler sees it.
#[derive(Debug, Clone, Copy)]
pub struct InstanceView {
    pub id: InstanceId,
    /// Tokens of KV the admission controller may still hand out
    /// (capacity × target-util − used − pending reservations).
    pub free_kv_tokens: u64,
    pub capacity_tokens: u64,
    pub running: usize,
    pub max_batch: usize,
}

/// Scheduling context for one `schedule` call.
pub struct SchedCtx<'a> {
    pub now: SimTime,
    pub instances: &'a [InstanceView],
    pub buffer: &'a RequestBuffer,
}

/// A chunk lease: run `req` on `instance` for up to `chunk` generated
/// tokens.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Assignment {
    pub req: RequestId,
    pub instance: InstanceId,
    pub chunk: u32,
}

/// A rollout scheduling policy.
///
/// Policies are constructed by name through
/// [`crate::rollout::PolicyRegistry`]; register new implementations
/// there so every front door (CLI, experiments, benches, sessions)
/// picks them up.
pub trait Scheduler {
    /// Stable policy name (constant per instance; no allocation — this
    /// is queried on the scheduling hot path).
    fn name(&self) -> &'static str;

    /// Called once at iteration start with the full group list. Policies
    /// other than the Oracle variants must not read `gen_len`.
    fn init(
        &mut self,
        groups: &[GroupSpec],
        cfg: &WorkloadConfig,
        sys: &SystemConfig,
    );

    /// Inject cross-iteration warm-start context (the
    /// [`crate::iteration::ContextStore`] priors). Called by the driver
    /// after [`init`](Self::init). Returns whether the policy consumed
    /// the priors — the driver uses this to keep the SD layer's
    /// probe-priority handling consistent with the scheduler's. The
    /// default ignores history, which is correct for history-free
    /// baselines.
    fn warm_start(&mut self, _priors: &crate::iteration::ContextPriors) -> bool {
        false
    }

    /// Produce as many assignments as current capacity allows, appended
    /// to `out` (a reusable scratch buffer the driver clears between
    /// passes — the steady-state loop allocates nothing).
    fn schedule(&mut self, ctx: &SchedCtx, out: &mut Vec<Assignment>);

    /// A request finished (reached its true length).
    fn on_finished(&mut self, _req: &ReqState) {}

    /// A chunk lease ended with the request unfinished.
    fn on_chunk_end(&mut self, _req: &ReqState) {}

    /// An assignment this policy produced did not materialize: the
    /// driver's admission re-check rejected it, or the in-flight
    /// transfer bounced off capacity on arrival — the request is back in
    /// the waiting set with no progress change. Policies that maintain
    /// incremental candidate structures (see [`lazyheap`]) must re-index
    /// the request here; stateless policies can ignore it.
    fn on_requeued(&mut self, _req: &ReqState) {}

    /// Fault layer: `lost` crashed or was reclaimed. The driver already
    /// returned its `drained` in-flight requests to the waiting queue;
    /// `live` is the surviving fleet (post-change, excluding `lost`).
    ///
    /// The default routes every drained request through
    /// [`on_chunk_end`](Self::on_chunk_end), so history-keeping policies
    /// (Seer's `ContextManager`) preserve in-flight progress across the
    /// fault exactly as they do across a voluntary chunk migration.
    /// Policies that *pin* requests to instances must override this to
    /// re-home everything pinned to the lost instance, or those requests
    /// starve forever.
    fn on_instance_lost(
        &mut self,
        _lost: InstanceId,
        drained: &[RequestId],
        _live: &[InstanceId],
        buffer: &RequestBuffer,
    ) {
        for id in drained {
            self.on_chunk_end(buffer.get(*id));
        }
    }

    /// Fault layer: capacity arrived — `added` instances joined the
    /// fleet, through elastic scale-up or recovery of a previously
    /// downed instance; `live` is the post-change fleet (including
    /// `added`). The default is a no-op, which is correct for policies
    /// that pick instances per scheduling cycle from the live views
    /// (Seer); pinning policies should rebalance waiting work onto the
    /// newcomers or they idle (and, after a fully-downed interval,
    /// groups still pinned to a dead instance would starve).
    fn on_instances_added(
        &mut self,
        _added: &[InstanceId],
        _live: &[InstanceId],
        _buffer: &RequestBuffer,
    ) {
    }

    /// Choose a preemption victim among `running` (id, first_scheduled)
    /// on an instance that ran out of KV. Default: vLLM-style LIFO
    /// (latest-scheduled evicted first).
    fn preempt_victim(
        &mut self,
        running: &[(RequestId, SimTime)],
        _buffer: &RequestBuffer,
    ) -> Option<RequestId> {
        running.iter().max_by_key(|(id, t)| (*t, id.0)).map(|(id, _)| *id)
    }

    /// Divided rollout: park KV in the global pool between chunks and on
    /// preemption (true), or drop it and re-prefill (false — the
    /// conventional baselines).
    fn uses_global_pool(&self) -> bool {
        true
    }

    /// Tail-packing telemetry, read once by the driver at finalize time:
    /// `(tail_packed, tail_resume_tokens)` — how many requests this
    /// policy diverted onto its tail-packing path, and the generated
    /// tokens those requests carried when first diverted. Policies
    /// without a tail-packing path report zeros.
    fn tail_stats(&self) -> (u64, u64) {
        (0, 0)
    }
}

/// Helper shared by policies: pick the instance with the most free KV
/// that can admit `demand` tokens and has a batch slot.
///
/// Tie-breaking is explicit and deterministic: on equal effective free
/// KV, the lowest-index instance wins (the strict `>` below never
/// replaces an equal earlier candidate). Cross-backend runs with equal
/// seeds rely on this for reproducibility — do not weaken it to `>=`.
pub fn select_instance(
    instances: &[InstanceView],
    reserved: &[u64],
    demand: u64,
) -> Option<usize> {
    let mut best: Option<(usize, u64)> = None;
    for (i, inst) in instances.iter().enumerate() {
        let free = inst.free_kv_tokens.saturating_sub(reserved[i]);
        if free >= demand && inst.running < inst.max_batch {
            if best.map(|(_, bf)| free > bf).unwrap_or(true) {
                best = Some((i, free));
            }
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn iv(id: u32, free: u64, running: usize) -> InstanceView {
        InstanceView {
            id: InstanceId(id),
            free_kv_tokens: free,
            capacity_tokens: 10_000,
            running,
            max_batch: 8,
        }
    }

    #[test]
    fn select_instance_picks_most_free() {
        let insts = [iv(0, 100, 0), iv(1, 5000, 0), iv(2, 900, 0)];
        let reserved = [0, 0, 0];
        assert_eq!(select_instance(&insts, &reserved, 200), Some(1));
    }

    #[test]
    fn select_instance_respects_reservations_and_batch() {
        let insts = [iv(0, 5000, 8), iv(1, 5000, 0)];
        let reserved = [0, 4900];
        // Instance 0 has KV but no batch slot; 1 has a slot but reserved.
        assert_eq!(select_instance(&insts, &reserved, 200), None);
    }

    #[test]
    fn select_instance_none_when_too_big() {
        let insts = [iv(0, 100, 0)];
        assert_eq!(select_instance(&insts, &[0], 101), None);
        assert_eq!(select_instance(&insts, &[0], 100), Some(0));
    }

    #[test]
    fn select_instance_tie_breaks_lowest_index() {
        // All equal: index 0 must win, deterministically.
        let insts = [iv(0, 5000, 0), iv(1, 5000, 0), iv(2, 5000, 0)];
        assert_eq!(select_instance(&insts, &[0, 0, 0], 200), Some(0));
        // Equal after reservations: the earliest of the tied pair wins.
        let insts = [iv(0, 4000, 0), iv(1, 6000, 0), iv(2, 5000, 0)];
        assert_eq!(
            select_instance(&insts, &[0, 1000, 0], 200),
            Some(1),
            "effective-free tie (5000) must go to the lower index"
        );
        // Ineligible lower index does not mask the tie-break among the
        // remaining candidates.
        let insts = [iv(0, 5000, 8), iv(1, 5000, 0), iv(2, 5000, 0)];
        assert_eq!(select_instance(&insts, &[0, 0, 0], 200), Some(1));
    }
}
