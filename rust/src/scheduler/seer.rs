//! Seer's context-aware scheduler — paper Algorithm 2 on top of divided
//! rollout (§3.2 + §3.3).
//!
//! Three context modes cover the Figure 10 ablation:
//! * `Learned` — the real system: probe requests run shortest-first in a
//!   high-priority path; everyone else runs approximate-LFS on the
//!   context manager's online group estimates, with a starvation guard.
//! * `Oracle`  — LFS on true lengths (upper bound).
//! * `None`    — divided rollout only, FCFS (the "No-Context" ablation and
//!   Table 4's "+ Divided Rollout" row).
//!
//! ## Incremental scheduling (hot-path overhaul)
//!
//! Earlier revisions rebuilt the candidate ordering from
//! `buffer.waiting()` on every pass: partition into probes/rest, then
//! two `sort_by_cached_key` calls — O(W log W) per pass (perf iterations
//! 2–4 in EXPERIMENTS.md §Perf only shaved constants off that). The
//! ordering is now *maintained*, not rebuilt: two stamped
//! [`LazyHeap`]s (probe SFS on `(generated, id)`, rest LFS on the mode's
//! priority key) are repaired by the lifecycle hooks —
//! [`Scheduler::on_finished`] / [`Scheduler::on_chunk_end`] re-key the
//! affected group's waiting members when (and only when) its estimate
//! actually moved, [`Scheduler::on_requeued`] re-indexes bounced
//! admissions, and warm-start re-keys prior'd groups. A steady-state
//! pass pops just the candidates it examines and returns the unconsumed
//! ones, so `schedule()` is o(waiting) amortized while producing the
//! **byte-identical assignment sequence** of the sort-based
//! implementation: lazy-heap pop order of current entries equals the
//! full sort under current keys (see [`super::lazyheap`]), and the
//! starvation-guard window replays the original vector-swap semantics
//! through an explicit pending deque.

use std::cmp::Reverse;
use std::collections::VecDeque;

use crate::config::{SystemConfig, WorkloadConfig};
use crate::coordinator::{ContextManager, Phase, ReqState};
use crate::sim::Rng;
use crate::workload::{GroupId, GroupSpec, RequestId};

use super::lazyheap::{Entry, LazyHeap, Stamps};
use super::{Assignment, SchedCtx, Scheduler};

/// How much length context the scheduler may use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ContextMode {
    Learned,
    Oracle,
    None,
}

/// Probe SFS key: fewest generated tokens first, id tie-break —
/// `Reverse` turns the max-heap into min-(generated, id) pops.
type ProbeKey = Reverse<(u32, u32)>;

/// A candidate taken from one of the two heaps during a pass; returned
/// to its heap at pass end whether or not it was assigned (the driver
/// may still reject an assignment, and next pass's pop-validation
/// discards entries for requests that really left the waiting set).
enum Pick {
    Probe(Entry<ProbeKey>),
    Rest(Entry<u64>),
}

impl Pick {
    fn req(&self) -> RequestId {
        match self {
            Pick::Probe(e) => e.req,
            Pick::Rest(e) => e.req,
        }
    }
}

pub struct SeerScheduler {
    mode: ContextMode,
    ctx_mgr: ContextManager,
    chunk_size: u32,
    starvation_frac: f64,
    rng: Rng,
    /// Scratch: scheduling decisions since the last starvation pick.
    picks_since_guard: u64,
    /// Cross-iteration length priors (survive `init`, which rebuilds the
    /// context manager at iteration start).
    priors: Vec<(crate::workload::GroupId, u32)>,
    // --- incremental candidate structures (see module docs) ----------
    stamps: Stamps,
    probe_heap: LazyHeap<ProbeKey>,
    rest_heap: LazyHeap<u64>,
    /// Request ids per group (for group-wide re-keying on estimate
    /// changes), indexed by `GroupId`.
    group_members: Vec<Vec<RequestId>>,
    /// In-pass lookahead buffer: rest candidates popped for a starvation
    /// window but not yet consumed, in exact pick order.
    rest_pending: VecDeque<Entry<u64>>,
    // Reusable pass scratch (allocation-free steady state).
    guard_window: Vec<Entry<u64>>,
    consumed_probe: Vec<Entry<ProbeKey>>,
    consumed_rest: Vec<Entry<u64>>,
}

impl SeerScheduler {
    pub fn new(mode: ContextMode) -> Self {
        SeerScheduler {
            mode,
            ctx_mgr: ContextManager::new(u32::MAX),
            chunk_size: 2048,
            starvation_frac: 0.05,
            rng: Rng::new(0x5EE12),
            picks_since_guard: 0,
            priors: Vec::new(),
            stamps: Stamps::default(),
            probe_heap: LazyHeap::new(),
            rest_heap: LazyHeap::new(),
            group_members: Vec::new(),
            rest_pending: VecDeque::new(),
            guard_window: Vec::new(),
            consumed_probe: Vec::new(),
            consumed_rest: Vec::new(),
        }
    }

    /// LFS key for a waiting request: higher = schedule earlier.
    fn priority_key(&self, r: &ReqState) -> u64 {
        match self.mode {
            ContextMode::Oracle => r.remaining_true() as u64,
            ContextMode::Learned => self.ctx_mgr.estimate(r.group()) as u64,
            ContextMode::None => 0,
        }
    }

    pub fn context_manager(&self) -> &ContextManager {
        &self.ctx_mgr
    }

    /// Does `r` currently belong on the high-priority probe path? Only
    /// while its group has no length context at all — neither an online
    /// finish nor a warm cross-iteration prior.
    fn is_probe_pending(&self, r: &ReqState) -> bool {
        r.is_probe
            && self.mode == ContextMode::Learned
            && !self.ctx_mgr.has_context(r.group())
    }

    /// (Re-)index one request under its current classification and key.
    /// Bumps the stamp, so every older entry for it goes stale.
    fn reindex(&mut self, r: &ReqState) {
        let stamp = self.stamps.bump(r.id());
        if self.is_probe_pending(r) {
            self.probe_heap
                .push(Reverse((r.generated, r.id().0)), r.id(), stamp);
        } else {
            let key = self.priority_key(r);
            self.rest_heap.push(key, r.id(), stamp);
        }
    }

    /// Re-key every member of `g` in the LFS heap. Only called once the
    /// group *has* context (post-finish, post-progress-raise, or
    /// warm-prior'd), so all members classify as rest and share the
    /// group estimate as their key — no per-request state needed.
    fn repush_group(&mut self, g: GroupId) {
        let key = self.ctx_mgr.estimate(g) as u64;
        let Some(members) = self.group_members.get(g.0 as usize) else {
            return;
        };
        for &id in members {
            let stamp = self.stamps.bump(id);
            self.rest_heap.push(key, id, stamp);
        }
    }

    /// Pop the next *current* probe candidate: stamp fresh, still
    /// waiting, still probe-classified, key matching. Mismatched keys or
    /// classifications are repaired in place (self-healing) rather than
    /// silently used.
    fn pop_valid_probe(&mut self, ctx: &SchedCtx) -> Option<Entry<ProbeKey>> {
        while let Some(e) = self.probe_heap.pop() {
            if !self.stamps.is_current(&e) {
                continue;
            }
            let r = ctx.buffer.get(e.req);
            if !matches!(r.phase, Phase::Waiting) {
                continue;
            }
            if !self.is_probe_pending(r) {
                // Group gained context since this entry was pushed:
                // migrate to the LFS heap at its current key.
                let key = self.priority_key(r);
                self.rest_heap.push_raw(Entry {
                    key,
                    req: e.req,
                    stamp: e.stamp,
                });
                continue;
            }
            let key = Reverse((r.generated, r.id().0));
            if key != e.key {
                self.probe_heap.push_raw(Entry { key, ..e });
                continue;
            }
            return Some(e);
        }
        None
    }

    /// Pop the next *current* rest candidate (see `pop_valid_probe`).
    fn pop_valid_rest(&mut self, ctx: &SchedCtx) -> Option<Entry<u64>> {
        while let Some(e) = self.rest_heap.pop() {
            if !self.stamps.is_current(&e) {
                continue;
            }
            let r = ctx.buffer.get(e.req);
            if !matches!(r.phase, Phase::Waiting) {
                continue;
            }
            if self.is_probe_pending(r) {
                self.probe_heap.push_raw(Entry {
                    key: Reverse((r.generated, r.id().0)),
                    req: e.req,
                    stamp: e.stamp,
                });
                continue;
            }
            let key = self.priority_key(r);
            if key != e.key {
                self.rest_heap.push_raw(Entry { key, ..e });
                continue;
            }
            return Some(e);
        }
        None
    }

    /// Next rest candidate in exact LFS order: lookahead buffer first
    /// (entries displaced by an earlier starvation window), then the
    /// heap.
    fn next_rest(&mut self, ctx: &SchedCtx) -> Option<Entry<u64>> {
        if let Some(e) = self.rest_pending.pop_front() {
            return Some(e);
        }
        self.pop_valid_rest(ctx)
    }

    /// Starvation-guard pick: look at the next ≤ 256 candidates in LFS
    /// order (`first` included), take the most underserved group's first
    /// entry, and leave the rest in the lookahead buffer in *exactly*
    /// the order the original vector-swap produced — the displaced front
    /// candidate is revisited at the chosen one's old position.
    fn guard_pick(&mut self, first: Entry<u64>, ctx: &SchedCtx) -> Entry<u64> {
        let mut window = std::mem::take(&mut self.guard_window);
        window.clear();
        window.push(first);
        while window.len() < 256 {
            match self.next_rest(ctx) {
                Some(e) => window.push(e),
                None => break,
            }
        }
        let g = self
            .ctx_mgr
            .most_underserved(window.iter().map(|e| ctx.buffer.get(e.req).group()));
        let pos = g
            .and_then(|g| {
                window
                    .iter()
                    .position(|e| ctx.buffer.get(e.req).group() == g)
            })
            .unwrap_or(0);
        let chosen = window.remove(pos);
        if pos > 0 {
            let displaced = window.remove(0);
            window.insert(pos - 1, displaced);
        }
        for e in window.drain(..).rev() {
            self.rest_pending.push_front(e);
        }
        self.guard_window = window;
        chosen
    }

    fn stash(&mut self, p: Pick) {
        match p {
            Pick::Probe(e) => self.consumed_probe.push(e),
            Pick::Rest(e) => self.consumed_rest.push(e),
        }
    }
}

impl Scheduler for SeerScheduler {
    fn name(&self) -> &'static str {
        match self.mode {
            ContextMode::Learned => "seer",
            ContextMode::Oracle => "seer-oracle-lfs",
            ContextMode::None => "seer-no-context",
        }
    }

    fn init(
        &mut self,
        groups: &[GroupSpec],
        cfg: &WorkloadConfig,
        sys: &SystemConfig,
    ) {
        self.ctx_mgr = ContextManager::with_priors(
            cfg.max_gen_len,
            self.priors.iter().copied(),
        );
        self.ctx_mgr.init_groups(groups);
        self.chunk_size = sys.chunk_size;
        self.starvation_frac = sys.starvation_guard_frac;
        self.picks_since_guard = 0;
        // Rebuild the incremental candidate structures for the new
        // iteration's id space.
        let n_reqs = groups
            .iter()
            .flat_map(|g| g.requests.iter())
            .map(|r| r.id.0 as usize + 1)
            .max()
            .unwrap_or(0);
        self.stamps.reset(n_reqs);
        self.probe_heap.clear();
        self.rest_heap.clear();
        self.rest_pending.clear();
        let n_groups = groups
            .iter()
            .map(|g| g.id.0 as usize + 1)
            .max()
            .unwrap_or(0);
        self.group_members.clear();
        self.group_members.resize(n_groups, Vec::new());
        for g in groups {
            self.group_members[g.id.0 as usize] =
                g.requests.iter().map(|r| r.id).collect();
            let has_ctx = self.ctx_mgr.has_context(g.id);
            for (i, r) in g.requests.iter().enumerate() {
                let stamp = self.stamps.bump(r.id);
                let probe =
                    i == 0 && self.mode == ContextMode::Learned && !has_ctx;
                if probe {
                    self.probe_heap.push(Reverse((0, r.id.0)), r.id, stamp);
                } else {
                    // generated == 0 at iteration start, so the Oracle
                    // key is the spec's full length.
                    let key = match self.mode {
                        ContextMode::Oracle => r.gen_len as u64,
                        ContextMode::Learned => {
                            self.ctx_mgr.estimate(g.id) as u64
                        }
                        ContextMode::None => 0,
                    };
                    self.rest_heap.push(key, r.id, stamp);
                }
            }
        }
    }

    /// Learned mode consumes cross-iteration length priors: prior'd
    /// groups start the rollout with a usable LFS estimate and skip the
    /// high-priority probe path entirely (no cold-start probe tax).
    /// Oracle already knows true lengths and No-Context ignores length
    /// context by design, so both leave history untouched.
    fn warm_start(&mut self, priors: &crate::iteration::ContextPriors) -> bool {
        if self.mode != ContextMode::Learned {
            return false;
        }
        self.priors = priors.estimates.clone();
        self.ctx_mgr.inject_priors(self.priors.iter().copied());
        // Prior'd groups flip probe → rest and take the prior as their
        // LFS key: re-index their members.
        for (g, _) in &priors.estimates {
            if self.ctx_mgr.has_context(*g) {
                self.repush_group(*g);
            }
        }
        true
    }

    fn schedule(&mut self, ctx: &SchedCtx, out: &mut Vec<Assignment>) {
        // Paper Alg. 2, run to fixpoint for this cycle: repeatedly pick
        // r* (probes SFS first, then LFS on estimates) and i* (most free
        // KV with room). Instance selection uses a max-heap on free KV
        // (perf iteration 2, EXPERIMENTS.md §Perf); candidates come from
        // the incrementally maintained lazy heaps (module docs).
        let n_waiting = ctx.buffer.n_waiting();
        self.probe_heap.maybe_compact(&self.stamps, n_waiting);
        self.rest_heap.maybe_compact(&self.stamps, n_waiting);
        debug_assert!(self.rest_pending.is_empty());

        // Heap of (free_kv, slots_left, idx); stale entries are lazily
        // re-pushed after adjustment.
        let mut heap: std::collections::BinaryHeap<(u64, usize, usize)> =
            ctx.instances
                .iter()
                .enumerate()
                .filter(|(_, v)| v.running < v.max_batch)
                .map(|(i, v)| {
                    (v.free_kv_tokens, v.max_batch - v.running, i)
                })
                .collect();

        let guard_every = if self.starvation_frac > 0.0 {
            (1.0 / self.starvation_frac).round() as u64
        } else {
            u64::MAX
        };

        loop {
            // Pick r*: probe queue first (high-priority path).
            let pick = if let Some(e) = self.pop_valid_probe(ctx) {
                Pick::Probe(e)
            } else if let Some(first) = self.next_rest(ctx) {
                // Starvation guard: periodically pick the most
                // underserved group's first waiting request instead.
                self.picks_since_guard += 1;
                let e = if self.mode == ContextMode::Learned
                    && self.picks_since_guard % guard_every == 0
                {
                    self.guard_pick(first, ctx)
                } else {
                    first
                };
                Pick::Rest(e)
            } else {
                break;
            };

            let rid = pick.req();
            let r = ctx.buffer.get(rid);
            let chunk = self.chunk_size;
            let demand = r.kv_demand(chunk);
            match heap.peek().copied() {
                Some((free, slots_left, i)) if free >= demand => {
                    heap.pop();
                    self.ctx_mgr.on_scheduled(r.group());
                    out.push(Assignment {
                        req: rid,
                        instance: ctx.instances[i].id,
                        chunk,
                    });
                    if slots_left > 1 {
                        heap.push((free - demand, slots_left - 1, i));
                    }
                    self.stash(pick);
                }
                _ => {
                    // Alg. 2 line 20: the most-free instance can't take
                    // this request, so no instance can (demands are
                    // near-uniform: existing KV + one chunk). Probes are
                    // precious — keep trying; for the LFS queue, stop
                    // after a bounded lookahead to keep cycles cheap.
                    self.stash(pick);
                    if out.len() > 4 * ctx.instances.len()
                        || heap.is_empty()
                    {
                        break;
                    }
                }
            }
        }

        // Pass end: every examined candidate returns to its heap with
        // its stamp intact — assigned ones too. If the driver applies an
        // assignment the request leaves Waiting and the entry is
        // discarded by next pass's validation; if the driver rejects it,
        // `on_requeued` re-stamps and the zombie goes stale either way.
        while let Some(e) = self.rest_pending.pop_front() {
            self.rest_heap.push_raw(e);
        }
        while let Some(e) = self.consumed_probe.pop() {
            self.probe_heap.push_raw(e);
        }
        while let Some(e) = self.consumed_rest.pop() {
            self.rest_heap.push_raw(e);
        }

        let _ = self.rng.next_u64(); // reserved for future stochastic tie-breaks
    }

    fn on_finished(&mut self, req: &ReqState) {
        let g = req.group();
        let had_ctx = self.ctx_mgr.has_context(g);
        let before = self.ctx_mgr.estimate(g);
        self.ctx_mgr.on_finished(g, req.generated);
        // Re-key the group's waiting members when its LFS key moved (or
        // its probe lost the fast path on the first finish).
        if self.mode == ContextMode::Learned
            && (!had_ctx || self.ctx_mgr.estimate(g) != before)
        {
            self.repush_group(g);
        }
    }

    /// The missed update path (regression fix): a chunk lease ended and
    /// the request migrates back into the queue — record its in-flight
    /// progress so a stale learned/prior estimate can't demote a
    /// demonstrably long group.
    fn on_chunk_end(&mut self, req: &ReqState) {
        let g = req.group();
        let before = self.ctx_mgr.estimate(g);
        self.ctx_mgr.on_progress(g, req.generated);
        // The request itself re-enters the waiting set with new
        // progress: re-index it under its current key.
        self.reindex(req);
        if self.mode == ContextMode::Learned
            && self.ctx_mgr.estimate(g) != before
        {
            self.repush_group(g);
        }
    }

    /// A produced assignment bounced (driver re-check or in-flight
    /// capacity loss): the request is back in the waiting set unchanged —
    /// restore exactly one current candidate entry for it.
    fn on_requeued(&mut self, req: &ReqState) {
        self.reindex(req);
    }

    fn uses_global_pool(&self) -> bool {
        true
    }

    /// With divided rollout, preemption should be rare (admission control
    /// reserves chunk-level budgets); when it happens, evict the request
    /// with the *shortest* estimate — it re-enters the LFS queue last.
    fn preempt_victim(
        &mut self,
        running: &[(RequestId, crate::sim::clock::SimTime)],
        buffer: &crate::coordinator::RequestBuffer,
    ) -> Option<RequestId> {
        running
            .iter()
            .min_by_key(|(id, _)| {
                let r = buffer.get(*id);
                (self.priority_key(r), u32::MAX - id.0)
            })
            .map(|(id, _)| *id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;
    use crate::coordinator::RequestBuffer;
    use crate::sim::clock::SimTime;
    use crate::workload::{generate_iteration, InstanceId};

    use crate::scheduler::InstanceView;

    fn setup(mode: ContextMode) -> (SeerScheduler, RequestBuffer, Vec<InstanceView>) {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 5);
        let buffer = RequestBuffer::from_groups(&w.groups);
        let mut s = SeerScheduler::new(mode);
        s.init(&w.groups, &cfg, &SystemConfig::default());
        let instances = (0..cfg.n_instances as u32)
            .map(|i| InstanceView {
                id: InstanceId(i),
                free_kv_tokens: cfg.hw.kv_capacity_tokens,
                capacity_tokens: cfg.hw.kv_capacity_tokens,
                running: 0,
                max_batch: cfg.hw.max_batch,
            })
            .collect();
        (s, buffer, instances)
    }

    fn run_pass(
        s: &mut SeerScheduler,
        buffer: &RequestBuffer,
        instances: &[InstanceView],
    ) -> Vec<Assignment> {
        let ctx = SchedCtx {
            now: SimTime::ZERO,
            instances,
            buffer,
        };
        let mut out = Vec::new();
        s.schedule(&ctx, &mut out);
        out
    }

    #[test]
    fn schedules_probes_first() {
        let (mut s, buffer, instances) = setup(ContextMode::Learned);
        let assignments = run_pass(&mut s, &buffer, &instances);
        assert!(!assignments.is_empty());
        // The earliest assignments must all be probes (one per group,
        // scheduled before any non-probe).
        let n_groups = buffer.all().iter().filter(|r| r.is_probe).count();
        let first_n: Vec<_> = assignments
            .iter()
            .take(n_groups.min(assignments.len()))
            .collect();
        for a in first_n {
            assert!(
                buffer.get(a.req).is_probe,
                "non-probe scheduled before probes: {a:?}"
            );
        }
    }

    #[test]
    fn oracle_mode_orders_by_true_length() {
        let (mut s, buffer, mut instances) = setup(ContextMode::Oracle);
        // Shrink capacity so only a few requests fit: the picks must be
        // the longest ones.
        for i in &mut instances {
            i.free_kv_tokens = 9000;
            i.max_batch = 1;
        }
        let assignments = run_pass(&mut s, &buffer, &instances);
        assert!(!assignments.is_empty());
        let mut lens: Vec<u32> = assignments
            .iter()
            .map(|a| buffer.get(a.req).remaining_true())
            .collect();
        let max_len = buffer
            .all()
            .iter()
            .map(|r| r.remaining_true())
            .max()
            .unwrap();
        lens.sort_by_key(|l| std::cmp::Reverse(*l));
        assert_eq!(lens[0], max_len, "oracle LFS must start with longest");
    }

    #[test]
    fn respects_batch_slots_and_kv() {
        let (mut s, buffer, mut instances) = setup(ContextMode::None);
        for i in &mut instances {
            i.max_batch = 2;
        }
        let assignments = run_pass(&mut s, &buffer, &instances);
        // No instance may receive more than max_batch assignments.
        let mut per_inst = std::collections::BTreeMap::new();
        for a in &assignments {
            *per_inst.entry(a.instance.0).or_insert(0usize) += 1;
        }
        for (_, n) in per_inst {
            assert!(n <= 2);
        }
    }

    /// The incremental heaps must make repeated passes over an unchanged
    /// buffer behave exactly like the rebuild-per-pass implementation:
    /// examined candidates are returned at pass end, so a second pass
    /// sees the identical candidate set.
    #[test]
    fn repeated_passes_without_application_are_stable() {
        let (mut s, buffer, mut instances) = setup(ContextMode::Learned);
        for i in &mut instances {
            i.max_batch = 4;
        }
        let first = run_pass(&mut s, &buffer, &instances);
        let second = run_pass(&mut s, &buffer, &instances);
        assert!(!first.is_empty());
        assert_eq!(
            first, second,
            "unapplied assignments must be re-producible next pass"
        );
    }

    #[test]
    fn warm_priors_skip_probe_path_and_seed_estimates() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 5);
        let buffer = RequestBuffer::from_groups(&w.groups);
        let mut s = SeerScheduler::new(ContextMode::Learned);
        s.init(&w.groups, &cfg, &SystemConfig::default());
        let priors = crate::iteration::ContextPriors {
            estimates: w.groups.iter().map(|g| (g.id, 321)).collect(),
            ..Default::default()
        };
        assert!(s.warm_start(&priors), "Learned mode must consume priors");
        for g in &w.groups {
            assert_eq!(s.context_manager().estimate(g.id), 321);
            assert!(s.context_manager().has_prior(g.id));
        }
        // With every group prior'd, nothing takes the probe fast path:
        // the first assignments follow LFS order, not probe-SFS.
        let instances = vec![InstanceView {
            id: InstanceId(0),
            free_kv_tokens: cfg.hw.kv_capacity_tokens,
            capacity_tokens: cfg.hw.kv_capacity_tokens,
            running: 0,
            max_batch: 4,
        }];
        let assignments = run_pass(&mut s, &buffer, &instances);
        assert!(!assignments.is_empty());
        // Re-init for a new iteration must retain the injected priors.
        s.init(&w.groups, &cfg, &SystemConfig::default());
        assert_eq!(s.context_manager().estimate(w.groups[0].id), 321);
    }

    /// Regression: migrating probes used to leave no trace — the
    /// scheduler had no `on_chunk_end` override, so a group whose probe
    /// re-entered the queue with substantial progress could be demoted
    /// below its true LFS rank once a short sibling finished first.
    #[test]
    fn chunk_end_progress_reaches_context_manager() {
        let (mut s, mut buffer, _) = setup(ContextMode::Learned);
        let id = buffer.all()[0].id();
        let group = buffer.get(id).group();
        buffer.mark_scheduled(id);
        {
            let r = buffer.get_mut(id);
            r.generated = 500;
        }
        buffer.mark_waiting(id);
        s.on_chunk_end(buffer.get(id));
        // A short sibling finishing must not shrink the estimate below
        // the migrated sibling's observed progress.
        let sib = buffer.all().iter().find(|r| r.group() == group && r.id() != id).unwrap().id();
        buffer.mark_scheduled(sib);
        {
            let r = buffer.get_mut(sib);
            r.generated = 10;
        }
        buffer.mark_finished(sib);
        s.on_finished(buffer.get(sib));
        assert_eq!(s.context_manager().estimate(group), 500);
    }

    #[test]
    fn learned_estimates_update_on_finish() {
        let (mut s, mut buffer, _) = setup(ContextMode::Learned);
        let id = buffer.all()[0].id();
        let group = buffer.get(id).group();
        buffer.mark_scheduled(id);
        {
            let r = buffer.get_mut(id);
            r.generated = r.spec.gen_len;
        }
        buffer.mark_finished(id);
        s.on_finished(buffer.get(id));
        let est = s.context_manager().estimate(group);
        assert_eq!(est, buffer.get(id).generated);
    }
}
