//! Seer's context-aware scheduler — paper Algorithm 2 on top of divided
//! rollout (§3.2 + §3.3).
//!
//! Three context modes cover the Figure 10 ablation:
//! * `Learned` — the real system: probe requests run shortest-first in a
//!   high-priority path; everyone else runs approximate-LFS on the
//!   context manager's online group estimates, with a starvation guard.
//! * `Oracle`  — LFS on true lengths (upper bound).
//! * `None`    — divided rollout only, FCFS (the "No-Context" ablation and
//!   Table 4's "+ Divided Rollout" row).

use crate::config::{SystemConfig, WorkloadConfig};
use crate::coordinator::{ContextManager, ReqState};
use crate::sim::Rng;
use crate::workload::{GroupSpec, RequestId};

use super::{Assignment, SchedCtx, Scheduler};

/// How much length context the scheduler may use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ContextMode {
    Learned,
    Oracle,
    None,
}

pub struct SeerScheduler {
    mode: ContextMode,
    ctx_mgr: ContextManager,
    chunk_size: u32,
    starvation_frac: f64,
    rng: Rng,
    /// Scratch: scheduling decisions since the last starvation pick.
    picks_since_guard: u64,
    /// Cross-iteration length priors (survive `init`, which rebuilds the
    /// context manager at iteration start).
    priors: Vec<(crate::workload::GroupId, u32)>,
}

impl SeerScheduler {
    pub fn new(mode: ContextMode) -> Self {
        SeerScheduler {
            mode,
            ctx_mgr: ContextManager::new(u32::MAX),
            chunk_size: 2048,
            starvation_frac: 0.05,
            rng: Rng::new(0x5EE12),
            picks_since_guard: 0,
            priors: Vec::new(),
        }
    }

    /// LFS key for a waiting request: higher = schedule earlier.
    fn priority_key(&self, r: &ReqState) -> u64 {
        match self.mode {
            ContextMode::Oracle => r.remaining_true() as u64,
            ContextMode::Learned => self.ctx_mgr.estimate(r.group()) as u64,
            ContextMode::None => 0,
        }
    }

    pub fn context_manager(&self) -> &ContextManager {
        &self.ctx_mgr
    }
}

impl Scheduler for SeerScheduler {
    fn name(&self) -> &'static str {
        match self.mode {
            ContextMode::Learned => "seer",
            ContextMode::Oracle => "seer-oracle-lfs",
            ContextMode::None => "seer-no-context",
        }
    }

    fn init(
        &mut self,
        groups: &[GroupSpec],
        cfg: &WorkloadConfig,
        sys: &SystemConfig,
    ) {
        self.ctx_mgr = ContextManager::with_priors(
            cfg.max_gen_len,
            self.priors.iter().copied(),
        );
        self.ctx_mgr.init_groups(groups);
        self.chunk_size = sys.chunk_size;
        self.starvation_frac = sys.starvation_guard_frac;
        self.picks_since_guard = 0;
    }

    /// Learned mode consumes cross-iteration length priors: prior'd
    /// groups start the rollout with a usable LFS estimate and skip the
    /// high-priority probe path entirely (no cold-start probe tax).
    /// Oracle already knows true lengths and No-Context ignores length
    /// context by design, so both leave history untouched.
    fn warm_start(&mut self, priors: &crate::iteration::ContextPriors) -> bool {
        if self.mode != ContextMode::Learned {
            return false;
        }
        self.priors = priors.estimates.clone();
        self.ctx_mgr.inject_priors(self.priors.iter().copied());
        true
    }

    fn schedule(&mut self, ctx: &SchedCtx) -> Vec<Assignment> {
        // Paper Alg. 2, run to fixpoint for this cycle: repeatedly pick
        // r* (probes SFS first, then LFS on estimates) and i* (most free
        // KV with room). Instance selection uses a max-heap on free KV
        // (perf iteration 2, EXPERIMENTS.md §Perf: O(log I) per pick
        // instead of an O(I) scan — 6x on the 3200-waiting bench).
        let mut out = Vec::new();
        // Heap of (free_kv, slots_left, idx); stale entries are lazily
        // re-pushed after adjustment.
        let mut heap: std::collections::BinaryHeap<(u64, usize, usize)> =
            ctx.instances
                .iter()
                .enumerate()
                .filter(|(_, v)| v.running < v.max_batch)
                .map(|(i, v)| {
                    (v.free_kv_tokens, v.max_batch - v.running, i)
                })
                .collect();

        // Candidate list: waiting requests.
        let mut probes: Vec<RequestId> = Vec::new();
        let mut rest: Vec<RequestId> = Vec::new();
        for id in ctx.buffer.waiting() {
            let r = ctx.buffer.get(id);
            // A probe only needs the high-priority path while the group
            // has no length context at all — neither an online finish
            // nor a warm cross-iteration prior.
            let probe_pending = r.is_probe
                && self.mode == ContextMode::Learned
                && !self.ctx_mgr.has_context(r.group());
            if probe_pending {
                probes.push(id);
            } else {
                rest.push(id);
            }
        }
        // SFS for probes: fewest generated tokens first (they surface
        // length signal soonest). Keys cached: priority_key hits the
        // context manager's BTreeMap, so computing it once per element
        // instead of per comparison matters at 3200 waiting (perf
        // iteration 3, EXPERIMENTS.md §Perf).
        probes.sort_by_cached_key(|id| {
            let r = ctx.buffer.get(*id);
            (r.generated, r.id().0)
        });
        // LFS for the rest on the mode's priority key; FCFS tiebreak.
        rest.sort_by_cached_key(|id| {
            let r = ctx.buffer.get(*id);
            (std::cmp::Reverse(self.priority_key(r)), r.id().0)
        });

        let guard_every = if self.starvation_frac > 0.0 {
            (1.0 / self.starvation_frac).round() as u64
        } else {
            u64::MAX
        };

        let mut pi = 0usize;
        let mut ri = 0usize;
        loop {
            // Pick r*: probe queue first (high-priority path).
            let rid = if pi < probes.len() {
                let id = probes[pi];
                pi += 1;
                id
            } else if ri < rest.len() {
                // Starvation guard: periodically pick the most
                // underserved group's first waiting request instead.
                self.picks_since_guard += 1;
                if self.mode == ContextMode::Learned
                    && self.picks_since_guard % guard_every == 0
                {
                    // Bounded scan window (perf iteration 4): an O(W)
                    // scan per guard pick made the decision loop
                    // quadratic at 3200 waiting; 256 candidates is ample
                    // to find a starved group.
                    let window = (ri + 256).min(rest.len());
                    let cand_groups = rest[ri..window]
                        .iter()
                        .map(|id| ctx.buffer.get(*id).group());
                    if let Some(g) = self.ctx_mgr.most_underserved(cand_groups)
                    {
                        if let Some(pos) = rest[ri..window]
                            .iter()
                            .position(|id| ctx.buffer.get(*id).group() == g)
                        {
                            rest.swap(ri, ri + pos);
                        }
                    }
                }
                let id = rest[ri];
                ri += 1;
                id
            } else {
                break;
            };

            let r = ctx.buffer.get(rid);
            let chunk = self.chunk_size;
            let demand = r.kv_demand(chunk);
            match heap.peek().copied() {
                Some((free, slots_left, i)) if free >= demand => {
                    heap.pop();
                    self.ctx_mgr.on_scheduled(r.group());
                    out.push(Assignment {
                        req: rid,
                        instance: ctx.instances[i].id,
                        chunk,
                    });
                    if slots_left > 1 {
                        heap.push((free - demand, slots_left - 1, i));
                    }
                }
                _ => {
                    // Alg. 2 line 20: the most-free instance can't take
                    // this request, so no instance can (demands are
                    // near-uniform: existing KV + one chunk). Probes are
                    // precious — keep trying; for the LFS queue, stop
                    // after a bounded lookahead to keep cycles cheap.
                    if out.len() > 4 * ctx.instances.len()
                        || heap.is_empty()
                    {
                        break;
                    }
                }
            }
        }
        let _ = self.rng.next_u64(); // reserved for future stochastic tie-breaks
        out
    }

    fn on_finished(&mut self, req: &ReqState) {
        self.ctx_mgr.on_finished(req.group(), req.generated);
    }

    /// The missed update path (regression fix): a chunk lease ended and
    /// the request migrates back into the queue — record its in-flight
    /// progress so a stale learned/prior estimate can't demote a
    /// demonstrably long group.
    fn on_chunk_end(&mut self, req: &ReqState) {
        self.ctx_mgr.on_progress(req.group(), req.generated);
    }

    fn uses_global_pool(&self) -> bool {
        true
    }

    /// With divided rollout, preemption should be rare (admission control
    /// reserves chunk-level budgets); when it happens, evict the request
    /// with the *shortest* estimate — it re-enters the LFS queue last.
    fn preempt_victim(
        &mut self,
        running: &[(RequestId, crate::sim::clock::SimTime)],
        buffer: &crate::coordinator::RequestBuffer,
    ) -> Option<RequestId> {
        running
            .iter()
            .min_by_key(|(id, _)| {
                let r = buffer.get(*id);
                (self.priority_key(r), u32::MAX - id.0)
            })
            .map(|(id, _)| *id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;
    use crate::coordinator::RequestBuffer;
    use crate::sim::clock::SimTime;
    use crate::workload::{generate_iteration, InstanceId};

    use crate::scheduler::InstanceView;

    fn setup(mode: ContextMode) -> (SeerScheduler, RequestBuffer, Vec<InstanceView>) {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 5);
        let buffer = RequestBuffer::from_groups(&w.groups);
        let mut s = SeerScheduler::new(mode);
        s.init(&w.groups, &cfg, &SystemConfig::default());
        let instances = (0..cfg.n_instances as u32)
            .map(|i| InstanceView {
                id: InstanceId(i),
                free_kv_tokens: cfg.hw.kv_capacity_tokens,
                capacity_tokens: cfg.hw.kv_capacity_tokens,
                running: 0,
                max_batch: cfg.hw.max_batch,
            })
            .collect();
        (s, buffer, instances)
    }

    #[test]
    fn schedules_probes_first() {
        let (mut s, buffer, instances) = setup(ContextMode::Learned);
        let ctx = SchedCtx {
            now: SimTime::ZERO,
            instances: &instances,
            buffer: &buffer,
        };
        let assignments = s.schedule(&ctx);
        assert!(!assignments.is_empty());
        // The earliest assignments must all be probes (one per group,
        // scheduled before any non-probe).
        let n_groups = buffer.all().iter().filter(|r| r.is_probe).count();
        let first_n: Vec<_> = assignments
            .iter()
            .take(n_groups.min(assignments.len()))
            .collect();
        for a in first_n {
            assert!(
                buffer.get(a.req).is_probe,
                "non-probe scheduled before probes: {a:?}"
            );
        }
    }

    #[test]
    fn oracle_mode_orders_by_true_length() {
        let (mut s, buffer, mut instances) = setup(ContextMode::Oracle);
        // Shrink capacity so only a few requests fit: the picks must be
        // the longest ones.
        for i in &mut instances {
            i.free_kv_tokens = 9000;
            i.max_batch = 1;
        }
        let ctx = SchedCtx {
            now: SimTime::ZERO,
            instances: &instances,
            buffer: &buffer,
        };
        let assignments = s.schedule(&ctx);
        assert!(!assignments.is_empty());
        let mut lens: Vec<u32> = assignments
            .iter()
            .map(|a| buffer.get(a.req).remaining_true())
            .collect();
        let max_len = buffer
            .all()
            .iter()
            .map(|r| r.remaining_true())
            .max()
            .unwrap();
        lens.sort_by_key(|l| std::cmp::Reverse(*l));
        assert_eq!(lens[0], max_len, "oracle LFS must start with longest");
    }

    #[test]
    fn respects_batch_slots_and_kv() {
        let (mut s, buffer, mut instances) = setup(ContextMode::None);
        for i in &mut instances {
            i.max_batch = 2;
        }
        let ctx = SchedCtx {
            now: SimTime::ZERO,
            instances: &instances,
            buffer: &buffer,
        };
        let assignments = s.schedule(&ctx);
        // No instance may receive more than max_batch assignments.
        let mut per_inst = std::collections::BTreeMap::new();
        for a in &assignments {
            *per_inst.entry(a.instance.0).or_insert(0usize) += 1;
        }
        for (_, n) in per_inst {
            assert!(n <= 2);
        }
    }

    #[test]
    fn warm_priors_skip_probe_path_and_seed_estimates() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 5);
        let buffer = RequestBuffer::from_groups(&w.groups);
        let mut s = SeerScheduler::new(ContextMode::Learned);
        s.init(&w.groups, &cfg, &SystemConfig::default());
        let priors = crate::iteration::ContextPriors {
            estimates: w.groups.iter().map(|g| (g.id, 321)).collect(),
            ..Default::default()
        };
        assert!(s.warm_start(&priors), "Learned mode must consume priors");
        for g in &w.groups {
            assert_eq!(s.context_manager().estimate(g.id), 321);
            assert!(s.context_manager().has_prior(g.id));
        }
        // With every group prior'd, nothing takes the probe fast path:
        // the first assignments follow LFS order, not probe-SFS.
        let instances = vec![InstanceView {
            id: InstanceId(0),
            free_kv_tokens: cfg.hw.kv_capacity_tokens,
            capacity_tokens: cfg.hw.kv_capacity_tokens,
            running: 0,
            max_batch: 4,
        }];
        let ctx = SchedCtx {
            now: SimTime::ZERO,
            instances: &instances,
            buffer: &buffer,
        };
        let assignments = s.schedule(&ctx);
        assert!(!assignments.is_empty());
        // Re-init for a new iteration must retain the injected priors.
        s.init(&w.groups, &cfg, &SystemConfig::default());
        assert_eq!(s.context_manager().estimate(w.groups[0].id), 321);
    }

    /// Regression: migrating probes used to leave no trace — the
    /// scheduler had no `on_chunk_end` override, so a group whose probe
    /// re-entered the queue with substantial progress could be demoted
    /// below its true LFS rank once a short sibling finished first.
    #[test]
    fn chunk_end_progress_reaches_context_manager() {
        let (mut s, mut buffer, _) = setup(ContextMode::Learned);
        let id = buffer.all()[0].id();
        let group = buffer.get(id).group();
        buffer.mark_scheduled(id);
        {
            let r = buffer.get_mut(id);
            r.generated = 500;
        }
        buffer.mark_waiting(id);
        s.on_chunk_end(buffer.get(id));
        // A short sibling finishing must not shrink the estimate below
        // the migrated sibling's observed progress.
        let sib = buffer.all().iter().find(|r| r.group() == group && r.id() != id).unwrap().id();
        buffer.mark_scheduled(sib);
        {
            let r = buffer.get_mut(sib);
            r.generated = 10;
        }
        buffer.mark_finished(sib);
        s.on_finished(buffer.get(sib));
        assert_eq!(s.context_manager().estimate(group), 500);
    }

    #[test]
    fn learned_estimates_update_on_finish() {
        let (mut s, mut buffer, _) = setup(ContextMode::Learned);
        let id = buffer.all()[0].id();
        let group = buffer.get(id).group();
        buffer.mark_scheduled(id);
        {
            let r = buffer.get_mut(id);
            r.generated = r.spec.gen_len;
        }
        buffer.mark_finished(id);
        s.on_finished(buffer.get(id));
        let est = s.context_manager().estimate(group);
        assert_eq!(est, buffer.get(id).generated);
    }
}
