//! Stamped lazy priority heaps — the incremental scheduling substrate.
//!
//! Every policy used to rebuild its candidate ordering from
//! `buffer.waiting()` on each scheduling pass: collect the waiting set,
//! partition, sort — O(W log W) per pass even when one request changed.
//! The hot-path overhaul replaces that with *incrementally maintained*
//! keyed heaps repaired on the scheduler's lifecycle hooks
//! (`on_finished` / `on_chunk_end` / `on_requeued` / fault hooks), so a
//! steady-state pass costs O(popped · log W) instead of a full rescan.
//!
//! The mechanism is lazy deletion with per-request stamps:
//!
//! * [`Stamps`] holds one generation counter per request id. Any event
//!   that (re)classifies a request or changes its sort key *bumps* the
//!   stamp and pushes a fresh [`Entry`]; older entries for the id become
//!   stale and are discarded when popped.
//! * [`LazyHeap`] is a plain max-heap of entries. The **owner validates
//!   at pop time**: an entry counts only if its stamp is current, the
//!   request is still `Waiting` in the buffer, and its key matches the
//!   freshly computed one (a mismatch is repaired by re-pushing at the
//!   corrected position — self-healing rather than silently using a
//!   stale order).
//! * Entries popped but not consumed by a pass (examined and skipped, or
//!   handed to the driver which may still reject the assignment) are
//!   returned with [`LazyHeap::push_raw`] — same stamp, no bump — so the
//!   next pass sees them again. Exactly one *current* entry exists per
//!   waiting request at all times: hook pushes always bump first.
//!
//! Determinism: the pop order of current entries equals the fully sorted
//! order of the waiting set under the current keys — [`Entry`] ordering
//! is total (key, then ascending request id, then stamp), so the
//! incremental schedulers reproduce the byte-identical assignment
//! sequences of the rebuild-and-sort implementations they replaced.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::workload::RequestId;

/// Per-request generation counters shared by all heaps of one policy
/// (a request may migrate between heaps, e.g. Seer's probe → LFS move;
/// one bump invalidates its entries everywhere).
#[derive(Debug, Default)]
pub struct Stamps(Vec<u32>);

impl Stamps {
    /// Reset for an iteration of `n` contiguous request ids.
    pub fn reset(&mut self, n: usize) {
        self.0.clear();
        self.0.resize(n, 0);
    }

    /// Invalidate every live entry for `req`; returns the new stamp to
    /// push with.
    pub fn bump(&mut self, req: RequestId) -> u32 {
        let s = &mut self.0[req.0 as usize];
        *s = s.wrapping_add(1);
        *s
    }

    pub fn current(&self, req: RequestId) -> u32 {
        self.0[req.0 as usize]
    }

    pub fn is_current<K: Ord + Copy>(&self, e: &Entry<K>) -> bool {
        self.current(e.req) == e.stamp
    }
}

/// One heap entry: a candidate request under sort key `K`.
///
/// Ordering is total and deterministic: key first (max-heap — *greater*
/// keys pop first), then **lower request id first** among equal keys
/// (the FCFS tie-break every policy documents), then stamp.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Entry<K: Ord + Copy> {
    pub key: K,
    pub req: RequestId,
    pub stamp: u32,
}

impl<K: Ord + Copy> Ord for Entry<K> {
    fn cmp(&self, other: &Self) -> Ordering {
        self.key
            .cmp(&other.key)
            // Reversed id comparison: in a max-heap, the *greater* entry
            // pops first, so the lower id must compare greater.
            .then_with(|| other.req.0.cmp(&self.req.0))
            .then_with(|| self.stamp.cmp(&other.stamp))
    }
}

impl<K: Ord + Copy> PartialOrd for Entry<K> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// A lazily-repaired candidate heap. Dumb by design: staleness checks
/// live with the owner, which has the buffer and the key function.
#[derive(Debug, Default)]
pub struct LazyHeap<K: Ord + Copy> {
    heap: BinaryHeap<Entry<K>>,
}

impl<K: Ord + Copy> LazyHeap<K> {
    pub fn new() -> Self {
        LazyHeap {
            heap: BinaryHeap::new(),
        }
    }

    pub fn clear(&mut self) {
        self.heap.clear();
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Push a freshly stamped entry (caller bumped the stamp).
    pub fn push(&mut self, key: K, req: RequestId, stamp: u32) {
        self.heap.push(Entry { key, req, stamp });
    }

    /// Return an examined-but-unconsumed entry without invalidating it.
    pub fn push_raw(&mut self, e: Entry<K>) {
        self.heap.push(e);
    }

    /// Pop the greatest entry, stale or not — the owner validates.
    pub fn pop(&mut self) -> Option<Entry<K>> {
        self.heap.pop()
    }

    /// Drop stamp-stale entries when the heap has accumulated well past
    /// the live population (`live` = current waiting-set size). Bounds
    /// memory on long runs; deterministic, since both operands are
    /// functions of the deterministic event history.
    pub fn maybe_compact(&mut self, stamps: &Stamps, live: usize) {
        if self.heap.len() > 64 && self.heap.len() > 4 * live {
            self.heap.retain(|e| stamps.is_current(e));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pop_order_is_key_desc_then_id_asc() {
        let mut stamps = Stamps::default();
        stamps.reset(8);
        let mut h: LazyHeap<u64> = LazyHeap::new();
        for (key, id) in [(5u64, 3u32), (9, 1), (5, 0), (9, 4), (1, 2)] {
            let r = RequestId(id);
            let s = stamps.bump(r);
            h.push(key, r, s);
        }
        let order: Vec<(u64, u32)> = std::iter::from_fn(|| h.pop())
            .map(|e| (e.key, e.req.0))
            .collect();
        assert_eq!(order, vec![(9, 1), (9, 4), (5, 0), (5, 3), (1, 2)]);
    }

    #[test]
    fn bump_invalidates_old_entries() {
        let mut stamps = Stamps::default();
        stamps.reset(4);
        let mut h: LazyHeap<u64> = LazyHeap::new();
        let r = RequestId(2);
        let s1 = stamps.bump(r);
        h.push(100, r, s1);
        let s2 = stamps.bump(r);
        h.push(7, r, s2);
        let first = h.pop().unwrap();
        assert_eq!(first.key, 100);
        assert!(!stamps.is_current(&first), "old entry must be stale");
        let second = h.pop().unwrap();
        assert_eq!(second.key, 7);
        assert!(stamps.is_current(&second));
    }

    #[test]
    fn push_raw_keeps_entry_current() {
        let mut stamps = Stamps::default();
        stamps.reset(2);
        let mut h: LazyHeap<u64> = LazyHeap::new();
        let r = RequestId(1);
        let s = stamps.bump(r);
        h.push(3, r, s);
        let e = h.pop().unwrap();
        assert!(stamps.is_current(&e));
        h.push_raw(e);
        let again = h.pop().unwrap();
        assert_eq!(again, e);
        assert!(stamps.is_current(&again));
    }

    #[test]
    fn compaction_drops_only_stale() {
        let mut stamps = Stamps::default();
        stamps.reset(512);
        let mut h: LazyHeap<u64> = LazyHeap::new();
        // Two generations of entries for every id: half go stale.
        for round in 0..2u64 {
            for id in 0..256u32 {
                let r = RequestId(id);
                let s = stamps.bump(r);
                h.push(round, r, s);
            }
        }
        assert_eq!(h.len(), 512);
        // live = 1 forces the 4x threshold to trip.
        h.maybe_compact(&stamps, 1);
        assert_eq!(h.len(), 256);
        while let Some(e) = h.pop() {
            assert!(stamps.is_current(&e));
            assert_eq!(e.key, 1);
        }
    }
}
