//! veRL-style baseline: group-level round-robin placement, instance-local
//! FCFS admission, no divided rollout, no global pool (preempted requests
//! re-prefill). This is the paper's primary baseline (§4.1): a
//! well-engineered synchronous system whose scheduling treats each prompt
//! group as a monolithic unit pinned to one instance.
//!
//! Hot-path overhaul: the pin table is a dense `Vec` over the contiguous
//! request-id space (O(1) lookups instead of a tree walk), and each
//! instance keeps an incrementally maintained FCFS candidate heap (see
//! [`super::lazyheap`]) instead of re-scanning the whole waiting set per
//! pass. A pass touches only instances with free batch slots and pops
//! only the candidates it examines — o(waiting) amortized when the fleet
//! is saturated — while emitting the byte-identical ascending-id
//! assignment order of the old global scan (per-instance admission is
//! independent, so processing queue-by-queue and sorting the output by
//! request id reproduces it exactly).

use std::collections::BTreeMap;

use crate::config::{SystemConfig, WorkloadConfig};
use crate::coordinator::{Phase, ReqState, RequestBuffer};
use crate::workload::{GroupId, GroupSpec, InstanceId, RequestId};

use super::lazyheap::{Entry, LazyHeap, Stamps};
use super::{Assignment, SchedCtx, Scheduler};

pub struct VerlScheduler {
    /// Pinned instance per request (group-level round-robin), indexed by
    /// request id.
    pin: Vec<InstanceId>,
    /// Per-instance FCFS candidate heaps over the waiting set (key `()`:
    /// the entry tie-break pops ascending request id), indexed by
    /// instance id. Repaired by the lifecycle hooks.
    queues: Vec<LazyHeap<()>>,
    stamps: Stamps,
    /// Pass scratch: entries examined this pass, returned afterwards.
    consumed: Vec<Entry<()>>,
    /// Admission watermark: tokens of decode headroom reserved beyond the
    /// current KV when admitting (vLLM-style optimistic admission — the
    /// source of later preemptions).
    watermark: u32,
    max_len: u32,
}

impl VerlScheduler {
    pub fn new() -> Self {
        VerlScheduler {
            pin: Vec::new(),
            queues: Vec::new(),
            stamps: Stamps::default(),
            consumed: Vec::new(),
            watermark: 256,
            max_len: u32::MAX,
        }
    }

    fn ensure_queue(&mut self, inst: InstanceId) {
        let i = inst.0 as usize;
        if i >= self.queues.len() {
            self.queues.resize_with(i + 1, LazyHeap::new);
        }
    }

    /// Restore the candidate entry for a request that is (back) in the
    /// waiting set, into its current pin's queue.
    fn push_waiting(&mut self, id: RequestId) {
        let inst = self.pin[id.0 as usize];
        self.ensure_queue(inst);
        let stamp = self.stamps.bump(id);
        self.queues[inst.0 as usize].push((), id, stamp);
    }

    /// Move a request's pin; if it is currently waiting, migrate its
    /// candidate entry to the new instance's queue.
    fn repin(&mut self, id: RequestId, to: InstanceId, buffer: &RequestBuffer) {
        if self.pin[id.0 as usize] == to {
            return;
        }
        self.pin[id.0 as usize] = to;
        if matches!(buffer.get(id).phase, Phase::Waiting) {
            self.push_waiting(id);
        }
    }
}

impl Default for VerlScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for VerlScheduler {
    fn name(&self) -> &'static str {
        "verl"
    }

    fn init(
        &mut self,
        groups: &[GroupSpec],
        cfg: &WorkloadConfig,
        _sys: &SystemConfig,
    ) {
        self.max_len = cfg.max_gen_len;
        let n_reqs = groups
            .iter()
            .flat_map(|g| g.requests.iter())
            .map(|r| r.id.0 as usize + 1)
            .max()
            .unwrap_or(0);
        self.pin.clear();
        self.pin.resize(n_reqs, InstanceId(0));
        self.stamps.reset(n_reqs);
        self.queues.clear();
        self.queues.resize_with(cfg.n_instances.max(1), LazyHeap::new);
        for (gi, g) in groups.iter().enumerate() {
            let inst = InstanceId((gi % cfg.n_instances) as u32);
            for r in &g.requests {
                self.pin[r.id.0 as usize] = inst;
                let stamp = self.stamps.bump(r.id);
                self.queues[inst.0 as usize].push((), r.id, stamp);
            }
        }
    }

    fn schedule(&mut self, ctx: &SchedCtx, out: &mut Vec<Assignment>) {
        let start = out.len();
        let n_waiting = ctx.buffer.n_waiting();
        let mut consumed = std::mem::take(&mut self.consumed);
        // Per-instance admission is independent (FCFS by id within each
        // pinned queue against that instance's own KV/slots), so the
        // fleet is processed queue-by-queue; instances without a free
        // slot cost O(1).
        for v in ctx.instances {
            let qi = v.id.0 as usize;
            if qi >= self.queues.len() {
                continue; // newcomer with nothing pinned to it yet
            }
            self.queues[qi].maybe_compact(&self.stamps, n_waiting);
            let mut slots = v.running;
            let mut reserved = 0u64;
            while slots < v.max_batch {
                let Some(e) = self.queues[qi].pop() else {
                    break;
                };
                if !self.stamps.is_current(&e) {
                    continue;
                }
                let r = ctx.buffer.get(e.req);
                if !matches!(r.phase, Phase::Waiting) {
                    continue;
                }
                debug_assert_eq!(
                    self.pin[e.req.0 as usize], v.id,
                    "candidate in the wrong instance queue"
                );
                consumed.push(e);
                // Optimistic admission: current KV + watermark only. A
                // KV-blocked candidate does not stop the scan — later
                // (smaller) requests may still fit, exactly like the old
                // full id-order scan.
                let demand = r.kv_demand(self.watermark);
                let free = v.free_kv_tokens.saturating_sub(reserved);
                if free >= demand {
                    reserved += demand;
                    slots += 1;
                    out.push(Assignment {
                        req: e.req,
                        instance: v.id,
                        // Whole-request lease: no divided rollout.
                        chunk: self.max_len,
                    });
                }
            }
            // Examined candidates return with stamps intact; entries for
            // requests the driver actually places go stale at their next
            // pop (phase check), rejected ones are re-stamped via
            // `on_requeued`.
            for e in consumed.drain(..) {
                self.queues[qi].push_raw(e);
            }
        }
        self.consumed = consumed;
        // The old implementation scanned the global waiting set in
        // ascending id order, so its assignment order interleaved
        // instances by request id: restore that exact order.
        out[start..].sort_by_key(|a| a.req.0);
    }

    /// A preempted request re-entered the waiting queue: restore its
    /// candidate entry (veRL has no voluntary chunk ends).
    fn on_chunk_end(&mut self, req: &ReqState) {
        self.push_waiting(req.id());
    }

    /// A produced assignment bounced off the driver's admission
    /// re-check: the request is still waiting — re-stamp its entry.
    fn on_requeued(&mut self, req: &ReqState) {
        self.push_waiting(req.id());
    }

    /// Elasticity: a lost instance's groups re-pin, whole, onto the
    /// survivors round-robin (mirrors the init-time placement). Without
    /// this, requests pinned to a dead instance would starve forever —
    /// the veRL baseline gets the same crash-survival machinery as Seer,
    /// it just pays re-prefill for the KV it lost.
    fn on_instance_lost(
        &mut self,
        lost: InstanceId,
        drained: &[RequestId],
        live: &[InstanceId],
        buffer: &RequestBuffer,
    ) {
        // The drained requests just re-entered the waiting set: restore
        // their candidate entries first (into the current pin's queue),
        // so they survive even a full outage — the dead instance's queue
        // is simply served again when it recovers.
        for &id in drained {
            self.push_waiting(id);
        }
        if live.is_empty() {
            return;
        }
        let mut target: BTreeMap<GroupId, InstanceId> = BTreeMap::new();
        let mut rr = 0usize;
        for id in buffer.all().iter().map(|r| r.id()) {
            if self.pin[id.0 as usize] != lost {
                continue;
            }
            let group = buffer.get(id).group();
            let tgt = *target.entry(group).or_insert_with(|| {
                let t = live[rr % live.len()];
                rr += 1;
                t
            });
            self.repin(id, tgt, buffer);
        }
    }

    /// Elasticity: re-home a proportional share of fully-waiting groups
    /// onto scale-up newcomers so they don't idle (every
    /// ⌈live/added⌉-th movable group, deterministically).
    fn on_instances_added(
        &mut self,
        added: &[InstanceId],
        live: &[InstanceId],
        buffer: &RequestBuffer,
    ) {
        if added.is_empty() || live.is_empty() {
            return;
        }
        let mut movable: BTreeMap<GroupId, bool> = BTreeMap::new();
        for r in buffer.all() {
            // Finished members don't pin a group: only *running* work
            // anchors it (its waiting siblings must stay movable, or a
            // post-outage re-home could strand them on a dead instance).
            if r.is_finished() {
                continue;
            }
            let e = movable.entry(r.group()).or_insert(true);
            if r.is_running() {
                *e = false;
            }
        }
        let groups: Vec<GroupId> = movable
            .iter()
            .filter(|(_, m)| **m)
            .map(|(g, _)| *g)
            .collect();
        if groups.is_empty() {
            return;
        }
        let stride = live.len().div_ceil(added.len()).max(1);
        let mut retarget: BTreeMap<GroupId, InstanceId> = BTreeMap::new();
        let mut ai = 0usize;
        for (i, g) in groups.iter().enumerate() {
            if i % stride != 0 {
                continue;
            }
            retarget.insert(*g, added[ai % added.len()]);
            ai += 1;
        }
        for r in buffer.all() {
            if let Some(t) = retarget.get(&r.group()) {
                self.repin(r.id(), *t, buffer);
            }
        }
    }

    fn uses_global_pool(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;
    use crate::scheduler::InstanceView;
    use crate::sim::clock::SimTime;
    use crate::workload::generate_iteration;

    fn pin_of(s: &VerlScheduler, id: RequestId) -> InstanceId {
        s.pin[id.0 as usize]
    }

    #[test]
    fn groups_are_pinned_whole() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 2);
        let mut s = VerlScheduler::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        for g in &w.groups {
            let insts: Vec<_> =
                g.requests.iter().map(|r| pin_of(&s, r.id)).collect();
            assert!(
                insts.windows(2).all(|w| w[0] == w[1]),
                "group split across instances"
            );
        }
        // Round-robin: consecutive groups on consecutive instances.
        assert_ne!(
            pin_of(&s, w.groups[0].requests[0].id),
            pin_of(&s, w.groups[1].requests[0].id)
        );
    }

    #[test]
    fn assignments_respect_pinning() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 3);
        let buffer = RequestBuffer::from_groups(&w.groups);
        let mut s = VerlScheduler::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        let instances: Vec<InstanceView> = (0..cfg.n_instances as u32)
            .map(|i| InstanceView {
                id: crate::workload::InstanceId(i),
                free_kv_tokens: cfg.hw.kv_capacity_tokens,
                capacity_tokens: cfg.hw.kv_capacity_tokens,
                running: 0,
                max_batch: cfg.hw.max_batch,
            })
            .collect();
        let ctx = SchedCtx {
            now: SimTime::ZERO,
            instances: &instances,
            buffer: &buffer,
        };
        let mut assignments = Vec::new();
        s.schedule(&ctx, &mut assignments);
        assert!(!assignments.is_empty());
        for a in &assignments {
            assert_eq!(a.instance, pin_of(&s, a.req));
            assert_eq!(a.chunk, cfg.max_gen_len);
        }
        // The emitted order is ascending request id — the order the old
        // global waiting-set scan produced.
        assert!(
            assignments.windows(2).all(|w| w[0].req.0 < w[1].req.0),
            "assignments must come out in ascending id order"
        );
    }

    #[test]
    fn instance_lost_repins_group_atomically() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 2);
        let buffer = RequestBuffer::from_groups(&w.groups);
        let mut s = VerlScheduler::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        let lost = InstanceId(0);
        let live: Vec<InstanceId> =
            (1..cfg.n_instances as u32).map(InstanceId).collect();
        s.on_instance_lost(lost, &[], &live, &buffer);
        for g in &w.groups {
            let insts: Vec<_> =
                g.requests.iter().map(|r| pin_of(&s, r.id)).collect();
            assert!(
                insts.windows(2).all(|w| w[0] == w[1]),
                "group split by re-pin"
            );
            assert_ne!(insts[0], lost, "group still pinned to lost instance");
        }
    }

    #[test]
    fn instances_added_rebalances_waiting_groups() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 2);
        let mut buffer = RequestBuffer::from_groups(&w.groups);
        // A finished member must not anchor its group: its waiting
        // siblings stay movable (post-outage re-home regression).
        let first = buffer.all()[0].id();
        buffer.mark_scheduled(first);
        buffer.mark_finished(first);
        let mut s = VerlScheduler::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        let added = vec![InstanceId(cfg.n_instances as u32)];
        let live: Vec<InstanceId> = (0..=cfg.n_instances as u32)
            .map(InstanceId)
            .collect();
        s.on_instances_added(&added, &live, &buffer);
        // The newcomer received at least one whole group.
        let moved: Vec<&GroupSpec> = w
            .groups
            .iter()
            .filter(|g| pin_of(&s, g.requests[0].id) == added[0])
            .collect();
        assert!(!moved.is_empty(), "scale-up instance got no work");
        for g in moved {
            for r in &g.requests {
                assert_eq!(
                    pin_of(&s, r.id),
                    added[0],
                    "group split by re-home"
                );
            }
        }
    }
}
