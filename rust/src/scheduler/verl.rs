//! veRL-style baseline: group-level round-robin placement, instance-local
//! FCFS admission, no divided rollout, no global pool (preempted requests
//! re-prefill). This is the paper's primary baseline (§4.1): a
//! well-engineered synchronous system whose scheduling treats each prompt
//! group as a monolithic unit pinned to one instance.

use std::collections::BTreeMap;

use crate::config::{SystemConfig, WorkloadConfig};
use crate::coordinator::RequestBuffer;
use crate::workload::{GroupId, GroupSpec, InstanceId, RequestId};

use super::{Assignment, SchedCtx, Scheduler};

pub struct VerlScheduler {
    /// Pinned instance per request (group-level round-robin).
    pin: BTreeMap<RequestId, InstanceId>,
    /// Admission watermark: tokens of decode headroom reserved beyond the
    /// current KV when admitting (vLLM-style optimistic admission — the
    /// source of later preemptions).
    watermark: u32,
    max_len: u32,
}

impl VerlScheduler {
    pub fn new() -> Self {
        VerlScheduler {
            pin: BTreeMap::new(),
            watermark: 256,
            max_len: u32::MAX,
        }
    }
}

impl Default for VerlScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for VerlScheduler {
    fn name(&self) -> &'static str {
        "verl"
    }

    fn init(
        &mut self,
        groups: &[GroupSpec],
        cfg: &WorkloadConfig,
        _sys: &SystemConfig,
    ) {
        self.pin.clear();
        self.max_len = cfg.max_gen_len;
        for (gi, g) in groups.iter().enumerate() {
            let inst = InstanceId((gi % cfg.n_instances) as u32);
            for r in &g.requests {
                self.pin.insert(r.id, inst);
            }
        }
    }

    fn schedule(&mut self, ctx: &SchedCtx) -> Vec<Assignment> {
        let mut out = Vec::new();
        let mut reserved = vec![0u64; ctx.instances.len()];
        let mut slots: Vec<usize> =
            ctx.instances.iter().map(|i| i.running).collect();
        let index_of: BTreeMap<u32, usize> = ctx
            .instances
            .iter()
            .enumerate()
            .map(|(i, v)| (v.id.0, i))
            .collect();

        // FCFS by request id within each instance's pinned queue.
        for id in ctx.buffer.waiting() {
            let inst = *self.pin.get(&id).expect("unpinned request");
            // The pinned instance may be down (fault layer): wait for it
            // to recover or for a loss/scale hook to re-pin the group.
            let Some(&i) = index_of.get(&inst.0) else {
                continue;
            };
            let r = ctx.buffer.get(id);
            // Optimistic admission: current KV + watermark only.
            let demand = r.kv_demand(self.watermark);
            let free =
                ctx.instances[i].free_kv_tokens.saturating_sub(reserved[i]);
            if free >= demand && slots[i] < ctx.instances[i].max_batch {
                reserved[i] += demand;
                slots[i] += 1;
                out.push(Assignment {
                    req: id,
                    instance: inst,
                    // Whole-request lease: no divided rollout.
                    chunk: self.max_len,
                });
            }
        }
        out
    }

    /// Elasticity: a lost instance's groups re-pin, whole, onto the
    /// survivors round-robin (mirrors the init-time placement). Without
    /// this, requests pinned to a dead instance would starve forever —
    /// the veRL baseline gets the same crash-survival machinery as Seer,
    /// it just pays re-prefill for the KV it lost.
    fn on_instance_lost(
        &mut self,
        lost: InstanceId,
        _drained: &[RequestId],
        live: &[InstanceId],
        buffer: &RequestBuffer,
    ) {
        if live.is_empty() {
            return;
        }
        let mut target: BTreeMap<GroupId, InstanceId> = BTreeMap::new();
        let mut rr = 0usize;
        for r in buffer.all() {
            if self.pin.get(&r.id()) != Some(&lost) {
                continue;
            }
            let tgt = *target.entry(r.group()).or_insert_with(|| {
                let t = live[rr % live.len()];
                rr += 1;
                t
            });
            self.pin.insert(r.id(), tgt);
        }
    }

    /// Elasticity: re-home a proportional share of fully-waiting groups
    /// onto scale-up newcomers so they don't idle (every
    /// ⌈live/added⌉-th movable group, deterministically).
    fn on_instances_added(
        &mut self,
        added: &[InstanceId],
        live: &[InstanceId],
        buffer: &RequestBuffer,
    ) {
        if added.is_empty() || live.is_empty() {
            return;
        }
        let mut movable: BTreeMap<GroupId, bool> = BTreeMap::new();
        for r in buffer.all() {
            // Finished members don't pin a group: only *running* work
            // anchors it (its waiting siblings must stay movable, or a
            // post-outage re-home could strand them on a dead instance).
            if r.is_finished() {
                continue;
            }
            let e = movable.entry(r.group()).or_insert(true);
            if r.is_running() {
                *e = false;
            }
        }
        let groups: Vec<GroupId> = movable
            .iter()
            .filter(|(_, m)| **m)
            .map(|(g, _)| *g)
            .collect();
        if groups.is_empty() {
            return;
        }
        let stride = live.len().div_ceil(added.len()).max(1);
        let mut retarget: BTreeMap<GroupId, InstanceId> = BTreeMap::new();
        let mut ai = 0usize;
        for (i, g) in groups.iter().enumerate() {
            if i % stride != 0 {
                continue;
            }
            retarget.insert(*g, added[ai % added.len()]);
            ai += 1;
        }
        for r in buffer.all() {
            if let Some(t) = retarget.get(&r.group()) {
                self.pin.insert(r.id(), *t);
            }
        }
    }

    fn uses_global_pool(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;
    use crate::scheduler::InstanceView;
    use crate::sim::clock::SimTime;
    use crate::workload::generate_iteration;

    #[test]
    fn groups_are_pinned_whole() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 2);
        let mut s = VerlScheduler::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        for g in &w.groups {
            let insts: Vec<_> =
                g.requests.iter().map(|r| s.pin[&r.id]).collect();
            assert!(
                insts.windows(2).all(|w| w[0] == w[1]),
                "group split across instances"
            );
        }
        // Round-robin: consecutive groups on consecutive instances.
        assert_ne!(
            s.pin[&w.groups[0].requests[0].id],
            s.pin[&w.groups[1].requests[0].id]
        );
    }

    #[test]
    fn assignments_respect_pinning() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 3);
        let buffer = RequestBuffer::from_groups(&w.groups);
        let mut s = VerlScheduler::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        let instances: Vec<InstanceView> = (0..cfg.n_instances as u32)
            .map(|i| InstanceView {
                id: crate::workload::InstanceId(i),
                free_kv_tokens: cfg.hw.kv_capacity_tokens,
                capacity_tokens: cfg.hw.kv_capacity_tokens,
                running: 0,
                max_batch: cfg.hw.max_batch,
            })
            .collect();
        let ctx = SchedCtx {
            now: SimTime::ZERO,
            instances: &instances,
            buffer: &buffer,
        };
        for a in s.schedule(&ctx) {
            assert_eq!(a.instance, s.pin[&a.req]);
            assert_eq!(a.chunk, cfg.max_gen_len);
        }
    }

    #[test]
    fn instance_lost_repins_group_atomically() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 2);
        let buffer = RequestBuffer::from_groups(&w.groups);
        let mut s = VerlScheduler::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        let lost = InstanceId(0);
        let live: Vec<InstanceId> =
            (1..cfg.n_instances as u32).map(InstanceId).collect();
        s.on_instance_lost(lost, &[], &live, &buffer);
        for g in &w.groups {
            let insts: Vec<_> =
                g.requests.iter().map(|r| s.pin[&r.id]).collect();
            assert!(
                insts.windows(2).all(|w| w[0] == w[1]),
                "group split by re-pin"
            );
            assert_ne!(insts[0], lost, "group still pinned to lost instance");
        }
    }

    #[test]
    fn instances_added_rebalances_waiting_groups() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 2);
        let mut buffer = RequestBuffer::from_groups(&w.groups);
        // A finished member must not anchor its group: its waiting
        // siblings stay movable (post-outage re-home regression).
        let first = buffer.all()[0].id();
        buffer.mark_scheduled(first);
        buffer.mark_finished(first);
        let mut s = VerlScheduler::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        let added = vec![InstanceId(cfg.n_instances as u32)];
        let live: Vec<InstanceId> = (0..=cfg.n_instances as u32)
            .map(InstanceId)
            .collect();
        s.on_instances_added(&added, &live, &buffer);
        // The newcomer received at least one whole group.
        let moved: Vec<&GroupSpec> = w
            .groups
            .iter()
            .filter(|g| s.pin[&g.requests[0].id] == added[0])
            .collect();
        assert!(!moved.is_empty(), "scale-up instance got no work");
        for g in moved {
            for r in &g.requests {
                assert_eq!(s.pin[&r.id], added[0], "group split by re-home");
            }
        }
    }
}
