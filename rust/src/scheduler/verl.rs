//! veRL-style baseline: group-level round-robin placement, instance-local
//! FCFS admission, no divided rollout, no global pool (preempted requests
//! re-prefill). This is the paper's primary baseline (§4.1): a
//! well-engineered synchronous system whose scheduling treats each prompt
//! group as a monolithic unit pinned to one instance.

use std::collections::BTreeMap;

use crate::config::{SystemConfig, WorkloadConfig};
use crate::workload::{GroupSpec, InstanceId, RequestId};

use super::{Assignment, SchedCtx, Scheduler};

pub struct VerlScheduler {
    /// Pinned instance per request (group-level round-robin).
    pin: BTreeMap<RequestId, InstanceId>,
    /// Admission watermark: tokens of decode headroom reserved beyond the
    /// current KV when admitting (vLLM-style optimistic admission — the
    /// source of later preemptions).
    watermark: u32,
    max_len: u32,
}

impl VerlScheduler {
    pub fn new() -> Self {
        VerlScheduler {
            pin: BTreeMap::new(),
            watermark: 256,
            max_len: u32::MAX,
        }
    }
}

impl Default for VerlScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for VerlScheduler {
    fn name(&self) -> &'static str {
        "verl"
    }

    fn init(
        &mut self,
        groups: &[GroupSpec],
        cfg: &WorkloadConfig,
        _sys: &SystemConfig,
    ) {
        self.pin.clear();
        self.max_len = cfg.max_gen_len;
        for (gi, g) in groups.iter().enumerate() {
            let inst = InstanceId((gi % cfg.n_instances) as u32);
            for r in &g.requests {
                self.pin.insert(r.id, inst);
            }
        }
    }

    fn schedule(&mut self, ctx: &SchedCtx) -> Vec<Assignment> {
        let mut out = Vec::new();
        let mut reserved = vec![0u64; ctx.instances.len()];
        let mut slots: Vec<usize> =
            ctx.instances.iter().map(|i| i.running).collect();
        let index_of: BTreeMap<u32, usize> = ctx
            .instances
            .iter()
            .enumerate()
            .map(|(i, v)| (v.id.0, i))
            .collect();

        // FCFS by request id within each instance's pinned queue.
        for id in ctx.buffer.waiting() {
            let inst = *self.pin.get(&id).expect("unpinned request");
            let i = index_of[&inst.0];
            let r = ctx.buffer.get(id);
            // Optimistic admission: current KV + watermark only.
            let demand = r.kv_demand(self.watermark);
            let free =
                ctx.instances[i].free_kv_tokens.saturating_sub(reserved[i]);
            if free >= demand && slots[i] < ctx.instances[i].max_batch {
                reserved[i] += demand;
                slots[i] += 1;
                out.push(Assignment {
                    req: id,
                    instance: inst,
                    // Whole-request lease: no divided rollout.
                    chunk: self.max_len,
                });
            }
        }
        out
    }

    fn uses_global_pool(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;
    use crate::coordinator::RequestBuffer;
    use crate::scheduler::InstanceView;
    use crate::sim::clock::SimTime;
    use crate::workload::generate_iteration;

    #[test]
    fn groups_are_pinned_whole() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 2);
        let mut s = VerlScheduler::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        for g in &w.groups {
            let insts: Vec<_> =
                g.requests.iter().map(|r| s.pin[&r.id]).collect();
            assert!(
                insts.windows(2).all(|w| w[0] == w[1]),
                "group split across instances"
            );
        }
        // Round-robin: consecutive groups on consecutive instances.
        assert_ne!(
            s.pin[&w.groups[0].requests[0].id],
            s.pin[&w.groups[1].requests[0].id]
        );
    }

    #[test]
    fn assignments_respect_pinning() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 3);
        let buffer = RequestBuffer::from_groups(&w.groups);
        let mut s = VerlScheduler::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        let instances: Vec<InstanceView> = (0..cfg.n_instances as u32)
            .map(|i| InstanceView {
                id: crate::workload::InstanceId(i),
                free_kv_tokens: cfg.hw.kv_capacity_tokens,
                capacity_tokens: cfg.hw.kv_capacity_tokens,
                running: 0,
                max_batch: cfg.hw.max_batch,
            })
            .collect();
        let ctx = SchedCtx {
            now: SimTime::ZERO,
            instances: &instances,
            buffer: &buffer,
        };
        for a in s.schedule(&ctx) {
            assert_eq!(a.instance, s.pin[&a.req]);
            assert_eq!(a.chunk, cfg.max_gen_len);
        }
    }
}
