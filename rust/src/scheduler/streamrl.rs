//! StreamRL-Oracle baseline (paper §4.1): skewness-aware scheduling with
//! *ground-truth* lengths (the strongest version of StreamRL's
//! prediction-based bucketing).
//!
//! Groups are bucketed by true maximum length; buckets are placed onto
//! instances LPT-style (longest processing time first) to balance total
//! work, and each instance runs its queue longest-first with a
//! concurrency cap derived from the bucket's length scale — small
//! concurrency for long-request buckets to avoid preemption, large for
//! short ones. Still: groups are atomic, there is no chunk migration, and
//! the cap is a static prediction — exactly the limitations §4.2.1
//! observes (it can even lose to veRL on out-of-distribution workloads
//! like Kimi-K2, where capping concurrency wastes an instance that is not
//! actually memory-constrained).

use std::collections::BTreeMap;

use crate::config::{SystemConfig, WorkloadConfig};
use crate::workload::{GroupSpec, InstanceId, RequestId};

use super::{Assignment, SchedCtx, Scheduler};

pub struct StreamRlOracle {
    pin: BTreeMap<RequestId, InstanceId>,
    /// True total length per request (oracle information).
    true_len: BTreeMap<RequestId, u32>,
    /// Per-instance concurrency cap from the bucketing model.
    conc_cap: Vec<usize>,
    max_len: u32,
    /// Safety factor on reserved KV per admitted request.
    safety: f64,
}

impl StreamRlOracle {
    pub fn new() -> Self {
        StreamRlOracle {
            pin: BTreeMap::new(),
            true_len: BTreeMap::new(),
            conc_cap: vec![],
            max_len: u32::MAX,
            safety: 1.15,
        }
    }
}

impl Default for StreamRlOracle {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for StreamRlOracle {
    fn name(&self) -> &'static str {
        "streamrl-oracle"
    }

    fn init(
        &mut self,
        groups: &[GroupSpec],
        cfg: &WorkloadConfig,
        _sys: &SystemConfig,
    ) {
        self.pin.clear();
        self.true_len.clear();
        self.max_len = cfg.max_gen_len;

        // Sort groups by total true work, longest first (LPT), and assign
        // each to the currently least-loaded instance.
        let mut order: Vec<usize> = (0..groups.len()).collect();
        let work = |g: &GroupSpec| -> u64 {
            g.requests
                .iter()
                .map(|r| (r.prompt_len + r.gen_len) as u64)
                .sum()
        };
        order.sort_by_key(|&i| std::cmp::Reverse(work(&groups[i])));

        let mut load = vec![0u64; cfg.n_instances];
        let mut inst_len_sum = vec![0u64; cfg.n_instances];
        let mut inst_reqs = vec![0u64; cfg.n_instances];
        for &gi in &order {
            let g = &groups[gi];
            let target = (0..cfg.n_instances)
                .min_by_key(|&i| load[i])
                .unwrap();
            load[target] += work(g);
            for r in &g.requests {
                self.pin.insert(r.id, InstanceId(target as u32));
                self.true_len.insert(r.id, r.gen_len);
                inst_len_sum[target] += (r.prompt_len + r.gen_len) as u64;
                inst_reqs[target] += 1;
            }
        }

        // Bucket concurrency model: cap = capacity / (mean final KV per
        // request × safety). Long buckets get small caps.
        self.conc_cap = (0..cfg.n_instances)
            .map(|i| {
                if inst_reqs[i] == 0 {
                    return 1;
                }
                let mean_len = (inst_len_sum[i] / inst_reqs[i]).max(1);
                ((cfg.hw.kv_capacity_tokens as f64
                    / (mean_len as f64 * self.safety))
                    .floor() as usize)
                    .clamp(1, cfg.hw.max_batch)
            })
            .collect();
    }

    fn schedule(&mut self, ctx: &SchedCtx) -> Vec<Assignment> {
        let mut out = Vec::new();
        let mut reserved = vec![0u64; ctx.instances.len()];
        let mut slots: Vec<usize> =
            ctx.instances.iter().map(|i| i.running).collect();
        let index_of: BTreeMap<u32, usize> = ctx
            .instances
            .iter()
            .enumerate()
            .map(|(i, v)| (v.id.0, i))
            .collect();

        // Longest-first within each instance's pinned queue.
        let mut waiting: Vec<RequestId> = ctx.buffer.waiting().collect();
        waiting.sort_by_key(|id| {
            std::cmp::Reverse(self.true_len.get(id).copied().unwrap_or(0))
        });

        for id in waiting {
            let inst = *self.pin.get(&id).expect("unpinned request");
            let i = index_of[&inst.0];
            if slots[i] >= self.conc_cap[i.min(self.conc_cap.len() - 1)]
                || slots[i] >= ctx.instances[i].max_batch
            {
                continue;
            }
            let r = ctx.buffer.get(id);
            // Oracle admission: reserve the *full* final KV footprint —
            // no preemption ever, at the cost of conservatism.
            let final_kv = (r.spec.prompt_len as u64
                + self.true_len.get(&id).copied().unwrap_or(0) as u64)
                as f64
                * self.safety;
            let demand = (final_kv as u64)
                .saturating_sub(r.kv_tokens)
                .max(1);
            let free =
                ctx.instances[i].free_kv_tokens.saturating_sub(reserved[i]);
            if free >= demand {
                reserved[i] += demand;
                slots[i] += 1;
                out.push(Assignment {
                    req: id,
                    instance: inst,
                    chunk: self.max_len,
                });
            }
        }
        out
    }

    fn uses_global_pool(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;
    use crate::workload::generate_iteration;

    #[test]
    fn lpt_balances_total_work() {
        let cfg = TaskPreset::Qwen2Vl72b.workload_for_test();
        let w = generate_iteration(&cfg, 4);
        let mut s = StreamRlOracle::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        // Per-instance total true work should be within 2x of each other
        // (LPT guarantee is 4/3 OPT for makespan; totals are near-even).
        let mut load = vec![0u64; cfg.n_instances];
        for g in &w.groups {
            let inst = s.pin[&g.requests[0].id].0 as usize;
            for r in &g.requests {
                load[inst] += (r.prompt_len + r.gen_len) as u64;
            }
        }
        let max = *load.iter().max().unwrap() as f64;
        let min = *load.iter().min().unwrap() as f64;
        assert!(max / min.max(1.0) < 2.0, "load {load:?}");
    }

    #[test]
    fn long_buckets_get_small_caps() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 4);
        let mut s = StreamRlOracle::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        // Compute mean pinned length per instance; caps must be
        // anti-monotone in length (longer => cap no larger).
        let mut sums = vec![(0u64, 0u64); cfg.n_instances];
        for g in &w.groups {
            let inst = s.pin[&g.requests[0].id].0 as usize;
            for r in &g.requests {
                sums[inst].0 += r.gen_len as u64;
                sums[inst].1 += 1;
            }
        }
        let mut pairs: Vec<(u64, usize)> = sums
            .iter()
            .zip(&s.conc_cap)
            .filter(|((_, n), _)| *n > 0)
            .map(|((sum, n), cap)| (sum / n, *cap))
            .collect();
        pairs.sort();
        for w2 in pairs.windows(2) {
            assert!(
                w2[0].1 >= w2[1].1,
                "caps not anti-monotone in length: {pairs:?}"
            );
        }
    }
}
