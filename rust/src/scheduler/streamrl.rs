//! StreamRL-Oracle baseline (paper §4.1): skewness-aware scheduling with
//! *ground-truth* lengths (the strongest version of StreamRL's
//! prediction-based bucketing).
//!
//! Groups are bucketed by true maximum length; buckets are placed onto
//! instances LPT-style (longest processing time first) to balance total
//! work, and each instance runs its queue longest-first with a
//! concurrency cap derived from the bucket's length scale — small
//! concurrency for long-request buckets to avoid preemption, large for
//! short ones. Still: groups are atomic, there is no chunk migration, and
//! the cap is a static prediction — exactly the limitations §4.2.1
//! observes (it can even lose to veRL on out-of-distribution workloads
//! like Kimi-K2, where capping concurrency wastes an instance that is not
//! actually memory-constrained).

use std::collections::BTreeMap;

use crate::config::{SystemConfig, WorkloadConfig};
use crate::coordinator::RequestBuffer;
use crate::workload::{GroupId, GroupSpec, InstanceId, RequestId};

use super::{Assignment, SchedCtx, Scheduler};

pub struct StreamRlOracle {
    pin: BTreeMap<RequestId, InstanceId>,
    /// True total length per request (oracle information).
    true_len: BTreeMap<RequestId, u32>,
    /// Per-instance concurrency cap from the bucketing model, keyed by
    /// instance id (the fleet can grow or shrink under elasticity, so a
    /// positional Vec would silently misattribute caps).
    conc_cap: BTreeMap<u32, usize>,
    max_len: u32,
    /// Safety factor on reserved KV per admitted request.
    safety: f64,
    /// Hardware constants captured at init so elastic rebalancing can
    /// recompute caps for a changed fleet.
    kv_capacity: u64,
    max_batch: usize,
}

impl StreamRlOracle {
    pub fn new() -> Self {
        StreamRlOracle {
            pin: BTreeMap::new(),
            true_len: BTreeMap::new(),
            conc_cap: BTreeMap::new(),
            max_len: u32::MAX,
            safety: 1.15,
            kv_capacity: u64::MAX,
            max_batch: usize::MAX,
        }
    }

    /// Bucket concurrency model: cap = capacity / (mean final KV per
    /// request × safety). Long buckets get small caps.
    fn cap_for(
        len_sum: u64,
        reqs: u64,
        kv_capacity: u64,
        safety: f64,
        max_batch: usize,
    ) -> usize {
        if reqs == 0 {
            return 1;
        }
        let mean_len = (len_sum / reqs).max(1);
        ((kv_capacity as f64 / (mean_len as f64 * safety)).floor() as usize)
            .clamp(1, max_batch)
    }

    /// Elastic re-placement: move the movable groups LPT-style onto the
    /// `live` fleet (least-loaded first), then refresh every live
    /// instance's concurrency cap from the resulting placement.
    ///
    /// `from == Some(lost)` moves exactly the groups pinned to the lost
    /// instance (their members were drained, so nothing is running);
    /// `from == None` (scale-up) moves every group with no running
    /// member, re-running the init-time LPT over the grown fleet.
    fn rebalance(
        &mut self,
        from: Option<InstanceId>,
        live: &[InstanceId],
        buffer: &RequestBuffer,
    ) {
        if live.is_empty() {
            return;
        }
        let mut group_pin: BTreeMap<GroupId, InstanceId> = BTreeMap::new();
        let mut group_work: BTreeMap<GroupId, u64> = BTreeMap::new();
        let mut group_movable: BTreeMap<GroupId, bool> = BTreeMap::new();
        for r in buffer.all() {
            if r.is_finished() {
                continue;
            }
            let g = r.group();
            if let Some(p) = self.pin.get(&r.id()) {
                group_pin.insert(g, *p);
            }
            *group_work.entry(g).or_insert(0) +=
                (r.spec.prompt_len + r.spec.gen_len) as u64;
            let movable = match from {
                Some(lost) => self.pin.get(&r.id()) == Some(&lost),
                None => !r.is_running(),
            };
            let e = group_movable.entry(g).or_insert(true);
            *e = *e && movable;
        }
        // Base load from the groups that stay put.
        let mut load: BTreeMap<u32, u64> =
            live.iter().map(|i| (i.0, 0u64)).collect();
        for (g, w) in &group_work {
            if group_movable.get(g).copied().unwrap_or(false) {
                continue;
            }
            if let Some(p) = group_pin.get(g) {
                if let Some(l) = load.get_mut(&p.0) {
                    *l += *w;
                }
            }
        }
        // LPT: heaviest movable group onto the least-loaded live
        // instance (lowest id breaks ties — determinism).
        let mut movable: Vec<(u64, GroupId)> = group_movable
            .iter()
            .filter(|(_, m)| **m)
            .map(|(g, _)| (group_work.get(g).copied().unwrap_or(0), *g))
            .collect();
        movable.sort_by_key(|(w, g)| (std::cmp::Reverse(*w), g.0));
        let mut new_pin: BTreeMap<GroupId, InstanceId> = BTreeMap::new();
        for (w, g) in movable {
            let target = *load
                .iter()
                .min_by_key(|&(id, l)| (*l, *id))
                .map(|(id, _)| id)
                .unwrap();
            *load.get_mut(&target).unwrap() += w;
            new_pin.insert(g, InstanceId(target));
        }
        for r in buffer.all() {
            if let Some(t) = new_pin.get(&r.group()) {
                self.pin.insert(r.id(), *t);
            }
        }
        // Refresh caps for the live fleet from the new placement.
        let mut sums: BTreeMap<u32, (u64, u64)> =
            live.iter().map(|i| (i.0, (0u64, 0u64))).collect();
        for r in buffer.all() {
            if r.is_finished() {
                continue;
            }
            if let Some(p) = self.pin.get(&r.id()) {
                if let Some(s) = sums.get_mut(&p.0) {
                    s.0 += (r.spec.prompt_len + r.spec.gen_len) as u64;
                    s.1 += 1;
                }
            }
        }
        for (id, (len_sum, reqs)) in sums {
            self.conc_cap.insert(
                id,
                Self::cap_for(
                    len_sum,
                    reqs,
                    self.kv_capacity,
                    self.safety,
                    self.max_batch,
                ),
            );
        }
    }
}

impl Default for StreamRlOracle {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for StreamRlOracle {
    fn name(&self) -> &'static str {
        "streamrl-oracle"
    }

    fn init(
        &mut self,
        groups: &[GroupSpec],
        cfg: &WorkloadConfig,
        _sys: &SystemConfig,
    ) {
        self.pin.clear();
        self.true_len.clear();
        self.max_len = cfg.max_gen_len;

        // Sort groups by total true work, longest first (LPT), and assign
        // each to the currently least-loaded instance.
        let mut order: Vec<usize> = (0..groups.len()).collect();
        let work = |g: &GroupSpec| -> u64 {
            g.requests
                .iter()
                .map(|r| (r.prompt_len + r.gen_len) as u64)
                .sum()
        };
        order.sort_by_key(|&i| std::cmp::Reverse(work(&groups[i])));

        let mut load = vec![0u64; cfg.n_instances];
        let mut inst_len_sum = vec![0u64; cfg.n_instances];
        let mut inst_reqs = vec![0u64; cfg.n_instances];
        for &gi in &order {
            let g = &groups[gi];
            let target = (0..cfg.n_instances)
                .min_by_key(|&i| load[i])
                .unwrap();
            load[target] += work(g);
            for r in &g.requests {
                self.pin.insert(r.id, InstanceId(target as u32));
                self.true_len.insert(r.id, r.gen_len);
                inst_len_sum[target] += (r.prompt_len + r.gen_len) as u64;
                inst_reqs[target] += 1;
            }
        }

        self.kv_capacity = cfg.hw.kv_capacity_tokens;
        self.max_batch = cfg.hw.max_batch;
        self.conc_cap = (0..cfg.n_instances)
            .map(|i| {
                (
                    i as u32,
                    Self::cap_for(
                        inst_len_sum[i],
                        inst_reqs[i],
                        cfg.hw.kv_capacity_tokens,
                        self.safety,
                        cfg.hw.max_batch,
                    ),
                )
            })
            .collect();
    }

    fn schedule(&mut self, ctx: &SchedCtx) -> Vec<Assignment> {
        let mut out = Vec::new();
        let mut reserved = vec![0u64; ctx.instances.len()];
        let mut slots: Vec<usize> =
            ctx.instances.iter().map(|i| i.running).collect();
        let index_of: BTreeMap<u32, usize> = ctx
            .instances
            .iter()
            .enumerate()
            .map(|(i, v)| (v.id.0, i))
            .collect();

        // Longest-first within each instance's pinned queue.
        let mut waiting: Vec<RequestId> = ctx.buffer.waiting().collect();
        waiting.sort_by_key(|id| {
            std::cmp::Reverse(self.true_len.get(id).copied().unwrap_or(0))
        });

        for id in waiting {
            let inst = *self.pin.get(&id).expect("unpinned request");
            // The pinned instance may be down (fault layer): wait for it
            // to recover or for a loss/scale hook to re-place the group.
            let Some(&i) = index_of.get(&inst.0) else {
                continue;
            };
            let cap = self
                .conc_cap
                .get(&inst.0)
                .copied()
                .unwrap_or(ctx.instances[i].max_batch);
            if slots[i] >= cap || slots[i] >= ctx.instances[i].max_batch {
                continue;
            }
            let r = ctx.buffer.get(id);
            // Oracle admission: reserve the *full* final KV footprint —
            // no preemption ever, at the cost of conservatism.
            let final_kv = (r.spec.prompt_len as u64
                + self.true_len.get(&id).copied().unwrap_or(0) as u64)
                as f64
                * self.safety;
            let demand = (final_kv as u64)
                .saturating_sub(r.kv_tokens)
                .max(1);
            let free =
                ctx.instances[i].free_kv_tokens.saturating_sub(reserved[i]);
            if free >= demand {
                reserved[i] += demand;
                slots[i] += 1;
                out.push(Assignment {
                    req: id,
                    instance: inst,
                    chunk: self.max_len,
                });
            }
        }
        out
    }

    /// Elasticity: re-place the lost instance's groups LPT over the
    /// survivors (the strongest version of StreamRL's static placement,
    /// re-run on the shrunk fleet).
    fn on_instance_lost(
        &mut self,
        lost: InstanceId,
        _drained: &[RequestId],
        live: &[InstanceId],
        buffer: &RequestBuffer,
    ) {
        self.conc_cap.remove(&lost.0);
        self.rebalance(Some(lost), live, buffer);
    }

    /// Elasticity: re-run LPT over the grown fleet for every group with
    /// no running member, so scale-up instances pick up queued work.
    fn on_instances_added(
        &mut self,
        added: &[InstanceId],
        live: &[InstanceId],
        buffer: &RequestBuffer,
    ) {
        if added.is_empty() {
            return;
        }
        self.rebalance(None, live, buffer);
    }

    fn uses_global_pool(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;
    use crate::workload::generate_iteration;

    #[test]
    fn lpt_balances_total_work() {
        let cfg = TaskPreset::Qwen2Vl72b.workload_for_test();
        let w = generate_iteration(&cfg, 4);
        let mut s = StreamRlOracle::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        // Per-instance total true work should be within 2x of each other
        // (LPT guarantee is 4/3 OPT for makespan; totals are near-even).
        let mut load = vec![0u64; cfg.n_instances];
        for g in &w.groups {
            let inst = s.pin[&g.requests[0].id].0 as usize;
            for r in &g.requests {
                load[inst] += (r.prompt_len + r.gen_len) as u64;
            }
        }
        let max = *load.iter().max().unwrap() as f64;
        let min = *load.iter().min().unwrap() as f64;
        assert!(max / min.max(1.0) < 2.0, "load {load:?}");
    }

    #[test]
    fn long_buckets_get_small_caps() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 4);
        let mut s = StreamRlOracle::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        // Compute mean pinned length per instance; caps must be
        // anti-monotone in length (longer => cap no larger).
        let mut sums = vec![(0u64, 0u64); cfg.n_instances];
        for g in &w.groups {
            let inst = s.pin[&g.requests[0].id].0 as usize;
            for r in &g.requests {
                sums[inst].0 += r.gen_len as u64;
                sums[inst].1 += 1;
            }
        }
        let mut pairs: Vec<(u64, usize)> = sums
            .iter()
            .enumerate()
            .filter(|(_, (_, n))| *n > 0)
            .map(|(i, (sum, n))| (sum / n, s.conc_cap[&(i as u32)]))
            .collect();
        pairs.sort();
        for w2 in pairs.windows(2) {
            assert!(
                w2[0].1 >= w2[1].1,
                "caps not anti-monotone in length: {pairs:?}"
            );
        }
    }

    #[test]
    fn instance_lost_replaces_groups_on_survivors() {
        use crate::coordinator::RequestBuffer;
        let cfg = TaskPreset::Qwen2Vl72b.workload_for_test();
        let w = generate_iteration(&cfg, 4);
        let buffer = RequestBuffer::from_groups(&w.groups);
        let mut s = StreamRlOracle::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        let lost = InstanceId(0);
        let live: Vec<InstanceId> =
            (1..cfg.n_instances as u32).map(InstanceId).collect();
        s.on_instance_lost(lost, &[], &live, &buffer);
        assert!(!s.conc_cap.contains_key(&lost.0));
        let mut survivor_load = vec![0u64; cfg.n_instances];
        for g in &w.groups {
            let inst = s.pin[&g.requests[0].id];
            assert_ne!(inst, lost, "group still pinned to lost instance");
            for r in &g.requests {
                assert_eq!(s.pin[&r.id], inst, "group split by re-place");
                survivor_load[inst.0 as usize] +=
                    (r.prompt_len + r.gen_len) as u64;
            }
        }
        // LPT re-placement keeps the survivors near-balanced.
        let loads: Vec<u64> = survivor_load[1..].to_vec();
        let max = *loads.iter().max().unwrap() as f64;
        let min = *loads.iter().min().unwrap() as f64;
        assert!(max / min.max(1.0) < 2.5, "unbalanced after loss: {loads:?}");
    }

    #[test]
    fn instances_added_gives_newcomers_work_and_caps() {
        use crate::coordinator::RequestBuffer;
        let cfg = TaskPreset::Qwen2Vl72b.workload_for_test();
        let w = generate_iteration(&cfg, 4);
        let buffer = RequestBuffer::from_groups(&w.groups);
        let mut s = StreamRlOracle::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        let added = vec![InstanceId(cfg.n_instances as u32)];
        let live: Vec<InstanceId> = (0..=cfg.n_instances as u32)
            .map(InstanceId)
            .collect();
        s.on_instances_added(&added, &live, &buffer);
        assert!(
            w.groups
                .iter()
                .any(|g| s.pin[&g.requests[0].id] == added[0]),
            "newcomer got no groups"
        );
        let cap = s.conc_cap[&added[0].0];
        assert!(cap >= 1 && cap <= cfg.hw.max_batch);
    }
}
