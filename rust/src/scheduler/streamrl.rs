//! StreamRL-Oracle baseline (paper §4.1): skewness-aware scheduling with
//! *ground-truth* lengths (the strongest version of StreamRL's
//! prediction-based bucketing).
//!
//! Groups are bucketed by true maximum length; buckets are placed onto
//! instances LPT-style (longest processing time first) to balance total
//! work, and each instance runs its queue longest-first with a
//! concurrency cap derived from the bucket's length scale — small
//! concurrency for long-request buckets to avoid preemption, large for
//! short ones. Still: groups are atomic, there is no chunk migration, and
//! the cap is a static prediction — exactly the limitations §4.2.1
//! observes (it can even lose to veRL on out-of-distribution workloads
//! like Kimi-K2, where capping concurrency wastes an instance that is not
//! actually memory-constrained).
//!
//! Hot-path overhaul: pin and true-length tables are dense `Vec`s over
//! the contiguous id space, and the global longest-first order lives in
//! one incrementally maintained [`LazyHeap`] — true lengths never change
//! within an iteration, so entries only need repair on waiting-set
//! re-entry (preemption, fault drains, bounced admissions). A pass pops
//! candidates in exact `(len desc, id asc)` order and stops as soon as
//! every live instance has reached its concurrency cap, instead of
//! re-collecting and re-sorting the whole waiting set.

use std::collections::BTreeMap;

use crate::config::{SystemConfig, WorkloadConfig};
use crate::coordinator::{Phase, ReqState, RequestBuffer};
use crate::workload::{GroupId, GroupSpec, InstanceId, RequestId};

use super::lazyheap::{Entry, LazyHeap, Stamps};
use super::{Assignment, SchedCtx, Scheduler};

pub struct StreamRlOracle {
    /// Pinned instance per request, indexed by request id.
    pin: Vec<InstanceId>,
    /// True total length per request (oracle information), indexed by
    /// request id.
    true_len: Vec<u32>,
    /// Global longest-first candidate heap over the waiting set (key =
    /// true length; entry tie-break pops ascending id among equals).
    lfs: LazyHeap<u32>,
    stamps: Stamps,
    /// Pass scratch: examined entries, returned afterwards; per-view
    /// admission state.
    consumed: Vec<Entry<u32>>,
    scratch_reserved: Vec<u64>,
    scratch_slots: Vec<usize>,
    scratch_view_of: Vec<usize>,
    /// Per-instance concurrency cap from the bucketing model, keyed by
    /// instance id (the fleet can grow or shrink under elasticity, so a
    /// positional Vec would silently misattribute caps).
    conc_cap: BTreeMap<u32, usize>,
    max_len: u32,
    /// Safety factor on reserved KV per admitted request.
    safety: f64,
    /// Hardware constants captured at init so elastic rebalancing can
    /// recompute caps for a changed fleet.
    kv_capacity: u64,
    max_batch: usize,
}

impl StreamRlOracle {
    pub fn new() -> Self {
        StreamRlOracle {
            pin: Vec::new(),
            true_len: Vec::new(),
            lfs: LazyHeap::new(),
            stamps: Stamps::default(),
            consumed: Vec::new(),
            scratch_reserved: Vec::new(),
            scratch_slots: Vec::new(),
            scratch_view_of: Vec::new(),
            conc_cap: BTreeMap::new(),
            max_len: u32::MAX,
            safety: 1.15,
            kv_capacity: u64::MAX,
            max_batch: usize::MAX,
        }
    }

    /// Bucket concurrency model: cap = capacity / (mean final KV per
    /// request × safety). Long buckets get small caps.
    fn cap_for(
        len_sum: u64,
        reqs: u64,
        kv_capacity: u64,
        safety: f64,
        max_batch: usize,
    ) -> usize {
        if reqs == 0 {
            return 1;
        }
        let mean_len = (len_sum / reqs).max(1);
        ((kv_capacity as f64 / (mean_len as f64 * safety)).floor() as usize)
            .clamp(1, max_batch)
    }

    /// Restore the candidate entry for a request that is (back) in the
    /// waiting set. The key is its static true length, so re-pins never
    /// require repair — only waiting-set re-entry does.
    fn push_waiting(&mut self, id: RequestId) {
        let key = self.true_len[id.0 as usize];
        let stamp = self.stamps.bump(id);
        self.lfs.push(key, id, stamp);
    }

    /// Elastic re-placement: move the movable groups LPT-style onto the
    /// `live` fleet (least-loaded first), then refresh every live
    /// instance's concurrency cap from the resulting placement.
    ///
    /// `from == Some(lost)` moves exactly the groups pinned to the lost
    /// instance (their members were drained, so nothing is running);
    /// `from == None` (scale-up) moves every group with no running
    /// member, re-running the init-time LPT over the grown fleet.
    fn rebalance(
        &mut self,
        from: Option<InstanceId>,
        live: &[InstanceId],
        buffer: &RequestBuffer,
    ) {
        if live.is_empty() {
            return;
        }
        let mut group_pin: BTreeMap<GroupId, InstanceId> = BTreeMap::new();
        let mut group_work: BTreeMap<GroupId, u64> = BTreeMap::new();
        let mut group_movable: BTreeMap<GroupId, bool> = BTreeMap::new();
        for r in buffer.all() {
            if r.is_finished() {
                continue;
            }
            let g = r.group();
            group_pin.insert(g, self.pin[r.id().0 as usize]);
            *group_work.entry(g).or_insert(0) +=
                (r.spec.prompt_len + r.spec.gen_len) as u64;
            let movable = match from {
                Some(lost) => self.pin[r.id().0 as usize] == lost,
                None => !r.is_running(),
            };
            let e = group_movable.entry(g).or_insert(true);
            *e = *e && movable;
        }
        // Base load from the groups that stay put.
        let mut load: BTreeMap<u32, u64> =
            live.iter().map(|i| (i.0, 0u64)).collect();
        for (g, w) in &group_work {
            if group_movable.get(g).copied().unwrap_or(false) {
                continue;
            }
            if let Some(p) = group_pin.get(g) {
                if let Some(l) = load.get_mut(&p.0) {
                    *l += *w;
                }
            }
        }
        // LPT: heaviest movable group onto the least-loaded live
        // instance (lowest id breaks ties — determinism).
        let mut movable: Vec<(u64, GroupId)> = group_movable
            .iter()
            .filter(|(_, m)| **m)
            .map(|(g, _)| (group_work.get(g).copied().unwrap_or(0), *g))
            .collect();
        movable.sort_by_key(|(w, g)| (std::cmp::Reverse(*w), g.0));
        let mut new_pin: BTreeMap<GroupId, InstanceId> = BTreeMap::new();
        for (w, g) in movable {
            let target = *load
                .iter()
                .min_by_key(|&(id, l)| (*l, *id))
                .map(|(id, _)| id)
                .unwrap();
            *load.get_mut(&target).unwrap() += w;
            new_pin.insert(g, InstanceId(target));
        }
        for r in buffer.all() {
            if let Some(t) = new_pin.get(&r.group()) {
                self.pin[r.id().0 as usize] = *t;
            }
        }
        // Refresh caps for the live fleet from the new placement.
        let mut sums: BTreeMap<u32, (u64, u64)> =
            live.iter().map(|i| (i.0, (0u64, 0u64))).collect();
        for r in buffer.all() {
            if r.is_finished() {
                continue;
            }
            let p = self.pin[r.id().0 as usize];
            if let Some(s) = sums.get_mut(&p.0) {
                s.0 += (r.spec.prompt_len + r.spec.gen_len) as u64;
                s.1 += 1;
            }
        }
        for (id, (len_sum, reqs)) in sums {
            self.conc_cap.insert(
                id,
                Self::cap_for(
                    len_sum,
                    reqs,
                    self.kv_capacity,
                    self.safety,
                    self.max_batch,
                ),
            );
        }
    }
}

impl Default for StreamRlOracle {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for StreamRlOracle {
    fn name(&self) -> &'static str {
        "streamrl-oracle"
    }

    fn init(
        &mut self,
        groups: &[GroupSpec],
        cfg: &WorkloadConfig,
        _sys: &SystemConfig,
    ) {
        self.max_len = cfg.max_gen_len;
        let n_reqs = groups
            .iter()
            .flat_map(|g| g.requests.iter())
            .map(|r| r.id.0 as usize + 1)
            .max()
            .unwrap_or(0);
        self.pin.clear();
        self.pin.resize(n_reqs, InstanceId(0));
        self.true_len.clear();
        self.true_len.resize(n_reqs, 0);
        self.stamps.reset(n_reqs);
        self.lfs.clear();

        // Sort groups by total true work, longest first (LPT), and assign
        // each to the currently least-loaded instance.
        let mut order: Vec<usize> = (0..groups.len()).collect();
        let work = |g: &GroupSpec| -> u64 {
            g.requests
                .iter()
                .map(|r| (r.prompt_len + r.gen_len) as u64)
                .sum()
        };
        order.sort_by_key(|&i| std::cmp::Reverse(work(&groups[i])));

        let mut load = vec![0u64; cfg.n_instances];
        let mut inst_len_sum = vec![0u64; cfg.n_instances];
        let mut inst_reqs = vec![0u64; cfg.n_instances];
        for &gi in &order {
            let g = &groups[gi];
            let target = (0..cfg.n_instances)
                .min_by_key(|&i| load[i])
                .unwrap();
            load[target] += work(g);
            for r in &g.requests {
                self.pin[r.id.0 as usize] = InstanceId(target as u32);
                self.true_len[r.id.0 as usize] = r.gen_len;
                let stamp = self.stamps.bump(r.id);
                self.lfs.push(r.gen_len, r.id, stamp);
                inst_len_sum[target] += (r.prompt_len + r.gen_len) as u64;
                inst_reqs[target] += 1;
            }
        }

        self.kv_capacity = cfg.hw.kv_capacity_tokens;
        self.max_batch = cfg.hw.max_batch;
        self.conc_cap = (0..cfg.n_instances)
            .map(|i| {
                (
                    i as u32,
                    Self::cap_for(
                        inst_len_sum[i],
                        inst_reqs[i],
                        cfg.hw.kv_capacity_tokens,
                        self.safety,
                        cfg.hw.max_batch,
                    ),
                )
            })
            .collect();
    }

    fn schedule(&mut self, ctx: &SchedCtx, out: &mut Vec<Assignment>) {
        self.lfs.maybe_compact(&self.stamps, ctx.buffer.n_waiting());
        // Per-view admission state (reused scratch): reservation totals,
        // running counts, and a dense instance-id → view-index map.
        let mut reserved = std::mem::take(&mut self.scratch_reserved);
        let mut slots = std::mem::take(&mut self.scratch_slots);
        let mut view_of = std::mem::take(&mut self.scratch_view_of);
        reserved.clear();
        reserved.resize(ctx.instances.len(), 0);
        slots.clear();
        slots.extend(ctx.instances.iter().map(|v| v.running));
        let max_id = ctx
            .instances
            .iter()
            .map(|v| v.id.0 as usize + 1)
            .max()
            .unwrap_or(0);
        view_of.clear();
        view_of.resize(max_id, usize::MAX);
        let mut active = 0usize;
        for (i, v) in ctx.instances.iter().enumerate() {
            view_of[v.id.0 as usize] = i;
            let cap = self
                .conc_cap
                .get(&v.id.0)
                .copied()
                .unwrap_or(v.max_batch)
                .min(v.max_batch);
            if slots[i] < cap {
                active += 1;
            }
        }

        // Longest-first over the whole waiting set, exactly the order
        // the collect-and-sort implementation produced; stop as soon as
        // no live instance can admit anything more.
        let mut consumed = std::mem::take(&mut self.consumed);
        while active > 0 {
            let Some(e) = self.lfs.pop() else {
                break;
            };
            if !self.stamps.is_current(&e) {
                continue;
            }
            let r = ctx.buffer.get(e.req);
            if !matches!(r.phase, Phase::Waiting) {
                continue;
            }
            consumed.push(e);
            let inst = self.pin[e.req.0 as usize];
            // The pinned instance may be down (fault layer): wait for it
            // to recover or for a loss/scale hook to re-place the group.
            let i = match view_of.get(inst.0 as usize) {
                Some(&i) if i != usize::MAX => i,
                _ => continue,
            };
            let cap = self
                .conc_cap
                .get(&inst.0)
                .copied()
                .unwrap_or(ctx.instances[i].max_batch)
                .min(ctx.instances[i].max_batch);
            if slots[i] >= cap {
                continue;
            }
            // Oracle admission: reserve the *full* final KV footprint —
            // no preemption ever, at the cost of conservatism.
            let final_kv = (r.spec.prompt_len as u64
                + self.true_len[e.req.0 as usize] as u64)
                as f64
                * self.safety;
            let demand = (final_kv as u64)
                .saturating_sub(r.kv_tokens)
                .max(1);
            let free =
                ctx.instances[i].free_kv_tokens.saturating_sub(reserved[i]);
            if free >= demand {
                reserved[i] += demand;
                slots[i] += 1;
                out.push(Assignment {
                    req: e.req,
                    instance: inst,
                    chunk: self.max_len,
                });
                if slots[i] >= cap {
                    active -= 1;
                }
            }
        }
        for e in consumed.drain(..) {
            self.lfs.push_raw(e);
        }
        self.consumed = consumed;
        self.scratch_reserved = reserved;
        self.scratch_slots = slots;
        self.scratch_view_of = view_of;
    }

    /// A preempted request re-entered the waiting queue: restore its
    /// candidate entry.
    fn on_chunk_end(&mut self, req: &ReqState) {
        self.push_waiting(req.id());
    }

    /// A produced assignment bounced off the driver's admission
    /// re-check: the request is still waiting — re-stamp its entry.
    fn on_requeued(&mut self, req: &ReqState) {
        self.push_waiting(req.id());
    }

    /// Elasticity: re-place the lost instance's groups LPT over the
    /// survivors (the strongest version of StreamRL's static placement,
    /// re-run on the shrunk fleet).
    fn on_instance_lost(
        &mut self,
        lost: InstanceId,
        drained: &[RequestId],
        live: &[InstanceId],
        buffer: &RequestBuffer,
    ) {
        // Drained requests just re-entered the waiting set: restore
        // their candidate entries (keys are static, so the later re-pin
        // needs no further repair).
        for &id in drained {
            self.push_waiting(id);
        }
        self.conc_cap.remove(&lost.0);
        self.rebalance(Some(lost), live, buffer);
    }

    /// Elasticity: re-run LPT over the grown fleet for every group with
    /// no running member, so scale-up instances pick up queued work.
    fn on_instances_added(
        &mut self,
        added: &[InstanceId],
        live: &[InstanceId],
        buffer: &RequestBuffer,
    ) {
        if added.is_empty() {
            return;
        }
        self.rebalance(None, live, buffer);
    }

    fn uses_global_pool(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;
    use crate::workload::generate_iteration;

    fn pin_of(s: &StreamRlOracle, id: RequestId) -> InstanceId {
        s.pin[id.0 as usize]
    }

    #[test]
    fn lpt_balances_total_work() {
        let cfg = TaskPreset::Qwen2Vl72b.workload_for_test();
        let w = generate_iteration(&cfg, 4);
        let mut s = StreamRlOracle::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        // Per-instance total true work should be within 2x of each other
        // (LPT guarantee is 4/3 OPT for makespan; totals are near-even).
        let mut load = vec![0u64; cfg.n_instances];
        for g in &w.groups {
            let inst = pin_of(&s, g.requests[0].id).0 as usize;
            for r in &g.requests {
                load[inst] += (r.prompt_len + r.gen_len) as u64;
            }
        }
        let max = *load.iter().max().unwrap() as f64;
        let min = *load.iter().min().unwrap() as f64;
        assert!(max / min.max(1.0) < 2.0, "load {load:?}");
    }

    #[test]
    fn long_buckets_get_small_caps() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 4);
        let mut s = StreamRlOracle::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        // Compute mean pinned length per instance; caps must be
        // anti-monotone in length (longer => cap no larger).
        let mut sums = vec![(0u64, 0u64); cfg.n_instances];
        for g in &w.groups {
            let inst = pin_of(&s, g.requests[0].id).0 as usize;
            for r in &g.requests {
                sums[inst].0 += r.gen_len as u64;
                sums[inst].1 += 1;
            }
        }
        let mut pairs: Vec<(u64, usize)> = sums
            .iter()
            .enumerate()
            .filter(|(_, (_, n))| *n > 0)
            .map(|(i, (sum, n))| (sum / n, s.conc_cap[&(i as u32)]))
            .collect();
        pairs.sort();
        for w2 in pairs.windows(2) {
            assert!(
                w2[0].1 >= w2[1].1,
                "caps not anti-monotone in length: {pairs:?}"
            );
        }
    }

    #[test]
    fn schedule_emits_longest_first_order() {
        use crate::coordinator::RequestBuffer;
        use crate::scheduler::InstanceView;
        use crate::sim::clock::SimTime;
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 4);
        let buffer = RequestBuffer::from_groups(&w.groups);
        let mut s = StreamRlOracle::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        let instances: Vec<InstanceView> = (0..cfg.n_instances as u32)
            .map(|i| InstanceView {
                id: InstanceId(i),
                free_kv_tokens: cfg.hw.kv_capacity_tokens,
                capacity_tokens: cfg.hw.kv_capacity_tokens,
                running: 0,
                max_batch: cfg.hw.max_batch,
            })
            .collect();
        let ctx = SchedCtx {
            now: SimTime::ZERO,
            instances: &instances,
            buffer: &buffer,
        };
        let mut out = Vec::new();
        s.schedule(&ctx, &mut out);
        assert!(!out.is_empty());
        let keys: Vec<(std::cmp::Reverse<u32>, u32)> = out
            .iter()
            .map(|a| {
                (std::cmp::Reverse(s.true_len[a.req.0 as usize]), a.req.0)
            })
            .collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted, "not in (len desc, id asc) order");
    }

    #[test]
    fn instance_lost_replaces_groups_on_survivors() {
        use crate::coordinator::RequestBuffer;
        let cfg = TaskPreset::Qwen2Vl72b.workload_for_test();
        let w = generate_iteration(&cfg, 4);
        let buffer = RequestBuffer::from_groups(&w.groups);
        let mut s = StreamRlOracle::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        let lost = InstanceId(0);
        let live: Vec<InstanceId> =
            (1..cfg.n_instances as u32).map(InstanceId).collect();
        s.on_instance_lost(lost, &[], &live, &buffer);
        assert!(!s.conc_cap.contains_key(&lost.0));
        let mut survivor_load = vec![0u64; cfg.n_instances];
        for g in &w.groups {
            let inst = pin_of(&s, g.requests[0].id);
            assert_ne!(inst, lost, "group still pinned to lost instance");
            for r in &g.requests {
                assert_eq!(pin_of(&s, r.id), inst, "group split by re-place");
                survivor_load[inst.0 as usize] +=
                    (r.prompt_len + r.gen_len) as u64;
            }
        }
        // LPT re-placement keeps the survivors near-balanced.
        let loads: Vec<u64> = survivor_load[1..].to_vec();
        let max = *loads.iter().max().unwrap() as f64;
        let min = *loads.iter().min().unwrap() as f64;
        assert!(max / min.max(1.0) < 2.5, "unbalanced after loss: {loads:?}");
    }

    #[test]
    fn instances_added_gives_newcomers_work_and_caps() {
        use crate::coordinator::RequestBuffer;
        let cfg = TaskPreset::Qwen2Vl72b.workload_for_test();
        let w = generate_iteration(&cfg, 4);
        let buffer = RequestBuffer::from_groups(&w.groups);
        let mut s = StreamRlOracle::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        let added = vec![InstanceId(cfg.n_instances as u32)];
        let live: Vec<InstanceId> = (0..=cfg.n_instances as u32)
            .map(InstanceId)
            .collect();
        s.on_instances_added(&added, &live, &buffer);
        assert!(
            w.groups
                .iter()
                .any(|g| pin_of(&s, g.requests[0].id) == added[0]),
            "newcomer got no groups"
        );
        let cap = s.conc_cap[&added[0].0];
        assert!(cap >= 1 && cap <= cfg.hw.max_batch);
    }
}
