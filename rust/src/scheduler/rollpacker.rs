//! RollPacker-style tail-packing scheduler (cf. PAPERS.md: prompt-level
//! reordering + stop-and-resume packing of stragglers).
//!
//! Three mechanisms, all driven by the same [`ContextManager`] length
//! estimates Seer learns online (and warm-starts from the
//! cross-iteration store):
//!
//! 1. **Admission reordering** — waiting requests on *general* instances
//!    run shortest-estimate-first, so the bulk of short requests clears
//!    early and the iteration's tail is made of genuinely long requests,
//!    not unlucky queueing.
//! 2. **Tail lanes** — a configurable fraction of the live fleet
//!    ([`crate::config::SystemConfig::tail_lane_frac`], the
//!    highest-indexed instances) is dedicated to packing known-long
//!    requests, longest-first, so stragglers co-batch with each other
//!    instead of pinning otherwise-idle general instances.
//! 3. **Stop-and-resume** — a request on a general lane is leased only up
//!    to the tail threshold (`chunk = min(chunk_size, threshold −
//!    generated)`). When it crosses the threshold the lease expires
//!    through the ordinary divided-rollout chunk-end path: KV parks in
//!    the global pool, the request re-enters the waiting set, and
//!    [`Scheduler::on_chunk_end`] reclassifies it onto the tail lanes —
//!    the exact drain/re-queue + KV-migration machinery the fault layer
//!    uses, but scheduler-initiated.
//!
//! ## Incremental candidate maintenance
//!
//! Same [`super::lazyheap`] idiom as Seer: two stamped heaps (general
//! SFS on `Reverse(estimate)`, tail LFS on `estimate`) share one stamp
//! table; lifecycle hooks re-index exactly the affected requests, and
//! estimate changes mark the group *dirty* — dirty groups are re-keyed
//! at the top of the next `schedule` pass, where the buffer is in scope
//! to read each member's phase and progress. Pop-time validation
//! self-heals entries whose classification or key drifted, so a request
//! sits in at most one *current* heap position at all times.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::ops::Range;

use crate::config::{SystemConfig, WorkloadConfig};
use crate::coordinator::{ContextManager, Phase, ReqState, RequestBuffer};
use crate::workload::{GroupId, GroupSpec, InstanceId, RequestId};

use super::lazyheap::{Entry, LazyHeap, Stamps};
use super::{Assignment, SchedCtx, Scheduler};

/// General-lane SFS key: smallest estimate first, id tie-break via the
/// shared `Entry` ordering (lower id pops first on equal keys).
type ShortKey = Reverse<u64>;

/// A candidate taken from one of the two heaps during a pass; returned
/// at pass end whether or not it was assigned (the driver may still
/// reject the assignment — next pass's validation discards entries for
/// requests that really left the waiting set).
enum Pick {
    Short(Entry<ShortKey>),
    Tail(Entry<u64>),
}

impl Pick {
    fn req(&self) -> RequestId {
        match self {
            Pick::Short(e) => e.req,
            Pick::Tail(e) => e.req,
        }
    }
}

pub struct RollPackerScheduler {
    ctx_mgr: ContextManager,
    chunk_size: u32,
    /// Generated-token threshold past which a request counts as tail.
    threshold: u32,
    /// Fraction of live instances dedicated to tail lanes.
    tail_frac: f64,
    /// Cross-iteration length priors (survive `init`, which rebuilds the
    /// context manager at iteration start).
    priors: Vec<(GroupId, u32)>,
    // --- incremental candidate structures (see module docs) ----------
    stamps: Stamps,
    short_heap: LazyHeap<ShortKey>,
    tail_heap: LazyHeap<u64>,
    /// Request ids per group, indexed by `GroupId` (for group-wide
    /// re-keying when an estimate moves).
    group_members: Vec<Vec<RequestId>>,
    /// Groups whose estimate moved since the last pass; their waiting
    /// members are re-keyed (and re-classified) at the next `schedule`.
    dirty: Vec<GroupId>,
    group_dirty: Vec<bool>,
    /// Requests already counted in `tail_packed` (first tail-class
    /// assignment only).
    diverted: Vec<bool>,
    tail_packed: u64,
    tail_resume_tokens: u64,
    // Reusable pass scratch (allocation-free steady state).
    dirty_scratch: Vec<RequestId>,
    consumed_short: Vec<Entry<ShortKey>>,
    consumed_tail: Vec<Entry<u64>>,
}

impl Default for RollPackerScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl RollPackerScheduler {
    pub fn new() -> Self {
        RollPackerScheduler {
            ctx_mgr: ContextManager::new(u32::MAX),
            chunk_size: 2048,
            threshold: u32::MAX,
            tail_frac: 0.25,
            priors: Vec::new(),
            stamps: Stamps::default(),
            short_heap: LazyHeap::new(),
            tail_heap: LazyHeap::new(),
            group_members: Vec::new(),
            dirty: Vec::new(),
            group_dirty: Vec::new(),
            diverted: Vec::new(),
            tail_packed: 0,
            tail_resume_tokens: 0,
            dirty_scratch: Vec::new(),
            consumed_short: Vec::new(),
            consumed_tail: Vec::new(),
        }
    }

    pub fn context_manager(&self) -> &ContextManager {
        &self.ctx_mgr
    }

    /// Tail classification: demonstrably long (ran past the threshold),
    /// or known-long up front (the group has real length context — an
    /// online finish, raised progress, or a warm prior — at or above the
    /// threshold). Cold groups default to the upper-bound estimate, so
    /// the `has_context` gate keeps them on the general lanes where
    /// their first chunks *discover* their length.
    fn is_tail(&self, r: &ReqState) -> bool {
        r.generated >= self.threshold
            || (self.ctx_mgr.has_context(r.group())
                && self.ctx_mgr.estimate(r.group()) >= self.threshold)
    }

    /// Lease length: tail-class requests run full chunks; general-class
    /// requests are leased only up to the threshold, so a straggler
    /// stops there and resumes packed (module docs, mechanism 3).
    fn chunk_for(&self, r: &ReqState) -> u32 {
        if self.is_tail(r) {
            self.chunk_size
        } else {
            // General class implies generated < threshold.
            self.chunk_size.min(self.threshold - r.generated).max(1)
        }
    }

    /// How many of `n` live instances are tail lanes: `ceil(frac × n)`,
    /// but always leaving at least one general lane, and none at all on
    /// a single-instance fleet (nothing to dedicate).
    fn n_tail_lanes(&self, n: usize) -> usize {
        if n < 2 || self.tail_frac <= 0.0 {
            return 0;
        }
        ((n as f64 * self.tail_frac).ceil() as usize).clamp(1, n - 1)
    }

    /// (Re-)index one request under its current classification and key.
    /// Bumps the stamp, so every older entry for it goes stale.
    fn reindex(&mut self, r: &ReqState) {
        let stamp = self.stamps.bump(r.id());
        let est = self.ctx_mgr.estimate(r.group()) as u64;
        if self.is_tail(r) {
            self.tail_heap.push(est, r.id(), stamp);
        } else {
            self.short_heap.push(Reverse(est), r.id(), stamp);
        }
    }

    /// Mark `g` for re-keying at the next pass (estimate moved, or a
    /// warm prior arrived). Deferred because classification needs each
    /// member's phase and progress, which only the buffer knows.
    fn mark_dirty(&mut self, g: GroupId) {
        let gi = g.0 as usize;
        if gi < self.group_dirty.len() && !self.group_dirty[gi] {
            self.group_dirty[gi] = true;
            self.dirty.push(g);
        }
    }

    /// Re-key every *waiting* member of the groups marked dirty since
    /// the last pass.
    fn flush_dirty(&mut self, buffer: &RequestBuffer) {
        if self.dirty.is_empty() {
            return;
        }
        let mut scratch = std::mem::take(&mut self.dirty_scratch);
        scratch.clear();
        for g in self.dirty.drain(..) {
            self.group_dirty[g.0 as usize] = false;
            scratch.extend(self.group_members[g.0 as usize].iter().copied());
        }
        for id in scratch.drain(..) {
            let r = buffer.get(id);
            if matches!(r.phase, Phase::Waiting) {
                self.reindex(r);
            }
        }
        self.dirty_scratch = scratch;
    }

    /// Pop the next *current* general-lane candidate: stamp fresh, still
    /// waiting, still general-classified, key matching. Mismatches are
    /// repaired in place (self-healing) rather than silently used.
    fn pop_valid_short(&mut self, ctx: &SchedCtx) -> Option<Entry<ShortKey>> {
        while let Some(e) = self.short_heap.pop() {
            if !self.stamps.is_current(&e) {
                continue;
            }
            let r = ctx.buffer.get(e.req);
            if !matches!(r.phase, Phase::Waiting) {
                continue;
            }
            let est = self.ctx_mgr.estimate(r.group()) as u64;
            if self.is_tail(r) {
                // Crossed the threshold since this entry was pushed:
                // migrate to the tail heap at its current key.
                self.tail_heap.push_raw(Entry {
                    key: est,
                    req: e.req,
                    stamp: e.stamp,
                });
                continue;
            }
            let key = Reverse(est);
            if key != e.key {
                self.short_heap.push_raw(Entry { key, ..e });
                continue;
            }
            return Some(e);
        }
        None
    }

    /// Pop the next *current* tail candidate (see `pop_valid_short`).
    fn pop_valid_tail(&mut self, ctx: &SchedCtx) -> Option<Entry<u64>> {
        while let Some(e) = self.tail_heap.pop() {
            if !self.stamps.is_current(&e) {
                continue;
            }
            let r = ctx.buffer.get(e.req);
            if !matches!(r.phase, Phase::Waiting) {
                continue;
            }
            let est = self.ctx_mgr.estimate(r.group()) as u64;
            if !self.is_tail(r) {
                self.short_heap.push_raw(Entry {
                    key: Reverse(est),
                    req: e.req,
                    stamp: e.stamp,
                });
                continue;
            }
            if est != e.key {
                self.tail_heap.push_raw(Entry { key: est, ..e });
                continue;
            }
            return Some(e);
        }
        None
    }

    fn stash(&mut self, p: Pick) {
        match p {
            Pick::Short(e) => self.consumed_short.push(e),
            Pick::Tail(e) => self.consumed_tail.push(e),
        }
    }

    /// Fill one lane set. `tail_first` selects the candidate order: tail
    /// lanes prefer tail candidates (longest-first) and fall back to
    /// general ones; general lanes the reverse. The fallback means no
    /// lane idles while any work waits — tail lanes act as extra general
    /// capacity until stragglers exist, and a lone general fleet
    /// (`n_tail == 0`) still serves tail-class requests.
    fn lane_pass(
        &mut self,
        ctx: &SchedCtx,
        out: &mut Vec<Assignment>,
        lanes: Range<usize>,
        tail_first: bool,
    ) {
        // Max-heap of (free_kv, slots_left, global view index); stale
        // entries are re-pushed after adjustment (same shape as Seer's
        // instance heap).
        let mut heap: BinaryHeap<(u64, usize, usize)> = lanes
            .filter(|&i| {
                let v = &ctx.instances[i];
                v.running < v.max_batch
            })
            .map(|i| {
                let v = &ctx.instances[i];
                (v.free_kv_tokens, v.max_batch - v.running, i)
            })
            .collect();
        if heap.is_empty() {
            return;
        }
        loop {
            let pick = if tail_first {
                self.pop_valid_tail(ctx)
                    .map(Pick::Tail)
                    .or_else(|| self.pop_valid_short(ctx).map(Pick::Short))
            } else {
                self.pop_valid_short(ctx)
                    .map(Pick::Short)
                    .or_else(|| self.pop_valid_tail(ctx).map(Pick::Tail))
            };
            let Some(pick) = pick else { break };
            let rid = pick.req();
            let r = ctx.buffer.get(rid);
            let chunk = self.chunk_for(r);
            let demand = r.kv_demand(chunk);
            match heap.peek().copied() {
                Some((free, slots_left, i)) if free >= demand => {
                    heap.pop();
                    self.ctx_mgr.on_scheduled(r.group());
                    if self.is_tail(r) && !self.diverted[rid.0 as usize] {
                        // First tail-class assignment: this request is
                        // now packed with the other stragglers; record
                        // the progress it resumes with.
                        self.diverted[rid.0 as usize] = true;
                        self.tail_packed += 1;
                        self.tail_resume_tokens += r.generated as u64;
                    }
                    out.push(Assignment {
                        req: rid,
                        instance: ctx.instances[i].id,
                        chunk,
                    });
                    if slots_left > 1 {
                        heap.push((free - demand, slots_left - 1, i));
                    }
                    self.stash(pick);
                }
                _ => {
                    // Most-free lane can't take it → no lane in this set
                    // can; bounded lookahead keeps cycles cheap.
                    self.stash(pick);
                    if out.len() > 4 * ctx.instances.len() || heap.is_empty()
                    {
                        break;
                    }
                }
            }
        }
    }
}

impl Scheduler for RollPackerScheduler {
    fn name(&self) -> &'static str {
        "rollpacker"
    }

    fn init(
        &mut self,
        groups: &[GroupSpec],
        cfg: &WorkloadConfig,
        sys: &SystemConfig,
    ) {
        self.ctx_mgr = ContextManager::with_priors(
            cfg.max_gen_len,
            self.priors.iter().copied(),
        );
        self.ctx_mgr.init_groups(groups);
        self.chunk_size = sys.chunk_size;
        self.tail_frac = sys.tail_lane_frac;
        // A request is "tail" past twice the workload's mean length —
        // the heavy-tailed presets put the straggler mass well above
        // that, while the bulk of requests never hits the stop.
        self.threshold = cfg
            .avg_gen_len
            .saturating_mul(2)
            .clamp(sys.chunk_size.max(1), cfg.max_gen_len.max(1));
        // Rebuild the incremental candidate structures for the new
        // iteration's id space.
        let n_reqs = groups
            .iter()
            .flat_map(|g| g.requests.iter())
            .map(|r| r.id.0 as usize + 1)
            .max()
            .unwrap_or(0);
        self.stamps.reset(n_reqs);
        self.short_heap.clear();
        self.tail_heap.clear();
        self.diverted.clear();
        self.diverted.resize(n_reqs, false);
        self.tail_packed = 0;
        self.tail_resume_tokens = 0;
        let n_groups = groups
            .iter()
            .map(|g| g.id.0 as usize + 1)
            .max()
            .unwrap_or(0);
        self.group_members.clear();
        self.group_members.resize(n_groups, Vec::new());
        self.dirty.clear();
        self.group_dirty.clear();
        self.group_dirty.resize(n_groups, false);
        for g in groups {
            self.group_members[g.id.0 as usize] =
                g.requests.iter().map(|r| r.id).collect();
            let est = self.ctx_mgr.estimate(g.id) as u64;
            // generated == 0 at iteration start, so only known-long
            // groups (retained priors) classify as tail here.
            let tail = self.ctx_mgr.has_context(g.id)
                && est >= self.threshold as u64;
            for r in &g.requests {
                let stamp = self.stamps.bump(r.id);
                if tail {
                    self.tail_heap.push(est, r.id, stamp);
                } else {
                    self.short_heap.push(Reverse(est), r.id, stamp);
                }
            }
        }
    }

    /// Cross-iteration priors are the admission-reordering signal:
    /// prior'd groups start with a usable estimate, so shorts sort ahead
    /// and known-long groups go straight to the tail lanes.
    fn warm_start(&mut self, priors: &crate::iteration::ContextPriors) -> bool {
        self.priors = priors.estimates.clone();
        self.ctx_mgr.inject_priors(self.priors.iter().copied());
        for (g, _) in &priors.estimates {
            if self.ctx_mgr.has_context(*g) {
                self.mark_dirty(*g);
            }
        }
        true
    }

    fn schedule(&mut self, ctx: &SchedCtx, out: &mut Vec<Assignment>) {
        self.flush_dirty(ctx.buffer);
        let n_waiting = ctx.buffer.n_waiting();
        self.short_heap.maybe_compact(&self.stamps, n_waiting);
        self.tail_heap.maybe_compact(&self.stamps, n_waiting);

        // Lane split, recomputed from the live fleet every pass: the
        // highest-indexed `n_tail` views are tail lanes. (The driver's
        // views are the up instances in index order, so the split is
        // deterministic and self-adjusts across faults and scale events
        // without any pinned state.)
        let n = ctx.instances.len();
        let n_tail = self.n_tail_lanes(n);
        let split = n - n_tail;
        self.lane_pass(ctx, out, split..n, true);
        self.lane_pass(ctx, out, 0..split, false);

        // Pass end: every examined candidate returns to its heap with
        // its stamp intact — assigned ones too. If the driver applies an
        // assignment the request leaves Waiting and the entry is
        // discarded by next pass's validation; if the driver rejects it,
        // `on_requeued` re-stamps and the zombie goes stale either way.
        while let Some(e) = self.consumed_short.pop() {
            self.short_heap.push_raw(e);
        }
        while let Some(e) = self.consumed_tail.pop() {
            self.tail_heap.push_raw(e);
        }
    }

    fn on_finished(&mut self, req: &ReqState) {
        let g = req.group();
        let had_ctx = self.ctx_mgr.has_context(g);
        let before = self.ctx_mgr.estimate(g);
        self.ctx_mgr.on_finished(g, req.generated);
        if !had_ctx || self.ctx_mgr.estimate(g) != before {
            self.mark_dirty(g);
        }
    }

    /// A lease ended with the request unfinished — the stop half of
    /// stop-and-resume when the lease was threshold-clamped. Record the
    /// in-flight progress and re-index: a request now at/past the
    /// threshold reclassifies onto the tail heap here.
    fn on_chunk_end(&mut self, req: &ReqState) {
        let g = req.group();
        let before = self.ctx_mgr.estimate(g);
        self.ctx_mgr.on_progress(g, req.generated);
        self.reindex(req);
        if self.ctx_mgr.estimate(g) != before {
            self.mark_dirty(g);
        }
    }

    /// A produced assignment bounced (driver re-check or in-flight
    /// capacity loss): the request is back in the waiting set unchanged —
    /// restore exactly one current candidate entry for it.
    fn on_requeued(&mut self, req: &ReqState) {
        self.reindex(req);
    }

    /// Fault drain and scheduler-initiated stop share one resume path:
    /// route every drained request through [`Self::on_chunk_end`], so
    /// its progress raises the group estimate and a straggler drained
    /// off a dead instance re-enters *tail-classified* — it resumes
    /// packed instead of restarting among the shorts. No pinned state to
    /// repair: the lane split is recomputed from the live views.
    fn on_instance_lost(
        &mut self,
        _lost: InstanceId,
        drained: &[RequestId],
        _live: &[InstanceId],
        buffer: &RequestBuffer,
    ) {
        for id in drained {
            self.on_chunk_end(buffer.get(*id));
        }
    }

    /// Capacity arrived: nothing to rebalance — the next `schedule` pass
    /// derives the lane split from the enlarged fleet, and the global
    /// candidate heaps serve newcomers immediately.
    fn on_instances_added(
        &mut self,
        _added: &[InstanceId],
        _live: &[InstanceId],
        _buffer: &RequestBuffer,
    ) {
    }

    /// Evict the request with the shortest estimate: it re-enters the
    /// general queue near the front and loses the least resident work.
    fn preempt_victim(
        &mut self,
        running: &[(RequestId, crate::sim::clock::SimTime)],
        buffer: &RequestBuffer,
    ) -> Option<RequestId> {
        running
            .iter()
            .min_by_key(|(id, _)| {
                let r = buffer.get(*id);
                (self.ctx_mgr.estimate(r.group()), u32::MAX - id.0)
            })
            .map(|(id, _)| *id)
    }

    fn uses_global_pool(&self) -> bool {
        true
    }

    fn tail_stats(&self) -> (u64, u64) {
        (self.tail_packed, self.tail_resume_tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;
    use crate::scheduler::InstanceView;
    use crate::sim::clock::SimTime;
    use crate::workload::{generate_iteration, InstanceId};

    fn setup() -> (RollPackerScheduler, RequestBuffer, Vec<InstanceView>) {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 5);
        let buffer = RequestBuffer::from_groups(&w.groups);
        let mut s = RollPackerScheduler::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        let instances = (0..cfg.n_instances as u32)
            .map(|i| InstanceView {
                id: InstanceId(i),
                free_kv_tokens: cfg.hw.kv_capacity_tokens,
                capacity_tokens: cfg.hw.kv_capacity_tokens,
                running: 0,
                max_batch: cfg.hw.max_batch,
            })
            .collect();
        (s, buffer, instances)
    }

    fn run_pass(
        s: &mut RollPackerScheduler,
        buffer: &RequestBuffer,
        instances: &[InstanceView],
    ) -> Vec<Assignment> {
        let ctx = SchedCtx {
            now: SimTime::ZERO,
            instances,
            buffer,
        };
        let mut out = Vec::new();
        s.schedule(&ctx, &mut out);
        out
    }

    /// Warm priors reorder admission: with tight capacity, general lanes
    /// must take the shortest-estimate groups first.
    #[test]
    fn warm_priors_order_general_lanes_shortest_first() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 5);
        let buffer = RequestBuffer::from_groups(&w.groups);
        let mut s = RollPackerScheduler::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        // Distinct short estimates per group, all below the threshold.
        let priors = crate::iteration::ContextPriors {
            estimates: w
                .groups
                .iter()
                .enumerate()
                .map(|(i, g)| (g.id, 10 + 10 * i as u32))
                .collect(),
            ..Default::default()
        };
        assert!(s.warm_start(&priors), "rollpacker must consume priors");
        // One general instance with one slot: the pick must be from the
        // minimum-estimate group.
        let instances = vec![InstanceView {
            id: InstanceId(0),
            free_kv_tokens: cfg.hw.kv_capacity_tokens,
            capacity_tokens: cfg.hw.kv_capacity_tokens,
            running: 0,
            max_batch: 1,
        }];
        let out = run_pass(&mut s, &buffer, &instances);
        assert_eq!(out.len(), 1);
        assert_eq!(
            buffer.get(out[0].req).group(),
            w.groups[0].id,
            "shortest-estimate group must be admitted first"
        );
    }

    /// General-lane leases stop at the threshold: the granted chunk
    /// never lets a general-class request run past it.
    #[test]
    fn general_leases_clamp_at_threshold() {
        let (mut s, buffer, instances) = setup();
        let out = run_pass(&mut s, &buffer, &instances);
        assert!(!out.is_empty());
        for a in &out {
            let r = buffer.get(a.req);
            if !s.is_tail(r) {
                assert!(
                    r.generated + a.chunk <= s.threshold,
                    "lease {} + {} overruns threshold {}",
                    r.generated,
                    a.chunk,
                    s.threshold
                );
            }
        }
    }

    /// A request past the threshold reclassifies onto the tail lanes
    /// (highest-indexed instances) and is counted exactly once.
    #[test]
    fn threshold_crossers_resume_on_tail_lanes() {
        let (mut s, mut buffer, instances) = setup();
        let n = instances.len();
        let n_tail = s.n_tail_lanes(n);
        assert!(n_tail >= 1, "test preset must yield a tail lane");
        let tail_ids: Vec<u32> =
            (n - n_tail..n).map(|i| instances[i].id.0).collect();
        // Drive one request past the threshold by hand.
        let id = buffer.all()[0].id();
        buffer.mark_scheduled(id);
        buffer.get_mut(id).generated = s.threshold;
        buffer.mark_waiting(id);
        s.on_chunk_end(buffer.get(id));
        let out = run_pass(&mut s, &buffer, &instances);
        let a = out
            .iter()
            .find(|a| a.req == id)
            .expect("tail request must be scheduled");
        assert!(
            tail_ids.contains(&a.instance.0),
            "tail-class request landed on general lane {:?}",
            a.instance
        );
        assert_eq!(s.tail_stats(), (1, s.threshold as u64));
        // Re-running without applying must not double-count.
        let _ = run_pass(&mut s, &buffer, &instances);
        assert_eq!(s.tail_stats().0, 1, "tail_packed must count uniquely");
    }

    /// With a single instance there are no tail lanes, but tail-class
    /// work must still be served (fallback, no starvation).
    #[test]
    fn single_instance_serves_tail_class() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 5);
        let mut buffer = RequestBuffer::from_groups(&w.groups);
        let mut s = RollPackerScheduler::new();
        s.init(&w.groups, &cfg, &SystemConfig::default());
        let id = buffer.all()[0].id();
        buffer.mark_scheduled(id);
        buffer.get_mut(id).generated = s.threshold + 7;
        buffer.mark_waiting(id);
        s.on_chunk_end(buffer.get(id));
        let instances = vec![InstanceView {
            id: InstanceId(0),
            free_kv_tokens: cfg.hw.kv_capacity_tokens,
            capacity_tokens: cfg.hw.kv_capacity_tokens,
            running: 0,
            max_batch: cfg.hw.max_batch,
        }];
        assert_eq!(s.n_tail_lanes(1), 0);
        let out = run_pass(&mut s, &buffer, &instances);
        assert!(
            out.iter().any(|a| a.req == id),
            "tail-class request must fall back onto the general fleet"
        );
    }

    /// The incremental heaps must make repeated passes over an unchanged
    /// buffer reproduce the identical assignment sequence (examined
    /// candidates return at pass end).
    #[test]
    fn repeated_passes_without_application_are_stable() {
        let (mut s, buffer, mut instances) = setup();
        for i in &mut instances {
            i.max_batch = 4;
        }
        let first = run_pass(&mut s, &buffer, &instances);
        let second = run_pass(&mut s, &buffer, &instances);
        assert!(!first.is_empty());
        assert_eq!(
            first, second,
            "unapplied assignments must be re-producible next pass"
        );
    }

    /// Progress reported through `on_chunk_end` must reach the context
    /// manager (the estimate can only rise past observed progress).
    #[test]
    fn chunk_end_progress_reaches_context_manager() {
        let (mut s, mut buffer, _) = setup();
        let id = buffer.all()[0].id();
        let group = buffer.get(id).group();
        buffer.mark_scheduled(id);
        buffer.get_mut(id).generated = 500;
        buffer.mark_waiting(id);
        s.on_chunk_end(buffer.get(id));
        let sib = buffer
            .all()
            .iter()
            .find(|r| r.group() == group && r.id() != id)
            .unwrap()
            .id();
        buffer.mark_scheduled(sib);
        buffer.get_mut(sib).generated = 10;
        buffer.mark_finished(sib);
        s.on_finished(buffer.get(sib));
        assert_eq!(s.context_manager().estimate(group), 500);
    }
}
