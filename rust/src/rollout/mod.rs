//! The real-model rollout engine: drives the tiny transformer (AOT HLO
//! artifacts via [`crate::runtime`]) through the Seer coordinator at
//! batch-slot granularity — divided rollout as slot leases, probe-first
//! context scheduling, and grouped speculative decoding through the DGDS.

pub mod engine;

pub use engine::{RealRollout, RealRolloutConfig, RolloutReport, SeqResult};
