//! Rollout: the unified session layer plus the real-model engine.
//!
//! * [`session`] — the single front door: [`RolloutSession`] builder over
//!   the [`RolloutBackend`] trait, implemented by the discrete-event
//!   cluster simulator and the real-model slot engine, producing one
//!   unified [`RolloutReport`].
//! * [`registry`] — name-keyed constructors for schedulers and SD
//!   strategies ([`PolicyRegistry`]); new policies register in one place.
//! * [`observer`] — the streaming [`RolloutEvent`] API every backend
//!   narrates into ([`RolloutObserver`]).
//! * [`engine`] — the real-model engine itself: the tiny transformer (AOT
//!   HLO artifacts via [`crate::runtime`]) driven at batch-slot
//!   granularity with divided rollout, probe-first context scheduling,
//!   and grouped speculative decoding through the DGDS.

pub mod engine;
pub mod observer;
pub mod registry;
pub mod session;

pub use engine::{RealRollout, RealRolloutConfig, SeqRequest, StopRule};
pub use observer::{
    EventMux, MuxFrame, ObserverHub, RolloutEvent, RolloutObserver,
};
pub use registry::PolicyRegistry;
pub use session::{
    RealBackend, RolloutBackend, RolloutReport, RolloutSession,
    RolloutSessionBuilder, RolloutStream, SeqResult, SimBackend,
};
