//! Real-model rollout engine: one "instance" = the artifact's B batch
//! slots, driven token-by-token through the AOT HLO entry points.
//!
//! This is the end-to-end composition proof for the three-layer stack: the
//! L3 coordinator ideas run for real here —
//!
//! * **divided rollout**: slot leases of `chunk_tokens`; an expiring lease
//!   extracts the slot's KV (`slot_extract`) into a host-side pool (the
//!   Mooncake analogue) and re-admits later via `slot_update` — no
//!   re-prefill;
//! * **context-aware scheduling**: the first request of each group is a
//!   probe; groups without signal run first (SFS), the rest approximate
//!   LFS on learned group estimates;
//! * **adaptive grouped speculative decoding**: drafts come from the DGDS
//!   per-group CSTs; verification uses the Pallas verify kernel artifact;
//!   acceptance is exact sampling (sample from the true distribution,
//!   accept while it reproduces the draft).

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Result};
use xla::Literal;

use crate::runtime::ModelRuntime;
use crate::sim::Rng;
use crate::spec::dgds::{DraftClient, DraftServer, SpeculationArgs};

/// Stop rule for a generated sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopRule {
    /// Stop after exactly this many generated tokens.
    MaxTokens(usize),
    /// Stop at this token id (or at the config's max_gen cap).
    Eos(u32),
}

/// One input request.
#[derive(Debug, Clone)]
pub struct SeqRequest {
    pub group: usize,
    pub prompt: Vec<u32>,
    pub stop: StopRule,
}

/// One finished sequence.
#[derive(Debug, Clone)]
pub struct SeqResult {
    pub group: usize,
    pub prompt_len: usize,
    pub tokens: Vec<u32>,
    /// Engine decode/verify forward passes this request was resident for.
    pub steps_resident: u64,
    /// Times the request was parked and re-admitted (divided rollout).
    pub migrations: u32,
}

/// Rollout configuration.
#[derive(Debug, Clone)]
pub struct RealRolloutConfig {
    pub temperature: f64,
    /// Grouped speculative decoding through the DGDS.
    pub use_spec: bool,
    /// Slot lease length in generated tokens (divided rollout); 0 = no
    /// chunking (requests hold slots to completion).
    pub chunk_tokens: usize,
    /// Context-aware ordering (probe-first + LFS estimates) vs FCFS.
    pub context_aware: bool,
    pub seed: u64,
    /// Hard cap on generated tokens per request.
    pub max_gen: usize,
}

impl Default for RealRolloutConfig {
    fn default() -> Self {
        RealRolloutConfig {
            temperature: 1.0,
            use_spec: true,
            chunk_tokens: 0,
            context_aware: true,
            seed: 0,
            max_gen: 64,
        }
    }
}

/// Aggregate statistics of one rollout run.
#[derive(Debug, Clone, Default)]
pub struct RolloutReport {
    pub results: Vec<SeqResult>,
    pub engine_steps: u64,
    pub verify_steps: u64,
    pub draft_tokens_proposed: u64,
    pub draft_tokens_accepted: u64,
    pub tokens_generated: u64,
    pub migrations: u64,
    pub wall_secs: f64,
}

impl RolloutReport {
    pub fn throughput(&self) -> f64 {
        if self.wall_secs == 0.0 {
            0.0
        } else {
            self.tokens_generated as f64 / self.wall_secs
        }
    }

    pub fn mean_acceptance_len(&self) -> f64 {
        if self.verify_steps == 0 {
            1.0
        } else {
            1.0 + self.draft_tokens_accepted as f64 / self.verify_steps as f64
        }
    }
}

enum ReqState {
    Waiting,
    /// Parked between chunk leases: KV held host-side.
    Parked {
        kc1: Literal,
        vc1: Literal,
        cache_len: i32,
        cur_token: u32,
    },
    #[allow(dead_code)] // slot recorded for debugging/symmetry
    Active(usize),
    Done,
}

struct ReqRt {
    spec: SeqRequest,
    state: ReqState,
    generated: Vec<u32>,
    /// Tokens already pushed to the DGDS.
    dgds_sent: usize,
    steps_resident: u64,
    migrations: u32,
}

#[derive(Clone)]
struct SlotState {
    req: usize,
    cache_len: i32,
    cur_token: u32,
    chunk_left: usize,
}

/// The engine itself.
pub struct RealRollout<'m> {
    pub model: &'m ModelRuntime,
    pub cfg: RealRolloutConfig,
    pub rng: Rng,
}

impl<'m> RealRollout<'m> {
    pub fn new(model: &'m ModelRuntime, cfg: RealRolloutConfig) -> Self {
        let rng = Rng::new(cfg.seed ^ 0xD0_11_00);
        RealRollout { model, cfg, rng }
    }

    pub fn run(&mut self, requests: Vec<SeqRequest>) -> Result<RolloutReport> {
        let start = Instant::now();
        let d = self.model.manifest.dims;
        let (b, g, p, s, v) =
            (d.batch, d.draft_width, d.prefill_len, d.max_seq, d.vocab);
        for r in &requests {
            if r.prompt.is_empty() || r.prompt.len() > p {
                bail!("prompt length {} not in [1, {p}]", r.prompt.len());
            }
            let cap = match r.stop {
                StopRule::MaxTokens(n) => n,
                StopRule::Eos(_) => self.cfg.max_gen,
            };
            if r.prompt.len() + cap + g + 1 > s {
                bail!(
                    "prompt {} + max_gen {cap} + draft {g} exceeds cache {s}",
                    r.prompt.len()
                );
            }
        }

        let mut reqs: Vec<ReqRt> = requests
            .into_iter()
            .map(|spec| ReqRt {
                spec,
                state: ReqState::Waiting,
                generated: vec![],
                dgds_sent: 0,
                steps_resident: 0,
                migrations: 0,
            })
            .collect();

        // Group context: probe = lowest request index per group; estimate
        // = max finished length (None until a sibling finishes).
        let mut probe_of: BTreeMap<usize, usize> = BTreeMap::new();
        for (i, r) in reqs.iter().enumerate() {
            probe_of.entry(r.spec.group).or_insert(i);
        }
        let mut estimate: BTreeMap<usize, usize> = BTreeMap::new();

        // DGDS.
        let server = DraftServer::spawn();
        let mut client = DraftClient::new();
        let group_ids: Vec<String> = {
            let mut gs: Vec<usize> =
                reqs.iter().map(|r| r.spec.group).collect();
            gs.sort();
            gs.dedup();
            for gid in &gs {
                server.register_group(&format!("g{gid}"), 3600);
            }
            gs.iter().map(|gi| format!("g{gi}")).collect()
        };

        // Batch caches: start zeroed via a dummy whole-batch prefill.
        let zero_tokens = vec![0i32; b * p];
        let one_lens = vec![1i32; b];
        let (_, mut kc, mut vc) =
            self.model.prefill(&zero_tokens, &one_lens)?;
        let mut slots: Vec<Option<SlotState>> = vec![None; b];
        let mut cache_lens = vec![1i32; b];

        let mut report = RolloutReport::default();
        let spec_args = SpeculationArgs {
            max_spec_tokens: g - 1,
            pattern_lookup_max: 24,
            pattern_lookup_min: 2,
            top_k: 1,
        };

        loop {
            // ---------------- admissions -------------------------------
            loop {
                let Some(slot) = slots.iter().position(Option::is_none)
                else {
                    break;
                };
                let Some(next) = self.pick_next(&reqs, &probe_of, &estimate)
                else {
                    break;
                };
                let st = match std::mem::replace(
                    &mut reqs[next].state,
                    ReqState::Active(slot),
                ) {
                    ReqState::Waiting => {
                        // Fresh admission: single-sequence prefill.
                        let prompt = reqs[next].spec.prompt.clone();
                        let mut padded = vec![0i32; p];
                        for (i, &t) in prompt.iter().enumerate() {
                            padded[i] = t as i32;
                        }
                        let (logits, kc1, vc1) = self
                            .model
                            .prefill_one(&padded, prompt.len() as i32)?;
                        let (nkc, nvc) = self.model.slot_update(
                            &kc, &vc, &kc1, &vc1, slot as i32,
                        )?;
                        kc = nkc;
                        vc = nvc;
                        let tok = self.rng.sample_softmax(
                            &logits[..v],
                            self.cfg.temperature,
                        ) as u32;
                        reqs[next].generated.push(tok);
                        report.tokens_generated += 1;
                        SlotState {
                            req: next,
                            cache_len: prompt.len() as i32,
                            cur_token: tok,
                            chunk_left: self.chunk_budget(),
                        }
                    }
                    ReqState::Parked {
                        kc1,
                        vc1,
                        cache_len,
                        cur_token,
                    } => {
                        // Re-admission from the pool: no re-prefill.
                        let (nkc, nvc) = self.model.slot_update(
                            &kc, &vc, &kc1, &vc1, slot as i32,
                        )?;
                        kc = nkc;
                        vc = nvc;
                        reqs[next].migrations += 1;
                        report.migrations += 1;
                        SlotState {
                            req: next,
                            cache_len,
                            cur_token,
                            chunk_left: self.chunk_budget(),
                        }
                    }
                    other => {
                        reqs[next].state = other;
                        break;
                    }
                };
                cache_lens[slot] = st.cache_len;
                slots[slot] = Some(st);
            }

            if slots.iter().all(Option::is_none) {
                break; // everything finished
            }

            // ---------------- one engine step --------------------------
            // Refresh draft contexts periodically (cheap in-process).
            if self.cfg.use_spec {
                client.fetch(&server, &group_ids);
            }

            // Collect drafts.
            let mut drafts: Vec<Vec<u32>> = vec![vec![]; b];
            if self.cfg.use_spec {
                let mut queries = vec![];
                let mut qslots = vec![];
                let mut gids: Vec<String> = vec![];
                let mut patterns: Vec<Vec<u32>> = vec![];
                for (slot, st) in slots.iter().enumerate() {
                    let Some(st) = st else { continue };
                    let r = &reqs[st.req];
                    let mut pattern: Vec<u32> = r
                        .spec
                        .prompt
                        .iter()
                        .chain(r.generated.iter())
                        .copied()
                        .collect();
                    let keep = pattern.len().saturating_sub(32);
                    pattern.drain(..keep);
                    gids.push(format!("g{}", r.spec.group));
                    patterns.push(pattern);
                    qslots.push(slot);
                }
                for i in 0..qslots.len() {
                    queries.push((
                        gids[i].as_str(),
                        patterns[i].as_slice(),
                        spec_args,
                    ));
                }
                let answers = client.batch_speculate(&queries);
                for (i, paths) in answers.into_iter().enumerate() {
                    if let Some(best) = paths.into_iter().next() {
                        drafts[qslots[i]] = best.tokens;
                    }
                }
            }

            let any_draft = drafts.iter().any(|d| !d.is_empty());
            let mut new_tokens_per_slot: Vec<Vec<u32>> = vec![vec![]; b];

            if any_draft {
                // Verify path: one forward scores G positions per slot.
                let mut draft_tokens = vec![0i32; b * g];
                for (slot, st) in slots.iter().enumerate() {
                    if let Some(st) = st {
                        draft_tokens[slot * g] = st.cur_token as i32;
                        for (i, &t) in
                            drafts[slot].iter().take(g - 1).enumerate()
                        {
                            draft_tokens[slot * g + 1 + i] = t as i32;
                        }
                    }
                }
                let (logits, nkc, nvc) =
                    self.model.verify(&draft_tokens, &cache_lens, &kc, &vc)?;
                kc = nkc;
                vc = nvc;
                report.verify_steps += 1;
                for (slot, st) in slots.iter_mut().enumerate() {
                    let Some(st) = st else { continue };
                    let d = &drafts[slot];
                    report.draft_tokens_proposed += d.len() as u64;
                    let mut accepted = 0usize;
                    let mut toks = vec![];
                    for i in 0..=d.len().min(g - 1) {
                        let row = &logits
                            [(slot * g + i) * v..(slot * g + i + 1) * v];
                        let t = self
                            .rng
                            .sample_softmax(row, self.cfg.temperature)
                            as u32;
                        toks.push(t);
                        if i < d.len() && t == d[i] {
                            accepted += 1;
                        } else {
                            break;
                        }
                    }
                    report.draft_tokens_accepted += accepted as u64;
                    // Committed KV: cur_token + accepted drafts.
                    st.cache_len += 1 + accepted as i32;
                    st.cur_token = *toks.last().unwrap();
                    new_tokens_per_slot[slot] = toks;
                }
            } else {
                // Plain decode step.
                let mut cur = vec![0i32; b];
                for (slot, st) in slots.iter().enumerate() {
                    if let Some(st) = st {
                        cur[slot] = st.cur_token as i32;
                    }
                }
                let (logits, nkc, nvc) =
                    self.model.decode(&cur, &cache_lens, &kc, &vc)?;
                kc = nkc;
                vc = nvc;
                for (slot, st) in slots.iter_mut().enumerate() {
                    let Some(st) = st else { continue };
                    let row = &logits[slot * v..(slot + 1) * v];
                    let t =
                        self.rng.sample_softmax(row, self.cfg.temperature)
                            as u32;
                    st.cache_len += 1;
                    st.cur_token = t;
                    new_tokens_per_slot[slot] = vec![t];
                }
            }
            report.engine_steps += 1;

            // ---------------- commit + lifecycle ------------------------
            for slot in 0..b {
                let Some(st) = slots[slot].clone() else { continue };
                let toks = std::mem::take(&mut new_tokens_per_slot[slot]);
                if toks.is_empty() {
                    continue;
                }
                let req = st.req;
                let n = toks.len();
                reqs[req].generated.extend(&toks);
                reqs[req].steps_resident += 1;
                report.tokens_generated += n as u64;
                cache_lens[slot] = st.cache_len;
                {
                    let stm = slots[slot].as_mut().unwrap();
                    stm.chunk_left = stm.chunk_left.saturating_sub(n);
                }

                // Push new tokens to the DGDS (async append).
                if self.cfg.use_spec {
                    let r = &mut reqs[req];
                    let full: Vec<u32> = r
                        .spec
                        .prompt
                        .iter()
                        .chain(r.generated.iter())
                        .copied()
                        .collect();
                    server.update_cst(
                        &format!("g{}", r.spec.group),
                        req as u64,
                        r.dgds_sent,
                        &full[r.dgds_sent..],
                    );
                    r.dgds_sent = full.len();
                }

                // Completion?
                let done = {
                    let r = &reqs[req];
                    match r.spec.stop {
                        StopRule::MaxTokens(nmax) => {
                            r.generated.len() >= nmax
                        }
                        StopRule::Eos(eos) => {
                            r.generated.contains(&eos)
                                || r.generated.len() >= self.cfg.max_gen
                        }
                    }
                };
                if done {
                    // Trim past-stop tokens for MaxTokens semantics.
                    if let StopRule::MaxTokens(nmax) = reqs[req].spec.stop {
                        reqs[req].generated.truncate(nmax);
                    }
                    let glen = reqs[req].generated.len();
                    let group = reqs[req].spec.group;
                    let e = estimate.entry(group).or_insert(0);
                    *e = (*e).max(glen);
                    reqs[req].state = ReqState::Done;
                    slots[slot] = None;
                    cache_lens[slot] = 1;
                    continue;
                }

                // Chunk lease expiry (divided rollout): park only if
                // someone is waiting for the slot.
                let lease_up = self.cfg.chunk_tokens > 0
                    && slots[slot].as_ref().unwrap().chunk_left == 0;
                let someone_waiting = reqs
                    .iter()
                    .any(|r| matches!(r.state, ReqState::Waiting | ReqState::Parked { .. }));
                if lease_up && someone_waiting {
                    let st = slots[slot].take().unwrap();
                    let (kc1, vc1) =
                        self.model.slot_extract(&kc, &vc, slot as i32)?;
                    reqs[req].state = ReqState::Parked {
                        kc1,
                        vc1,
                        cache_len: st.cache_len,
                        cur_token: st.cur_token,
                    };
                    cache_lens[slot] = 1;
                }
            }
        }

        report.results = reqs
            .into_iter()
            .map(|r| SeqResult {
                group: r.spec.group,
                prompt_len: r.spec.prompt.len(),
                tokens: r.generated,
                steps_resident: r.steps_resident,
                migrations: r.migrations,
            })
            .collect();
        report.wall_secs = start.elapsed().as_secs_f64();
        Ok(report)
    }

    fn chunk_budget(&self) -> usize {
        if self.cfg.chunk_tokens == 0 {
            usize::MAX
        } else {
            self.cfg.chunk_tokens
        }
    }

    /// Scheduling order: probes of signal-less groups first (SFS), then
    /// LFS on group estimates; FCFS when context is off.
    fn pick_next(
        &self,
        reqs: &[ReqRt],
        probe_of: &BTreeMap<usize, usize>,
        estimate: &BTreeMap<usize, usize>,
    ) -> Option<usize> {
        let waiting = |i: &usize| {
            matches!(
                reqs[*i].state,
                ReqState::Waiting | ReqState::Parked { .. }
            )
        };
        let idxs: Vec<usize> =
            (0..reqs.len()).filter(|i| waiting(i)).collect();
        if idxs.is_empty() {
            return None;
        }
        if !self.cfg.context_aware {
            return idxs.first().copied();
        }
        // Probe path.
        let mut probes: Vec<usize> = idxs
            .iter()
            .copied()
            .filter(|&i| {
                probe_of.get(&reqs[i].spec.group) == Some(&i)
                    && !estimate.contains_key(&reqs[i].spec.group)
            })
            .collect();
        if !probes.is_empty() {
            probes.sort_by_key(|&i| (reqs[i].generated.len(), i));
            return probes.first().copied();
        }
        // Approximate LFS: largest (estimate − progress) first; groups
        // without estimates are conservatively "long".
        idxs.into_iter().max_by_key(|&i| {
            let est = estimate
                .get(&reqs[i].spec.group)
                .copied()
                .unwrap_or(self.cfg.max_gen);
            let remaining =
                est.saturating_sub(reqs[i].generated.len());
            (remaining, usize::MAX - i)
        })
    }
}
