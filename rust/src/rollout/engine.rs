//! Real-model rollout engine: one "instance" = the artifact's B batch
//! slots, driven token-by-token through the AOT HLO entry points.
//!
//! This is the end-to-end composition proof for the three-layer stack: the
//! L3 coordinator ideas run for real here —
//!
//! * **divided rollout**: slot leases of `chunk_tokens`; an expiring lease
//!   extracts the slot's KV (`slot_extract`) into a host-side pool (the
//!   Mooncake analogue) and re-admits later via `slot_update` — no
//!   re-prefill;
//! * **context-aware scheduling**: the first request of each group is a
//!   probe; groups without signal run first (SFS), the rest approximate
//!   LFS on learned group estimates;
//! * **adaptive grouped speculative decoding**: drafts come from the DGDS
//!   per-group CSTs; verification uses the Pallas verify kernel artifact;
//!   acceptance is exact sampling (sample from the true distribution,
//!   accept while it reproduces the draft).
//!
//! This is the real substrate behind the unified session API — construct
//! runs through [`crate::rollout::RolloutSession`] with `.real(..)`. The
//! engine speaks the same [`RolloutReport`]/[`SeqResult`] language as the
//! simulator and narrates the same lifecycle events ("instances" here are
//! batch slots).

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Result};
use xla::Literal;

use crate::coordinator::ContextManager;
use crate::metrics::{Completion, RolloutMetrics};
use crate::rollout::observer::{ObserverHub, RolloutEvent};
use crate::rollout::session::{RolloutReport, SeqResult};
use crate::runtime::ModelRuntime;
use crate::sim::clock::SimTime;
use crate::sim::Rng;
use crate::spec::dgds::{DraftClient, DraftServer, SpeculationArgs};
use crate::spec::simmodel::SdStrategy;
use crate::workload::{GroupId, GroupSpec, InstanceId, RequestId, RequestSpec};

/// Stop rule for a generated sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopRule {
    /// Stop after exactly this many generated tokens.
    MaxTokens(usize),
    /// Stop at this token id (or at the config's max_gen cap).
    Eos(u32),
}

/// One input request.
#[derive(Debug, Clone)]
pub struct SeqRequest {
    pub group: GroupId,
    pub prompt: Vec<u32>,
    pub stop: StopRule,
}

/// Rollout configuration.
#[derive(Debug, Clone)]
pub struct RealRolloutConfig {
    pub temperature: f64,
    /// Grouped speculative decoding through the DGDS.
    pub use_spec: bool,
    /// Slot lease length in generated tokens (divided rollout); 0 = no
    /// chunking (requests hold slots to completion).
    pub chunk_tokens: usize,
    /// Context-aware ordering (probe-first + LFS estimates) vs FCFS.
    pub context_aware: bool,
    pub seed: u64,
    /// Hard cap on generated tokens per request.
    pub max_gen: usize,
}

impl RealRolloutConfig {
    /// Name of the fixed scheduling policy this config selects (the slot
    /// engine's analogue of a registry scheduler name).
    pub fn scheduler_label(&self) -> &'static str {
        if self.context_aware {
            "probe-lfs"
        } else {
            "fcfs"
        }
    }

    /// Name of the SD strategy this config selects.
    pub fn sd_label(&self) -> &'static str {
        if self.use_spec {
            SdStrategy::GroupedCst.name()
        } else {
            SdStrategy::None.name()
        }
    }
}

impl Default for RealRolloutConfig {
    fn default() -> Self {
        RealRolloutConfig {
            temperature: 1.0,
            use_spec: true,
            chunk_tokens: 0,
            context_aware: true,
            seed: 0,
            max_gen: 64,
        }
    }
}

/// Where a request's slot lease currently stands.
enum SlotPhase {
    Waiting,
    /// Parked between chunk leases: KV held host-side.
    Parked {
        kc1: Literal,
        vc1: Literal,
        cache_len: i32,
        cur_token: u32,
    },
    #[allow(dead_code)] // slot recorded for debugging/symmetry
    Active(usize),
    Done,
}

struct ReqRt {
    spec: SeqRequest,
    state: SlotPhase,
    generated: Vec<u32>,
    /// Tokens already pushed to the DGDS.
    dgds_sent: usize,
    migrations: u32,
    first_admitted: Option<SimTime>,
}

#[derive(Clone)]
struct SlotState {
    req: usize,
    cache_len: i32,
    cur_token: u32,
    chunk_left: usize,
}

/// The engine itself.
pub struct RealRollout<'m> {
    pub model: &'m ModelRuntime,
    pub cfg: RealRolloutConfig,
    pub rng: Rng,
    /// Cross-iteration warm-start bundle (length estimates seed the
    /// probe-skip path; token streams pre-populate the DGDS CSTs).
    warm: Option<crate::iteration::ContextPriors>,
}

impl<'m> RealRollout<'m> {
    pub fn new(model: &'m ModelRuntime, cfg: RealRolloutConfig) -> Self {
        let rng = Rng::new(cfg.seed ^ 0xD0_11_00);
        RealRollout {
            model,
            cfg,
            rng,
            warm: None,
        }
    }

    /// Install cross-iteration priors before running: groups with a
    /// length estimate skip the probe phase, and historical token
    /// streams are appended to the group CSTs so grouped SD drafts from
    /// the first step.
    pub fn warm_start(&mut self, priors: crate::iteration::ContextPriors) {
        if !priors.is_empty() {
            self.warm = Some(priors);
        }
    }

    /// Run with no observers attached.
    pub fn run(&mut self, requests: Vec<SeqRequest>) -> Result<RolloutReport> {
        self.run_observed(requests, &mut ObserverHub::new())
    }

    /// Run the rollout to completion, streaming lifecycle events into
    /// `observers` (one "instance" per batch slot).
    pub fn run_observed(
        &mut self,
        requests: Vec<SeqRequest>,
        observers: &mut ObserverHub,
    ) -> Result<RolloutReport> {
        let start = Instant::now();
        let elapsed =
            |start: &Instant| SimTime::from_secs_f64(start.elapsed().as_secs_f64());
        let d = self.model.manifest.dims;
        let (b, g, p, s, v) =
            (d.batch, d.draft_width, d.prefill_len, d.max_seq, d.vocab);
        for r in &requests {
            if r.prompt.is_empty() || r.prompt.len() > p {
                bail!("prompt length {} not in [1, {p}]", r.prompt.len());
            }
            let cap = match r.stop {
                StopRule::MaxTokens(0) => {
                    // Admission always samples one token; a zero budget
                    // would break the Step-token/metrics invariant.
                    bail!("MaxTokens budget must be at least 1");
                }
                StopRule::MaxTokens(n) => n,
                StopRule::Eos(_) => self.cfg.max_gen,
            };
            if r.prompt.len() + cap + g + 1 > s {
                bail!(
                    "prompt {} + max_gen {cap} + draft {g} exceeds cache {s}",
                    r.prompt.len()
                );
            }
        }

        let mut reqs: Vec<ReqRt> = requests
            .into_iter()
            .map(|spec| ReqRt {
                spec,
                state: SlotPhase::Waiting,
                generated: vec![],
                dgds_sent: 0,
                migrations: 0,
                first_admitted: None,
            })
            .collect();

        // Group context: probe = lowest request index per group. Length
        // estimation is the same ContextManager the cluster scheduler
        // uses (conservative bound → warm prior → learned max, floored
        // by parked-sibling progress), so both backends share one set of
        // estimate semantics.
        let mut probe_of: BTreeMap<GroupId, usize> = BTreeMap::new();
        for (i, r) in reqs.iter().enumerate() {
            probe_of.entry(r.spec.group).or_insert(i);
        }
        let mut ctx_mgr = ContextManager::new(self.cfg.max_gen as u32);
        {
            let mut by_group: BTreeMap<GroupId, GroupSpec> = BTreeMap::new();
            for (i, r) in reqs.iter().enumerate() {
                let e = by_group.entry(r.spec.group).or_insert_with(|| {
                    GroupSpec {
                        id: r.spec.group,
                        prompt_len: r.spec.prompt.len() as u32,
                        requests: vec![],
                    }
                });
                e.requests.push(RequestSpec {
                    id: RequestId(i as u32),
                    group: r.spec.group,
                    prompt_len: r.spec.prompt.len() as u32,
                    // True lengths are unknown on this backend; the
                    // context manager never reads them.
                    gen_len: 0,
                });
            }
            let groups: Vec<GroupSpec> = by_group.into_values().collect();
            ctx_mgr.init_groups(&groups);
        }

        // DGDS.
        let server = DraftServer::spawn();
        let mut client = DraftClient::new();
        let group_ids: Vec<String> = {
            let mut gs: Vec<GroupId> =
                reqs.iter().map(|r| r.spec.group).collect();
            gs.sort();
            gs.dedup();
            for gid in &gs {
                // TTL in logical server ticks (messages), not seconds:
                // groups must outlive every update of this rollout.
                server.register_group(
                    &format!("g{}", gid.0),
                    DraftServer::DEFAULT_TTL_TICKS,
                );
            }
            gs.iter().map(|gi| format!("g{}", gi.0)).collect()
        };

        // Cross-iteration warm start: length priors go through the
        // context manager (clamped to max_gen; the first online finish
        // replaces them), and last epoch's token streams pre-populate
        // the group CSTs.
        if let Some(warm) = self.warm.take() {
            ctx_mgr.inject_priors(warm.estimates.iter().copied());
            if self.cfg.use_spec {
                for (g, streams) in &warm.streams {
                    server.warm_start(&format!("g{}", g.0), streams);
                }
                server.flush();
            }
        }

        // Batch caches: start zeroed via a dummy whole-batch prefill.
        let zero_tokens = vec![0i32; b * p];
        let one_lens = vec![1i32; b];
        let (_, mut kc, mut vc) =
            self.model.prefill(&zero_tokens, &one_lens)?;
        let mut slots: Vec<Option<SlotState>> = vec![None; b];
        let mut cache_lens = vec![1i32; b];

        let mut metrics = RolloutMetrics::new(1);
        // Slot-occupancy accounting for mean_utilization: Σ over engine
        // steps of the occupied-slot count (out of `b` per step).
        let mut occupied_slot_steps: u64 = 0;
        let spec_args = SpeculationArgs {
            max_spec_tokens: g - 1,
            pattern_lookup_max: 24,
            pattern_lookup_min: 2,
            top_k: 1,
        };

        loop {
            // ---------------- admissions -------------------------------
            loop {
                let Some(slot) = slots.iter().position(Option::is_none)
                else {
                    break;
                };
                let Some(next) = self.pick_next(&reqs, &probe_of, &ctx_mgr)
                else {
                    break;
                };
                let now = elapsed(&start);
                let st = match std::mem::replace(
                    &mut reqs[next].state,
                    SlotPhase::Active(slot),
                ) {
                    SlotPhase::Waiting => {
                        // Fresh admission: single-sequence prefill.
                        let prompt = reqs[next].spec.prompt.clone();
                        let mut padded = vec![0i32; p];
                        for (i, &t) in prompt.iter().enumerate() {
                            padded[i] = t as i32;
                        }
                        let (logits, kc1, vc1) = self
                            .model
                            .prefill_one(&padded, prompt.len() as i32)?;
                        let (nkc, nvc) = self.model.slot_update(
                            &kc, &vc, &kc1, &vc1, slot as i32,
                        )?;
                        kc = nkc;
                        vc = nvc;
                        let tok = self.rng.sample_softmax(
                            &logits[..v],
                            self.cfg.temperature,
                        ) as u32;
                        reqs[next].generated.push(tok);
                        reqs[next].first_admitted = Some(now);
                        metrics.tokens_generated += 1;
                        observers.emit(RolloutEvent::Scheduled {
                            req: RequestId(next as u32),
                            instance: InstanceId(slot as u32),
                            now,
                        });
                        // The prefill forward pass sampled one token.
                        observers.emit(RolloutEvent::Step {
                            instance: InstanceId(slot as u32),
                            steps: 1,
                            tokens: 1,
                            now,
                        });
                        SlotState {
                            req: next,
                            cache_len: prompt.len() as i32,
                            cur_token: tok,
                            chunk_left: self.chunk_budget(),
                        }
                    }
                    SlotPhase::Parked {
                        kc1,
                        vc1,
                        cache_len,
                        cur_token,
                    } => {
                        // Re-admission from the pool: no re-prefill.
                        let (nkc, nvc) = self.model.slot_update(
                            &kc, &vc, &kc1, &vc1, slot as i32,
                        )?;
                        kc = nkc;
                        vc = nvc;
                        reqs[next].migrations += 1;
                        metrics.migrations += 1;
                        observers.emit(RolloutEvent::Scheduled {
                            req: RequestId(next as u32),
                            instance: InstanceId(slot as u32),
                            now,
                        });
                        observers.emit(RolloutEvent::Migration {
                            req: RequestId(next as u32),
                            to: InstanceId(slot as u32),
                            now,
                        });
                        SlotState {
                            req: next,
                            cache_len,
                            cur_token,
                            chunk_left: self.chunk_budget(),
                        }
                    }
                    other => {
                        reqs[next].state = other;
                        break;
                    }
                };
                cache_lens[slot] = st.cache_len;
                slots[slot] = Some(st);
            }

            if slots.iter().all(Option::is_none) {
                break; // everything finished
            }

            // ---------------- one engine step --------------------------
            // Refresh draft contexts periodically (cheap in-process).
            if self.cfg.use_spec {
                client.fetch(&server, &group_ids);
            }

            // Collect drafts.
            let mut drafts: Vec<Vec<u32>> = vec![vec![]; b];
            if self.cfg.use_spec {
                let mut queries = vec![];
                let mut qslots = vec![];
                let mut gids: Vec<String> = vec![];
                let mut patterns: Vec<Vec<u32>> = vec![];
                for (slot, st) in slots.iter().enumerate() {
                    let Some(st) = st else { continue };
                    let r = &reqs[st.req];
                    let mut pattern: Vec<u32> = r
                        .spec
                        .prompt
                        .iter()
                        .chain(r.generated.iter())
                        .copied()
                        .collect();
                    let keep = pattern.len().saturating_sub(32);
                    pattern.drain(..keep);
                    gids.push(format!("g{}", r.spec.group.0));
                    patterns.push(pattern);
                    qslots.push(slot);
                }
                for i in 0..qslots.len() {
                    queries.push((
                        gids[i].as_str(),
                        patterns[i].as_slice(),
                        spec_args,
                    ));
                }
                let answers = client.batch_speculate(&queries);
                for (i, paths) in answers.into_iter().enumerate() {
                    if let Some(best) = paths.into_iter().next() {
                        drafts[qslots[i]] = best.tokens;
                    }
                }
            }

            let any_draft = drafts.iter().any(|d| !d.is_empty());
            let mut new_tokens_per_slot: Vec<Vec<u32>> = vec![vec![]; b];

            if any_draft {
                // Verify path: one forward scores G positions per slot.
                let mut draft_tokens = vec![0i32; b * g];
                for (slot, st) in slots.iter().enumerate() {
                    if let Some(st) = st {
                        draft_tokens[slot * g] = st.cur_token as i32;
                        for (i, &t) in
                            drafts[slot].iter().take(g - 1).enumerate()
                        {
                            draft_tokens[slot * g + 1 + i] = t as i32;
                        }
                    }
                }
                let (logits, nkc, nvc) =
                    self.model.verify(&draft_tokens, &cache_lens, &kc, &vc)?;
                kc = nkc;
                vc = nvc;
                metrics.verify_steps += 1;
                for (slot, st) in slots.iter_mut().enumerate() {
                    let Some(st) = st else { continue };
                    let d = &drafts[slot];
                    metrics.spec_draft_tokens += d.len() as u64;
                    let mut accepted = 0usize;
                    let mut toks = vec![];
                    for i in 0..=d.len().min(g - 1) {
                        let row = &logits
                            [(slot * g + i) * v..(slot * g + i + 1) * v];
                        let t = self
                            .rng
                            .sample_softmax(row, self.cfg.temperature)
                            as u32;
                        toks.push(t);
                        if i < d.len() && t == d[i] {
                            accepted += 1;
                        } else {
                            break;
                        }
                    }
                    metrics.spec_accepted_tokens += accepted as u64;
                    // Committed KV: cur_token + accepted drafts.
                    st.cache_len += 1 + accepted as i32;
                    st.cur_token = *toks.last().unwrap();
                    new_tokens_per_slot[slot] = toks;
                }
            } else {
                // Plain decode step.
                let mut cur = vec![0i32; b];
                for (slot, st) in slots.iter().enumerate() {
                    if let Some(st) = st {
                        cur[slot] = st.cur_token as i32;
                    }
                }
                let (logits, nkc, nvc) =
                    self.model.decode(&cur, &cache_lens, &kc, &vc)?;
                kc = nkc;
                vc = nvc;
                for (slot, st) in slots.iter_mut().enumerate() {
                    let Some(st) = st else { continue };
                    let row = &logits[slot * v..(slot + 1) * v];
                    let t =
                        self.rng.sample_softmax(row, self.cfg.temperature)
                            as u32;
                    st.cache_len += 1;
                    st.cur_token = t;
                    new_tokens_per_slot[slot] = vec![t];
                }
            }
            metrics.engine_steps += 1;
            occupied_slot_steps +=
                slots.iter().filter(|s| s.is_some()).count() as u64;
            let step_now = elapsed(&start);

            // ---------------- commit + lifecycle ------------------------
            for slot in 0..b {
                let Some(st) = slots[slot].clone() else { continue };
                let mut toks =
                    std::mem::take(&mut new_tokens_per_slot[slot]);
                if toks.is_empty() {
                    continue;
                }
                let req = st.req;
                // Clamp speculative overshoot past a MaxTokens budget up
                // front, so every counter (metrics, Step events, DGDS
                // pushes) sees only tokens the request keeps and
                // Σ gen_len == tokens_generated holds on this backend
                // too. (The KV already holds the extra accepted tokens,
                // but the request completes this commit, freeing the
                // slot.) An emptied commit must still fall through to the
                // completion check below — `continue` here would leave a
                // budget-exhausted request resident forever.
                if let StopRule::MaxTokens(nmax) = reqs[req].spec.stop {
                    let room =
                        nmax.saturating_sub(reqs[req].generated.len());
                    toks.truncate(room);
                }
                let n = toks.len();
                if n > 0 {
                    reqs[req].generated.extend(&toks);
                    metrics.tokens_generated += n as u64;
                    // One Step per occupied slot (an "instance" here is
                    // a batch slot), so per-slot observers attribute the
                    // batched forward's work correctly.
                    observers.emit(RolloutEvent::Step {
                        instance: InstanceId(slot as u32),
                        steps: 1,
                        tokens: n as u64,
                        now: step_now,
                    });
                    cache_lens[slot] = st.cache_len;
                    {
                        let stm = slots[slot].as_mut().unwrap();
                        stm.chunk_left = stm.chunk_left.saturating_sub(n);
                    }
                }

                // Push new tokens to the DGDS (async append).
                if n > 0 && self.cfg.use_spec {
                    let r = &mut reqs[req];
                    let full: Vec<u32> = r
                        .spec
                        .prompt
                        .iter()
                        .chain(r.generated.iter())
                        .copied()
                        .collect();
                    server.update_cst(
                        &format!("g{}", r.spec.group.0),
                        req as u64,
                        r.dgds_sent,
                        &full[r.dgds_sent..],
                    );
                    r.dgds_sent = full.len();
                }

                // Completion?
                let done = {
                    let r = &reqs[req];
                    match r.spec.stop {
                        StopRule::MaxTokens(nmax) => {
                            r.generated.len() >= nmax
                        }
                        StopRule::Eos(eos) => {
                            r.generated.contains(&eos)
                                || r.generated.len() >= self.cfg.max_gen
                        }
                    }
                };
                if done {
                    // MaxTokens outputs are exact: budgets are >= 1 (so
                    // the admission token always fits) and commits are
                    // clamped to the remaining room above.
                    let glen = reqs[req].generated.len();
                    let group = reqs[req].spec.group;
                    ctx_mgr.on_finished(group, glen as u32);
                    reqs[req].state = SlotPhase::Done;
                    slots[slot] = None;
                    cache_lens[slot] = 1;
                    let now = elapsed(&start);
                    metrics.completions.push(Completion {
                        id: RequestId(req as u32),
                        finished_at: now,
                        first_scheduled_at: reqs[req]
                            .first_admitted
                            .unwrap_or(now),
                        gen_len: glen as u32,
                        // The real engine runs one policy per rollout.
                        policy_version: 0,
                    });
                    observers.emit(RolloutEvent::Finished {
                        req: RequestId(req as u32),
                        gen_len: glen as u32,
                        now,
                    });
                    continue;
                }

                // Chunk lease expiry (divided rollout): park only if
                // someone is waiting for the slot.
                let lease_up = self.cfg.chunk_tokens > 0
                    && slots[slot].as_ref().unwrap().chunk_left == 0;
                let someone_waiting = reqs
                    .iter()
                    .any(|r| matches!(r.state, SlotPhase::Waiting | SlotPhase::Parked { .. }));
                if lease_up && someone_waiting {
                    let st = slots[slot].take().unwrap();
                    // The missed-update path: a parked sibling's progress
                    // floors stale learned/warm estimates.
                    ctx_mgr.on_progress(
                        reqs[req].spec.group,
                        reqs[req].generated.len() as u32,
                    );
                    let (kc1, vc1) =
                        self.model.slot_extract(&kc, &vc, slot as i32)?;
                    reqs[req].state = SlotPhase::Parked {
                        kc1,
                        vc1,
                        cache_len: st.cache_len,
                        cur_token: st.cur_token,
                    };
                    cache_lens[slot] = 1;
                    observers.emit(RolloutEvent::ChunkEnd {
                        req: RequestId(req as u32),
                        instance: InstanceId(slot as u32),
                        preempted: false,
                        now: elapsed(&start),
                    });
                }
            }
        }

        let wall_secs = start.elapsed().as_secs_f64();
        metrics.makespan = SimTime::from_secs_f64(wall_secs);
        // Busy time = makespan scaled by mean slot occupancy, so
        // mean_utilization() measures how full the batch actually ran
        // rather than a constant 1.0.
        let slot_steps = metrics.engine_steps * b as u64;
        metrics.busy_time[0] = if slot_steps == 0 {
            metrics.makespan
        } else {
            SimTime::from_secs_f64(
                wall_secs * occupied_slot_steps as f64 / slot_steps as f64,
            )
        };
        metrics.tau = if metrics.verify_steps == 0 {
            1.0
        } else {
            1.0 + metrics.spec_accepted_tokens as f64
                / metrics.verify_steps as f64
        };
        let sequences = reqs
            .into_iter()
            .enumerate()
            .map(|(i, r)| SeqResult {
                id: RequestId(i as u32),
                group: r.spec.group,
                prompt_len: r.spec.prompt.len() as u32,
                gen_len: r.generated.len() as u32,
                tokens: r.generated,
                chunks: r.migrations + 1,
                preemptions: 0,
                migrations: r.migrations,
                aborted: false,
            })
            .collect();
        Ok(RolloutReport {
            backend: "real",
            scheduler: self.cfg.scheduler_label(),
            sd: self.cfg.sd_label(),
            metrics,
            sequences,
            wall_secs,
        })
    }

    fn chunk_budget(&self) -> usize {
        if self.cfg.chunk_tokens == 0 {
            usize::MAX
        } else {
            self.cfg.chunk_tokens
        }
    }

    /// Scheduling order: probes of context-less groups first (SFS), then
    /// LFS on the context manager's estimates; FCFS when context is off.
    /// Estimate semantics live entirely in [`ContextManager`]: online
    /// finishes replace warm priors, parked-sibling progress floors stale
    /// estimates, and groups without any context rank at the
    /// conservative `max_gen` bound.
    fn pick_next(
        &self,
        reqs: &[ReqRt],
        probe_of: &BTreeMap<GroupId, usize>,
        ctx_mgr: &ContextManager,
    ) -> Option<usize> {
        let waiting = |i: &usize| {
            matches!(
                reqs[*i].state,
                SlotPhase::Waiting | SlotPhase::Parked { .. }
            )
        };
        let idxs: Vec<usize> =
            (0..reqs.len()).filter(|i| waiting(i)).collect();
        if idxs.is_empty() {
            return None;
        }
        if !self.cfg.context_aware {
            return idxs.first().copied();
        }
        // Probe path (skipped for groups with online or warm context).
        let mut probes: Vec<usize> = idxs
            .iter()
            .copied()
            .filter(|&i| {
                probe_of.get(&reqs[i].spec.group) == Some(&i)
                    && !ctx_mgr.has_context(reqs[i].spec.group)
            })
            .collect();
        if !probes.is_empty() {
            probes.sort_by_key(|&i| (reqs[i].generated.len(), i));
            return probes.first().copied();
        }
        // Approximate LFS: largest (estimate − progress) first.
        idxs.into_iter().max_by_key(|&i| {
            let est = ctx_mgr.estimate(reqs[i].spec.group) as usize;
            let remaining =
                est.saturating_sub(reqs[i].generated.len());
            (remaining, usize::MAX - i)
        })
    }
}
