//! Name-keyed registry for scheduling policies and SD strategies.
//!
//! Every front door — the CLI, the experiment harness, the benches, the
//! session builder — resolves policy names through one [`PolicyRegistry`]
//! instead of hand-rolled `match` arms, so a new policy registers in
//! exactly one place and unknown names fail with the full list of known
//! ones. [`PolicyRegistry::builtin`] carries everything the CLI
//! advertises; callers can [`register_scheduler`](PolicyRegistry::register_scheduler)
//! additional constructors (e.g. experimental policies in a bench) on a
//! local copy without touching this module.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::scheduler::{
    ContextMode, RollPackerScheduler, Scheduler, SeerScheduler,
    StreamRlOracle, VerlScheduler,
};
use crate::spec::simmodel::SdStrategy;

/// Constructor for a boxed scheduling policy.
pub type SchedulerCtor = fn() -> Box<dyn Scheduler>;

pub struct PolicyRegistry {
    schedulers: BTreeMap<&'static str, SchedulerCtor>,
    sds: BTreeMap<&'static str, SdStrategy>,
}

impl PolicyRegistry {
    /// A registry with no entries (for tests and fully custom setups).
    pub fn empty() -> Self {
        PolicyRegistry {
            schedulers: BTreeMap::new(),
            sds: BTreeMap::new(),
        }
    }

    /// All in-tree policies, under the names the CLI advertises.
    pub fn builtin() -> Self {
        let mut r = Self::empty();
        r.register_scheduler("seer", || {
            Box::new(SeerScheduler::new(ContextMode::Learned))
        });
        r.register_scheduler("no-context", || {
            Box::new(SeerScheduler::new(ContextMode::None))
        });
        r.register_scheduler("oracle", || {
            Box::new(SeerScheduler::new(ContextMode::Oracle))
        });
        r.register_scheduler("verl", || Box::new(VerlScheduler::new()));
        r.register_scheduler("streamrl", || Box::new(StreamRlOracle::new()));
        r.register_scheduler("rollpacker", || {
            Box::new(RollPackerScheduler::new())
        });
        for sd in [
            SdStrategy::None,
            SdStrategy::GroupedCst,
            SdStrategy::SuffixDecoding,
            SdStrategy::DraftModel,
            SdStrategy::Mtp,
        ] {
            r.register_sd(sd.name(), sd);
        }
        r
    }

    pub fn register_scheduler(
        &mut self,
        name: &'static str,
        ctor: SchedulerCtor,
    ) {
        self.schedulers.insert(name, ctor);
    }

    pub fn register_sd(&mut self, name: &'static str, sd: SdStrategy) {
        self.sds.insert(name, sd);
    }

    /// Construct a fresh (uninitialized) scheduler by name.
    pub fn scheduler(&self, name: &str) -> Result<Box<dyn Scheduler>> {
        self.schedulers.get(name).map(|ctor| ctor()).ok_or_else(|| {
            anyhow!(
                "unknown scheduler '{name}'; known: {}",
                self.scheduler_names().join(", ")
            )
        })
    }

    pub fn sd(&self, name: &str) -> Result<SdStrategy> {
        self.sds.get(name).copied().ok_or_else(|| {
            anyhow!(
                "unknown SD strategy '{name}'; known: {}",
                self.sd_names().join(", ")
            )
        })
    }

    /// Registered scheduler names, sorted.
    pub fn scheduler_names(&self) -> Vec<&'static str> {
        self.schedulers.keys().copied().collect()
    }

    /// Registered SD strategy names, sorted.
    pub fn sd_names(&self) -> Vec<&'static str> {
        self.sds.keys().copied().collect()
    }
}

impl Default for PolicyRegistry {
    fn default() -> Self {
        Self::builtin()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_covers_cli_names() {
        let r = PolicyRegistry::builtin();
        assert_eq!(
            r.scheduler_names(),
            vec![
                "no-context",
                "oracle",
                "rollpacker",
                "seer",
                "streamrl",
                "verl"
            ]
        );
        assert_eq!(
            r.sd_names(),
            vec!["draft-model", "grouped-cst", "mtp", "none", "suffix-decoding"]
        );
    }

    #[test]
    fn unknown_names_error_with_known_list() {
        let r = PolicyRegistry::builtin();
        let e = r.scheduler("nope").unwrap_err().to_string();
        assert!(e.contains("unknown scheduler 'nope'"), "{e}");
        assert!(e.contains("seer"), "{e}");
        let e = r.sd("nope").unwrap_err().to_string();
        assert!(e.contains("unknown SD strategy 'nope'"), "{e}");
    }

    #[test]
    fn custom_registration() {
        let mut r = PolicyRegistry::empty();
        r.register_scheduler("mine", || Box::new(VerlScheduler::new()));
        assert!(r.scheduler("mine").is_ok());
        assert!(r.scheduler("verl").is_err());
    }
}
