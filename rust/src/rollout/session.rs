//! The unified rollout session: one front door for both execution
//! substrates.
//!
//! A [`RolloutSession`] couples a [`RolloutBackend`] — the discrete-event
//! cluster simulator ([`SimBackend`]) or the real-model slot engine
//! ([`RealBackend`]) — with a set of streaming [`RolloutObserver`]s, and
//! produces one [`RolloutReport`] whose request results and
//! [`RolloutMetrics`] mean the same thing on either substrate. Policies
//! are resolved by name through the [`PolicyRegistry`], so adding a
//! scheduler or SD strategy never touches a call site.
//!
//! ```
//! use seer::config::TaskPreset;
//! use seer::metrics::EventCounts;
//! use seer::rollout::RolloutSession;
//!
//! # fn main() -> anyhow::Result<()> {
//! let report = RolloutSession::builder()
//!     .workload(TaskPreset::Moonlight.workload_for_test())
//!     .scheduler("seer")
//!     .sd("grouped-cst")
//!     .seed(42)
//!     .observer(Box::new(EventCounts::default())) // optional event taps
//!     .run()?;
//! assert!(report.metrics.throughput() > 0.0);
//! # Ok(())
//! # }
//! ```
//!
//! The real-model backend takes the same shape: swap `.workload(..)` for
//! `.real(&model, RealRolloutConfig::default()).requests(reqs)`. For
//! multi-iteration training, [`RolloutSessionBuilder::context_store`]
//! warm-starts the context manager and grouped-SD state from a
//! [`crate::iteration::ContextStore`], and
//! [`RolloutSessionBuilder::groups`] injects an explicitly re-sampled
//! epoch workload (see [`crate::iteration::TrainingDriver`]).

use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::{SystemConfig, WorkloadConfig};
use crate::engine::cluster::ClusterSim;
use crate::iteration::{ContextPriors, ContextStore};
use crate::metrics::RolloutMetrics;
use crate::rollout::engine::{RealRollout, RealRolloutConfig, SeqRequest};
use crate::rollout::observer::{ObserverHub, RolloutObserver};
use crate::rollout::registry::PolicyRegistry;
use crate::runtime::ModelRuntime;
use crate::scheduler::Scheduler;
use crate::sim::clock::SimTime;
use crate::sim::faults::FaultPlan;
use crate::spec::simmodel::SdStrategy;
use crate::util::json::Json;
use crate::util::stats::Summary;
use crate::workload::{generate_iteration, GroupId, GroupSpec, RequestId};

/// One request's outcome, unified across backends.
#[derive(Debug, Clone)]
pub struct SeqResult {
    pub id: RequestId,
    pub group: GroupId,
    pub prompt_len: u32,
    /// Tokens actually generated (== `tokens.len()` on the real backend).
    pub gen_len: u32,
    /// Generated token ids. Real backend only: the fluid simulator tracks
    /// counts, not contents, so this is empty there.
    pub tokens: Vec<u32>,
    /// Chunk leases this request ran as (> 1 means divided rollout split
    /// it across placements).
    pub chunks: u32,
    /// KV-pressure evictions suffered (simulated backend only).
    pub preemptions: u32,
    /// Times the request's KV moved through the pool into a placement —
    /// placement *changes* on the simulator, every host round-trip
    /// (re-admission) on the real backend. Matches the backend's
    /// `Migration` events and `RolloutMetrics::migrations`.
    pub migrations: u32,
    /// Terminated by a fault-script abort: `gen_len` is partial and the
    /// request is excluded from completion accounting (simulated backend
    /// only; the real engine has no fault layer).
    pub aborted: bool,
}

/// The unified result of one rollout run.
///
/// `metrics.makespan` is virtual time on the simulated backend and equals
/// `wall_secs` on the real backend, so `metrics.throughput()` is the
/// backend's native tokens-per-second either way.
pub struct RolloutReport {
    /// Which backend produced this report (`"sim"` or `"real"`).
    pub backend: &'static str,
    /// Self-reported name of the scheduling policy that ran.
    pub scheduler: &'static str,
    /// SD strategy name (`"none"` when speculation was off).
    pub sd: &'static str,
    pub metrics: RolloutMetrics,
    /// Per-request outcomes, in request-id order.
    pub sequences: Vec<SeqResult>,
    /// Host wall-clock duration of the run.
    pub wall_secs: f64,
}

impl RolloutReport {
    pub fn throughput(&self) -> f64 {
        self.metrics.throughput()
    }

    pub fn mean_acceptance_len(&self) -> f64 {
        self.metrics.mean_acceptance_len()
    }

    /// Serialize the report's summary statistics for bench/trajectory
    /// tooling (`seer rollout --json`).
    pub fn to_json(&self) -> Json {
        let m = &self.metrics;
        let mut o = std::collections::BTreeMap::new();
        let mut put = |k: &str, v: Json| {
            o.insert(k.to_string(), v);
        };
        put("backend", Json::Str(self.backend.to_string()));
        put("scheduler", Json::Str(self.scheduler.to_string()));
        put("sd", Json::Str(self.sd.to_string()));
        put("reqs", Json::Num(self.sequences.len() as f64));
        put("completions", Json::Num(m.completions.len() as f64));
        put("tokens_generated", Json::Num(m.tokens_generated as f64));
        put("makespan_secs", Json::Num(m.makespan.as_secs_f64()));
        put("wall_secs", Json::Num(self.wall_secs));
        put("throughput_tok_s", Json::Num(m.throughput()));
        put(
            "tail_secs_last10pct",
            Json::Num(m.tail_time(0.10).as_secs_f64()),
        );
        put("mean_utilization", Json::Num(m.mean_utilization()));
        put("preemptions", Json::Num(m.preemptions as f64));
        put("migrations", Json::Num(m.migrations as f64));
        put("migrated_bytes", Json::Num(m.migrated_bytes as f64));
        put("re_prefill_tokens", Json::Num(m.re_prefill_tokens as f64));
        put("engine_steps", Json::Num(m.engine_steps as f64));
        put("verify_steps", Json::Num(m.verify_steps as f64));
        put("spec_draft_tokens", Json::Num(m.spec_draft_tokens as f64));
        put(
            "spec_accepted_tokens",
            Json::Num(m.spec_accepted_tokens as f64),
        );
        put("tau", Json::Num(m.mean_acceptance_len()));
        // Policy-version staleness (all zero on synchronous rollouts —
        // the async/hybrid driver folds per-completion lag in via
        // `RolloutMetrics::apply_staleness`).
        put("stale_requests", Json::Num(m.stale_requests as f64));
        put("staleness_max", Json::Num(m.staleness_max as f64));
        put("staleness_mean", Json::Num(m.staleness_mean()));
        // Tail packing (zero for policies without tail lanes).
        put("tail_packed", Json::Num(m.tail_packed as f64));
        put(
            "tail_resume_tokens",
            Json::Num(m.tail_resume_tokens as f64),
        );
        // Bubble drafting (zero with the knob off).
        put(
            "bubble_draft_secs",
            Json::Num(m.bubble_draft_time.as_secs_f64()),
        );
        put(
            "bubble_accept_tokens",
            Json::Num(m.bubble_accept_tokens as f64),
        );
        // Fault & elasticity layer (all zero on a healthy run).
        put("aborted", Json::Num(m.aborted as f64));
        put("instances_lost", Json::Num(m.instances_lost as f64));
        put("instances_added", Json::Num(m.instances_added as f64));
        put("fault_lost_tokens", Json::Num(m.fault_lost_tokens as f64));
        put("fault_requeued", Json::Num(m.fault_requeued as f64));
        put(
            "fault_recovery_secs_mean",
            Json::Num(m.mean_recovery_latency().as_secs_f64()),
        );
        if !m.completions.is_empty() {
            let mut s = Summary::new();
            s.extend(m.completions.iter().map(|c| c.gen_len as f64));
            let mut g = std::collections::BTreeMap::new();
            g.insert("mean".to_string(), Json::Num(s.mean()));
            g.insert("p50".to_string(), Json::Num(s.percentile(50.0)));
            g.insert("p90".to_string(), Json::Num(s.percentile(90.0)));
            g.insert("p99".to_string(), Json::Num(s.percentile(99.0)));
            g.insert("max".to_string(), Json::Num(s.max()));
            o.insert("gen_len".to_string(), Json::Obj(g));
        }
        Json::Obj(o)
    }
}

/// One rollout execution substrate. Implementations run a configured
/// iteration to completion exactly once, streaming lifecycle events to
/// `observers` and returning the unified report.
pub trait RolloutBackend {
    fn name(&self) -> &'static str;
    fn scheduler_name(&self) -> &'static str;
    fn sd_name(&self) -> &'static str;
    fn run(&mut self, observers: ObserverHub) -> Result<RolloutReport>;
}

// ---------------------------------------------------------------------
// Simulated backend.
// ---------------------------------------------------------------------

/// The discrete-event cluster simulator behind the backend trait: one
/// seeded workload iteration through [`ClusterSim`] with the production
/// coordinator/scheduler/spec code.
pub struct SimBackend {
    cfg: WorkloadConfig,
    sys: SystemConfig,
    scheduler: Option<Box<dyn Scheduler>>,
    scheduler_name: &'static str,
    sd: SdStrategy,
    seed: u64,
    /// Cluster-scale override (the sweep layer's scale dimension).
    n_instances: Option<usize>,
    stop_after: Option<usize>,
    sample_interval: Option<SimTime>,
    /// Explicit epoch workload (overrides generation from `cfg`/`seed`).
    groups: Option<Vec<GroupSpec>>,
    /// Cross-iteration warm-start context.
    priors: Option<ContextPriors>,
    /// Policy drift since the warm priors were recorded (discounts warm
    /// reference streams in the SD acceptance model; 0 = same policy).
    warm_drift: f64,
    /// Deterministic fault & elasticity script.
    faults: Option<FaultPlan>,
    /// Wall-time event-loop breakdown to stderr (`--profile`).
    profile: bool,
}

impl RolloutBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn scheduler_name(&self) -> &'static str {
        self.scheduler_name
    }

    fn sd_name(&self) -> &'static str {
        self.sd.name()
    }

    fn run(&mut self, observers: ObserverHub) -> Result<RolloutReport> {
        // The wall clock covers the whole session — workload generation
        // through result assembly — matching what the pre-session
        // benches measured around `run_rollout`.
        let start = Instant::now();
        let (sim, expected) = self.prepare(observers)?;
        // Single-shot drain: `ClusterSim::run` is exactly
        // `start() + step_until(FAR_FUTURE) + finish()`, so this path
        // and the suspendable [`RolloutStream`] produce identical
        // outcomes by construction.
        let out = sim.run();
        Ok(assemble_sim_report(
            self.scheduler_name,
            self.sd.name(),
            self.stop_after,
            expected,
            out,
            start,
        ))
    }
}

impl SimBackend {
    /// Build the fully configured [`ClusterSim`] and the expected
    /// completion count. Consumes the one-shot state (scheduler, groups,
    /// priors, faults) — a second call bails like a second `run` would.
    fn prepare(&mut self, observers: ObserverHub) -> Result<(ClusterSim, usize)> {
        let Some(scheduler) = self.scheduler.take() else {
            bail!("rollout session already ran");
        };
        if let Some(n) = self.n_instances {
            self.cfg.n_instances = n.max(1);
        }
        let groups = self
            .groups
            .take()
            .unwrap_or_else(|| generate_iteration(&self.cfg, self.seed).groups);
        let expected: usize = groups.iter().map(|g| g.requests.len()).sum();
        let mut sim = ClusterSim::new(
            self.cfg.clone(),
            self.sys.clone(),
            groups,
            scheduler,
            self.sd,
        )
        .with_observers(observers);
        if let Some(priors) = self.priors.take() {
            sim = sim.with_warm_context(&priors, self.warm_drift);
        }
        if let Some(n) = self.stop_after {
            sim = sim.stop_after(n);
        }
        if let Some(t) = self.sample_interval {
            sim = sim.sample_interval(t);
        }
        if let Some(plan) = self.faults.take() {
            sim = sim.with_faults(plan);
        }
        if self.profile {
            sim = sim.with_profiling();
        }
        Ok((sim, expected))
    }
}

/// Shared tail of a simulated rollout: completion-conservation check plus
/// sequence/report assembly. Used by both the single-shot
/// [`SimBackend::run`] path and [`RolloutStream::finish`], so the two
/// paths cannot drift apart.
fn assemble_sim_report(
    scheduler: &'static str,
    sd: &'static str,
    stop_after: Option<usize>,
    expected: usize,
    out: crate::engine::cluster::RolloutOutcome,
    start: Instant,
) -> RolloutReport {
    if stop_after.is_none() {
        // Conservation under faults: everything not explicitly
        // aborted by the script must have completed.
        out.metrics
            .check_complete(expected - out.metrics.aborted as usize);
    }
    let sequences: Vec<SeqResult> = out
        .buffer
        .all()
        .iter()
        .map(|r| SeqResult {
            id: r.id(),
            group: r.group(),
            prompt_len: r.spec.prompt_len,
            gen_len: r.generated,
            tokens: vec![],
            chunks: r.chunks_run,
            preemptions: r.preemptions,
            migrations: r.migrations,
            aborted: r.aborted,
        })
        .collect();
    RolloutReport {
        backend: "sim",
        scheduler,
        sd,
        metrics: out.metrics,
        sequences,
        wall_secs: start.elapsed().as_secs_f64(),
    }
}

// ---------------------------------------------------------------------
// Suspendable streaming rollout (simulated backend).
// ---------------------------------------------------------------------

/// A simulated rollout that can be advanced in bounded virtual-time
/// segments and suspended/resumed between them — the session-layer
/// surface the async/hybrid [`crate::iteration::TrainingDriver`] modes
/// drive. Obtain via [`RolloutSessionBuilder::start_stream`].
///
/// State machine: the stream starts *running*; [`suspend`] parks it
/// (further [`run_until`] calls are an error), [`resume`] un-parks it,
/// and [`finish`] consumes a drained stream into the same
/// [`RolloutReport`] the single-shot path produces. Virtual time only
/// advances inside [`run_until`], so a suspended stream holds the
/// cluster frozen mid-flight with all queues and KV state intact.
///
/// [`suspend`]: RolloutStream::suspend
/// [`resume`]: RolloutStream::resume
/// [`run_until`]: RolloutStream::run_until
/// [`finish`]: RolloutStream::finish
pub struct RolloutStream {
    sim: ClusterSim,
    scheduler_name: &'static str,
    sd_name: &'static str,
    expected: usize,
    stop_after: Option<usize>,
    start: Instant,
    suspended: bool,
    done: bool,
}

impl RolloutStream {
    pub fn scheduler_name(&self) -> &'static str {
        self.scheduler_name
    }

    pub fn sd_name(&self) -> &'static str {
        self.sd_name
    }

    /// Advance the simulation until the event queue is exhausted or the
    /// next event lies strictly *after* `deadline` (events at exactly
    /// the deadline are processed). Returns `true` once the rollout is
    /// complete. Pass [`SimTime::FAR_FUTURE`] to drain.
    pub fn run_until(&mut self, deadline: SimTime) -> Result<bool> {
        if self.suspended {
            bail!("rollout stream is suspended; resume() before run_until()");
        }
        if !self.done {
            self.done = self.sim.step_until(deadline);
        }
        Ok(self.done)
    }

    /// Park the stream. Virtual time is frozen until
    /// [`Self::resume`]; suspending twice is an error.
    pub fn suspend(&mut self) -> Result<()> {
        if self.suspended {
            bail!("rollout stream is already suspended");
        }
        self.suspended = true;
        Ok(())
    }

    /// Un-park a suspended stream. Resuming a running stream is an
    /// error.
    pub fn resume(&mut self) -> Result<()> {
        if !self.suspended {
            bail!("rollout stream is not suspended");
        }
        self.suspended = false;
        Ok(())
    }

    pub fn is_suspended(&self) -> bool {
        self.suspended
    }

    /// Whether the underlying rollout has drained.
    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Stamp the policy version subsequently *finishing* requests
    /// complete under — the async driver calls this as trained updates
    /// land mid-rollout. Versions are absolute (epoch index + 1).
    pub fn set_policy_version(&mut self, v: u64) {
        self.sim.set_policy_version(v);
    }

    /// Consume a drained stream into the unified report. Erroring on an
    /// undrained stream (rather than silently draining) keeps the
    /// driver's overlap accounting honest.
    pub fn finish(self) -> Result<RolloutReport> {
        if !self.done {
            bail!("rollout stream still has work in flight; run_until(SimTime::FAR_FUTURE) first");
        }
        let out = self.sim.finish();
        Ok(assemble_sim_report(
            self.scheduler_name,
            self.sd_name,
            self.stop_after,
            self.expected,
            out,
            self.start,
        ))
    }
}

// ---------------------------------------------------------------------
// Real-model backend.
// ---------------------------------------------------------------------

/// The real-model slot engine behind the backend trait: token-by-token
/// generation through the AOT HLO entry points.
pub struct RealBackend<'m> {
    model: &'m ModelRuntime,
    cfg: RealRolloutConfig,
    requests: Option<Vec<SeqRequest>>,
    /// Cross-iteration warm-start context (estimates + DGDS streams).
    priors: Option<ContextPriors>,
}

impl RolloutBackend for RealBackend<'_> {
    fn name(&self) -> &'static str {
        "real"
    }

    fn scheduler_name(&self) -> &'static str {
        // The slot engine has fixed policies, named for what they do.
        self.cfg.scheduler_label()
    }

    fn sd_name(&self) -> &'static str {
        self.cfg.sd_label()
    }

    fn run(&mut self, mut observers: ObserverHub) -> Result<RolloutReport> {
        let Some(requests) = self.requests.take() else {
            bail!("rollout session already ran");
        };
        let mut roller = RealRollout::new(self.model, self.cfg.clone());
        if let Some(priors) = self.priors.take() {
            roller.warm_start(priors);
        }
        roller.run_observed(requests, &mut observers)
    }
}

// ---------------------------------------------------------------------
// Session + builder.
// ---------------------------------------------------------------------

/// A configured, not-yet-run rollout. Obtain via
/// [`RolloutSession::builder`]; consume with [`RolloutSession::run`].
pub struct RolloutSession<'m> {
    backend: Box<dyn RolloutBackend + 'm>,
    observers: ObserverHub,
}

impl<'m> RolloutSession<'m> {
    pub fn builder() -> RolloutSessionBuilder<'m> {
        RolloutSessionBuilder::new()
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Self-reported name of the resolved scheduling policy.
    pub fn scheduler_name(&self) -> &'static str {
        self.backend.scheduler_name()
    }

    pub fn sd_name(&self) -> &'static str {
        self.backend.sd_name()
    }

    /// Run the rollout to completion.
    pub fn run(mut self) -> Result<RolloutReport> {
        self.backend.run(self.observers)
    }
}

enum SdChoice {
    Name(String),
    Strategy(SdStrategy),
}

/// Builder for [`RolloutSession`]. Simulator defaults mirror the CLI:
/// `seer` scheduling, `grouped-cst` speculation, seed 42, default
/// [`SystemConfig`]. Simulator-only knobs on a real-backend session are
/// an error, not a silent no-op — the real engine is configured entirely
/// through [`RealRolloutConfig`].
pub struct RolloutSessionBuilder<'m> {
    registry: PolicyRegistry,
    observers: ObserverHub,
    workload: Option<WorkloadConfig>,
    system: Option<SystemConfig>,
    scheduler: Option<String>,
    sd: Option<SdChoice>,
    seed: Option<u64>,
    n_instances: Option<usize>,
    stop_after: Option<usize>,
    sample_interval: Option<SimTime>,
    groups: Option<Vec<GroupSpec>>,
    priors: Option<ContextPriors>,
    warm_drift: f64,
    faults: Option<FaultPlan>,
    profile: bool,
    real: Option<(&'m ModelRuntime, RealRolloutConfig)>,
    requests: Vec<SeqRequest>,
}

impl<'m> RolloutSessionBuilder<'m> {
    fn new() -> Self {
        RolloutSessionBuilder {
            registry: PolicyRegistry::builtin(),
            observers: ObserverHub::new(),
            workload: None,
            system: None,
            scheduler: None,
            sd: None,
            seed: None,
            n_instances: None,
            stop_after: None,
            sample_interval: None,
            groups: None,
            priors: None,
            warm_drift: 0.0,
            faults: None,
            profile: false,
            real: None,
            requests: Vec::new(),
        }
    }

    /// Simulated backend: the workload to generate and run.
    pub fn workload(mut self, cfg: WorkloadConfig) -> Self {
        self.workload = Some(cfg);
        self
    }

    pub fn system(mut self, sys: SystemConfig) -> Self {
        self.system = Some(sys);
        self
    }

    /// Resolve the scheduling policy by registry name. To run a custom
    /// policy, register its constructor via
    /// [`PolicyRegistry::register_scheduler`] and pass the registry with
    /// [`registry`](Self::registry).
    pub fn scheduler(mut self, name: &str) -> Self {
        self.scheduler = Some(name.to_string());
        self
    }

    /// Resolve the SD strategy by registry name.
    pub fn sd(mut self, name: &str) -> Self {
        self.sd = Some(SdChoice::Name(name.to_string()));
        self
    }

    pub fn sd_strategy(mut self, sd: SdStrategy) -> Self {
        self.sd = Some(SdChoice::Strategy(sd));
        self
    }

    /// Simulated backend: the workload-generation seed (default 42). The
    /// real engine's RNG seed lives in [`RealRolloutConfig::seed`].
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    /// Simulated backend: override the workload's cluster scale
    /// (`n_instances`, clamped to ≥ 1) without cloning and editing the
    /// whole config — the sweep layer's scale dimension. Workload
    /// *generation* is independent of the instance count, so the same
    /// seed produces the same requests at every scale.
    pub fn n_instances(mut self, n: usize) -> Self {
        self.n_instances = Some(n);
        self
    }

    /// Partial Rollout: terminate after `n` completions (simulated
    /// backend only; skips the all-requests-completed check).
    pub fn stop_after(mut self, n: usize) -> Self {
        self.stop_after = Some(n);
        self
    }

    pub fn sample_interval(mut self, t: SimTime) -> Self {
        self.sample_interval = Some(t);
        self
    }

    /// Simulated backend: run this explicit group list instead of
    /// generating one from the workload config + seed. The multi-epoch
    /// [`crate::iteration::TrainingDriver`] uses this to feed
    /// [`crate::workload::generate_epoch`] re-samples through the
    /// session layer.
    pub fn groups(mut self, groups: Vec<GroupSpec>) -> Self {
        self.groups = Some(groups);
        self
    }

    /// Warm-start the rollout from a cross-iteration
    /// [`ContextStore`]: the context manager receives per-group length
    /// priors (skipping the cold-start probe tax), the simulated SD
    /// model starts with historical reference counts, and the real
    /// engine pre-populates its DGDS CSTs from stored token streams.
    pub fn context_store(self, store: &ContextStore) -> Self {
        self.context_priors(store.priors())
    }

    /// Like [`context_store`](Self::context_store), from an
    /// already-extracted prior bundle.
    pub fn context_priors(mut self, priors: ContextPriors) -> Self {
        if !priors.is_empty() {
            self.priors = Some(priors);
        }
        self
    }

    /// Policy drift accumulated since the warm-start priors were
    /// recorded (epoch-drift sigma; simulated backend). The SD
    /// acceptance model discounts warm reference streams by it —
    /// RhymeRL-style history replay fades as the policy moves. Ignored
    /// without priors; 0 (the default) treats history like fresh
    /// same-policy streams.
    pub fn warm_drift(mut self, drift: f64) -> Self {
        self.warm_drift = drift.max(0.0);
        self
    }

    /// Simulated backend: replay a deterministic fault & elasticity
    /// script ([`FaultPlan`]) during the rollout — instance crashes,
    /// stragglers, recoveries, elastic scale events and request aborts
    /// at exact virtual timestamps. Faults are part of the run's
    /// identity: same seed + same plan ⇒ bit-identical report.
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        if !plan.is_empty() {
            self.faults = Some(plan);
        }
        self
    }

    /// Simulated backend: print a wall-time breakdown of the event loop
    /// (scheduler passes vs engine commit/plan vs observer emission,
    /// pass counts, mean waiting-set size) to stderr when the run
    /// completes — `seer rollout --profile`. Wall clock never enters the
    /// report.
    pub fn profile(mut self, on: bool) -> Self {
        self.profile = on;
        self
    }

    /// Attach a streaming observer (may be called repeatedly).
    pub fn observer(mut self, o: Box<dyn RolloutObserver>) -> Self {
        self.observers.push(o);
        self
    }

    /// Replace the registry names are resolved against.
    pub fn registry(mut self, r: PolicyRegistry) -> Self {
        self.registry = r;
        self
    }

    /// Real-model backend: drive `model` through the slot engine.
    pub fn real(mut self, model: &'m ModelRuntime, cfg: RealRolloutConfig) -> Self {
        self.real = Some((model, cfg));
        self
    }

    /// Requests for the real-model backend.
    pub fn requests(mut self, reqs: Vec<SeqRequest>) -> Self {
        self.requests = reqs;
        self
    }

    pub fn build(self) -> Result<RolloutSession<'m>> {
        if let Some((model, cfg)) = self.real {
            if self.workload.is_some() {
                bail!("choose one backend: .workload(..) or .real(..)");
            }
            if self.requests.is_empty() {
                bail!("real backend needs .requests(..)");
            }
            // Reject simulator-only knobs instead of silently dropping
            // them: the real engine is configured via RealRolloutConfig.
            if self.scheduler.is_some()
                || self.sd.is_some()
                || self.seed.is_some()
                || self.system.is_some()
                || self.n_instances.is_some()
                || self.stop_after.is_some()
                || self.sample_interval.is_some()
                || self.groups.is_some()
                || self.faults.is_some()
                || self.warm_drift != 0.0
                || self.profile
            {
                bail!(
                    "scheduler/sd/seed/system/n_instances/stop_after/\
                     sample_interval/groups/faults/warm_drift/profile \
                     are simulator-only; configure the real engine via \
                     RealRolloutConfig"
                );
            }
            return Ok(RolloutSession {
                backend: Box::new(RealBackend {
                    model,
                    cfg,
                    requests: Some(self.requests),
                    priors: self.priors,
                }),
                observers: self.observers,
            });
        }
        let (backend, observers) = self.build_sim()?;
        Ok(RolloutSession {
            backend: Box::new(backend),
            observers,
        })
    }

    /// Resolve the simulator arm of the builder into a ready
    /// [`SimBackend`] plus the observer hub. Shared by [`Self::build`]
    /// and [`Self::start_stream`].
    fn build_sim(self) -> Result<(SimBackend, ObserverHub)> {
        let Some(cfg) = self.workload else {
            bail!("a session needs .workload(..) or .real(..)");
        };
        if !self.requests.is_empty() {
            bail!(".requests(..) is for the real backend");
        }
        let scheduler = self
            .registry
            .scheduler(self.scheduler.as_deref().unwrap_or("seer"))?;
        let scheduler_name = scheduler.name();
        let sd = match self.sd {
            Some(SdChoice::Name(n)) => self.registry.sd(&n)?,
            Some(SdChoice::Strategy(s)) => s,
            None => SdStrategy::GroupedCst,
        };
        Ok((
            SimBackend {
                cfg,
                sys: self.system.unwrap_or_default(),
                scheduler: Some(scheduler),
                scheduler_name,
                sd,
                seed: self.seed.unwrap_or(42),
                n_instances: self.n_instances,
                stop_after: self.stop_after,
                sample_interval: self.sample_interval,
                groups: self.groups,
                priors: self.priors,
                warm_drift: self.warm_drift,
                faults: self.faults,
                profile: self.profile,
            },
            self.observers,
        ))
    }

    /// Start a suspendable streaming rollout ([`RolloutStream`]) —
    /// simulator only; the real slot engine runs single-shot. Workload
    /// generation and cluster construction happen here, so a stream
    /// that is immediately drained to [`SimTime::FAR_FUTURE`] and
    /// finished produces the same report as [`Self::run`].
    pub fn start_stream(self) -> Result<RolloutStream> {
        if self.real.is_some() {
            bail!(
                "streaming suspend/resume is simulator-only; \
                 the real backend runs single-shot via .run()"
            );
        }
        let start = Instant::now();
        let (mut backend, observers) = self.build_sim()?;
        let scheduler_name = backend.scheduler_name;
        let sd_name = backend.sd.name();
        let stop_after = backend.stop_after;
        let (mut sim, expected) = backend.prepare(observers)?;
        sim.start();
        Ok(RolloutStream {
            sim,
            scheduler_name,
            sd_name,
            expected,
            stop_after,
            start,
            suspended: false,
            done: false,
        })
    }

    /// `build()?.run()` in one call.
    pub fn run(self) -> Result<RolloutReport> {
        self.build()?.run()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;

    #[test]
    fn build_rejects_missing_backend() {
        let e = RolloutSession::builder().build();
        assert!(e.is_err());
    }

    #[test]
    fn build_rejects_unknown_scheduler_name() {
        let e = RolloutSession::builder()
            .workload(TaskPreset::Moonlight.workload_for_test())
            .scheduler("not-a-policy")
            .build();
        assert!(e.unwrap_err().to_string().contains("unknown scheduler"));
    }

    #[test]
    fn build_rejects_requests_on_sim_backend() {
        use crate::rollout::engine::StopRule;
        use crate::workload::GroupId;
        let e = RolloutSession::builder()
            .workload(TaskPreset::Moonlight.workload_for_test())
            .requests(vec![SeqRequest {
                group: GroupId(0),
                prompt: vec![1, 2, 3],
                stop: StopRule::MaxTokens(4),
            }])
            .build();
        assert!(e
            .unwrap_err()
            .to_string()
            .contains(".requests(..) is for the real backend"));
        // An empty request vec is just the sim default, not an error.
        let ok = RolloutSession::builder()
            .workload(TaskPreset::Moonlight.workload_for_test())
            .requests(vec![])
            .build();
        assert!(ok.is_ok());
    }

    #[test]
    fn n_instances_override_scales_the_fleet() {
        let run = |n: Option<usize>| {
            let mut b = RolloutSession::builder()
                .workload(TaskPreset::Moonlight.workload_for_test())
                .scheduler("seer")
                .sd("none")
                .seed(7);
            if let Some(n) = n {
                b = b.n_instances(n);
            }
            b.run().unwrap()
        };
        let scaled = run(Some(3));
        // The fleet really ran at the overridden scale...
        assert_eq!(scaled.metrics.busy_time.len(), 3);
        // ...on the same workload: generation is scale-independent.
        let base = run(None);
        assert_ne!(
            base.metrics.busy_time.len(),
            3,
            "base workload must differ in scale for this test to bite"
        );
        assert_eq!(
            scaled.metrics.tokens_generated,
            base.metrics.tokens_generated
        );
    }

    #[test]
    fn stream_without_suspension_matches_single_shot_run() {
        let builder = || {
            RolloutSession::builder()
                .workload(TaskPreset::Moonlight.workload_for_test())
                .scheduler("seer")
                .sd("grouped-cst")
                .seed(42)
        };
        let strip = |r: &RolloutReport| {
            let mut j = r.to_json();
            if let Json::Obj(m) = &mut j {
                m.remove("wall_secs"); // host wall clock, not comparable
            }
            j.to_string()
        };
        let single = builder().run().unwrap();
        let mut stream = builder().start_stream().unwrap();
        assert!(!stream.is_done());
        // Drain in small virtual-time segments to exercise the
        // deadline boundary, not one FAR_FUTURE shot.
        let mut deadline = SimTime::from_secs(3);
        while !stream.run_until(deadline).unwrap() {
            deadline += SimTime::from_secs(3);
        }
        let streamed = stream.finish().unwrap();
        assert_eq!(strip(&single), strip(&streamed));
    }

    #[test]
    fn stream_suspend_resume_state_machine() {
        let builder = || {
            RolloutSession::builder()
                .workload(TaskPreset::Moonlight.workload_for_test())
                .scheduler("seer")
                .sd("none")
                .seed(7)
        };
        // Finishing an undrained stream is an error.
        let fresh = builder().start_stream().unwrap();
        assert!(fresh
            .finish()
            .unwrap_err()
            .to_string()
            .contains("still has work in flight"));

        let mut s = builder().start_stream().unwrap();
        assert!(!s.is_suspended());
        assert!(s.resume().is_err(), "resume while running must fail");
        s.suspend().unwrap();
        assert!(s.is_suspended());
        assert!(s.suspend().is_err(), "double suspend must fail");
        assert!(
            s.run_until(SimTime::from_secs(1)).is_err(),
            "run_until while suspended must fail"
        );
        s.resume().unwrap();
        assert!(s.run_until(SimTime::FAR_FUTURE).unwrap());
        let report = s.finish().unwrap();
        assert!(report.metrics.throughput() > 0.0);
        assert_eq!(report.backend, "sim");
    }

    #[test]
    fn session_reports_resolved_names() {
        let s = RolloutSession::builder()
            .workload(TaskPreset::Moonlight.workload_for_test())
            .scheduler("oracle")
            .sd("none")
            .build()
            .unwrap();
        assert_eq!(s.backend_name(), "sim");
        assert_eq!(s.scheduler_name(), "seer-oracle-lfs");
        assert_eq!(s.sd_name(), "none");
    }
}
