//! Streaming observation of a rollout in progress.
//!
//! Both rollout backends (the discrete-event cluster simulator and the
//! real-model slot engine) narrate request lifecycle transitions as a
//! stream of [`RolloutEvent`]s. Anything that wants to watch a rollout —
//! live progress output, metrics cross-checks, future online-serving
//! hooks — implements [`RolloutObserver`] and attaches itself to a
//! [`crate::rollout::RolloutSession`] before the run starts. The
//! [`crate::metrics::EventCounts`] tally is one such observer; it is
//! given no special treatment by the engines.
//!
//! Observers needing to inspect their state after the run should be
//! attached as `Rc<RefCell<T>>` (a blanket impl forwards events through
//! the cell), keeping a second handle outside the session:
//!
//! ```
//! use std::cell::RefCell;
//! use std::rc::Rc;
//!
//! use seer::config::TaskPreset;
//! use seer::metrics::EventCounts;
//! use seer::rollout::RolloutSession;
//!
//! # fn main() -> anyhow::Result<()> {
//! let counts = Rc::new(RefCell::new(EventCounts::default()));
//! let report = RolloutSession::builder()
//!     .workload(TaskPreset::Moonlight.workload_for_test())
//!     .observer(Box::new(counts.clone()))
//!     .run()?;
//! assert_eq!(
//!     counts.borrow().finished,
//!     report.metrics.completions.len() as u64
//! );
//! # Ok(())
//! # }
//! ```

use std::cell::RefCell;
use std::rc::Rc;

use crate::sim::clock::SimTime;
use crate::workload::{InstanceId, RequestId};

/// One request-lifecycle or engine-progress event.
///
/// `now` is virtual time on the simulated backend and wall-clock time
/// since rollout start on the real backend. On the real backend an
/// "instance" is a batch slot of the single engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RolloutEvent {
    /// A waiting request was granted a chunk lease on an instance.
    Scheduled {
        req: RequestId,
        instance: InstanceId,
        now: SimTime,
    },
    /// A lease ended with the request unfinished: parked voluntarily at
    /// chunk expiry (`preempted == false`) or evicted under KV pressure
    /// (`preempted == true`).
    ChunkEnd {
        req: RequestId,
        instance: InstanceId,
        preempted: bool,
        now: SimTime,
    },
    /// A parked request's KV moved through the global pool back into a
    /// placement (divided rollout in action). The simulator emits this
    /// only when the placement differs from the last one (same-instance
    /// pool refetches are free there); the real backend emits it on
    /// every re-admission, since parked KV always round-trips through
    /// host memory.
    Migration {
        req: RequestId,
        to: InstanceId,
        now: SimTime,
    },
    /// A request reached its stop condition.
    Finished {
        req: RequestId,
        gen_len: u32,
        now: SimTime,
    },
    /// An engine committed generation progress. On the simulator, one
    /// event per committed macro-interval on an instance (`steps` =
    /// forward passes in the interval). On the real engine, one event
    /// per occupied batch slot per batched forward — plus one for each
    /// admission prefill — so summing `steps` yields slot-steps there,
    /// not engine forwards. Summing `tokens` yields
    /// `RolloutMetrics::tokens_generated` on both backends.
    Step {
        instance: InstanceId,
        steps: u64,
        tokens: u64,
        now: SimTime,
    },
    /// Fault layer: an instance crashed or was reclaimed. Its `drained`
    /// in-flight requests were returned to the waiting queue (their
    /// uncommitted progress discarded) and the scheduler was asked to
    /// rebalance via [`crate::scheduler::Scheduler::on_instance_lost`].
    InstanceLost {
        instance: InstanceId,
        drained: u32,
        now: SimTime,
    },
    /// Fault layer: a request drained off a lost instance was re-admitted
    /// onto a live placement (the divided-rollout re-queue path closing
    /// the recovery loop).
    Rebalanced {
        req: RequestId,
        to: InstanceId,
        now: SimTime,
    },
    /// Fault layer: a request was terminated by a scripted abort after
    /// generating `generated` tokens; it will not complete.
    Aborted {
        req: RequestId,
        generated: u32,
        now: SimTime,
    },
}

impl RolloutEvent {
    /// The event's timestamp (virtual time on the simulator, wall-clock
    /// offset on the real backend) — all variants carry one, and streams
    /// are non-decreasing in it (asserted by the invariant tests).
    pub fn now(&self) -> SimTime {
        match self {
            RolloutEvent::Scheduled { now, .. }
            | RolloutEvent::ChunkEnd { now, .. }
            | RolloutEvent::Migration { now, .. }
            | RolloutEvent::Finished { now, .. }
            | RolloutEvent::Step { now, .. }
            | RolloutEvent::InstanceLost { now, .. }
            | RolloutEvent::Rebalanced { now, .. }
            | RolloutEvent::Aborted { now, .. } => *now,
        }
    }
}

/// A sink for the rollout event stream.
pub trait RolloutObserver {
    fn on_event(&mut self, ev: &RolloutEvent);
}

/// Forwarding impl so callers can keep a handle to an observer they hand
/// to a session (see the module docs).
impl<T: RolloutObserver> RolloutObserver for Rc<RefCell<T>> {
    fn on_event(&mut self, ev: &RolloutEvent) {
        self.borrow_mut().on_event(ev);
    }
}

/// Fan-out of one event stream to any number of observers, in attachment
/// order. An empty hub is free: backends emit unconditionally and `emit`
/// is a no-op loop.
#[derive(Default)]
pub struct ObserverHub {
    observers: Vec<Box<dyn RolloutObserver>>,
}

impl ObserverHub {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, observer: Box<dyn RolloutObserver>) {
        self.observers.push(observer);
    }

    pub fn len(&self) -> usize {
        self.observers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.observers.is_empty()
    }

    pub fn emit(&mut self, ev: RolloutEvent) {
        for o in &mut self.observers {
            o.on_event(&ev);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Default)]
    struct Tally(u64);
    impl RolloutObserver for Tally {
        fn on_event(&mut self, _ev: &RolloutEvent) {
            self.0 += 1;
        }
    }

    #[test]
    fn hub_fans_out_to_all_observers() {
        let a = Rc::new(RefCell::new(Tally::default()));
        let b = Rc::new(RefCell::new(Tally::default()));
        let mut hub = ObserverHub::new();
        hub.push(Box::new(a.clone()));
        hub.push(Box::new(b.clone()));
        assert_eq!(hub.len(), 2);
        for i in 0..3 {
            hub.emit(RolloutEvent::Finished {
                req: RequestId(i),
                gen_len: 10,
                now: SimTime::ZERO,
            });
        }
        assert_eq!(a.borrow().0, 3);
        assert_eq!(b.borrow().0, 3);
    }

    #[test]
    fn every_event_reports_its_timestamp() {
        let t = SimTime::from_micros(42);
        let evs = [
            RolloutEvent::Scheduled {
                req: RequestId(0),
                instance: InstanceId(0),
                now: t,
            },
            RolloutEvent::InstanceLost {
                instance: InstanceId(1),
                drained: 3,
                now: t,
            },
            RolloutEvent::Rebalanced {
                req: RequestId(0),
                to: InstanceId(2),
                now: t,
            },
            RolloutEvent::Aborted {
                req: RequestId(0),
                generated: 9,
                now: t,
            },
        ];
        for ev in evs {
            assert_eq!(ev.now(), t);
        }
    }

    #[test]
    fn empty_hub_is_inert() {
        let mut hub = ObserverHub::new();
        assert!(hub.is_empty());
        hub.emit(RolloutEvent::Step {
            instance: InstanceId(0),
            steps: 1,
            tokens: 1,
            now: SimTime::ZERO,
        });
    }
}
