//! Streaming observation of a rollout in progress.
//!
//! Both rollout backends (the discrete-event cluster simulator and the
//! real-model slot engine) narrate request lifecycle transitions as a
//! stream of [`RolloutEvent`]s. Anything that wants to watch a rollout —
//! live progress output, metrics cross-checks, future online-serving
//! hooks — implements [`RolloutObserver`] and attaches itself to a
//! [`crate::rollout::RolloutSession`] before the run starts. The
//! [`crate::metrics::EventCounts`] tally is one such observer; it is
//! given no special treatment by the engines.
//!
//! Observers needing to inspect their state after the run should be
//! attached as `Rc<RefCell<T>>` (a blanket impl forwards events through
//! the cell), keeping a second handle outside the session:
//!
//! ```
//! use std::cell::RefCell;
//! use std::rc::Rc;
//!
//! use seer::config::TaskPreset;
//! use seer::metrics::EventCounts;
//! use seer::rollout::RolloutSession;
//!
//! # fn main() -> anyhow::Result<()> {
//! let counts = Rc::new(RefCell::new(EventCounts::default()));
//! let report = RolloutSession::builder()
//!     .workload(TaskPreset::Moonlight.workload_for_test())
//!     .observer(Box::new(counts.clone()))
//!     .run()?;
//! assert_eq!(
//!     counts.borrow().finished,
//!     report.metrics.completions.len() as u64
//! );
//! # Ok(())
//! # }
//! ```

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::metrics::EventCounts;
use crate::sim::clock::SimTime;
use crate::util::json::Json;
use crate::workload::{InstanceId, RequestId};

/// One request-lifecycle or engine-progress event.
///
/// `now` is virtual time on the simulated backend and wall-clock time
/// since rollout start on the real backend. On the real backend an
/// "instance" is a batch slot of the single engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RolloutEvent {
    /// A waiting request was granted a chunk lease on an instance.
    Scheduled {
        req: RequestId,
        instance: InstanceId,
        now: SimTime,
    },
    /// A lease ended with the request unfinished: parked voluntarily at
    /// chunk expiry (`preempted == false`) or evicted under KV pressure
    /// (`preempted == true`).
    ChunkEnd {
        req: RequestId,
        instance: InstanceId,
        preempted: bool,
        now: SimTime,
    },
    /// A parked request's KV moved through the global pool back into a
    /// placement (divided rollout in action). The simulator emits this
    /// only when the placement differs from the last one (same-instance
    /// pool refetches are free there); the real backend emits it on
    /// every re-admission, since parked KV always round-trips through
    /// host memory.
    Migration {
        req: RequestId,
        to: InstanceId,
        now: SimTime,
    },
    /// A request reached its stop condition.
    Finished {
        req: RequestId,
        gen_len: u32,
        now: SimTime,
    },
    /// An engine committed generation progress. On the simulator, one
    /// event per committed macro-interval on an instance (`steps` =
    /// forward passes in the interval). On the real engine, one event
    /// per occupied batch slot per batched forward — plus one for each
    /// admission prefill — so summing `steps` yields slot-steps there,
    /// not engine forwards. Summing `tokens` yields
    /// `RolloutMetrics::tokens_generated` on both backends.
    Step {
        instance: InstanceId,
        steps: u64,
        tokens: u64,
        now: SimTime,
    },
    /// Fault layer: an instance crashed or was reclaimed. Its `drained`
    /// in-flight requests were returned to the waiting queue (their
    /// uncommitted progress discarded) and the scheduler was asked to
    /// rebalance via [`crate::scheduler::Scheduler::on_instance_lost`].
    InstanceLost {
        instance: InstanceId,
        drained: u32,
        now: SimTime,
    },
    /// Fault layer: a request drained off a lost instance was re-admitted
    /// onto a live placement (the divided-rollout re-queue path closing
    /// the recovery loop).
    Rebalanced {
        req: RequestId,
        to: InstanceId,
        now: SimTime,
    },
    /// Fault layer: a request was terminated by a scripted abort after
    /// generating `generated` tokens; it will not complete.
    Aborted {
        req: RequestId,
        generated: u32,
        now: SimTime,
    },
}

impl RolloutEvent {
    /// The event's timestamp (virtual time on the simulator, wall-clock
    /// offset on the real backend) — all variants carry one, and streams
    /// are non-decreasing in it (asserted by the invariant tests).
    pub fn now(&self) -> SimTime {
        match self {
            RolloutEvent::Scheduled { now, .. }
            | RolloutEvent::ChunkEnd { now, .. }
            | RolloutEvent::Migration { now, .. }
            | RolloutEvent::Finished { now, .. }
            | RolloutEvent::Step { now, .. }
            | RolloutEvent::InstanceLost { now, .. }
            | RolloutEvent::Rebalanced { now, .. }
            | RolloutEvent::Aborted { now, .. } => *now,
        }
    }

    /// The event's wire name (`"scheduled"`, `"chunk_end"`, …) — the
    /// `event` field of [`RolloutEvent::to_json`].
    pub fn kind(&self) -> &'static str {
        match self {
            RolloutEvent::Scheduled { .. } => "scheduled",
            RolloutEvent::ChunkEnd { .. } => "chunk_end",
            RolloutEvent::Migration { .. } => "migration",
            RolloutEvent::Finished { .. } => "finished",
            RolloutEvent::Step { .. } => "step",
            RolloutEvent::InstanceLost { .. } => "instance_lost",
            RolloutEvent::Rebalanced { .. } => "rebalanced",
            RolloutEvent::Aborted { .. } => "aborted",
        }
    }

    /// Serialize the event as one JSON object — the serve plane's
    /// `subscribe` stream emits exactly this (plus a `type` tag), so a
    /// streamed sequence is directly comparable with a locally observed
    /// one. Timestamps are integer microseconds (`t_us`): lossless and
    /// byte-stable.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        let mut put = |k: &str, v: Json| {
            o.insert(k.to_string(), v);
        };
        put("event", Json::Str(self.kind().to_string()));
        put("t_us", Json::Num(self.now().as_micros() as f64));
        match *self {
            RolloutEvent::Scheduled { req, instance, .. } => {
                put("req", Json::Num(req.0 as f64));
                put("instance", Json::Num(instance.0 as f64));
            }
            RolloutEvent::ChunkEnd {
                req,
                instance,
                preempted,
                ..
            } => {
                put("req", Json::Num(req.0 as f64));
                put("instance", Json::Num(instance.0 as f64));
                put("preempted", Json::Bool(preempted));
            }
            RolloutEvent::Migration { req, to, .. } => {
                put("req", Json::Num(req.0 as f64));
                put("to", Json::Num(to.0 as f64));
            }
            RolloutEvent::Finished { req, gen_len, .. } => {
                put("req", Json::Num(req.0 as f64));
                put("gen_len", Json::Num(gen_len as f64));
            }
            RolloutEvent::Step {
                instance,
                steps,
                tokens,
                ..
            } => {
                put("instance", Json::Num(instance.0 as f64));
                put("steps", Json::Num(steps as f64));
                put("tokens", Json::Num(tokens as f64));
            }
            RolloutEvent::InstanceLost {
                instance, drained, ..
            } => {
                put("instance", Json::Num(instance.0 as f64));
                put("drained", Json::Num(drained as f64));
            }
            RolloutEvent::Rebalanced { req, to, .. } => {
                put("req", Json::Num(req.0 as f64));
                put("to", Json::Num(to.0 as f64));
            }
            RolloutEvent::Aborted { req, generated, .. } => {
                put("req", Json::Num(req.0 as f64));
                put("generated", Json::Num(generated as f64));
            }
        }
        Json::Obj(o)
    }
}

/// A sink for the rollout event stream.
pub trait RolloutObserver {
    fn on_event(&mut self, ev: &RolloutEvent);
}

/// Forwarding impl so callers can keep a handle to an observer they hand
/// to a session (see the module docs).
impl<T: RolloutObserver> RolloutObserver for Rc<RefCell<T>> {
    fn on_event(&mut self, ev: &RolloutEvent) {
        self.borrow_mut().on_event(ev);
    }
}

/// Fan-out of one event stream to any number of observers, in attachment
/// order. An empty hub is free: backends emit unconditionally and `emit`
/// is a no-op loop.
#[derive(Default)]
pub struct ObserverHub {
    observers: Vec<Box<dyn RolloutObserver>>,
}

impl ObserverHub {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, observer: Box<dyn RolloutObserver>) {
        self.observers.push(observer);
    }

    pub fn len(&self) -> usize {
        self.observers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.observers.is_empty()
    }

    pub fn emit(&mut self, ev: RolloutEvent) {
        for o in &mut self.observers {
            o.on_event(&ev);
        }
    }
}

// ---------------------------------------------------------------------
// Multiplexing observer (the serve plane's event fan-out).
// ---------------------------------------------------------------------

/// One frame of a multiplexed event stream (see [`EventMux`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MuxFrame {
    /// A replay-buffered sequence was truncated at the mux's cap before
    /// this subscriber attached: the subscriber sees a suffix, not the
    /// full stream. Always the first frame when it applies.
    Truncated,
    /// One rollout lifecycle event, in emission order.
    Event(RolloutEvent),
    /// Periodic progress telemetry: the running [`EventCounts`] tally
    /// plus the timestamp of the event that triggered the sample.
    Telemetry { counts: EventCounts, now: SimTime },
    /// The stream is over; no further frames will arrive.
    Closed,
}

#[derive(Debug, Default)]
struct MuxState {
    /// Full event history for late subscribers, up to `replay_cap`.
    buffer: Vec<RolloutEvent>,
    /// The buffer stopped growing at the cap (subscribers attaching
    /// after that point get [`MuxFrame::Truncated`] first).
    truncated: bool,
    /// Live subscriber channels; senders whose receiver hung up are
    /// dropped on the next emission.
    subs: Vec<Sender<MuxFrame>>,
    /// In-process metrics tally — the mux is itself an observer hub of
    /// sorts: metrics always consume the stream even with no subscriber.
    counts: EventCounts,
    /// Events since the last telemetry frame.
    since_telemetry: u64,
    closed: bool,
}

/// A thread-safe fan-out observer: every event is tallied into an
/// in-process [`EventCounts`] and broadcast to any number of
/// dynamically attached subscribers, with a bounded replay buffer so a
/// subscriber attaching *after* the run started still sees the stream
/// from the beginning (until the cap).
///
/// This is the serve plane's `subscribe` primitive: the job executor
/// attaches a clone of the mux to the session (it implements
/// [`RolloutObserver`]), and every `subscribe` connection registers a
/// channel via [`EventMux::subscribe`] from another thread. Unlike
/// [`ObserverHub`] — which owns its observers for the duration of one
/// single-threaded run — the mux is `Clone + Send + Sync` and accepts
/// subscribers while the rollout is in flight.
#[derive(Debug, Clone)]
pub struct EventMux {
    state: Arc<Mutex<MuxState>>,
    /// A telemetry frame is emitted every this many events (0 = never).
    telemetry_every: u64,
    /// Replay-buffer cap, in events.
    replay_cap: usize,
}

impl EventMux {
    /// Default telemetry cadence (events per telemetry frame).
    pub const DEFAULT_TELEMETRY_EVERY: u64 = 4096;
    /// Default replay-buffer cap (events). At the default cap the buffer
    /// tops out at a few MB; longer streams are delivered as suffixes to
    /// late subscribers ([`MuxFrame::Truncated`]).
    pub const DEFAULT_REPLAY_CAP: usize = 1 << 17;

    pub fn new() -> Self {
        Self::with_limits(Self::DEFAULT_TELEMETRY_EVERY, Self::DEFAULT_REPLAY_CAP)
    }

    /// A mux with explicit telemetry cadence (0 disables telemetry
    /// frames) and replay-buffer cap.
    pub fn with_limits(telemetry_every: u64, replay_cap: usize) -> Self {
        EventMux {
            state: Arc::new(Mutex::new(MuxState::default())),
            telemetry_every,
            replay_cap,
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, MuxState> {
        // A poisoned mux mutex means an observer thread panicked while
        // holding it; the state is plain data, so keep serving it.
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Attach a subscriber: returns a receiver that first replays every
    /// buffered frame (prefixed by [`MuxFrame::Truncated`] if the buffer
    /// hit its cap), then delivers live frames as they happen, and ends
    /// with [`MuxFrame::Closed`] once [`EventMux::close`] is called.
    pub fn subscribe(&self) -> Receiver<MuxFrame> {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut s = self.lock();
        if s.truncated {
            let _ = tx.send(MuxFrame::Truncated);
        }
        for ev in &s.buffer {
            let _ = tx.send(MuxFrame::Event(*ev));
        }
        if s.closed {
            let _ = tx.send(MuxFrame::Closed);
        } else {
            s.subs.push(tx);
        }
        rx
    }

    /// Snapshot of the in-process tally.
    pub fn counts(&self) -> EventCounts {
        self.lock().counts
    }

    /// End the stream: every current and future subscriber receives
    /// [`MuxFrame::Closed`] after the buffered frames. Idempotent.
    pub fn close(&self) {
        let mut s = self.lock();
        if s.closed {
            return;
        }
        s.closed = true;
        for tx in s.subs.drain(..) {
            let _ = tx.send(MuxFrame::Closed);
        }
    }

    /// Whether the replay buffer overflowed its cap.
    pub fn truncated(&self) -> bool {
        self.lock().truncated
    }

    /// Whether [`EventMux::close`] has been called — i.e. no further
    /// frames will ever be emitted.
    pub fn is_closed(&self) -> bool {
        self.lock().closed
    }
}

impl Default for EventMux {
    fn default() -> Self {
        Self::new()
    }
}

impl RolloutObserver for EventMux {
    fn on_event(&mut self, ev: &RolloutEvent) {
        let mut s = self.lock();
        s.counts.on_event(ev);
        if s.buffer.len() < self.replay_cap {
            s.buffer.push(*ev);
        } else {
            s.truncated = true;
        }
        let mut telemetry = None;
        if self.telemetry_every > 0 {
            s.since_telemetry += 1;
            if s.since_telemetry >= self.telemetry_every {
                s.since_telemetry = 0;
                telemetry = Some(MuxFrame::Telemetry {
                    counts: s.counts,
                    now: ev.now(),
                });
            }
        }
        // Broadcast, dropping subscribers whose receiver hung up.
        s.subs.retain(|tx| {
            if tx.send(MuxFrame::Event(*ev)).is_err() {
                return false;
            }
            match &telemetry {
                Some(t) => tx.send(t.clone()).is_ok(),
                None => true,
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Default)]
    struct Tally(u64);
    impl RolloutObserver for Tally {
        fn on_event(&mut self, _ev: &RolloutEvent) {
            self.0 += 1;
        }
    }

    #[test]
    fn hub_fans_out_to_all_observers() {
        let a = Rc::new(RefCell::new(Tally::default()));
        let b = Rc::new(RefCell::new(Tally::default()));
        let mut hub = ObserverHub::new();
        hub.push(Box::new(a.clone()));
        hub.push(Box::new(b.clone()));
        assert_eq!(hub.len(), 2);
        for i in 0..3 {
            hub.emit(RolloutEvent::Finished {
                req: RequestId(i),
                gen_len: 10,
                now: SimTime::ZERO,
            });
        }
        assert_eq!(a.borrow().0, 3);
        assert_eq!(b.borrow().0, 3);
    }

    #[test]
    fn every_event_reports_its_timestamp() {
        let t = SimTime::from_micros(42);
        let evs = [
            RolloutEvent::Scheduled {
                req: RequestId(0),
                instance: InstanceId(0),
                now: t,
            },
            RolloutEvent::InstanceLost {
                instance: InstanceId(1),
                drained: 3,
                now: t,
            },
            RolloutEvent::Rebalanced {
                req: RequestId(0),
                to: InstanceId(2),
                now: t,
            },
            RolloutEvent::Aborted {
                req: RequestId(0),
                generated: 9,
                now: t,
            },
        ];
        for ev in evs {
            assert_eq!(ev.now(), t);
        }
    }

    #[test]
    fn empty_hub_is_inert() {
        let mut hub = ObserverHub::new();
        assert!(hub.is_empty());
        hub.emit(RolloutEvent::Step {
            instance: InstanceId(0),
            steps: 1,
            tokens: 1,
            now: SimTime::ZERO,
        });
    }

    #[test]
    fn event_json_carries_kind_and_fields() {
        let ev = RolloutEvent::Finished {
            req: RequestId(7),
            gen_len: 128,
            now: SimTime::from_micros(1500),
        };
        let j = ev.to_json();
        assert_eq!(j.get("event").and_then(Json::as_str), Some("finished"));
        assert_eq!(j.get("t_us").and_then(Json::as_u64), Some(1500));
        assert_eq!(j.get("req").and_then(Json::as_u64), Some(7));
        assert_eq!(j.get("gen_len").and_then(Json::as_u64), Some(128));
    }

    fn nth_event(i: u64) -> RolloutEvent {
        RolloutEvent::Step {
            instance: InstanceId((i % 4) as u32),
            steps: 1,
            tokens: i,
            now: SimTime::from_micros(i),
        }
    }

    #[test]
    fn mux_live_and_late_subscribers_see_identical_sequences() {
        let mut mux = EventMux::with_limits(0, 1024);
        let live = mux.subscribe();
        for i in 0..5 {
            mux.on_event(&nth_event(i));
        }
        // A late subscriber replays the buffer and then runs live.
        let late = mux.subscribe();
        for i in 5..8 {
            mux.on_event(&nth_event(i));
        }
        mux.close();
        let drain = |rx: Receiver<MuxFrame>| -> Vec<MuxFrame> {
            rx.iter().collect()
        };
        let a = drain(live);
        let b = drain(late);
        assert_eq!(a, b);
        assert_eq!(a.len(), 9); // 8 events + Closed
        assert_eq!(a.last(), Some(&MuxFrame::Closed));
        for (i, frame) in a.iter().take(8).enumerate() {
            assert_eq!(*frame, MuxFrame::Event(nth_event(i as u64)));
        }
        assert_eq!(mux.counts().events, 8);
        assert_eq!(mux.counts().tokens, (0..8).sum::<u64>());
    }

    #[test]
    fn mux_emits_telemetry_on_cadence() {
        let mut mux = EventMux::with_limits(3, 1024);
        let rx = mux.subscribe();
        for i in 0..7 {
            mux.on_event(&nth_event(i));
        }
        mux.close();
        let frames: Vec<MuxFrame> = rx.iter().collect();
        let telemetry: Vec<&MuxFrame> = frames
            .iter()
            .filter(|f| matches!(f, MuxFrame::Telemetry { .. }))
            .collect();
        // 7 events at cadence 3 → telemetry after events 3 and 6.
        assert_eq!(telemetry.len(), 2);
        match telemetry[0] {
            MuxFrame::Telemetry { counts, now } => {
                assert_eq!(counts.events, 3);
                assert_eq!(*now, SimTime::from_micros(2));
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn mux_replay_cap_marks_truncation() {
        let mut mux = EventMux::with_limits(0, 4);
        for i in 0..10 {
            mux.on_event(&nth_event(i));
        }
        assert!(mux.truncated());
        let rx = mux.subscribe();
        mux.close();
        let frames: Vec<MuxFrame> = rx.iter().collect();
        assert_eq!(frames.first(), Some(&MuxFrame::Truncated));
        // 4 buffered events survive; counts still cover all 10.
        assert_eq!(frames.len(), 6); // Truncated + 4 events + Closed
        assert_eq!(mux.counts().events, 10);
    }

    #[test]
    fn mux_subscribing_after_close_gets_closed_frame() {
        let mut mux = EventMux::with_limits(0, 16);
        mux.on_event(&nth_event(0));
        mux.close();
        mux.close(); // idempotent
        let rx = mux.subscribe();
        let frames: Vec<MuxFrame> = rx.iter().collect();
        assert_eq!(
            frames,
            vec![MuxFrame::Event(nth_event(0)), MuxFrame::Closed]
        );
    }

    #[test]
    fn mux_drops_hung_up_subscribers() {
        let mut mux = EventMux::with_limits(0, 16);
        let rx = mux.subscribe();
        drop(rx);
        mux.on_event(&nth_event(0));
        mux.on_event(&nth_event(1));
        assert_eq!(mux.counts().events, 2);
    }
}
