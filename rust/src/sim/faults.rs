//! Deterministic fault & elasticity scripts for the cluster simulator.
//!
//! Production rollout fleets are not static: instances slow down, die and
//! get reclaimed mid-iteration, and the scheduler must migrate
//! partially-generated requests without losing their context (paper §4;
//! Laminar and RollPacker make the same failure/straggler argument). A
//! [`FaultPlan`] is a *script* of timed [`FaultEvent`]s that
//! [`crate::engine::cluster::ClusterSim`] replays at exact virtual
//! timestamps, so a faulty run is exactly as reproducible as a healthy
//! one: same seed + same plan ⇒ same event trace (checked by
//! `rust/tests/faults.rs`).
//!
//! Plans are JSON-serializable through the in-tree [`crate::util::json`]
//! (`seer rollout --faults <file>` replays a saved script against any
//! scheduler), and [`FaultPlan::random`] generates seeded random scripts
//! for the property harness in `rust/tests/invariants.rs`.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::sim::clock::SimTime;
use crate::sim::Rng;
use crate::util::json::Json;
use crate::workload::{InstanceId, RequestId};

/// One scripted fault or elasticity event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultEvent {
    /// The instance crashes: its HBM-resident KV is lost, its in-flight
    /// requests are drained back into the waiting queue (uncommitted
    /// interval progress is discarded and must be re-generated).
    InstanceDown { instance: InstanceId },
    /// The instance becomes a straggler: every engine step takes
    /// `factor`× its modeled time until the instance recovers.
    InstanceSlowdown { instance: InstanceId, factor: f64 },
    /// A downed instance rejoins (or a straggler returns to full speed).
    InstanceRecover { instance: InstanceId },
    /// Elastic scale-up: `n` fresh instances join the fleet.
    ScaleUp { n: usize },
    /// Elastic reclamation: the `n` highest-indexed live instances are
    /// drained and removed (the driver keeps at least one instance live).
    ScaleDown { n: usize },
    /// Cancel one request outright (user abort / filtered sample). The
    /// request terminates as *aborted*, not completed.
    RequestAbort { req: RequestId },
    /// Trainer-side: train-step compute runs `factor`× slower while the
    /// pipeline clock (`U_k` time, seconds) is inside `[from, until)`.
    /// Overlapping windows multiply. Replayed by
    /// [`trainer_step`], not by the rollout cluster.
    TrainerSlowdown { factor: f64, from: f64, until: f64 },
    /// Trainer-side: training halts for `secs` at pipeline-clock second
    /// `at`. A stall that lands while the trainer is idle (between
    /// steps) is absorbed for free; one that lands inside a busy train
    /// step inserts `secs` of zero progress. Fires at most once.
    TrainerStall { at: f64, secs: f64 },
    /// Trainer-side: iteration `at_iter`'s in-flight train step is lost
    /// (torn optimizer state) and redone in full from the last
    /// checkpoint — one extra attempt per crash event at that iteration.
    TrainerCrash { at_iter: usize },
}

impl FaultEvent {
    /// Stable JSON discriminator for this event kind.
    pub fn kind(&self) -> &'static str {
        match self {
            FaultEvent::InstanceDown { .. } => "instance_down",
            FaultEvent::InstanceSlowdown { .. } => "instance_slowdown",
            FaultEvent::InstanceRecover { .. } => "instance_recover",
            FaultEvent::ScaleUp { .. } => "scale_up",
            FaultEvent::ScaleDown { .. } => "scale_down",
            FaultEvent::RequestAbort { .. } => "request_abort",
            FaultEvent::TrainerSlowdown { .. } => "trainer_slowdown",
            FaultEvent::TrainerStall { .. } => "trainer_stall",
            FaultEvent::TrainerCrash { .. } => "trainer_crash",
        }
    }

    /// Whether this event targets the training side of the pipeline
    /// (replayed by [`trainer_step`]) rather than the rollout cluster.
    pub fn is_trainer(&self) -> bool {
        matches!(
            self,
            FaultEvent::TrainerSlowdown { .. }
                | FaultEvent::TrainerStall { .. }
                | FaultEvent::TrainerCrash { .. }
        )
    }
}

/// A fault event pinned to a virtual timestamp.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimedFault {
    pub at: SimTime,
    pub event: FaultEvent,
}

/// A deterministic script of timed fault events.
///
/// ```
/// use seer::sim::faults::{FaultEvent, FaultPlan};
/// use seer::workload::InstanceId;
///
/// let plan = FaultPlan::new()
///     .at(30.0, FaultEvent::InstanceDown { instance: InstanceId(1) })
///     .at(45.0, FaultEvent::ScaleUp { n: 1 })
///     .at(60.0, FaultEvent::InstanceRecover { instance: InstanceId(1) });
/// let json = plan.to_json().to_string();
/// assert_eq!(FaultPlan::from_json_str(&json).unwrap(), plan);
/// ```
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<TimedFault>,
}

impl FaultPlan {
    pub fn new() -> Self {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Append an event at `secs` (virtual seconds since rollout start).
    pub fn at(mut self, secs: f64, event: FaultEvent) -> Self {
        self.events.push(TimedFault {
            at: SimTime::from_secs_f64(secs),
            event,
        });
        self
    }

    /// The plan with events in timestamp order (stable: same-timestamp
    /// events keep their authored order, which the simulator's FIFO event
    /// queue then preserves — required for determinism).
    pub fn sorted(mut self) -> Self {
        self.events.sort_by_key(|e| e.at);
        self
    }

    /// Sanity-check event parameters (factors positive and finite, scale
    /// counts non-zero). Structural feasibility — e.g. never leaving the
    /// fleet empty — is the driver's job, since it depends on run state.
    pub fn validate(&self) -> Result<()> {
        for (i, e) in self.events.iter().enumerate() {
            match e.event {
                FaultEvent::InstanceSlowdown { factor, .. } => {
                    if !(factor.is_finite() && factor > 0.0) {
                        bail!("fault event {i}: slowdown factor {factor} must be finite and > 0");
                    }
                }
                FaultEvent::ScaleUp { n } | FaultEvent::ScaleDown { n } => {
                    if n == 0 {
                        bail!("fault event {i}: {} of 0 instances", e.event.kind());
                    }
                }
                FaultEvent::TrainerSlowdown { factor, from, until } => {
                    if !(factor.is_finite() && factor > 0.0) {
                        bail!("fault event {i}: trainer slowdown factor {factor} must be finite and > 0");
                    }
                    if !(from.is_finite() && until.is_finite() && 0.0 <= from && from <= until) {
                        bail!("fault event {i}: trainer slowdown window [{from}, {until}) must satisfy 0 <= from <= until");
                    }
                }
                FaultEvent::TrainerStall { at, secs } => {
                    if !(at.is_finite() && at >= 0.0 && secs.is_finite() && secs >= 0.0) {
                        bail!("fault event {i}: trainer stall at {at} for {secs}s must be finite and non-negative");
                    }
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Seeded random script for property tests: a mix of crashes (half of
    /// which later recover), one straggler, elastic scale events, and a
    /// few request aborts, all inside `(0.05, 0.85) × horizon_secs`.
    /// Deterministic in the arguments. Instance 0 is never crashed and
    /// scale-downs are clamped by the driver, so a generated plan can
    /// never leave the fleet empty.
    pub fn random(
        seed: u64,
        n_instances: usize,
        n_requests: usize,
        horizon_secs: f64,
    ) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xFA_017);
        let mut plan = FaultPlan::new();
        let t = |rng: &mut Rng| rng.uniform(0.05, 0.85) * horizon_secs;
        if n_instances > 1 {
            let n_down = rng.range_usize(0, (n_instances - 1).min(2));
            let mut victims: Vec<u32> = (1..n_instances as u32).collect();
            for _ in 0..n_down {
                let vi = rng.range_usize(0, victims.len() - 1);
                let v = InstanceId(victims.swap_remove(vi));
                let down_at = t(&mut rng);
                plan = plan.at(down_at, FaultEvent::InstanceDown { instance: v });
                if rng.bool(0.5) {
                    let back = down_at + rng.uniform(0.05, 0.3) * horizon_secs;
                    plan = plan
                        .at(back, FaultEvent::InstanceRecover { instance: v });
                }
            }
        }
        if rng.bool(0.7) {
            plan = plan.at(
                t(&mut rng),
                FaultEvent::InstanceSlowdown {
                    instance: InstanceId(rng.below(n_instances.max(1) as u64) as u32),
                    factor: rng.uniform(1.5, 4.0),
                },
            );
        }
        if rng.bool(0.5) {
            plan = plan.at(
                t(&mut rng),
                FaultEvent::ScaleUp {
                    n: rng.range_usize(1, 2),
                },
            );
        }
        if n_instances > 2 && rng.bool(0.3) {
            plan = plan.at(t(&mut rng), FaultEvent::ScaleDown { n: 1 });
        }
        if n_requests > 0 {
            for _ in 0..rng.range_usize(0, 2) {
                plan = plan.at(
                    t(&mut rng),
                    FaultEvent::RequestAbort {
                        req: RequestId(rng.below(n_requests as u64) as u32),
                    },
                );
            }
        }
        plan.sorted()
    }

    /// Seeded random *trainer-side* script for the chaos/property
    /// harnesses: one slowdown window, up to two stalls, and up to one
    /// crash inside the first `iters` iterations, all parameterized over
    /// `horizon_secs` of pipeline-clock time. Deterministic in the
    /// arguments. Kept separate from [`FaultPlan::random`] so existing
    /// cluster-fault property tests keep their exact draw sequences.
    pub fn random_trainer(seed: u64, iters: usize, horizon_secs: f64) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0x7E_A13);
        let mut plan = FaultPlan::new();
        if rng.bool(0.8) {
            let from = rng.uniform(0.05, 0.6) * horizon_secs;
            let until = from + rng.uniform(0.1, 0.35) * horizon_secs;
            plan = plan.at(
                from,
                FaultEvent::TrainerSlowdown {
                    factor: rng.uniform(1.5, 4.0),
                    from,
                    until,
                },
            );
        }
        for _ in 0..rng.range_usize(0, 2) {
            let at = rng.uniform(0.05, 0.85) * horizon_secs;
            plan = plan.at(
                at,
                FaultEvent::TrainerStall {
                    at,
                    secs: rng.uniform(0.02, 0.15) * horizon_secs,
                },
            );
        }
        if iters > 0 && rng.bool(0.6) {
            let at_iter = rng.range_usize(0, iters - 1);
            plan = plan.at(
                at_iter as f64,
                FaultEvent::TrainerCrash { at_iter },
            );
        }
        plan.sorted()
    }

    /// Split the plan into its cluster-side and trainer-side halves
    /// (each sorted, authored order preserved within a timestamp): the
    /// rollout cluster replays the first, the training driver's pipeline
    /// recurrence ([`trainer_step`]) replays the second. One `--faults`
    /// file can therefore script both failure domains.
    pub fn partition(&self) -> (FaultPlan, FaultPlan) {
        let (trainer, cluster): (Vec<TimedFault>, Vec<TimedFault>) = self
            .events
            .iter()
            .partition(|e| e.event.is_trainer());
        (FaultPlan { events: cluster }, FaultPlan { events: trainer })
    }

    // -----------------------------------------------------------------
    // JSON (de)serialization through util::json.
    // -----------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let events = self
            .events
            .iter()
            .map(|e| {
                let mut o = std::collections::BTreeMap::new();
                o.insert("at_secs".to_string(), Json::Num(e.at.as_secs_f64()));
                o.insert(
                    "kind".to_string(),
                    Json::Str(e.event.kind().to_string()),
                );
                match e.event {
                    FaultEvent::InstanceDown { instance }
                    | FaultEvent::InstanceRecover { instance } => {
                        o.insert(
                            "instance".to_string(),
                            Json::Num(instance.0 as f64),
                        );
                    }
                    FaultEvent::InstanceSlowdown { instance, factor } => {
                        o.insert(
                            "instance".to_string(),
                            Json::Num(instance.0 as f64),
                        );
                        o.insert("factor".to_string(), Json::Num(factor));
                    }
                    FaultEvent::ScaleUp { n } | FaultEvent::ScaleDown { n } => {
                        o.insert("n".to_string(), Json::Num(n as f64));
                    }
                    FaultEvent::RequestAbort { req } => {
                        o.insert("req".to_string(), Json::Num(req.0 as f64));
                    }
                    FaultEvent::TrainerSlowdown { factor, from, until } => {
                        o.insert("factor".to_string(), Json::Num(factor));
                        o.insert("from".to_string(), Json::Num(from));
                        o.insert("until".to_string(), Json::Num(until));
                    }
                    FaultEvent::TrainerStall { at, secs } => {
                        o.insert("at".to_string(), Json::Num(at));
                        o.insert("secs".to_string(), Json::Num(secs));
                    }
                    FaultEvent::TrainerCrash { at_iter } => {
                        o.insert(
                            "at_iter".to_string(),
                            Json::Num(at_iter as f64),
                        );
                    }
                }
                Json::Obj(o)
            })
            .collect();
        let mut root = std::collections::BTreeMap::new();
        root.insert("events".to_string(), Json::Arr(events));
        Json::Obj(root)
    }

    pub fn from_json(json: &Json) -> Result<FaultPlan> {
        let events = json
            .get("events")
            .and_then(|e| e.as_arr())
            .context("fault plan: missing 'events' array")?;
        let mut plan = FaultPlan::new();
        for (i, ev) in events.iter().enumerate() {
            let at = ev
                .get("at_secs")
                .and_then(|v| v.as_f64())
                .with_context(|| format!("fault event {i}: missing 'at_secs'"))?;
            if !(at.is_finite() && at >= 0.0) {
                bail!("fault event {i}: bad at_secs {at}");
            }
            let kind = ev
                .get("kind")
                .and_then(|v| v.as_str())
                .with_context(|| format!("fault event {i}: missing 'kind'"))?;
            let instance = || -> Result<InstanceId> {
                Ok(InstanceId(
                    ev.get("instance")
                        .and_then(|v| v.as_u64())
                        .with_context(|| {
                            format!("fault event {i}: missing 'instance'")
                        })? as u32,
                ))
            };
            let event = match kind {
                "instance_down" => FaultEvent::InstanceDown {
                    instance: instance()?,
                },
                "instance_recover" => FaultEvent::InstanceRecover {
                    instance: instance()?,
                },
                "instance_slowdown" => FaultEvent::InstanceSlowdown {
                    instance: instance()?,
                    factor: ev
                        .get("factor")
                        .and_then(|v| v.as_f64())
                        .with_context(|| {
                            format!("fault event {i}: missing 'factor'")
                        })?,
                },
                "scale_up" | "scale_down" => {
                    let n = ev
                        .get("n")
                        .and_then(|v| v.as_usize())
                        .with_context(|| format!("fault event {i}: missing 'n'"))?;
                    if kind == "scale_up" {
                        FaultEvent::ScaleUp { n }
                    } else {
                        FaultEvent::ScaleDown { n }
                    }
                }
                "request_abort" => FaultEvent::RequestAbort {
                    req: RequestId(
                        ev.get("req").and_then(|v| v.as_u64()).with_context(
                            || format!("fault event {i}: missing 'req'"),
                        )? as u32,
                    ),
                },
                "trainer_slowdown" => {
                    let f64_field = |key: &str| -> Result<f64> {
                        ev.get(key).and_then(|v| v.as_f64()).with_context(
                            || format!("fault event {i}: missing '{key}'"),
                        )
                    };
                    FaultEvent::TrainerSlowdown {
                        factor: f64_field("factor")?,
                        from: f64_field("from")?,
                        until: f64_field("until")?,
                    }
                }
                "trainer_stall" => FaultEvent::TrainerStall {
                    at: ev.get("at").and_then(|v| v.as_f64()).with_context(
                        || format!("fault event {i}: missing 'at'"),
                    )?,
                    secs: ev.get("secs").and_then(|v| v.as_f64()).with_context(
                        || format!("fault event {i}: missing 'secs'"),
                    )?,
                },
                "trainer_crash" => FaultEvent::TrainerCrash {
                    at_iter: ev
                        .get("at_iter")
                        .and_then(|v| v.as_usize())
                        .with_context(|| {
                            format!("fault event {i}: missing 'at_iter'")
                        })?,
                },
                other => bail!("fault event {i}: unknown kind '{other}'"),
            };
            plan = plan.at(at, event);
        }
        let plan = plan.sorted();
        plan.validate()?;
        Ok(plan)
    }

    pub fn from_json_str(text: &str) -> Result<FaultPlan> {
        let json = Json::parse(text)
            .map_err(|e| anyhow::anyhow!("fault plan: {e}"))?;
        Self::from_json(&json)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing fault plan to {path:?}"))
    }

    pub fn load(path: &Path) -> Result<FaultPlan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading fault plan from {path:?}"))?;
        Self::from_json_str(&text)
    }
}

// ---------------------------------------------------------------------
// Trainer-side fault replay (the training half of the failure domain).
// ---------------------------------------------------------------------

/// The outcome of replaying one train step through a plan's trainer-side
/// events (see [`trainer_step`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrainerStepOutcome {
    /// Pipeline-clock second at which the (possibly redone) step lands.
    pub end_secs: f64,
    /// Crash-forced redo count (`TrainerCrash` events at this iteration).
    pub retries: u64,
    /// Seconds added over the fault-free `start + base` landing time.
    pub fault_secs: f64,
}

/// Replay iteration `iter`'s train step — `base_secs` of fault-free
/// compute starting at pipeline-clock `start_secs` — through the
/// trainer-side events of `plan`, returning when it actually lands.
///
/// This is the *one* implementation of trainer-fault semantics: both
/// [`crate::iteration::TrainingDriver`] and the sweep cell pipeline call
/// it with identical `(start, base)` inputs, which is what keeps `--mode
/// async --lag 0` byte-identical to `--mode sync` under any trainer
/// plan. Pure `f64` walking, no wall clock, no RNG.
///
/// Semantics:
/// - Each [`FaultEvent::TrainerCrash`] with `at_iter == iter` costs one
///   full extra attempt (the in-flight step is lost and redone from the
///   last checkpoint); attempts run back to back.
/// - [`FaultEvent::TrainerSlowdown`] windows divide progress rate by
///   `factor` while the clock is inside `[from, until)`; overlapping
///   windows multiply.
/// - A [`FaultEvent::TrainerStall`] whose `at` falls inside a busy
///   attempt inserts `secs` of zero progress; stalls before the step
///   starts land in trainer-idle time and are absorbed free. Because
///   train steps never overlap in pipeline time (`U_k` is monotone),
///   each stall fires at most once per run.
///
/// The enclosing [`TimedFault::at`] timestamp is only the plan's sort
/// key for trainer events; timing lives in the variant fields.
pub fn trainer_step(
    plan: &FaultPlan,
    iter: usize,
    start_secs: f64,
    base_secs: f64,
) -> TrainerStepOutcome {
    let mut slowdowns: Vec<(f64, f64, f64)> = Vec::new();
    let mut stalls: Vec<(f64, f64)> = Vec::new();
    let mut retries = 0u64;
    for e in &plan.events {
        match e.event {
            FaultEvent::TrainerSlowdown { factor, from, until } => {
                slowdowns.push((from, until, factor));
            }
            FaultEvent::TrainerStall { at, secs } => stalls.push((at, secs)),
            FaultEvent::TrainerCrash { at_iter } if at_iter == iter => {
                retries += 1;
            }
            _ => {}
        }
    }
    stalls.sort_by(|a, b| a.0.total_cmp(&b.0));

    // One attempt: walk `work` fault-free seconds of compute forward
    // from `t0`, piecewise over slowdown-window boundaries and stalls.
    let walk_once = |t0: f64| -> f64 {
        let mut t = t0;
        let mut work = base_secs;
        while work > 0.0 {
            // Progress-rate divisor from the windows active at `t`.
            let mut factor = 1.0;
            for &(from, until, f) in &slowdowns {
                if from <= t && t < until {
                    factor *= f;
                }
            }
            // A stall exactly at `t` fires now (strictly-later stalls
            // are breakpoints below); the shift past it re-enters the
            // loop so overlapping windows re-price the remainder.
            if let Some(&(at, secs)) = stalls.iter().find(|&&(at, _)| at == t)
            {
                // Mark consumed by nudging past it is unnecessary: the
                // next loop iteration sees `t = at + secs > at` (or the
                // zero-length stall is a no-op either way).
                t += secs;
                if secs > 0.0 {
                    continue;
                }
            }
            // Next breakpoint: a window edge or stall strictly after `t`.
            let mut next = f64::INFINITY;
            for &(from, until, _) in &slowdowns {
                if from > t {
                    next = next.min(from);
                }
                if until > t {
                    next = next.min(until);
                }
            }
            for &(at, _) in &stalls {
                if at > t {
                    next = next.min(at);
                }
            }
            let finish = t + work * factor;
            if finish <= next {
                return finish;
            }
            work -= (next - t) / factor;
            t = next;
        }
        t
    };

    let mut t = start_secs;
    for _ in 0..=retries {
        t = walk_once(t);
    }
    TrainerStepOutcome {
        end_secs: t,
        retries,
        fault_secs: t - (start_secs + base_secs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_plan() -> FaultPlan {
        FaultPlan::new()
            .at(30.0, FaultEvent::InstanceDown { instance: InstanceId(1) })
            .at(
                10.0,
                FaultEvent::InstanceSlowdown {
                    instance: InstanceId(0),
                    factor: 2.5,
                },
            )
            .at(45.0, FaultEvent::ScaleUp { n: 2 })
            .at(50.0, FaultEvent::ScaleDown { n: 1 })
            .at(60.0, FaultEvent::InstanceRecover { instance: InstanceId(1) })
            .at(5.0, FaultEvent::RequestAbort { req: RequestId(7) })
            .at(
                20.0,
                FaultEvent::TrainerSlowdown {
                    factor: 2.0,
                    from: 20.0,
                    until: 35.0,
                },
            )
            .at(40.0, FaultEvent::TrainerStall { at: 40.0, secs: 3.0 })
            .at(1.0, FaultEvent::TrainerCrash { at_iter: 1 })
    }

    #[test]
    fn json_round_trips_every_kind() {
        let plan = sample_plan().sorted();
        let text = plan.to_json().to_string();
        let back = FaultPlan::from_json_str(&text).unwrap();
        assert_eq!(back, plan);
    }

    #[test]
    fn from_json_str_rejects_malformed_documents() {
        // The `--faults` load path (and the serve API behind it) must
        // turn every malformed document into an Err, never a panic.
        let full = sample_plan().sorted().to_json().to_string();
        // Truncated at every byte boundary.
        for cut in 1..full.len() {
            assert!(
                FaultPlan::from_json_str(&full[..cut]).is_err(),
                "truncated at {cut} parsed"
            );
        }
        // Over-deep nesting bombs fail fast in the parser.
        let deep = format!("{}1{}", "[".repeat(50_000), "]".repeat(50_000));
        let e = FaultPlan::from_json_str(&deep).unwrap_err().to_string();
        assert!(e.contains("nesting too deep"), "{e}");
        // Type confusion at every schema level.
        for bad in [
            r#"42"#,
            r#"{"events": 42}"#,
            r#"{"events": [42]}"#,
            r#"{"events": [{"kind": "instance_down"}]}"#,
            r#"{"events": [{"at_secs": "soon", "kind": "scale_up", "n": 1}]}"#,
            r#"{"events": [{"at_secs": -1, "kind": "scale_up", "n": 1}]}"#,
            r#"{"events": [{"at_secs": 1, "kind": "warp", "n": 1}]}"#,
            r#"{"events": [{"at_secs": 1, "kind": "instance_slowdown", "instance": 0, "factor": "fast"}]}"#,
            r#"{"events": [{"at_secs": 1, "kind": "trainer_slowdown", "factor": 2.0, "from": 1}]}"#,
            r#"{"events": [{"at_secs": 1, "kind": "trainer_slowdown", "factor": 2.0, "from": 5, "until": 1}]}"#,
            r#"{"events": [{"at_secs": 1, "kind": "trainer_stall", "at": 1}]}"#,
            r#"{"events": [{"at_secs": 1, "kind": "trainer_stall", "at": 1, "secs": -2}]}"#,
            r#"{"events": [{"at_secs": 1, "kind": "trainer_crash"}]}"#,
        ] {
            assert!(FaultPlan::from_json_str(bad).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn sorted_orders_by_time_stably() {
        let plan = sample_plan().sorted();
        let times: Vec<u64> =
            plan.events.iter().map(|e| e.at.as_micros()).collect();
        let mut expect = times.clone();
        expect.sort();
        assert_eq!(times, expect);
        // Same-timestamp events keep authored order.
        let twin = FaultPlan::new()
            .at(1.0, FaultEvent::ScaleUp { n: 1 })
            .at(1.0, FaultEvent::ScaleDown { n: 1 })
            .sorted();
        assert!(matches!(twin.events[0].event, FaultEvent::ScaleUp { .. }));
    }

    #[test]
    fn validate_rejects_bad_params() {
        let bad = FaultPlan::new().at(
            1.0,
            FaultEvent::InstanceSlowdown {
                instance: InstanceId(0),
                factor: 0.0,
            },
        );
        assert!(bad.validate().is_err());
        let bad = FaultPlan::new().at(1.0, FaultEvent::ScaleUp { n: 0 });
        assert!(bad.validate().is_err());
        let bad = FaultPlan::new().at(
            1.0,
            FaultEvent::TrainerSlowdown {
                factor: 2.0,
                from: 10.0,
                until: 5.0,
            },
        );
        assert!(bad.validate().is_err());
        let bad = FaultPlan::new()
            .at(1.0, FaultEvent::TrainerStall { at: 1.0, secs: -1.0 });
        assert!(bad.validate().is_err());
        assert!(sample_plan().validate().is_ok());
    }

    #[test]
    fn partition_splits_trainer_from_cluster_events() {
        let plan = sample_plan().sorted();
        let (cluster, trainer) = plan.partition();
        assert_eq!(cluster.len() + trainer.len(), plan.len());
        assert_eq!(trainer.len(), 3);
        assert!(trainer.events.iter().all(|e| e.event.is_trainer()));
        assert!(cluster.events.iter().all(|e| !e.event.is_trainer()));
        // Partition preserves each half's relative (sorted) order.
        for half in [&cluster, &trainer] {
            let times: Vec<u64> =
                half.events.iter().map(|e| e.at.as_micros()).collect();
            let mut expect = times.clone();
            expect.sort();
            assert_eq!(times, expect);
        }
    }

    #[test]
    fn random_trainer_is_deterministic_and_trainer_only() {
        let a = FaultPlan::random_trainer(7, 4, 300.0);
        let b = FaultPlan::random_trainer(7, 4, 300.0);
        assert_eq!(a, b);
        assert!(a.events.iter().all(|e| e.event.is_trainer()));
        a.validate().unwrap();
        // Crash iterations stay inside the run.
        for e in &a.events {
            if let FaultEvent::TrainerCrash { at_iter } = e.event {
                assert!(at_iter < 4);
            }
        }
        let c = FaultPlan::random_trainer(8, 4, 300.0);
        let d = FaultPlan::random_trainer(9, 4, 300.0);
        assert!(a != c || a != d);
    }

    #[test]
    fn trainer_step_is_identity_without_trainer_events() {
        let plan = FaultPlan::new()
            .at(1.0, FaultEvent::ScaleUp { n: 1 })
            .sorted();
        let out = trainer_step(&plan, 0, 10.0, 5.0);
        assert_eq!(out.end_secs, 15.0);
        assert_eq!(out.retries, 0);
        assert_eq!(out.fault_secs, 0.0);
    }

    #[test]
    fn trainer_step_applies_slowdown_stall_and_crash_exactly() {
        // Slowdown 2x over [12, 14): step [10, 15) fault-free becomes
        // 10→12 (2s work) + 2s wall for 1s work + 2s remaining = 16.
        let slow = FaultPlan::new().at(
            12.0,
            FaultEvent::TrainerSlowdown {
                factor: 2.0,
                from: 12.0,
                until: 14.0,
            },
        );
        let out = trainer_step(&slow, 0, 10.0, 5.0);
        assert_eq!(out.end_secs, 16.0);
        assert_eq!(out.fault_secs, 1.0);

        // A stall inside the busy window inserts its full length...
        let stall = FaultPlan::new()
            .at(12.0, FaultEvent::TrainerStall { at: 12.0, secs: 3.0 });
        let out = trainer_step(&stall, 0, 10.0, 5.0);
        assert_eq!(out.end_secs, 18.0);
        // ...but a stall in idle time (before the step starts) is free.
        let idle = FaultPlan::new()
            .at(2.0, FaultEvent::TrainerStall { at: 2.0, secs: 3.0 });
        let out = trainer_step(&idle, 0, 10.0, 5.0);
        assert_eq!(out.end_secs, 15.0);
        assert_eq!(out.fault_secs, 0.0);

        // One crash at this iteration = one full redo, back to back.
        let crash =
            FaultPlan::new().at(0.0, FaultEvent::TrainerCrash { at_iter: 2 });
        let out = trainer_step(&crash, 2, 10.0, 5.0);
        assert_eq!(out.end_secs, 20.0);
        assert_eq!(out.retries, 1);
        assert_eq!(out.fault_secs, 5.0);
        // Other iterations are untouched by that crash.
        let out = trainer_step(&crash, 1, 10.0, 5.0);
        assert_eq!(out.end_secs, 15.0);
        assert_eq!(out.retries, 0);
    }

    #[test]
    fn trainer_step_is_deterministic_and_monotone() {
        let plan = FaultPlan::random_trainer(3, 6, 200.0);
        let a = trainer_step(&plan, 1, 30.0, 12.0);
        let b = trainer_step(&plan, 1, 30.0, 12.0);
        assert_eq!(a, b);
        // Faults only ever delay the landing.
        assert!(a.end_secs >= 42.0);
        assert!(a.fault_secs >= 0.0);
    }

    #[test]
    fn from_json_rejects_garbage() {
        assert!(FaultPlan::from_json_str("{}").is_err());
        assert!(FaultPlan::from_json_str(
            r#"{"events":[{"at_secs":1,"kind":"nope"}]}"#
        )
        .is_err());
        assert!(FaultPlan::from_json_str(
            r#"{"events":[{"at_secs":-1,"kind":"scale_up","n":1}]}"#
        )
        .is_err());
        assert!(FaultPlan::from_json_str(
            r#"{"events":[{"at_secs":1,"kind":"instance_down"}]}"#
        )
        .is_err());
    }

    #[test]
    fn random_is_deterministic_and_sorted() {
        let a = FaultPlan::random(9, 4, 64, 100.0);
        let b = FaultPlan::random(9, 4, 64, 100.0);
        assert_eq!(a, b);
        let times: Vec<u64> = a.events.iter().map(|e| e.at.as_micros()).collect();
        let mut expect = times.clone();
        expect.sort();
        assert_eq!(times, expect);
        // Never crashes instance 0 (the generator's liveness guarantee).
        for e in &a.events {
            if let FaultEvent::InstanceDown { instance } = e.event {
                assert_ne!(instance, InstanceId(0));
            }
        }
        // Different seeds give different plans (overwhelmingly likely
        // across this many draws).
        let c = FaultPlan::random(10, 4, 64, 100.0);
        let d = FaultPlan::random(11, 4, 64, 100.0);
        assert!(a != c || a != d);
    }

    #[test]
    fn save_load_round_trip() {
        let plan = sample_plan().sorted();
        let path = std::env::temp_dir()
            .join(format!("seer_fault_plan_{}.json", std::process::id()));
        plan.save(&path).unwrap();
        let back = FaultPlan::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(back, plan);
    }
}
