//! Event queue: a binary heap keyed by (time, sequence number) so that
//! same-timestamp events pop in insertion order — required for the
//! determinism invariant.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::clock::SimTime;

/// An event scheduled at `time`, carrying a payload `E`.
#[derive(Debug, Clone)]
pub struct ScheduledEvent<E> {
    pub time: SimTime,
    pub seq: u64,
    pub payload: E,
}

impl<E> PartialEq for ScheduledEvent<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for ScheduledEvent<E> {}

impl<E> Ord for ScheduledEvent<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap behaviour inside BinaryHeap (a max-heap).
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<E> PartialOrd for ScheduledEvent<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-ordered event queue over payload type `E`.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<ScheduledEvent<E>>,
    next_seq: u64,
    now: SimTime,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            next_seq: 0,
            now: SimTime::ZERO,
        }
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `payload` at absolute time `at` (must not be in the past).
    pub fn schedule_at(&mut self, at: SimTime, payload: E) {
        debug_assert!(
            at >= self.now,
            "scheduling into the past: {at:?} < {:?}",
            self.now
        );
        let ev = ScheduledEvent {
            time: at,
            seq: self.next_seq,
            payload,
        };
        self.next_seq += 1;
        self.heap.push(ev);
    }

    /// Schedule `payload` after a relative delay.
    pub fn schedule_in(&mut self, delay: SimTime, payload: E) {
        self.schedule_at(self.now + delay, payload);
    }

    /// Pop the next event, advancing `now` to its timestamp.
    pub fn pop(&mut self) -> Option<ScheduledEvent<E>> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.time >= self.now);
        self.now = ev.time;
        Some(ev)
    }

    /// Peek at the next event time without popping.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime::from_micros(30), "c");
        q.schedule_at(SimTime::from_micros(10), "a");
        q.schedule_at(SimTime::from_micros(20), "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop())
            .map(|e| e.payload)
            .collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn fifo_within_same_timestamp() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule_at(SimTime::from_micros(5), i);
        }
        let order: Vec<_> = std::iter::from_fn(|| q.pop())
            .map(|e| e.payload)
            .collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn now_advances() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime::from_micros(100), ());
        assert_eq!(q.now(), SimTime::ZERO);
        q.pop();
        assert_eq!(q.now(), SimTime::from_micros(100));
        q.schedule_in(SimTime::from_micros(50), ());
        q.pop();
        assert_eq!(q.now(), SimTime::from_micros(150));
    }

    #[test]
    fn peek_does_not_advance() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime::from_micros(42), 1);
        assert_eq!(q.peek_time(), Some(SimTime::from_micros(42)));
        assert_eq!(q.now(), SimTime::ZERO);
        assert_eq!(q.len(), 1);
    }
}
