//! Virtual time. Microsecond integer ticks: totally ordered, hashable, and
//! immune to float-accumulation drift across millions of events.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in virtual time, in integer microseconds since simulation start.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);
    /// A time later than any event a rollout can produce; used as the
    /// "no deadline" sentinel.
    pub const FAR_FUTURE: SimTime = SimTime(u64::MAX / 4);

    pub fn from_secs_f64(s: f64) -> Self {
        debug_assert!(s >= 0.0 && s.is_finite(), "bad duration {s}");
        SimTime((s * 1e6).round() as u64)
    }

    pub fn from_micros(us: u64) -> Self {
        SimTime(us)
    }

    pub fn from_millis(ms: u64) -> Self {
        SimTime(ms * 1_000)
    }

    pub fn from_secs(s: u64) -> Self {
        SimTime(s * 1_000_000)
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }

    pub fn as_micros(self) -> u64 {
        self.0
    }

    pub fn saturating_sub(self, other: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(other.0))
    }

    pub fn min(self, other: SimTime) -> SimTime {
        SimTime(self.0.min(other.0))
    }

    pub fn max(self, other: SimTime) -> SimTime {
        SimTime(self.0.max(other.0))
    }
}

impl Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        debug_assert!(self.0 >= rhs.0, "time underflow {self:?} - {rhs:?}");
        SimTime(self.0 - rhs.0)
    }
}

impl fmt::Debug for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.6}s", self.as_secs_f64())
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.3}s", self.as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_secs() {
        let t = SimTime::from_secs_f64(1.5);
        assert_eq!(t.as_micros(), 1_500_000);
        assert!((t.as_secs_f64() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn ordering_and_arith() {
        let a = SimTime::from_millis(10);
        let b = SimTime::from_millis(25);
        assert!(a < b);
        assert_eq!((b - a).as_micros(), 15_000);
        assert_eq!((a + b).as_micros(), 35_000);
        assert_eq!(a.saturating_sub(b), SimTime::ZERO);
    }

    #[test]
    fn min_max() {
        let a = SimTime::from_micros(3);
        let b = SimTime::from_micros(7);
        assert_eq!(a.min(b), a);
        assert_eq!(a.max(b), b);
    }
}
