//! Deterministic discrete-event simulation core.
//!
//! Every cluster-scale experiment in this repo runs on this substrate: the
//! coordinator and engine code under test is the production code, and this
//! module only supplies virtual time, an event queue and a seeded RNG so
//! that runs are exactly reproducible (same seed ⇒ same event trace, an
//! invariant checked by `rust/tests/invariants.rs`).

pub mod clock;
pub mod events;
pub mod rng;

pub use clock::SimTime;
pub use events::{EventQueue, ScheduledEvent};
pub use rng::Rng;
