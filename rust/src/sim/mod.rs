//! Deterministic discrete-event simulation core.
//!
//! Every cluster-scale experiment in this repo runs on this substrate: the
//! coordinator and engine code under test is the production code, and this
//! module only supplies virtual time, an event queue, a seeded RNG and
//! deterministic fault scripts ([`faults::FaultPlan`]) so that runs are
//! exactly reproducible (same seed + same fault plan ⇒ same event trace,
//! an invariant checked by `rust/tests/invariants.rs` and
//! `rust/tests/faults.rs`).

pub mod clock;
pub mod events;
pub mod faults;
pub mod rng;

pub use clock::SimTime;
pub use events::{EventQueue, ScheduledEvent};
pub use faults::{FaultEvent, FaultPlan, TimedFault};
pub use rng::Rng;
