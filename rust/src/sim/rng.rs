//! Deterministic RNG + the distributions the workload generators need.
//!
//! splitmix64 seeding into xoshiro256++ — no external `rand` crate exists
//! in this offline environment, and determinism across platforms matters
//! more than cryptographic quality here. All distribution sampling is
//! implemented from first principles (Box–Muller, inverse-CDF, alias-free
//! categorical) and unit-tested against analytic moments.

/// xoshiro256++ with splitmix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box–Muller.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-group / per-request RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift; bias is negligible for our n.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid ln(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal with the *underlying* normal's mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "categorical with zero total weight");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample from a softmax over `logits` with temperature `temp`.
    /// Used by the real-model rollout path for token sampling.
    pub fn sample_softmax(&mut self, logits: &[f32], temp: f64) -> usize {
        debug_assert!(!logits.is_empty());
        if temp <= 1e-6 {
            // Greedy.
            return logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
        }
        let inv_t = 1.0 / temp;
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let mut cum = Vec::with_capacity(logits.len());
        let mut total = 0.0f64;
        for &l in logits {
            total += ((l as f64 - m) * inv_t).exp();
            cum.push(total);
        }
        let x = self.f64() * total;
        match cum.binary_search_by(|c| c.partial_cmp(&x).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(logits.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_moments() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(8);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::new(9);
        let mut xs: Vec<f64> = (0..50_001).map(|_| r.lognormal(2.0, 0.7)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[25_000];
        // Median of lognormal = e^mu.
        assert!((median - 2.0f64.exp()).abs() / 2.0f64.exp() < 0.05);
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(10);
        let n = 100_000;
        let mean: f64 =
            (0..n).map(|_| r.exponential(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(12);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.25, "ratio {ratio}");
    }

    #[test]
    fn sample_softmax_greedy_and_spread() {
        let mut r = Rng::new(13);
        let logits = [0.0f32, 5.0, 1.0];
        assert_eq!(r.sample_softmax(&logits, 0.0), 1);
        let mut hit1 = 0;
        for _ in 0..1000 {
            if r.sample_softmax(&logits, 1.0) == 1 {
                hit1 += 1;
            }
        }
        assert!(hit1 > 900, "peaked distribution should dominate: {hit1}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(14);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(5);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let xa: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xa, xb);
    }
}
