//! Figure 2: distribution of output lengths during rollout across the
//! three reasoning tasks — rendered as per-task histograms plus summary
//! percentiles.

use crate::config::ALL_PRESETS;
use crate::util::stats::{Histogram, Summary};
use crate::workload::generate_iteration;

use super::common::Scale;

pub fn run(scale: &Scale) -> anyhow::Result<()> {
    for preset in ALL_PRESETS {
        let cfg = scale.workload(preset);
        let w = generate_iteration(&cfg, scale.seed);
        let mut s = Summary::new();
        let mut h = Histogram::new(0.0, cfg.max_gen_len as f64, 24);
        for r in w.requests() {
            s.add(r.gen_len as f64);
            h.add(r.gen_len as f64);
        }
        println!(
            "\n# Figure 2 — {} (n={} requests, scale={})",
            cfg.name,
            s.len(),
            if scale.fast { "fast" } else { "full" }
        );
        println!(
            "mean {:.0}  p50 {:.0}  p90 {:.0}  p99 {:.0}  max {:.0}",
            s.mean(),
            s.percentile(50.0),
            s.percentile(90.0),
            s.percentile(99.0),
            s.max()
        );
        print!("{}", h.render(48));
    }
    println!(
        "\nshape check: all three tasks span two-plus orders of magnitude \
         with a pronounced right tail (paper Fig. 2)."
    );
    Ok(())
}
