//! Table 4: improvement breakdown — cumulative speedup from divided
//! rollout, context-aware scheduling, and grouped speculative decoding.

use crate::config::ALL_PRESETS;
use crate::spec::simmodel::SdStrategy;
use crate::util::table::{fmt_x, Table};

use super::common::{mean_throughput, Scale};

pub fn run(scale: &Scale) -> anyhow::Result<()> {
    // (label, registry scheduler name, SD strategy), cumulative.
    let stages: Vec<(&str, &str, SdStrategy)> = vec![
        ("Baseline (veRL)", "verl", SdStrategy::None),
        ("+ Divided Rollout", "no-context", SdStrategy::None),
        ("+ Context Sched.", "seer", SdStrategy::None),
        ("+ Grouped SD", "seer", SdStrategy::GroupedCst),
    ];
    let mut t = Table::new(
        "Table 4: Performance improvement breakdown (cumulative)",
        &["Method", "Moonlight", "Qwen2-VL-72B", "Kimi-K2"],
    );
    let mut base = [0.0f64; 3];
    for (label, sched, sd) in stages {
        let mut cells = vec![label.to_string()];
        for (pi, preset) in ALL_PRESETS.iter().enumerate() {
            let tp = mean_throughput(scale, *preset, sched, sd);
            if base[pi] == 0.0 {
                base[pi] = tp;
            }
            cells.push(fmt_x(tp / base[pi]));
        }
        t.row(&cells);
    }
    t.note("paper: divided 1.16-1.42x, +context 1.27-1.56x, +SD 1.53-2.04x");
    t.print();
    Ok(())
}
