//! Figure 7: end-to-end rollout throughput of RL systems across tasks and
//! group sizes — veRL, veRL+vanilla-SD, StreamRL-Oracle, and SEER.

use crate::config::{TaskPreset, ALL_PRESETS};
use crate::engine::cluster::run_rollout;
use crate::scheduler::{
    ContextMode, Scheduler, SeerScheduler, StreamRlOracle, VerlScheduler,
};
use crate::spec::simmodel::SdStrategy;
use crate::util::table::{fmt_x, Table};

use super::common::Scale;

/// The paper's per-task vanilla SD baseline (§4.1).
pub fn vanilla_sd_for(preset: TaskPreset) -> SdStrategy {
    match preset {
        TaskPreset::Moonlight => SdStrategy::SuffixDecoding,
        TaskPreset::Qwen2Vl72b => SdStrategy::DraftModel,
        TaskPreset::KimiK2 => SdStrategy::Mtp,
    }
}

pub fn systems(preset: TaskPreset) -> Vec<(&'static str, fn() -> Box<dyn Scheduler>, SdStrategy)> {
    let vanilla = vanilla_sd_for(preset);
    vec![
        ("veRL", (|| Box::new(VerlScheduler::new()) as Box<dyn Scheduler>) as fn() -> _, SdStrategy::None),
        ("veRL+SD", || Box::new(VerlScheduler::new()), vanilla),
        ("StreamRL-Oracle", || Box::new(StreamRlOracle::new()), SdStrategy::None),
        ("SEER", || Box::new(SeerScheduler::new(ContextMode::Learned)), SdStrategy::GroupedCst),
    ]
}

pub fn run(scale: &Scale) -> anyhow::Result<()> {
    for preset in ALL_PRESETS {
        let base = scale.workload(preset);
        let group_sizes: &[usize] = &[8, 16];
        let mut t = Table::new(
            &format!("Figure 7 — rollout throughput, {}", base.name),
            &["System", "G=8 tok/s", "G=8 vs veRL", "G=16 tok/s", "G=16 vs veRL"],
        );
        let mut rows: Vec<Vec<String>> = vec![];
        let mut base_tp = [0.0f64; 2];
        for (name, mk, sd) in systems(preset) {
            let mut cells = vec![name.to_string()];
            for (gi, &g) in group_sizes.iter().enumerate() {
                let cfg = base.with_group_size(g);
                let sys = scale.sys(&cfg);
                let mut tp = 0.0;
                for i in 0..scale.iters {
                    let out = run_rollout(&cfg, &sys, mk(), sd, scale.seed + i as u64);
                    tp += out.metrics.throughput();
                }
                tp /= scale.iters as f64;
                if name == "veRL" {
                    base_tp[gi] = tp;
                }
                cells.push(format!("{tp:.0}"));
                cells.push(fmt_x(tp / base_tp[gi].max(1e-9)));
            }
            rows.push(cells);
        }
        for r in &rows {
            t.row(r);
        }
        t.note("paper: SEER gains 44-104% over veRL; StreamRL-Oracle can lose to veRL on kimi-k2");
        t.print();
    }
    Ok(())
}
