//! Figure 7: end-to-end rollout throughput of RL systems across tasks and
//! group sizes — veRL, veRL+vanilla-SD, StreamRL-Oracle, and SEER.

use crate::config::{TaskPreset, ALL_PRESETS};
use crate::rollout::RolloutSession;
use crate::spec::simmodel::SdStrategy;
use crate::util::table::{fmt_x, Table};

use super::common::Scale;

/// The paper's per-task vanilla SD baseline (§4.1).
pub fn vanilla_sd_for(preset: TaskPreset) -> SdStrategy {
    match preset {
        TaskPreset::Moonlight => SdStrategy::SuffixDecoding,
        TaskPreset::Qwen2Vl72b => SdStrategy::DraftModel,
        TaskPreset::KimiK2 => SdStrategy::Mtp,
    }
}

/// The Figure 7 system matrix: (label, registry scheduler name, SD).
pub fn systems(
    preset: TaskPreset,
) -> Vec<(&'static str, &'static str, SdStrategy)> {
    let vanilla = vanilla_sd_for(preset);
    vec![
        ("veRL", "verl", SdStrategy::None),
        ("veRL+SD", "verl", vanilla),
        ("StreamRL-Oracle", "streamrl", SdStrategy::None),
        ("SEER", "seer", SdStrategy::GroupedCst),
    ]
}

pub fn run(scale: &Scale) -> anyhow::Result<()> {
    for preset in ALL_PRESETS {
        let base = scale.workload(preset);
        let group_sizes: &[usize] = &[8, 16];
        let mut t = Table::new(
            &format!("Figure 7 — rollout throughput, {}", base.name),
            &["System", "G=8 tok/s", "G=8 vs veRL", "G=16 tok/s", "G=16 vs veRL"],
        );
        let mut rows: Vec<Vec<String>> = vec![];
        let mut base_tp = [0.0f64; 2];
        for (name, sched, sd) in systems(preset) {
            let mut cells = vec![name.to_string()];
            for (gi, &g) in group_sizes.iter().enumerate() {
                let cfg = base.with_group_size(g);
                let sys = scale.sys(&cfg);
                let mut tp = 0.0;
                for i in 0..scale.iters {
                    let report = RolloutSession::builder()
                        .workload(cfg.clone())
                        .system(sys.clone())
                        .scheduler(sched)
                        .sd_strategy(sd)
                        .seed(scale.seed + i as u64)
                        .run()?;
                    tp += report.metrics.throughput();
                }
                tp /= scale.iters as f64;
                if name == "veRL" {
                    base_tp[gi] = tp;
                }
                cells.push(format!("{tp:.0}"));
                cells.push(fmt_x(tp / base_tp[gi].max(1e-9)));
            }
            rows.push(cells);
        }
        for r in &rows {
            t.row(r);
        }
        t.note("paper: SEER gains 44-104% over veRL; StreamRL-Oracle can lose to veRL on kimi-k2");
        t.print();
    }
    Ok(())
}
