//! Figure 7: end-to-end rollout throughput of RL systems across tasks and
//! group sizes — veRL, veRL+vanilla-SD, StreamRL-Oracle, SEER, and the
//! RollPacker tail-packing policy, plus paired speedup/tail-reduction
//! statistics for RollPacker against every other system (through the
//! shared script in [`super::common::print_paired_vs`]).
//!
//! The measurement grid (system × group size × seed) fans out through
//! the parallel [`crate::sweep::SweepRunner`]; results are order-restored
//! before averaging, so the table is identical at any thread count.

use crate::config::{TaskPreset, ALL_PRESETS};
use crate::rollout::RolloutSession;
use crate::spec::simmodel::SdStrategy;
use crate::util::table::{fmt_x, Table};

use super::common::{print_paired_vs, runner, PairedRow, Scale};

/// The paper's per-task vanilla SD baseline (§4.1).
pub fn vanilla_sd_for(preset: TaskPreset) -> SdStrategy {
    match preset {
        TaskPreset::Moonlight => SdStrategy::SuffixDecoding,
        TaskPreset::Qwen2Vl72b => SdStrategy::DraftModel,
        TaskPreset::KimiK2 => SdStrategy::Mtp,
    }
}

/// The Figure 7 system matrix: (label, registry scheduler name, SD).
pub fn systems(
    preset: TaskPreset,
) -> Vec<(&'static str, &'static str, SdStrategy)> {
    let vanilla = vanilla_sd_for(preset);
    vec![
        ("veRL", "verl", SdStrategy::None),
        ("veRL+SD", "verl", vanilla),
        ("StreamRL-Oracle", "streamrl", SdStrategy::None),
        ("SEER", "seer", SdStrategy::GroupedCst),
        ("RollPacker", "rollpacker", SdStrategy::GroupedCst),
    ]
}

pub fn run(scale: &Scale) -> anyhow::Result<()> {
    let runner = runner();
    for preset in ALL_PRESETS {
        let base = scale.workload(preset);
        let group_sizes: &[usize] = &[8, 16];
        let systems = systems(preset);
        // Flatten the measurement grid; each item is one rollout.
        let mut items: Vec<(usize, usize, &str, SdStrategy, usize, u64)> =
            Vec::new();
        for (si, &(_, sched, sd)) in systems.iter().enumerate() {
            for (gi, &g) in group_sizes.iter().enumerate() {
                for i in 0..scale.iters {
                    items.push((si, gi, sched, sd, g, scale.seed + i as u64));
                }
            }
        }
        let tps = runner.try_map(&items, |_, &(_, _, sched, sd, g, seed)| {
            let cfg = base.with_group_size(g);
            let sys = scale.sys(&cfg);
            let report = RolloutSession::builder()
                .workload(cfg)
                .system(sys)
                .scheduler(sched)
                .sd_strategy(sd)
                .seed(seed)
                .run()?;
            let m = &report.metrics;
            Ok((
                m.throughput(),
                m.makespan.as_secs_f64(),
                m.tail_time(0.10).as_secs_f64(),
            ))
        })?;
        let mean_tp = |si: usize, gi: usize| {
            let vals: Vec<f64> = items
                .iter()
                .zip(&tps)
                .filter(|((s, g, ..), _)| *s == si && *g == gi)
                .map(|(_, &(tp, _, _))| tp)
                .collect();
            vals.iter().sum::<f64>() / vals.len() as f64
        };
        let mut t = Table::new(
            &format!("Figure 7 — rollout throughput, {}", base.name),
            &["System", "G=8 tok/s", "G=8 vs veRL", "G=16 tok/s", "G=16 vs veRL"],
        );
        let mut base_tp = [0.0f64; 2];
        for (si, (name, _, _)) in systems.iter().enumerate() {
            let mut cells = vec![name.to_string()];
            for gi in 0..group_sizes.len() {
                let tp = mean_tp(si, gi);
                if si == 0 {
                    base_tp[gi] = tp;
                }
                cells.push(format!("{tp:.0}"));
                cells.push(fmt_x(tp / base_tp[gi].max(1e-9)));
            }
            t.row(&cells);
        }
        t.note("paper: SEER gains 44-104% over veRL; StreamRL-Oracle can lose to veRL on kimi-k2");
        t.print();
        // Paired statistics for the tail-packing policy vs every other
        // system, over the aligned (group-size, seed) observations
        // (shared script — common.rs).
        let rows: Vec<PairedRow> = systems
            .iter()
            .enumerate()
            .map(|(si, (label, _, _))| {
                let mine: Vec<&(f64, f64, f64)> = items
                    .iter()
                    .zip(&tps)
                    .filter(|((s, ..), _)| *s == si)
                    .map(|(_, v)| v)
                    .collect();
                PairedRow {
                    label: label.to_string(),
                    makespans: mine.iter().map(|v| v.1).collect(),
                    tails: mine.iter().map(|v| v.2).collect(),
                }
            })
            .collect();
        print_paired_vs(
            &format!("fig7 {}", base.name),
            "RollPacker",
            &rows,
            scale.seed,
        );
    }
    Ok(())
}
