//! Figure 9: the same utilization series as Figure 3, with SEER — the
//! preemption storm disappears and the tail compresses.

use crate::config::TaskPreset;
use crate::spec::simmodel::SdStrategy;

use super::common::{measure, Scale};
use super::fig3_baseline_util::print_utilization_series;

pub fn run(scale: &Scale) -> anyhow::Result<()> {
    let res = measure(
        scale,
        TaskPreset::Qwen2Vl72b,
        "seer",
        "seer",
        SdStrategy::GroupedCst,
    );
    print_utilization_series("Figure 9 (SEER, Qwen2-VL)", &res.report.metrics);
    println!(
        "preemption events: {}   migrations: {}   migrated GiB: {:.1}",
        res.report.metrics.preemptions,
        res.report.metrics.migrations,
        res.report.metrics.migrated_bytes as f64 / (1u64 << 30) as f64,
    );
    let tail = res.report.metrics.tail_time(0.10);
    let total = res.report.metrics.makespan;
    println!(
        "long-tail (last 10%): {:.0}s of {:.0}s total ({:.0}%)",
        tail.as_secs_f64(),
        total.as_secs_f64(),
        100.0 * tail.as_secs_f64() / total.as_secs_f64().max(1e-9)
    );
    Ok(())
}
