//! Figure 3: KVCache utilization, running request count, and preemptions
//! during a baseline (veRL) rollout of the Qwen2-VL-72B task — the
//! motivating pathology: early-phase preemption storms, late-phase
//! long-tail idleness.

use crate::config::TaskPreset;
use crate::spec::simmodel::SdStrategy;

use super::common::{measure, Scale};

pub fn run(scale: &Scale) -> anyhow::Result<()> {
    let res = measure(
        scale,
        TaskPreset::Qwen2Vl72b,
        "verl",
        "verl",
        SdStrategy::None,
    );
    print_utilization_series(
        "Figure 3 (veRL baseline, Qwen2-VL)",
        &res.report.metrics,
    );
    println!(
        "preemption events: {}   re-prefilled tokens: {}",
        res.report.metrics.preemptions, res.report.metrics.re_prefill_tokens
    );
    let tail = res.report.metrics.tail_time(0.10);
    let total = res.report.metrics.makespan;
    println!(
        "long-tail (last 10% of requests): {:.0}s of {:.0}s total ({:.0}%)",
        tail.as_secs_f64(),
        total.as_secs_f64(),
        100.0 * tail.as_secs_f64() / total.as_secs_f64().max(1e-9)
    );
    Ok(())
}

/// Shared with Figure 9: render the KV-utilization + running-request
/// time series, averaged across instances, in ~30 buckets.
pub fn print_utilization_series(
    title: &str,
    m: &crate::metrics::RolloutMetrics,
) {
    println!("\n# {title}");
    if m.load_samples.is_empty() {
        println!("(no load samples — rollout too short for the sample interval)");
        return;
    }
    let end = m.makespan.as_secs_f64().max(1e-9);
    const BUCKETS: usize = 30;
    let mut util = vec![(0.0f64, 0usize); BUCKETS];
    let mut running = vec![(0.0f64, 0usize); BUCKETS];
    for s in &m.load_samples {
        let b = ((s.t.as_secs_f64() / end) * BUCKETS as f64) as usize;
        let b = b.min(BUCKETS - 1);
        util[b].0 += s.kv_utilization;
        util[b].1 += 1;
        running[b].0 += s.running as f64;
        running[b].1 += 1;
    }
    println!("{:>8} {:>10} {:>12}", "t", "kv-util", "running/inst");
    for b in 0..BUCKETS {
        if util[b].1 == 0 {
            continue;
        }
        let t = end * (b as f64 + 0.5) / BUCKETS as f64;
        let u = util[b].0 / util[b].1 as f64;
        let r = running[b].0 / running[b].1 as f64;
        let bar = "#".repeat((u * 32.0) as usize);
        println!("{t:>7.0}s {u:>9.2} {r:>12.1}  |{bar}");
    }
}
