//! Table 2: mean acceptance length of n-gram speculative decoding with
//! grouped pattern references — measured on the *real* CST (not the sim
//! profile): we generate group-correlated token streams, build the CST
//! from n sibling reference streams plus the target's own history, replay
//! the target stream, and count accepted draft tokens per step.

use crate::spec::cst::Cst;
use crate::spec::multipath::speculate_multipath;
use crate::util::table::Table;
use crate::workload::tokens::{GroupTokenGen, TokenGenConfig};

use super::common::Scale;

/// Accepted tokens for one draft vs the true continuation.
fn accepted(draft: &[u32], truth: &[u32]) -> usize {
    draft
        .iter()
        .zip(truth)
        .take_while(|(d, t)| d == t)
        .count()
}

/// Replay speculation over a target stream. Returns the mean acceptance
/// length including the bonus token (paper's metric).
pub fn replay(
    refs: &[Vec<u32>],
    target: &[u32],
    gamma: usize,
    top_k: usize,
) -> f64 {
    let mut cst = Cst::new();
    for (i, r) in refs.iter().enumerate() {
        cst.append(i as u64 + 1, 0, r);
    }
    let own: u64 = 0;
    let mut pos = 16usize.min(target.len());
    cst.append(own, 0, &target[..pos]);
    let mut total = 0usize;
    let mut steps = 0usize;
    while pos + 1 < target.len() {
        let pattern_start = pos.saturating_sub(24);
        let pattern = &target[pattern_start..pos];
        let acc = if top_k <= 1 {
            let draft = cst.speculate(pattern, gamma, 24, 2);
            accepted(&draft, &target[pos..])
        } else {
            speculate_multipath(&cst, pattern, gamma, 24, 2, top_k, 0.0)
                .iter()
                .map(|p| accepted(&p.tokens, &target[pos..]))
                .max()
                .unwrap_or(0)
        };
        // Advance by accepted drafts + the bonus token.
        let advance = (acc + 1).min(target.len() - pos);
        cst.append(own, pos, &target[pos..pos + advance]);
        pos += advance;
        total += advance;
        steps += 1;
    }
    total as f64 / steps.max(1) as f64
}

pub fn run(scale: &Scale) -> anyhow::Result<()> {
    let n_groups = if scale.fast { 8 } else { 20 };
    let resp_len = if scale.fast { 1200 } else { 4000 };
    let gamma = 16;
    let ref_counts = [0usize, 1, 5, 15];
    let modes = [("Linear", 1usize), ("Multi-Path (k=2)", 2), ("Multi-Path (k=4)", 4)];

    let mut t = Table::new(
        "Table 2: mean acceptance length vs grouped references",
        &["Ref. Count", "Linear", "Multi-Path (k=2)", "Multi-Path (k=4)"],
    );
    for &n in &ref_counts {
        let mut row = vec![format!("n = {n}")];
        for (_, k) in modes {
            let mut total = 0.0;
            for g in 0..n_groups {
                let gen = GroupTokenGen::new(
                    TokenGenConfig::default(),
                    scale.seed ^ (g as u64) << 8,
                );
                let target = gen.response(0, resp_len, scale.seed + g as u64);
                let refs: Vec<Vec<u32>> = (0..n)
                    .map(|i| gen.response(i + 1, resp_len, scale.seed ^ 0xB0B + i as u64))
                    .collect();
                total += replay(&refs, &target, gamma, k);
            }
            row.push(format!("{:.2}", total / n_groups as f64));
        }
        t.row(&row);
    }
    t.note("paper: 1.70/1.77/1.85 at n=0 rising to 2.53/2.69/2.85 at n=15 — acceptance grows with grouped references and multi-path drafting");
    t.print();
    Ok(())
}
