//! Table 3: model configurations and RL workload characteristics (the
//! preset definitions themselves — printed for completeness and checked
//! against the paper's numbers by the preset tests).

use crate::config::ALL_PRESETS;
use crate::util::table::Table;

pub fn run() -> anyhow::Result<()> {
    let mut t = Table::new(
        "Table 3: Model configurations and RL workload characteristics",
        &[
            "Metric",
            "Moonlight",
            "Qwen2-VL-72B",
            "Kimi-K2",
        ],
    );
    let w: Vec<_> = ALL_PRESETS.iter().map(|p| p.workload()).collect();
    let row = |name: &str, f: &dyn Fn(usize) -> String| {
        vec![name.to_string(), f(0), f(1), f(2)]
    };
    t.row(&row("Total GPUs", &|i| {
        (w[i].n_instances * w[i].gpus_per_instance).to_string()
    }));
    t.row(&row("GPUs per Instance", &|i| {
        w[i].gpus_per_instance.to_string()
    }));
    t.row(&row("Reqs per Iter", &|i| w[i].reqs_per_iter.to_string()));
    t.row(&row("Group Size", &|i| w[i].group_size.to_string()));
    t.row(&row("Temperature", &|i| format!("{}", w[i].temperature)));
    t.row(&row("Max. Gen. Length", &|i| w[i].max_gen_len.to_string()));
    t.row(&row("Avg. Gen. Length", &|i| w[i].avg_gen_len.to_string()));
    t.row(&row("KV bytes/token", &|i| {
        format!("{}K", w[i].hw.kv_bytes_per_token / 1024)
    }));
    t.row(&row("KV capacity (tokens/inst)", &|i| {
        w[i].hw.kv_capacity_tokens.to_string()
    }));
    t.note("paper values reproduced exactly; last two rows are this repo's calibration (DESIGN.md §2)");
    t.print();
    Ok(())
}
