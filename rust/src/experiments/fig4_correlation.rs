//! Figure 4: length correlation within response groups — the heatmap's
//! statistic (within-group vs between-group spread of log lengths) plus a
//! sample of group "columns" like the paper's visual.

use crate::config::TaskPreset;
use crate::workload::{generate_iteration, lengths::group_length_spread};

use super::common::Scale;

pub fn run(scale: &Scale) -> anyhow::Result<()> {
    for preset in [TaskPreset::Moonlight, TaskPreset::Qwen2Vl72b] {
        let cfg = scale.workload(preset);
        let w = generate_iteration(&cfg, scale.seed);
        let groups: Vec<Vec<u32>> = w
            .groups
            .iter()
            .map(|g| g.requests.iter().map(|r| r.gen_len).collect())
            .collect();
        let (within, between) = group_length_spread(&groups);
        println!("\n# Figure 4 — {}", cfg.name);
        println!(
            "std of log-lengths: within-group {:.3}, between-group {:.3} \
             (ratio {:.2} — strong intra-group correlation)",
            within,
            between,
            between / within.max(1e-9)
        );
        println!("sample group columns (each row = one group, cells = lengths):");
        for g in groups.iter().take(8) {
            let cells: Vec<String> =
                g.iter().map(|l| format!("{l:>6}")).collect();
            println!("  [{}]", cells.join(" "));
        }
    }
    Ok(())
}
