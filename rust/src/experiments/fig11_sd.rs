//! Figure 11: normalized throughput and mean acceptance length (τ) of SD
//! strategies across the three tasks — no-SD, the task's vanilla SD, and
//! SEER's adaptive grouped SD (all on the same scheduler so the decoding
//! effect is isolated, as in the paper's ablation on veRL).

use crate::config::ALL_PRESETS;
use crate::engine::cluster::ClusterSim;
use crate::scheduler::VerlScheduler;
use crate::spec::simmodel::SdStrategy;
use crate::util::table::{fmt_x, Table};
use crate::workload::generate_iteration;

use super::common::Scale;
use super::fig7_throughput::vanilla_sd_for;

pub fn run(scale: &Scale) -> anyhow::Result<()> {
    let mut t = Table::new(
        "Figure 11 — SD strategies: normalized throughput and τ",
        &["Task", "Strategy", "Throughput", "vs no-SD", "τ (mean accept len)"],
    );
    for preset in ALL_PRESETS {
        let cfg = scale.workload(preset);
        let sys = scale.sys(&cfg);
        let strategies = [
            SdStrategy::None,
            vanilla_sd_for(preset),
            SdStrategy::GroupedCst,
        ];
        let mut base = 0.0f64;
        for sd in strategies {
            let w = generate_iteration(&cfg, scale.seed);
            let sim = ClusterSim::new(
                cfg.clone(),
                sys.clone(),
                w.groups,
                Box::new(VerlScheduler::new()),
                sd,
            );
            // (mean_acceptance needs the sim alive; compute before run
            // consumes it — run returns outcome, so grab τ from metrics.)
            let out = sim.run();
            let tp = out.metrics.throughput();
            if sd == SdStrategy::None {
                base = tp;
            }
            let tau = out.metrics.mean_acceptance_len();
            t.row(&[
                cfg.name.to_string(),
                sd.name().into(),
                format!("{tp:.0}"),
                fmt_x(tp / base.max(1e-9)),
                format!("{tau:.2}"),
            ]);
        }
    }
    t.note("paper: grouped SD beats vanilla by up to 1.3x; draft-model SD has highest τ but lowest throughput gain");
    t.print();
    Ok(())
}
