//! Figure 11: normalized throughput and mean acceptance length (τ) of SD
//! strategies across the three tasks — no-SD, the task's vanilla SD, and
//! SEER's adaptive grouped SD (all on the same scheduler so the decoding
//! effect is isolated, as in the paper's ablation on veRL).

use crate::config::ALL_PRESETS;
use crate::spec::simmodel::SdStrategy;
use crate::util::table::{fmt_x, Table};

use super::common::Scale;
use super::fig7_throughput::vanilla_sd_for;

pub fn run(scale: &Scale) -> anyhow::Result<()> {
    let mut t = Table::new(
        "Figure 11 — SD strategies: normalized throughput and τ",
        &["Task", "Strategy", "Throughput", "vs no-SD", "τ (mean accept len)"],
    );
    for preset in ALL_PRESETS {
        let task_name = scale.workload(preset).name;
        let strategies = [
            SdStrategy::None,
            vanilla_sd_for(preset),
            SdStrategy::GroupedCst,
        ];
        let mut base = 0.0f64;
        for sd in strategies {
            // All on the same scheduler so the decoding effect is
            // isolated, as in the paper's ablation.
            let report = scale.session(preset, "verl", sd).run()?;
            let tp = report.metrics.throughput();
            if sd == SdStrategy::None {
                base = tp;
            }
            let tau = report.metrics.mean_acceptance_len();
            t.row(&[
                task_name.to_string(),
                sd.name().into(),
                format!("{tp:.0}"),
                fmt_x(tp / base.max(1e-9)),
                format!("{tau:.2}"),
            ]);
        }
    }
    t.note("paper: grouped SD beats vanilla by up to 1.3x; draft-model SD has highest τ but lowest throughput gain");
    t.print();
    Ok(())
}
