//! Shared experiment plumbing: workload scaling (full paper scale vs the
//! fast CI scale), run helpers, and result records.

use crate::config::{SystemConfig, TaskPreset, WorkloadConfig};
use crate::engine::cluster::{run_rollout, RolloutOutcome};
use crate::scheduler::Scheduler;
use crate::spec::simmodel::SdStrategy;
use crate::util::cli::Args;

/// Scale selector: experiments run at a reduced-but-faithful scale by
/// default (`fast`), or closer to paper scale with `--full`.
#[derive(Debug, Clone, Copy)]
pub struct Scale {
    pub fast: bool,
    pub seed: u64,
    /// Iterations to average (paper: 5).
    pub iters: usize,
}

impl Scale {
    pub fn from_args(fast: bool, args: &Args) -> Scale {
        Scale {
            fast: fast || !args.has_flag("full"),
            seed: args.get_u64("seed", 42),
            iters: args.get_usize("iters", if fast { 1 } else { 3 }),
        }
    }

    pub fn fast_default() -> Scale {
        Scale {
            fast: true,
            seed: 42,
            iters: 1,
        }
    }

    /// The workload for `preset` at this scale.
    pub fn workload(&self, preset: TaskPreset) -> WorkloadConfig {
        if self.fast {
            // Faithful-shape reduction: keeps the memory-pressure regime,
            // the tail shape AND the groups-per-instance statistics that
            // drive inter-instance imbalance (DESIGN.md §2). Instance
            // counts shrink only 2x so extreme-value effects survive.
            match preset {
                TaskPreset::Moonlight => preset.workload().scaled(2, 16),
                TaskPreset::Qwen2Vl72b => preset.workload().scaled(2, 8),
                TaskPreset::KimiK2 => preset.workload().scaled(2, 16),
            }
        } else {
            preset.workload()
        }
    }

    pub fn sys(&self, cfg: &WorkloadConfig) -> SystemConfig {
        let mut sys = SystemConfig::default();
        if self.fast {
            // Chunk size scales with generation length.
            sys.chunk_size = (cfg.avg_gen_len / 4).clamp(64, 2048);
        }
        sys
    }
}

/// One (scheduler, SD) rollout measurement.
pub struct RunResult {
    pub label: String,
    pub outcome: RolloutOutcome,
}

pub fn measure(
    scale: &Scale,
    preset: TaskPreset,
    label: &str,
    make_sched: impl Fn() -> Box<dyn Scheduler>,
    sd: SdStrategy,
) -> RunResult {
    let cfg = scale.workload(preset);
    let sys = scale.sys(&cfg);
    let outcome = run_rollout(&cfg, &sys, make_sched(), sd, scale.seed);
    RunResult {
        label: label.to_string(),
        outcome,
    }
}

/// Multi-iteration mean throughput (tokens/s).
pub fn mean_throughput(
    scale: &Scale,
    preset: TaskPreset,
    make_sched: &dyn Fn() -> Box<dyn Scheduler>,
    sd: SdStrategy,
) -> f64 {
    let cfg = scale.workload(preset);
    let sys = scale.sys(&cfg);
    let mut total = 0.0;
    for i in 0..scale.iters {
        let out = run_rollout(&cfg, &sys, make_sched(), sd, scale.seed + i as u64);
        total += out.metrics.throughput();
    }
    total / scale.iters as f64
}
