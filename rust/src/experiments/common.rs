//! Shared experiment plumbing: workload scaling (full paper scale vs the
//! fast CI scale), session-based run helpers, and result records.
//!
//! All measurements construct rollouts through
//! [`crate::rollout::RolloutSession`], resolving policies by registry
//! name — experiments never build schedulers by hand.

use crate::config::{SystemConfig, TaskPreset, WorkloadConfig};
use crate::rollout::{RolloutReport, RolloutSession};
use crate::spec::simmodel::SdStrategy;
use crate::sweep::SweepRunner;
use crate::util::cli::Args;
use crate::util::stats::{paired_speedup, paired_tail_reduction, Paired};
use crate::util::table::{fmt_x, Table};

/// The sweep runner multi-run experiments fan out through. Thread count
/// comes from `SEER_SWEEP_THREADS` (default: one per core, capped at 8);
/// results are order-restored, so experiment output is identical at any
/// thread count.
pub fn runner() -> SweepRunner {
    SweepRunner::from_env()
}

/// Scale selector: experiments run at a reduced-but-faithful scale by
/// default (`fast`), or closer to paper scale with `--full`.
#[derive(Debug, Clone, Copy)]
pub struct Scale {
    pub fast: bool,
    pub seed: u64,
    /// Iterations to average (paper: 5).
    pub iters: usize,
}

impl Scale {
    pub fn from_args(fast: bool, args: &Args) -> Scale {
        Scale {
            fast: fast || !args.has_flag("full"),
            seed: args.get_u64("seed", 42),
            iters: args.get_usize("iters", if fast { 1 } else { 3 }),
        }
    }

    pub fn fast_default() -> Scale {
        Scale {
            fast: true,
            seed: 42,
            iters: 1,
        }
    }

    /// The workload for `preset` at this scale.
    pub fn workload(&self, preset: TaskPreset) -> WorkloadConfig {
        if self.fast {
            // Faithful-shape reduction: keeps the memory-pressure regime,
            // the tail shape AND the groups-per-instance statistics that
            // drive inter-instance imbalance (DESIGN.md §2). Instance
            // counts shrink only 2x so extreme-value effects survive.
            match preset {
                TaskPreset::Moonlight => preset.workload().scaled(2, 16),
                TaskPreset::Qwen2Vl72b => preset.workload().scaled(2, 8),
                TaskPreset::KimiK2 => preset.workload().scaled(2, 16),
            }
        } else {
            preset.workload()
        }
    }

    pub fn sys(&self, cfg: &WorkloadConfig) -> SystemConfig {
        let mut sys = SystemConfig::default();
        if self.fast {
            // Chunk size scales with generation length.
            sys.chunk_size = (cfg.avg_gen_len / 4).clamp(64, 2048);
        }
        sys
    }

    /// A session builder pre-configured for `preset` at this scale.
    pub fn session(
        &self,
        preset: TaskPreset,
        scheduler: &str,
        sd: SdStrategy,
    ) -> crate::rollout::session::RolloutSessionBuilder<'static> {
        let cfg = self.workload(preset);
        let sys = self.sys(&cfg);
        RolloutSession::builder()
            .workload(cfg)
            .system(sys)
            .scheduler(scheduler)
            .sd_strategy(sd)
            .seed(self.seed)
    }
}

/// One (scheduler, SD) rollout measurement.
pub struct RunResult {
    pub label: String,
    pub report: RolloutReport,
}

pub fn measure(
    scale: &Scale,
    preset: TaskPreset,
    label: &str,
    scheduler: &str,
    sd: SdStrategy,
) -> RunResult {
    let report = scale
        .session(preset, scheduler, sd)
        .run()
        .expect("rollout session failed");
    RunResult {
        label: label.to_string(),
        report,
    }
}

/// One labelled system's aligned samples for [`print_paired_vs`]: the
/// per-observation makespans and tail times, in the same observation
/// order for every system (seeds, or (group-size, seed) pairs — any
/// axis, as long as it is identical across systems).
pub struct PairedRow {
    pub label: String,
    pub makespans: Vec<f64>,
    pub tails: Vec<f64>,
}

/// The shared paired-statistics script (ISSUE 7 acceptance): per-paired-
/// observation speedup (`other_makespan / candidate_makespan`, mean with
/// seeded-bootstrap CI) and tail reduction (`1 − candidate_tail /
/// other_tail`) of `candidate` against every other system. Both the
/// `faults` and `fig7` experiments (and `multi-iter`, on warm
/// per-iteration samples) report through this one function, so the
/// comparison methodology cannot drift between experiments.
pub fn print_paired_vs(title: &str, candidate: &str, rows: &[PairedRow], seed: u64) {
    let Some(cand) = rows.iter().find(|r| r.label == candidate) else {
        return;
    };
    let mut t = Table::new(
        &format!("{title} — paired statistics, {candidate} vs the rest"),
        &["Versus", "n", "Speedup", "CI 95%", "wins", "Tail redux", "CI 95%", "wins"],
    );
    let fmt_ci = |p: &Paired| format!("[{:.2}, {:.2}]", p.ci.lo, p.ci.hi);
    for other in rows.iter().filter(|r| r.label != candidate) {
        let sp = paired_speedup(&other.makespans, &cand.makespans, seed);
        let tr = paired_tail_reduction(&other.tails, &cand.tails, seed);
        t.row(&[
            other.label.clone(),
            sp.n.to_string(),
            fmt_x(sp.mean),
            fmt_ci(&sp),
            format!("{}/{}", sp.wins, sp.n),
            format!("{:+.0}%", 100.0 * tr.mean),
            fmt_ci(&tr),
            format!("{}/{}", tr.wins, tr.n),
        ]);
    }
    t.note(
        "per-observation pairing: speedup = other/candidate makespan, \
         tail redux = 1 - candidate/other tail time (positive = shorter)",
    );
    t.print();
}

/// Multi-iteration mean throughput (tokens/s).
pub fn mean_throughput(
    scale: &Scale,
    preset: TaskPreset,
    scheduler: &str,
    sd: SdStrategy,
) -> f64 {
    let mut total = 0.0;
    for i in 0..scale.iters {
        let report = scale
            .session(preset, scheduler, sd)
            .seed(scale.seed + i as u64)
            .run()
            .expect("rollout session failed");
        total += report.metrics.throughput();
    }
    total / scale.iters as f64
}
