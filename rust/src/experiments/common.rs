//! Shared experiment plumbing: workload scaling (full paper scale vs the
//! fast CI scale), session-based run helpers, and result records.
//!
//! All measurements construct rollouts through
//! [`crate::rollout::RolloutSession`], resolving policies by registry
//! name — experiments never build schedulers by hand.

use crate::config::{SystemConfig, TaskPreset, WorkloadConfig};
use crate::rollout::{RolloutReport, RolloutSession};
use crate::spec::simmodel::SdStrategy;
use crate::sweep::SweepRunner;
use crate::util::cli::Args;

/// The sweep runner multi-run experiments fan out through. Thread count
/// comes from `SEER_SWEEP_THREADS` (default: one per core, capped at 8);
/// results are order-restored, so experiment output is identical at any
/// thread count.
pub fn runner() -> SweepRunner {
    SweepRunner::from_env()
}

/// Scale selector: experiments run at a reduced-but-faithful scale by
/// default (`fast`), or closer to paper scale with `--full`.
#[derive(Debug, Clone, Copy)]
pub struct Scale {
    pub fast: bool,
    pub seed: u64,
    /// Iterations to average (paper: 5).
    pub iters: usize,
}

impl Scale {
    pub fn from_args(fast: bool, args: &Args) -> Scale {
        Scale {
            fast: fast || !args.has_flag("full"),
            seed: args.get_u64("seed", 42),
            iters: args.get_usize("iters", if fast { 1 } else { 3 }),
        }
    }

    pub fn fast_default() -> Scale {
        Scale {
            fast: true,
            seed: 42,
            iters: 1,
        }
    }

    /// The workload for `preset` at this scale.
    pub fn workload(&self, preset: TaskPreset) -> WorkloadConfig {
        if self.fast {
            // Faithful-shape reduction: keeps the memory-pressure regime,
            // the tail shape AND the groups-per-instance statistics that
            // drive inter-instance imbalance (DESIGN.md §2). Instance
            // counts shrink only 2x so extreme-value effects survive.
            match preset {
                TaskPreset::Moonlight => preset.workload().scaled(2, 16),
                TaskPreset::Qwen2Vl72b => preset.workload().scaled(2, 8),
                TaskPreset::KimiK2 => preset.workload().scaled(2, 16),
            }
        } else {
            preset.workload()
        }
    }

    pub fn sys(&self, cfg: &WorkloadConfig) -> SystemConfig {
        let mut sys = SystemConfig::default();
        if self.fast {
            // Chunk size scales with generation length.
            sys.chunk_size = (cfg.avg_gen_len / 4).clamp(64, 2048);
        }
        sys
    }

    /// A session builder pre-configured for `preset` at this scale.
    pub fn session(
        &self,
        preset: TaskPreset,
        scheduler: &str,
        sd: SdStrategy,
    ) -> crate::rollout::session::RolloutSessionBuilder<'static> {
        let cfg = self.workload(preset);
        let sys = self.sys(&cfg);
        RolloutSession::builder()
            .workload(cfg)
            .system(sys)
            .scheduler(scheduler)
            .sd_strategy(sd)
            .seed(self.seed)
    }
}

/// One (scheduler, SD) rollout measurement.
pub struct RunResult {
    pub label: String,
    pub report: RolloutReport,
}

pub fn measure(
    scale: &Scale,
    preset: TaskPreset,
    label: &str,
    scheduler: &str,
    sd: SdStrategy,
) -> RunResult {
    let report = scale
        .session(preset, scheduler, sd)
        .run()
        .expect("rollout session failed");
    RunResult {
        label: label.to_string(),
        report,
    }
}

/// Multi-iteration mean throughput (tokens/s).
pub fn mean_throughput(
    scale: &Scale,
    preset: TaskPreset,
    scheduler: &str,
    sd: SdStrategy,
) -> f64 {
    let mut total = 0.0;
    for i in 0..scale.iters {
        let report = scale
            .session(preset, scheduler, sd)
            .seed(scale.seed + i as u64)
            .run()
            .expect("rollout session failed");
        total += report.metrics.throughput();
    }
    total / scale.iters as f64
}
