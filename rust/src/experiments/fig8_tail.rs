//! Figure 8: tail time (time spent solely on the last 10% of requests)
//! and total rollout time, veRL vs SEER, across the three tasks.
//!
//! The six rollouts (3 tasks × 2 systems) run concurrently through the
//! parallel [`crate::sweep::SweepRunner`]; order is restored before the
//! table is printed.

use crate::config::{TaskPreset, ALL_PRESETS};
use crate::spec::simmodel::SdStrategy;
use crate::util::table::{fmt_pct, fmt_secs, Table};

use super::common::{runner, Scale};

pub fn run(scale: &Scale) -> anyhow::Result<()> {
    let items: Vec<(TaskPreset, &str, SdStrategy)> = ALL_PRESETS
        .into_iter()
        .flat_map(|preset| {
            [
                (preset, "verl", SdStrategy::None),
                (preset, "seer", SdStrategy::GroupedCst),
            ]
        })
        .collect();
    let reports = runner().try_map(&items, |_, &(preset, sched, sd)| {
        scale.session(preset, sched, sd).run()
    })?;
    let mut t = Table::new(
        "Figure 8 — tail time and total rollout time",
        &[
            "Task", "System", "Total", "Tail (last 10%)", "Tail frac",
            "Tail reduction",
        ],
    );
    for (pi, preset) in ALL_PRESETS.into_iter().enumerate() {
        let verl = &reports[2 * pi];
        let seer = &reports[2 * pi + 1];
        let cfg = scale.workload(preset);
        let vt = verl.metrics.tail_time(0.10).as_secs_f64();
        let vtot = verl.metrics.makespan.as_secs_f64();
        let st = seer.metrics.tail_time(0.10).as_secs_f64();
        let stot = seer.metrics.makespan.as_secs_f64();
        t.row(&[
            cfg.name.to_string(),
            "veRL".into(),
            fmt_secs(vtot),
            fmt_secs(vt),
            fmt_pct(vt / vtot.max(1e-9)),
            "-".into(),
        ]);
        t.row(&[
            "".into(),
            "SEER".into(),
            fmt_secs(stot),
            fmt_secs(st),
            fmt_pct(st / stot.max(1e-9)),
            fmt_pct(1.0 - st / vt.max(1e-9)),
        ]);
    }
    t.note("paper: memory-constrained tasks spend up to 50% of time in the tail; SEER cuts tail time 72-94%");
    t.print();
    Ok(())
}
