//! Multi-iteration training with the cross-iteration context store:
//! iteration-1 vs iteration-N long-tail latency, warm vs cold.
//!
//! Not a figure from the paper — this measures the subsystem the paper's
//! within-iteration machinery makes possible across iterations (cf.
//! RhymeRL, arXiv:2508.18588): persisting the learned group-length
//! context and grouped-SD reference statistics between GRPO epochs. Two
//! drivers run the same drifting epoch sequence from the same seed; the
//! *cold* one rebuilds all context every epoch (today's default in
//! synchronous RL systems), the *warm* one consumes the store from
//! iteration 2 on. The warm driver's p99 finish time and tail time drop
//! below both its own iteration 1 and the cold baseline's matching
//! iterations. Both context-consuming schedulers (seer and the
//! rollpacker tail-packing policy) run the identical warm/cold pairing,
//! and the warm per-iteration samples feed the shared cross-policy
//! paired statistics ([`super::common::print_paired_vs`]).

use anyhow::Result;

use crate::config::TaskPreset;
use crate::iteration::{IterationSummary, TrainingConfig, TrainingDriver};
use crate::util::table::Table;

use super::common::{print_paired_vs, runner, PairedRow, Scale};

/// Paired per-iteration measurements (same seed, same epochs).
pub struct MultiIterResult {
    pub cold: Vec<IterationSummary>,
    pub warm: Vec<IterationSummary>,
}

impl MultiIterResult {
    /// Warm-over-cold p99 speedup for iteration `i`.
    pub fn p99_speedup(&self, i: usize) -> f64 {
        self.cold[i].p99_finish_secs / self.warm[i].p99_finish_secs.max(1e-9)
    }
}

pub fn measure(scale: &Scale) -> Result<MultiIterResult> {
    measure_scheduler(scale, "seer")
}

/// Warm/cold driver pair for one scheduling policy. Both schedulers in
/// [`run`] go through this, so the warm-start comparison methodology is
/// identical for seer and rollpacker.
pub fn measure_scheduler(
    scale: &Scale,
    scheduler: &str,
) -> Result<MultiIterResult> {
    let iters = scale.iters.max(3);
    let cfg = |warm: bool| TrainingConfig {
        system: scale.sys(&scale.workload(TaskPreset::Moonlight)),
        scheduler: scheduler.to_string(),
        iters,
        seed: scale.seed,
        warm_start: warm,
        ..TrainingConfig::new(scale.workload(TaskPreset::Moonlight))
    };
    // The cold and warm drivers are independent (same seed, same epoch
    // sequence), so they run as two parallel sweep work items.
    let modes = [false, true];
    let mut results = runner()
        .try_map(&modes, |_, &warm| TrainingDriver::new(cfg(warm)).run())?
        .into_iter();
    let cold = results.next().expect("cold driver result");
    let warm = results.next().expect("warm driver result");
    Ok(MultiIterResult { cold, warm })
}

pub fn run(scale: &Scale) -> Result<()> {
    let mut warm_rows: Vec<PairedRow> = Vec::new();
    for scheduler in ["seer", "rollpacker"] {
        let r = measure_scheduler(scale, scheduler)?;
        print_scheduler(scheduler, &r);
        // Warm per-iteration samples feed the cross-policy paired
        // statistics below (iterations are seed/epoch-aligned).
        warm_rows.push(PairedRow {
            label: scheduler.to_string(),
            makespans: r.warm.iter().map(|s| s.makespan_secs).collect(),
            tails: r.warm.iter().map(|s| s.tail_secs).collect(),
        });
    }
    print_paired_vs("multi-iter warm", "rollpacker", &warm_rows, scale.seed);
    Ok(())
}

fn print_scheduler(scheduler: &str, r: &MultiIterResult) {
    println!(
        "Cross-iteration context store ({scheduler}): {} GRPO iterations, \
         same seed/epochs",
        r.cold.len()
    );
    let mut t = Table::new(
        &format!("multi-iter ({scheduler}): warm vs cold long-tail latency"),
        &[
            "iter",
            "cold p99 (s)",
            "warm p99 (s)",
            "cold tail (s)",
            "warm tail (s)",
            "cold makespan",
            "warm makespan",
            "p99 speedup",
        ],
    );
    for i in 0..r.cold.len() {
        let (c, w) = (&r.cold[i], &r.warm[i]);
        t.row(&[
            format!("{}", i + 1),
            format!("{:.1}", c.p99_finish_secs),
            format!("{:.1}", w.p99_finish_secs),
            format!("{:.1}", c.tail_secs),
            format!("{:.1}", w.tail_secs),
            format!("{:.1}", c.makespan_secs),
            format!("{:.1}", w.makespan_secs),
            format!("{:.2}x", r.p99_speedup(i)),
        ]);
    }
    t.print();
    println!(
        "(iteration 1 is cold in both runs — the store has nothing to \
         offer yet; from iteration 2 the warm run consumes last epoch's \
         learned context)"
    );
}
