//! `faults` experiment (extension beyond the paper): tail latency and
//! wasted-work overhead under an *identical* deterministic fault script —
//! Seer vs veRL vs StreamRL-Oracle vs RollPacker, plus paired per-seed
//! speedup/tail-reduction statistics for the tail-packing policy against
//! every baseline (through the shared script in
//! [`super::common::print_paired_vs`]).
//!
//! The script crashes one instance early, turns another into a straggler
//! mid-run, scales a replacement in, and finally recovers the crashed
//! instance — the elastic-fleet scenario Seer's divided rollout was built
//! for (PAPER.md §4; Laminar makes the same argument for RL post-training
//! at scale). All four systems replay the same script at the same
//! virtual timestamps, so differences are pure scheduling policy: Seer's
//! and RollPacker's chunk-level leases bound the work resident on any
//! one instance, so a
//! crash loses less progress and the drained requests re-enter the LFS
//! queue with their context intact; the baselines re-pin whole groups and
//! re-prefill everything the crash destroyed.

use crate::config::TaskPreset;
use crate::sim::faults::{FaultEvent, FaultPlan};
use crate::spec::simmodel::SdStrategy;
use crate::util::table::{fmt_secs, Table};
use crate::workload::InstanceId;

use super::common::{print_paired_vs, runner, PairedRow, Scale};

pub fn run(scale: &Scale) -> anyhow::Result<()> {
    let preset = TaskPreset::Qwen2Vl72b;

    // Size the script to the workload: fractions of a clean baseline
    // makespan, so the same scenario shape holds at every scale.
    let clean = scale
        .session(preset, "verl", SdStrategy::None)
        .run()?;
    let horizon = clean.metrics.makespan.as_secs_f64();
    let plan = FaultPlan::new()
        .at(
            0.15 * horizon,
            FaultEvent::InstanceDown {
                instance: InstanceId(1),
            },
        )
        .at(
            0.30 * horizon,
            FaultEvent::InstanceSlowdown {
                instance: InstanceId(0),
                factor: 2.5,
            },
        )
        .at(0.40 * horizon, FaultEvent::ScaleUp { n: 1 })
        .at(
            0.60 * horizon,
            FaultEvent::InstanceRecover {
                instance: InstanceId(1),
            },
        )
        .sorted();

    let mut t = Table::new(
        "Fault tolerance — identical fault script, all schedulers",
        &[
            "System",
            "Makespan",
            "Tail (10%)",
            "Lost tokens",
            "Re-prefill",
            "Requeued",
            "Recovery",
        ],
    );
    // All four systems replay the same script concurrently (sweep
    // runner) at every paired seed; results come back in grid order
    // (system-major, seed-minor).
    let systems = [
        ("veRL", "verl", SdStrategy::None),
        ("StreamRL-O", "streamrl", SdStrategy::None),
        ("SEER", "seer", SdStrategy::GroupedCst),
        ("RollPacker", "rollpacker", SdStrategy::GroupedCst),
    ];
    let seeds: Vec<u64> =
        (0..scale.iters.max(2)).map(|i| scale.seed + i as u64).collect();
    let mut items = Vec::new();
    for &(_, scheduler, sd) in &systems {
        for &seed in &seeds {
            items.push((scheduler, sd, seed));
        }
    }
    let reports = runner().try_map(&items, |_, &(scheduler, sd, seed)| {
        scale
            .session(preset, scheduler, sd)
            .seed(seed)
            .faults(plan.clone())
            .run()
    })?;
    for (si, &(label, _, _)) in systems.iter().enumerate() {
        // Table rows show the base seed; the paired statistics below
        // use every seed.
        let m = &reports[si * seeds.len()].metrics;
        anyhow::ensure!(
            m.instances_lost >= 1,
            "{label}: fault script never fired (horizon {horizon:.0}s)"
        );
        t.row(&[
            label.into(),
            fmt_secs(m.makespan.as_secs_f64()),
            fmt_secs(m.tail_time(0.10).as_secs_f64()),
            m.fault_lost_tokens.to_string(),
            m.re_prefill_tokens.to_string(),
            m.fault_requeued.to_string(),
            fmt_secs(m.mean_recovery_latency().as_secs_f64()),
        ]);
    }
    t.note(
        "same seed + same script for every row; divided rollout bounds \
         per-crash work loss and re-queues with context intact",
    );
    t.print();
    // Paired speedup / tail-reduction of the tail-packing policy vs
    // every baseline, from the same runs (shared script — common.rs).
    let rows: Vec<PairedRow> = systems
        .iter()
        .enumerate()
        .map(|(si, &(label, _, _))| {
            let rs = &reports[si * seeds.len()..(si + 1) * seeds.len()];
            PairedRow {
                label: label.to_string(),
                makespans: rs
                    .iter()
                    .map(|r| r.metrics.makespan.as_secs_f64())
                    .collect(),
                tails: rs
                    .iter()
                    .map(|r| r.metrics.tail_time(0.10).as_secs_f64())
                    .collect(),
            }
        })
        .collect();
    print_paired_vs("faults", "RollPacker", &rows, scale.seed);
    Ok(())
}
