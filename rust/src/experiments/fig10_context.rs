//! Figure 10: impact of length context — No-Context (divided rollout
//! only) vs SEER (learned estimates) vs Oracle (true lengths, LFS), on
//! normalized throughput and normalized tail latency.

use crate::config::TaskPreset;
use crate::spec::simmodel::SdStrategy;
use crate::util::table::{fmt_pct, fmt_x, Table};

use super::common::{measure, Scale};

pub fn run(scale: &Scale) -> anyhow::Result<()> {
    let preset = TaskPreset::Qwen2Vl72b;
    let baseline =
        measure(scale, preset, "verl", "verl", SdStrategy::None);
    // Registry names for the context ablation's scheduler variants.
    let variants = [
        ("No-Context", "no-context"),
        ("SEER", "seer"),
        ("Oracle", "oracle"),
    ];
    let base_tp = baseline.report.metrics.throughput();
    let base_tail = baseline.report.metrics.tail_time(0.10).as_secs_f64();

    let mut t = Table::new(
        "Figure 10 — impact of length context (Qwen2-VL-72B)",
        &["Policy", "Norm. throughput", "Norm. tail latency", "Tail cut vs baseline"],
    );
    t.row(&[
        "veRL baseline".into(),
        fmt_x(1.0),
        fmt_x(1.0),
        "-".into(),
    ]);
    let mut oracle_tp = 0.0;
    let mut seer_tp = 0.0;
    for (name, sched) in variants {
        let res = measure(scale, preset, name, sched, SdStrategy::None);
        let tp = res.report.metrics.throughput();
        let tail = res.report.metrics.tail_time(0.10).as_secs_f64();
        if name == "Oracle" {
            oracle_tp = tp;
        }
        if name == "SEER" {
            seer_tp = tp;
        }
        t.row(&[
            name.into(),
            fmt_x(tp / base_tp.max(1e-9)),
            fmt_x(tail / base_tail.max(1e-9)),
            fmt_pct(1.0 - tail / base_tail.max(1e-9)),
        ]);
    }
    t.note("paper: no-context cuts tail ~21%, SEER ~89%; SEER reaches ~96% of Oracle throughput");
    t.print();
    if oracle_tp > 0.0 {
        println!(
            "SEER / Oracle throughput: {:.1}%",
            100.0 * seer_tp / oracle_tp
        );
    }
    Ok(())
}
