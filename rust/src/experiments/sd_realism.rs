//! SD realism: how much of speculative decoding's win survives contact
//! with a moving policy and an emptying cluster.
//!
//! Two mechanisms, two paired comparisons:
//!
//! 1. **History replay** (cf. RhymeRL, arXiv:2508.18588): the warm
//!    training driver seeds grouped-CST reference counts from last
//!    epoch's streams. Those references describe a *stale* policy, so
//!    the acceptance model discounts them by the per-epoch drift sigma
//!    (`SpecCtx::effective_refs`). Sweeping drift shows the gain decay:
//!    at sigma 0 replayed history is as good as fresh siblings, by
//!    sigma 0.25 the SD-side discount reaches zero and only the
//!    scheduler's length priors still distinguish warm from cold.
//! 2. **Bubble drafting** (cf. BubbleSpec, arXiv:2503.19449): once some
//!    instances drain at end of rollout, `bubble_draft_frac` redirects
//!    their idle capacity into deeper drafts for the stragglers —
//!    gamma deepens toward `gamma_max` and the offloaded share of the
//!    draft cost leaves the critical path.
//!
//! Both comparisons report per-seed paired tail-latency statistics
//! through [`super::common::print_paired_vs`], the same script the
//! fault/scheduler experiments use.

use anyhow::Result;

use crate::config::TaskPreset;
use crate::iteration::{TrainingConfig, TrainingDriver};
use crate::spec::simmodel::SdStrategy;
use crate::util::table::Table;

use super::common::{print_paired_vs, runner, PairedRow, Scale};

/// Drift sweep points. Fast scale keeps the two endpoints (full warm
/// credit at 0, fully discounted references at 0.25); full scale fills
/// in the decay curve.
fn drifts(scale: &Scale) -> Vec<f64> {
    if scale.fast {
        vec![0.0, 0.25]
    } else {
        vec![0.0, 0.05, 0.10, 0.25]
    }
}

fn seeds(scale: &Scale) -> Vec<u64> {
    let n: u64 = if scale.fast { 2 } else { 4 };
    (0..n).map(|i| scale.seed + i).collect()
}

pub fn run(scale: &Scale) -> Result<()> {
    history_replay(scale)?;
    bubble_drafting(scale)
}

/// Warm vs cold training drivers across the drift sweep. Each (drift,
/// seed) cell runs the identical epoch sequence twice — only warm-start
/// differs — so per-(seed, warm-iteration) samples pair exactly.
fn history_replay(scale: &Scale) -> Result<()> {
    let drifts = drifts(scale);
    let seeds = seeds(scale);
    let iters = scale.iters.max(3);
    let cfg = |drift: f64, seed: u64, warm: bool| TrainingConfig {
        system: scale.sys(&scale.workload(TaskPreset::Moonlight)),
        iters,
        seed,
        drift,
        warm_start: warm,
        ..TrainingConfig::new(scale.workload(TaskPreset::Moonlight))
    };
    let mut work = Vec::new();
    for &d in &drifts {
        for &s in &seeds {
            for warm in [false, true] {
                work.push((d, s, warm));
            }
        }
    }
    let results = runner()
        .try_map(&work, |_, &(d, s, warm)| {
            TrainingDriver::new(cfg(d, s, warm)).run()
        })?;

    println!(
        "History replay: warm SD references vs per-epoch policy drift \
         ({} seeds x {} iterations per cell)",
        seeds.len(),
        iters
    );
    let mut t = Table::new(
        "sd-realism: warm-start gain vs drift (warm iterations only)",
        &[
            "drift sigma",
            "cold p99 (s)",
            "warm p99 (s)",
            "p99 speedup",
            "cold tail (s)",
            "warm tail (s)",
        ],
    );
    let mut paired: Vec<(f64, [PairedRow; 2])> = Vec::new();
    for (di, &d) in drifts.iter().enumerate() {
        let mut cold = PairedRow {
            label: "cold".into(),
            makespans: Vec::new(),
            tails: Vec::new(),
        };
        let mut warm = PairedRow {
            label: "warm".into(),
            makespans: Vec::new(),
            tails: Vec::new(),
        };
        let (mut cp99, mut wp99, mut ct, mut wt) = (0.0, 0.0, 0.0, 0.0);
        for si in 0..seeds.len() {
            let base = (di * seeds.len() + si) * 2;
            let (c, w) = (&results[base], &results[base + 1]);
            // Iteration 1 is cold in both runs; only warm-capable
            // iterations contribute observations.
            for i in 1..iters {
                cold.makespans.push(c[i].makespan_secs);
                cold.tails.push(c[i].tail_secs);
                warm.makespans.push(w[i].makespan_secs);
                warm.tails.push(w[i].tail_secs);
                cp99 += c[i].p99_finish_secs;
                wp99 += w[i].p99_finish_secs;
                ct += c[i].tail_secs;
                wt += w[i].tail_secs;
            }
        }
        let n = (seeds.len() * (iters - 1)) as f64;
        t.row(&[
            format!("{d:.2}"),
            format!("{:.1}", cp99 / n),
            format!("{:.1}", wp99 / n),
            format!("{:.2}x", cp99 / wp99.max(1e-9)),
            format!("{:.1}", ct / n),
            format!("{:.1}", wt / n),
        ]);
        paired.push((d, [cold, warm]));
    }
    t.print();
    for (d, rows) in &paired {
        print_paired_vs(
            &format!("sd-realism history replay (drift sigma={d:.2})"),
            "warm",
            rows,
            scale.seed,
        );
    }
    println!(
        "(warm references are discounted by (1 - 4*sigma); past sigma \
         0.25 the SD-side replay benefit is zero by construction and \
         any residual warm gain comes from the scheduler's length \
         priors)"
    );
    Ok(())
}

/// Bubble drafting on vs off, paired per seed on otherwise identical
/// single-iteration rollouts.
fn bubble_drafting(scale: &Scale) -> Result<()> {
    const FRAC: f64 = 0.5;
    let seeds = seeds(scale);
    let mut work = Vec::new();
    for &s in &seeds {
        for bubble in [false, true] {
            work.push((s, bubble));
        }
    }
    let reports = runner()
        .try_map(&work, |_, &(seed, bubble)| {
            let cfg = scale.workload(TaskPreset::Moonlight);
            let mut sys = scale.sys(&cfg);
            sys.bubble_draft_frac = if bubble { FRAC } else { 0.0 };
            scale
                .session(TaskPreset::Moonlight, "seer", SdStrategy::GroupedCst)
                .system(sys)
                .seed(seed)
                .run()
        })?;

    let mut t = Table::new(
        &format!(
            "sd-realism: bubble drafting (bubble_draft_frac={FRAC}) vs baseline"
        ),
        &[
            "seed",
            "base makespan",
            "bubble makespan",
            "base tail (s)",
            "bubble tail (s)",
            "offloaded draft (s)",
            "bubble tokens",
        ],
    );
    let mut base = PairedRow {
        label: "baseline".into(),
        makespans: Vec::new(),
        tails: Vec::new(),
    };
    let mut bubble = PairedRow {
        label: "bubble".into(),
        makespans: Vec::new(),
        tails: Vec::new(),
    };
    for (si, &s) in seeds.iter().enumerate() {
        let b = &reports[si * 2].metrics;
        let u = &reports[si * 2 + 1].metrics;
        assert_eq!(
            b.bubble_accept_tokens, 0,
            "baseline run must not draft in bubbles"
        );
        base.makespans.push(b.makespan.as_secs_f64());
        base.tails.push(b.tail_time(0.10).as_secs_f64());
        bubble.makespans.push(u.makespan.as_secs_f64());
        bubble.tails.push(u.tail_time(0.10).as_secs_f64());
        t.row(&[
            format!("{s}"),
            format!("{:.1}", b.makespan.as_secs_f64()),
            format!("{:.1}", u.makespan.as_secs_f64()),
            format!("{:.1}", b.tail_time(0.10).as_secs_f64()),
            format!("{:.1}", u.tail_time(0.10).as_secs_f64()),
            format!("{:.1}", u.bubble_draft_time.as_secs_f64()),
            format!("{}", u.bubble_accept_tokens),
        ]);
    }
    t.print();
    print_paired_vs(
        "sd-realism bubble drafting",
        "bubble",
        &[base, bubble],
        scale.seed,
    );
    println!(
        "(bubbles open once some instances drain while others still \
         run; the offloaded draft seconds leave the stragglers' \
         critical path and gamma deepens toward gamma_max)"
    );
    Ok(())
}
