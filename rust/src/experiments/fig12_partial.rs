//! Figure 12: SEER vs Partial Rollout (APRIL-style non-strictly
//! synchronous RL) on the Qwen2-VL-72B workload — throughput, plus the
//! output-length-distribution bias Partial Rollout introduces.

use crate::config::TaskPreset;
use crate::rollout::RolloutSession;
use crate::spec::simmodel::SdStrategy;
use crate::util::stats::Summary;
use crate::util::table::{fmt_x, Table};

use super::common::Scale;

pub fn run(scale: &Scale) -> anyhow::Result<()> {
    let preset = TaskPreset::Qwen2Vl72b;
    let cfg = scale.workload(preset);
    let sys = scale.sys(&cfg);

    // SEER: strict synchronous, all requests complete.
    let seer = scale
        .session(preset, "seer", SdStrategy::GroupedCst)
        .run()?;

    // Partial Rollout (APRIL setup): over-issue 2x the requests, stop
    // once the target count completes; the rest would carry over.
    let mut big = cfg.clone();
    big.reqs_per_iter = cfg.reqs_per_iter * 2;
    let partial = RolloutSession::builder()
        .workload(big)
        .system(sys)
        .scheduler("verl")
        .sd_strategy(SdStrategy::None)
        .seed(scale.seed)
        .stop_after(cfg.reqs_per_iter)
        .run()?;

    let mut t = Table::new(
        "Figure 12a — throughput: SEER vs Partial Rollout (Qwen2-VL)",
        &["System", "Completed", "Makespan", "Throughput tok/s", "vs Partial"],
    );
    // Effective throughput counts *completed* samples only: Partial
    // Rollout's over-issued, unfinished requests are work the iteration
    // cannot train on (they carry over), exactly the accounting the
    // paper's comparison uses.
    let completed_tp = |m: &crate::metrics::RolloutMetrics| {
        let toks: u64 = m.completions.iter().map(|c| c.gen_len as u64).sum();
        toks as f64 / m.makespan.as_secs_f64().max(1e-9)
    };
    let seer_tp = completed_tp(&seer.metrics);
    let part_tp = completed_tp(&partial.metrics);
    t.row(&[
        "Partial Rollout (2x over-issue)".into(),
        partial.metrics.completions.len().to_string(),
        format!("{:.0}s", partial.metrics.makespan.as_secs_f64()),
        format!("{part_tp:.0}"),
        fmt_x(1.0),
    ]);
    t.row(&[
        "SEER (strict sync)".into(),
        seer.metrics.completions.len().to_string(),
        format!("{:.0}s", seer.metrics.makespan.as_secs_f64()),
        format!("{seer_tp:.0}"),
        fmt_x(seer_tp / part_tp.max(1e-9)),
    ]);
    t.note("paper: SEER 43% higher throughput while staying strictly on-policy");
    t.print();

    // Figure 12b: length-distribution bias of the *completed* sets.
    let mut t2 = Table::new(
        "Figure 12b — completed-output length distribution",
        &["System", "mean", "p50", "p90", "p99", "max"],
    );
    for (name, metrics) in
        [("SEER", &seer.metrics), ("Partial Rollout", &partial.metrics)]
    {
        let mut s = Summary::new();
        s.extend(metrics.completions.iter().map(|c| c.gen_len as f64));
        t2.row(&[
            name.into(),
            format!("{:.0}", s.mean()),
            format!("{:.0}", s.percentile(50.0)),
            format!("{:.0}", s.percentile(90.0)),
            format!("{:.0}", s.percentile(99.0)),
            format!("{:.0}", s.max()),
        ]);
    }
    t2.note("paper: Partial Rollout under-represents long outputs (distributional skew risk)");
    t2.print();
    Ok(())
}
