//! `async-frontier` experiment (extension beyond the paper): the
//! staleness/throughput frontier of bounded-staleness overlap training
//! (Laminar-style, arXiv:2510.12633) on Seer's rollout machinery.
//!
//! One scheduler (seer + grouped-CST) runs the same multi-epoch
//! pipeline under every training mode — `sync`, `hybrid` (one-step
//! overlap), and `async` at increasing lag bounds — across a
//! fault-plan × drift grid with paired seeds. Sync is the correctness
//! anchor: zero staleness by construction, epochs strictly serialized.
//! Each overlap mode buys pipeline span (epoch k+1's rollout starts
//! before epoch k's weights land) at the price of rollouts sampled from
//! stale policy versions; the per-request staleness is bounded by the
//! mode's lag, and this experiment prints the measured frontier plus
//! the shared paired per-seed statistics
//! ([`super::common::print_paired_vs`]) so the span win is a CI, not a
//! point estimate.

use anyhow::Result;

use crate::config::{TaskPreset, TrainingMode};
use crate::sim::faults::{FaultEvent, FaultPlan};
use crate::spec::simmodel::SdStrategy;
use crate::sweep::SweepSpec;
use crate::util::table::Table;
use crate::workload::InstanceId;

use super::common::{print_paired_vs, runner, PairedRow, Scale};

/// The mode grid: sync anchor, one-step overlap, then the async lag
/// ladder.
fn modes() -> Vec<TrainingMode> {
    vec![
        TrainingMode::Sync,
        TrainingMode::Hybrid,
        TrainingMode::Async { lag: 1 },
        TrainingMode::Async { lag: 2 },
    ]
}

pub fn run(scale: &Scale) -> Result<()> {
    let preset = TaskPreset::Moonlight;
    let cfg = scale.workload(preset);
    let sys = scale.sys(&cfg);

    // Size the fault script to the workload (same idiom as `faults`):
    // fractions of a clean single-rollout makespan, so the scenario
    // shape holds at every scale.
    let clean = scale
        .session(preset, "seer", SdStrategy::GroupedCst)
        .run()?;
    let horizon = clean.metrics.makespan.as_secs_f64();
    let plan = FaultPlan::new()
        .at(
            0.20 * horizon,
            FaultEvent::InstanceDown {
                instance: InstanceId(1),
            },
        )
        .at(0.50 * horizon, FaultEvent::ScaleUp { n: 1 })
        .at(
            0.70 * horizon,
            FaultEvent::InstanceRecover {
                instance: InstanceId(1),
            },
        )
        .sorted();

    let seeds: Vec<u64> =
        (0..scale.iters.max(2)).map(|i| scale.seed + i as u64).collect();
    let mut spec = SweepSpec::new(cfg)
        .system(sys)
        .sd("grouped-cst")
        .seeds(seeds)
        .drifts([0.0, 0.05])
        .fault_plan("none", FaultPlan::new())
        .fault_plan("crash+scale", plan)
        .pipeline_iters(3);
    spec.schedulers = vec!["seer".to_string()];
    for mode in modes() {
        spec = spec.mode(mode);
    }

    let report = runner().run(&spec)?.report;

    // Invariants the frontier rests on: staleness never exceeds the
    // mode's bound, and the sync anchor never sees a stale request.
    for cell in &report.cells {
        anyhow::ensure!(
            cell.staleness_max <= cell.lag,
            "{} cell (seed {}): staleness {} exceeds lag bound {}",
            cell.mode,
            cell.seed,
            cell.staleness_max,
            cell.lag
        );
        if cell.mode == "sync" {
            anyhow::ensure!(
                cell.stale_requests == 0,
                "sync cell (seed {}) saw {} stale requests",
                cell.seed,
                cell.stale_requests
            );
        }
    }

    let mut t = Table::new(
        "async-frontier — mode x lag staleness/throughput frontier \
         (seer, grouped-cst, 3-epoch pipeline)",
        &[
            "Mode",
            "Lag",
            "Fault",
            "Drift",
            "Span (s)",
            "Tok/s",
            "Tok/s CI 95%",
            "Staleness",
        ],
    );
    for a in &report.aggregates {
        t.row(&[
            a.mode.clone(),
            a.lag.to_string(),
            a.fault_name.clone(),
            format!("{:.2}", a.drift),
            format!("{:.1}", a.mean_makespan_secs),
            format!("{:.0}", a.mean_throughput_tok_s),
            format!(
                "[{:.0}, {:.0}]",
                a.throughput_ci.lo, a.throughput_ci.hi
            ),
            format!("{:.3}", a.mean_staleness),
        ]);
    }
    t.note(
        "span = pipeline makespan of 3 epochs (last weight-update land); \
         staleness = mean policy-version lag per completed request, \
         bounded by the mode's lag (sync ≡ async lag 0)",
    );
    t.print();

    // Paired per-seed statistics: each mode's cells cover the identical
    // (fault, drift, seed) observation axis in the identical order (the
    // mode dimension sits between scheduler and scale in the grid), so
    // the samples pair exactly.
    let (_, grid_modes, _, faults, drifts, grid_seeds) = spec.dims();
    let per_mode = faults.len() * drifts.len() * grid_seeds.len();
    let rows: Vec<PairedRow> = grid_modes
        .iter()
        .enumerate()
        .map(|(mi, mode)| {
            let cells = &report.cells[mi * per_mode..(mi + 1) * per_mode];
            PairedRow {
                label: mode.tag(),
                makespans: cells.iter().map(|c| c.makespan_secs).collect(),
                tails: cells.iter().map(|c| c.tail_secs).collect(),
            }
        })
        .collect();
    print_paired_vs("async-frontier", "async:1", &rows, scale.seed);
    let stale_total: u64 =
        report.cells.iter().map(|c| c.stale_requests).sum();
    println!(
        "(total stale requests across overlap cells: {stale_total}; \
         every one bounded by its mode's lag)"
    );
    Ok(())
}
