//! `trainer-elastic` experiment (extension beyond the paper): the
//! mode × lag × trainer-fault frontier — how much of the overlap win
//! survives when the *trainer* is the unreliable half of the pipeline.
//!
//! The `faults` and `async-frontier` experiments stress the rollout
//! cluster; here the cluster stays healthy and a deterministic
//! trainer-side script (slowdown window, stall, one mid-run crash)
//! is replayed into the overlap recurrence instead. Every mode sees
//! the identical script under paired seeds, so the table answers two
//! questions with CIs rather than point estimates: how much pipeline
//! span each overlap mode still buys when train steps stretch, and
//! what a lost in-flight train step (crash ⇒ redo) costs per mode.
//! Two invariants are asserted on every run: healthy cells report
//! zero retries and zero fault seconds, and `async --lag 0` under the
//! trainer plan stays byte-identical to `sync` (the PR 10 acceptance
//! identity), modulo only the mode/lag labels themselves.

use anyhow::Result;

use crate::config::{TaskPreset, TrainingMode};
use crate::sim::faults::{FaultEvent, FaultPlan};
use crate::spec::simmodel::SdStrategy;
use crate::sweep::SweepSpec;
use crate::util::table::Table;

use super::common::{print_paired_vs, runner, PairedRow, Scale};

/// The mode grid: sync anchor, its lag-0 async twin (identity check),
/// one-step overlap, then the async lag ladder.
fn modes() -> Vec<TrainingMode> {
    vec![
        TrainingMode::Sync,
        TrainingMode::Async { lag: 0 },
        TrainingMode::Hybrid,
        TrainingMode::Async { lag: 1 },
        TrainingMode::Async { lag: 2 },
    ]
}

pub fn run(scale: &Scale) -> Result<()> {
    let preset = TaskPreset::Moonlight;
    let cfg = scale.workload(preset);
    let sys = scale.sys(&cfg);

    // Size the trainer script to the workload (same idiom as `faults`):
    // fractions of a clean single-rollout makespan, so the scenario
    // shape holds at every scale. Crash position is an epoch ordinal,
    // not a time, so it needs no scaling.
    let clean = scale
        .session(preset, "seer", SdStrategy::GroupedCst)
        .run()?;
    let horizon = clean.metrics.makespan.as_secs_f64();
    let plan = FaultPlan::new()
        .at(
            0.10 * horizon,
            FaultEvent::TrainerSlowdown {
                factor: 1.5,
                from: 0.10 * horizon,
                until: 0.60 * horizon,
            },
        )
        .at(
            0.30 * horizon,
            FaultEvent::TrainerStall {
                at: 0.30 * horizon,
                secs: 0.05 * horizon,
            },
        )
        .at(0.0, FaultEvent::TrainerCrash { at_iter: 1 })
        .sorted();

    let seeds: Vec<u64> =
        (0..scale.iters.max(2)).map(|i| scale.seed + i as u64).collect();
    let mut spec = SweepSpec::new(cfg)
        .system(sys)
        .sd("grouped-cst")
        .seeds(seeds)
        .drifts([0.05])
        .fault_plan("none", FaultPlan::new())
        .fault_plan("trainer-chaos", plan)
        .pipeline_iters(3);
    spec.schedulers = vec!["seer".to_string()];
    for mode in modes() {
        spec = spec.mode(mode);
    }

    let report = runner().run(&spec)?.report;

    // Invariant 1: a healthy trainer never retries and never loses
    // time to faults; a crashed one redoes at least one step.
    for cell in &report.cells {
        if cell.fault_name == "none" {
            anyhow::ensure!(
                cell.train_retries == 0 && cell.trainer_fault_secs == 0.0,
                "{} cell (seed {}): healthy trainer reported {} retries / \
                 {:.3}s fault time",
                cell.mode,
                cell.seed,
                cell.train_retries,
                cell.trainer_fault_secs
            );
        } else {
            anyhow::ensure!(
                cell.train_retries >= 1,
                "{} cell (seed {}): trainer crash at iter 1 produced no \
                 retry",
                cell.mode,
                cell.seed
            );
        }
    }

    // Invariant 2 (the PR 10 acceptance identity): async lag 0 is sync
    // under any trainer plan. The mode grid puts the sync block first
    // and the async:0 block second, each covering the identical
    // (fault, drift, seed) axis in the identical order, so cells pair
    // positionally; strip only the labels that *name* the mode.
    let (_, grid_modes, _, faults, drifts, grid_seeds) = spec.dims();
    let per_mode = faults.len() * drifts.len() * grid_seeds.len();
    for (sync_cell, lag0_cell) in report.cells[..per_mode]
        .iter()
        .zip(&report.cells[per_mode..2 * per_mode])
    {
        let strip = |c: &crate::sweep::CellResult| {
            let mut o = match c.to_json() {
                crate::util::json::Json::Obj(o) => o,
                other => unreachable!("cell JSON is an object, got {other}"),
            };
            for k in ["index", "mode", "lag"] {
                o.remove(k);
            }
            crate::util::json::Json::Obj(o).to_string()
        };
        anyhow::ensure!(
            strip(sync_cell) == strip(lag0_cell),
            "sync/async:0 identity broke under trainer faults (fault {}, \
             seed {})",
            sync_cell.fault_name,
            sync_cell.seed
        );
    }

    let mut t = Table::new(
        "trainer-elastic — mode x lag frontier under trainer-side faults \
         (seer, grouped-cst, 3-epoch pipeline)",
        &[
            "Mode",
            "Lag",
            "Fault",
            "Span (s)",
            "Tok/s",
            "Tok/s CI 95%",
            "Retries",
            "Fault (s)",
        ],
    );
    // `Aggregate` carries no trainer-fault fields (the JSON schema is
    // shared by every sweep); fold them from the cells, which sit in
    // the same contiguous per-group runs the aggregator consumed.
    for (g, a) in report.aggregates.iter().enumerate() {
        let group = &report.cells[g * a.n_seeds..(g + 1) * a.n_seeds];
        let retries: u64 = group.iter().map(|c| c.train_retries).sum();
        let fault_secs: f64 =
            group.iter().map(|c| c.trainer_fault_secs).sum();
        t.row(&[
            a.mode.clone(),
            a.lag.to_string(),
            a.fault_name.clone(),
            format!("{:.1}", a.mean_makespan_secs),
            format!("{:.0}", a.mean_throughput_tok_s),
            format!(
                "[{:.0}, {:.0}]",
                a.throughput_ci.lo, a.throughput_ci.hi
            ),
            retries.to_string(),
            format!("{:.1}", fault_secs),
        ]);
    }
    t.note(
        "span = pipeline makespan of 3 epochs; retries / fault (s) summed \
         over the group's seeds; crash redoes the in-flight train step, \
         slowdown/stall stretch U_k inside the overlap recurrence \
         (sync ≡ async lag 0 under any trainer plan — asserted)",
    );
    t.print();

    // Paired per-seed statistics against the sync anchor: every mode's
    // cells cover the identical (fault, drift, seed) observation axis
    // in the identical order, so the samples pair exactly.
    let rows: Vec<PairedRow> = grid_modes
        .iter()
        .enumerate()
        .map(|(mi, mode)| {
            let cells = &report.cells[mi * per_mode..(mi + 1) * per_mode];
            PairedRow {
                label: mode.tag(),
                makespans: cells.iter().map(|c| c.makespan_secs).collect(),
                tails: cells.iter().map(|c| c.tail_secs).collect(),
            }
        })
        .collect();
    print_paired_vs("trainer-elastic", "sync", &rows, scale.seed);
    let total_retries: u64 =
        report.cells.iter().map(|c| c.train_retries).sum();
    let total_fault: f64 =
        report.cells.iter().map(|c| c.trainer_fault_secs).sum();
    println!(
        "(total train retries across faulted cells: {total_retries}; \
         total trainer fault seconds: {total_fault:.1})"
    );
    Ok(())
}
