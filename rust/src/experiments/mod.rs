//! The experiment harness: one module per table/figure of the paper's
//! evaluation section (the README's reproduction table maps each id to
//! its artifact), plus extensions beyond the paper (`multi_iter`: the
//! cross-iteration context store; `faults`: scheduler comparison under a
//! deterministic fault & elasticity script). Every experiment prints the
//! same rows/series the paper reports and returns machine-readable
//! results for the smoke tests.

pub mod async_frontier;
pub mod common;
pub mod fault_tolerance;
pub mod fig10_context;
pub mod fig11_sd;
pub mod fig12_partial;
pub mod fig2_lengths;
pub mod fig3_baseline_util;
pub mod fig4_correlation;
pub mod fig7_throughput;
pub mod fig8_tail;
pub mod fig9_seer_util;
pub mod multi_iter;
pub mod sd_realism;
pub mod table1_phases;
pub mod table2_acceptance;
pub mod table3_config;
pub mod table4_ablation;
pub mod trainer_elastic;

use crate::util::cli::Args;

/// Run an experiment by id ("table1", "fig7", ... or "all").
pub fn run(id: &str, args: &Args) -> anyhow::Result<()> {
    let fast = args.has_flag("fast") || std::env::var("SEER_FAST").is_ok();
    let scale = common::Scale::from_args(fast, args);
    match id {
        "table1" => table1_phases::run(&scale),
        "table2" => table2_acceptance::run(&scale),
        "table3" => table3_config::run(),
        "table4" => table4_ablation::run(&scale),
        "fig2" => fig2_lengths::run(&scale),
        "fig3" => fig3_baseline_util::run(&scale),
        "fig4" => fig4_correlation::run(&scale),
        "fig7" => fig7_throughput::run(&scale),
        "fig8" => fig8_tail::run(&scale),
        "fig9" => fig9_seer_util::run(&scale),
        "fig10" => fig10_context::run(&scale),
        "fig11" => fig11_sd::run(&scale),
        "fig12" => fig12_partial::run(&scale),
        "multi-iter" => multi_iter::run(&scale),
        "faults" => fault_tolerance::run(&scale),
        "sd-realism" => sd_realism::run(&scale),
        "async-frontier" => async_frontier::run(&scale),
        "trainer-elastic" => trainer_elastic::run(&scale),
        "all" => {
            for id in ALL_IDS {
                println!("\n================ {id} ================");
                run(id, args)?;
            }
            Ok(())
        }
        other => anyhow::bail!(
            "unknown experiment '{other}'; one of {ALL_IDS:?} or 'all'"
        ),
    }
}

pub const ALL_IDS: [&str; 18] = [
    "table1", "fig2", "fig3", "fig4", "table2", "table3", "fig7", "fig8",
    "fig9", "table4", "fig10", "fig11", "fig12", "multi-iter", "faults",
    "sd-realism", "async-frontier", "trainer-elastic",
];
