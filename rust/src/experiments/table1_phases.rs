//! Table 1: time distribution across RL training phases (rollout /
//! training / weight update) per workload, with rollout measured on the
//! veRL baseline and the other phases from the calibrated phase model.

use crate::config::ALL_PRESETS;
use crate::rl::phases::PhaseModel;
use crate::spec::simmodel::SdStrategy;
use crate::util::table::{fmt_pct, Table};

use super::common::{measure, Scale};

pub fn run(scale: &Scale) -> anyhow::Result<()> {
    let mut t = Table::new(
        "Table 1: Time distribution across RL training phases",
        &["Workload", "Rollout", "Training", "Weight Update", "Iter total"],
    );
    // Paper reference rows: Moonlight 84/14/2, Qwen 63/31/6, Kimi 87/10/3.
    let paper = [
        ("moonlight", 0.84, 0.14, 0.02),
        ("qwen2-vl-72b", 0.63, 0.31, 0.06),
        ("kimi-k2", 0.87, 0.10, 0.03),
    ];
    for preset in ALL_PRESETS {
        let res = measure(scale, preset, "verl", "verl", SdStrategy::None);
        let cfg = scale.workload(preset);
        let model = PhaseModel::for_workload(&cfg);
        let split = model.split(
            res.report.metrics.makespan,
            res.report.metrics.tokens_generated,
        );
        let (r, tr, u) = split.fractions();
        t.row(&[
            cfg.name.to_string(),
            fmt_pct(r),
            fmt_pct(tr),
            fmt_pct(u),
            crate::util::table::fmt_secs(split.total().as_secs_f64()),
        ]);
    }
    t.note("paper: moonlight 84/14/2, qwen2-vl 63/31/6, kimi-k2 87/10/3 — rollout dominates everywhere");
    t.print();
    let _ = paper;
    Ok(())
}
