//! Workload generation: GRPO prompt groups with group-correlated output
//! lengths (Figures 2 & 4) and group-correlated token streams (Table 2).
//!
//! Core identity types for requests/groups/instances also live here, since
//! everything downstream (engine, coordinator, scheduler, spec) speaks in
//! these ids.

pub mod lengths;
pub mod tokens;

pub use lengths::LengthSampler;
pub use tokens::{GroupTokenGen, TokenGenConfig};

use crate::config::WorkloadConfig;
use crate::sim::Rng;

/// Request identifier, unique within one rollout iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct RequestId(pub u32);

/// GRPO prompt-group identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct GroupId(pub u32);

/// Inference instance identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct InstanceId(pub u32);

/// One request's ground truth, hidden from schedulers (only the Oracle
/// baseline may look at `gen_len`).
#[derive(Debug, Clone)]
pub struct RequestSpec {
    pub id: RequestId,
    pub group: GroupId,
    pub prompt_len: u32,
    /// True output length this request will reach (tokens).
    pub gen_len: u32,
}

/// One GRPO prompt group: G requests sharing a prompt.
#[derive(Debug, Clone)]
pub struct GroupSpec {
    pub id: GroupId,
    pub prompt_len: u32,
    pub requests: Vec<RequestSpec>,
}

impl GroupSpec {
    pub fn max_gen_len(&self) -> u32 {
        self.requests.iter().map(|r| r.gen_len).max().unwrap_or(0)
    }

    pub fn mean_gen_len(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.requests.iter().map(|r| r.gen_len as f64).sum::<f64>()
            / self.requests.len() as f64
    }
}

/// A full rollout iteration's workload.
#[derive(Debug, Clone)]
pub struct IterationWorkload {
    pub groups: Vec<GroupSpec>,
}

impl IterationWorkload {
    pub fn n_requests(&self) -> usize {
        self.groups.iter().map(|g| g.requests.len()).sum()
    }

    pub fn requests(&self) -> impl Iterator<Item = &RequestSpec> {
        self.groups.iter().flat_map(|g| g.requests.iter())
    }

    pub fn total_gen_tokens(&self) -> u64 {
        self.requests().map(|r| r.gen_len as u64).sum()
    }
}

/// Generate one iteration's workload from a task config, deterministically
/// from `seed`.
pub fn generate_iteration(cfg: &WorkloadConfig, seed: u64) -> IterationWorkload {
    let sampler = LengthSampler::from_config(cfg);
    let mut rng = Rng::new(seed ^ 0xC0FFEE);
    let n_groups = cfg.n_groups();
    let mut groups = Vec::with_capacity(n_groups);
    let mut next_req = 0u32;
    for gi in 0..n_groups {
        let mut grng = rng.fork(gi as u64);
        let (prompt_len, gen_lens) = sampler.sample_group(&mut grng);
        let requests = gen_lens
            .into_iter()
            .map(|gen_len| {
                let id = RequestId(next_req);
                next_req += 1;
                RequestSpec {
                    id,
                    group: GroupId(gi as u32),
                    prompt_len,
                    gen_len,
                }
            })
            .collect();
        groups.push(GroupSpec {
            id: GroupId(gi as u32),
            prompt_len,
            requests,
        });
    }
    IterationWorkload { groups }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;

    #[test]
    fn generates_requested_counts() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 1);
        assert_eq!(w.n_requests(), cfg.reqs_per_iter);
        assert_eq!(w.groups.len(), cfg.n_groups());
        for g in &w.groups {
            assert_eq!(g.requests.len(), cfg.group_size);
            for r in &g.requests {
                assert!(r.gen_len >= 1 && r.gen_len <= cfg.max_gen_len);
                assert_eq!(r.prompt_len, g.prompt_len);
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = TaskPreset::Qwen2Vl72b.workload_for_test();
        let a = generate_iteration(&cfg, 7);
        let b = generate_iteration(&cfg, 7);
        let c = generate_iteration(&cfg, 8);
        let lens =
            |w: &IterationWorkload| w.requests().map(|r| r.gen_len).collect::<Vec<_>>();
        assert_eq!(lens(&a), lens(&b));
        assert_ne!(lens(&a), lens(&c));
    }

    #[test]
    fn unique_request_ids() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 3);
        let mut ids: Vec<u32> = w.requests().map(|r| r.id.0).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), w.n_requests());
    }
}
