//! Workload generation: GRPO prompt groups with group-correlated output
//! lengths (Figures 2 & 4) and group-correlated token streams (Table 2).
//!
//! Core identity types for requests/groups/instances also live here, since
//! everything downstream (engine, coordinator, scheduler, spec) speaks in
//! these ids.

pub mod lengths;
pub mod tokens;

pub use lengths::LengthSampler;
pub use tokens::{GroupTokenGen, TokenGenConfig};

use crate::config::WorkloadConfig;
use crate::sim::Rng;

/// Request identifier, unique within one rollout iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct RequestId(pub u32);

/// GRPO prompt-group identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct GroupId(pub u32);

/// Inference instance identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct InstanceId(pub u32);

/// One request's ground truth, hidden from schedulers (only the Oracle
/// baseline may look at `gen_len`).
#[derive(Debug, Clone)]
pub struct RequestSpec {
    pub id: RequestId,
    pub group: GroupId,
    pub prompt_len: u32,
    /// True output length this request will reach (tokens).
    pub gen_len: u32,
}

/// One GRPO prompt group: G requests sharing a prompt.
#[derive(Debug, Clone)]
pub struct GroupSpec {
    pub id: GroupId,
    pub prompt_len: u32,
    pub requests: Vec<RequestSpec>,
}

impl GroupSpec {
    pub fn max_gen_len(&self) -> u32 {
        self.requests.iter().map(|r| r.gen_len).max().unwrap_or(0)
    }

    pub fn mean_gen_len(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.requests.iter().map(|r| r.gen_len as f64).sum::<f64>()
            / self.requests.len() as f64
    }
}

/// A full rollout iteration's workload.
#[derive(Debug, Clone)]
pub struct IterationWorkload {
    pub groups: Vec<GroupSpec>,
}

impl IterationWorkload {
    pub fn n_requests(&self) -> usize {
        self.groups.iter().map(|g| g.requests.len()).sum()
    }

    pub fn requests(&self) -> impl Iterator<Item = &RequestSpec> {
        self.groups.iter().flat_map(|g| g.requests.iter())
    }

    pub fn total_gen_tokens(&self) -> u64 {
        self.requests().map(|r| r.gen_len as u64).sum()
    }
}

/// Generate one iteration's workload from a task config, deterministically
/// from `seed`.
pub fn generate_iteration(cfg: &WorkloadConfig, seed: u64) -> IterationWorkload {
    let sampler = LengthSampler::from_config(cfg);
    let mut rng = Rng::new(seed ^ 0xC0FFEE);
    let n_groups = cfg.n_groups();
    let mut groups = Vec::with_capacity(n_groups);
    let mut next_req = 0u32;
    for gi in 0..n_groups {
        let mut grng = rng.fork(gi as u64);
        let (prompt_len, gen_lens) = sampler.sample_group(&mut grng);
        let requests = gen_lens
            .into_iter()
            .map(|gen_len| {
                let id = RequestId(next_req);
                next_req += 1;
                RequestSpec {
                    id,
                    group: GroupId(gi as u32),
                    prompt_len,
                    gen_len,
                }
            })
            .collect();
        groups.push(GroupSpec {
            id: GroupId(gi as u32),
            prompt_len,
            requests,
        });
    }
    IterationWorkload { groups }
}

/// Re-sample one *epoch* of the same prompt set, with per-group length
/// drift.
///
/// Synchronous GRPO revisits the same prompts every epoch; lengths stay
/// group-correlated across epochs but drift as the policy updates. This
/// generator models exactly that: epoch 0 is identical to
/// [`generate_iteration`] (same seed ⇒ same workload), and epoch `e > 0`
/// keeps every group's identity (ids, prompt length) while scaling its
/// lengths by a per-(epoch, group) log-normal factor with sigma `drift`
/// plus a smaller per-request factor with sigma `drift / 2`. With
/// `drift = 0` every epoch is identical. Deterministic in
/// `(cfg, seed, epoch, drift)`.
pub fn generate_epoch(
    cfg: &WorkloadConfig,
    seed: u64,
    epoch: u64,
    drift: f64,
) -> IterationWorkload {
    let mut w = generate_iteration(cfg, seed);
    if epoch == 0 || drift == 0.0 {
        return w;
    }
    let mut rng = Rng::new(seed ^ 0xE90C_4 ^ epoch.wrapping_mul(0x9E37_79B9));
    for g in &mut w.groups {
        let mut grng = rng.fork(g.id.0 as u64);
        // Group-level drift dominates; requests wobble around it.
        let group_f = grng.lognormal(-drift * drift / 2.0, drift);
        let s = drift / 2.0;
        for r in &mut g.requests {
            let req_f = grng.lognormal(-s * s / 2.0, s);
            r.gen_len =
                ((r.gen_len as f64 * group_f * req_f) as u32).clamp(1, cfg.max_gen_len);
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;

    #[test]
    fn generates_requested_counts() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 1);
        assert_eq!(w.n_requests(), cfg.reqs_per_iter);
        assert_eq!(w.groups.len(), cfg.n_groups());
        for g in &w.groups {
            assert_eq!(g.requests.len(), cfg.group_size);
            for r in &g.requests {
                assert!(r.gen_len >= 1 && r.gen_len <= cfg.max_gen_len);
                assert_eq!(r.prompt_len, g.prompt_len);
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = TaskPreset::Qwen2Vl72b.workload_for_test();
        let a = generate_iteration(&cfg, 7);
        let b = generate_iteration(&cfg, 7);
        let c = generate_iteration(&cfg, 8);
        let lens =
            |w: &IterationWorkload| w.requests().map(|r| r.gen_len).collect::<Vec<_>>();
        assert_eq!(lens(&a), lens(&b));
        assert_ne!(lens(&a), lens(&c));
    }

    #[test]
    fn epoch_zero_matches_iteration() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let a = generate_iteration(&cfg, 11);
        let b = generate_epoch(&cfg, 11, 0, 0.1);
        let lens =
            |w: &IterationWorkload| w.requests().map(|r| r.gen_len).collect::<Vec<_>>();
        assert_eq!(lens(&a), lens(&b));
    }

    #[test]
    fn epochs_drift_but_stay_group_correlated() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let e0 = generate_epoch(&cfg, 9, 0, 0.1);
        let e1 = generate_epoch(&cfg, 9, 1, 0.1);
        let e1b = generate_epoch(&cfg, 9, 1, 0.1);
        // Deterministic per (seed, epoch).
        let lens =
            |w: &IterationWorkload| w.requests().map(|r| r.gen_len).collect::<Vec<_>>();
        assert_eq!(lens(&e1), lens(&e1b));
        assert_ne!(lens(&e0), lens(&e1));
        // Group structure (ids, prompt) is preserved...
        for (a, b) in e0.groups.iter().zip(e1.groups.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.prompt_len, b.prompt_len);
        }
        // ...and lengths are *correlated* across epochs: a small drift
        // keeps each group's mean within a modest factor of epoch 0's.
        for (a, b) in e0.groups.iter().zip(e1.groups.iter()) {
            let (ma, mb) = (a.mean_gen_len().max(1.0), b.mean_gen_len().max(1.0));
            let ratio = (ma / mb).max(mb / ma);
            assert!(ratio < 2.5, "group {:?} drifted {ratio}x", a.id);
        }
    }

    #[test]
    fn zero_drift_epochs_identical() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let lens = |e: u64| {
            generate_epoch(&cfg, 4, e, 0.0)
                .requests()
                .map(|r| r.gen_len)
                .collect::<Vec<_>>()
        };
        assert_eq!(lens(0), lens(3));
    }

    #[test]
    fn unique_request_ids() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = generate_iteration(&cfg, 3);
        let mut ids: Vec<u32> = w.requests().map(|r| r.id.0).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), w.n_requests());
    }
}
