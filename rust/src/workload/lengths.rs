//! Group-correlated heavy-tailed length sampling.
//!
//! Model (DESIGN.md §2): the *group mean* is log-normal with sigma
//! `sigma_between` (the heavy tail of Figure 2), and each request's length
//! is the group mean times a small log-normal factor `sigma_within`
//! (the strong intra-group correlation of Figure 4). The location
//! parameter is calibrated so the expected length matches the preset's
//! `avg_gen_len`; lengths clip to [1, max_gen_len].

use crate::config::WorkloadConfig;
use crate::sim::Rng;

#[derive(Debug, Clone)]
pub struct LengthSampler {
    mu_between: f64,
    sigma_between: f64,
    sigma_within: f64,
    max_len: u32,
    group_size: usize,
    mu_prompt: f64,
    sigma_prompt: f64,
    max_prompt: u32,
}

impl LengthSampler {
    pub fn from_config(cfg: &WorkloadConfig) -> Self {
        LengthSampler::new(
            cfg.avg_gen_len,
            cfg.max_gen_len,
            cfg.sigma_between,
            cfg.sigma_within,
            cfg.group_size,
            cfg.avg_prompt_len,
            cfg.sigma_prompt,
        )
    }

    #[allow(clippy::too_many_arguments)]
    pub fn new(
        avg_len: u32,
        max_len: u32,
        sigma_between: f64,
        sigma_within: f64,
        group_size: usize,
        avg_prompt: u32,
        sigma_prompt: f64,
    ) -> Self {
        // E[L] = exp(mu_b + (sigma_b^2 + sigma_w^2) / 2); solve for mu_b,
        // then correct empirically for the [1, max] clipping, which pulls
        // the mean down on heavy-tailed presets.
        let var = sigma_between * sigma_between + sigma_within * sigma_within;
        let mut mu_between = (avg_len as f64).ln() - var / 2.0;
        // One-step multiplicative correction using a probe sample.
        let probe = {
            let s = LengthSampler {
                mu_between,
                sigma_between,
                sigma_within,
                max_len,
                group_size,
                mu_prompt: (avg_prompt as f64).ln()
                    - sigma_prompt * sigma_prompt / 2.0,
                sigma_prompt,
                max_prompt: avg_prompt * 8,
            };
            let mut rng = Rng::new(0xCA11B7A7E);
            let mut total = 0.0f64;
            let mut n = 0usize;
            for _ in 0..2000 {
                let (_, lens) = s.sample_group(&mut rng);
                total += lens.iter().map(|&l| l as f64).sum::<f64>();
                n += lens.len();
            }
            total / n as f64
        };
        if probe > 0.0 {
            mu_between += (avg_len as f64 / probe).ln().clamp(-0.5, 0.5);
        }
        LengthSampler {
            mu_between,
            sigma_between,
            sigma_within,
            max_len,
            group_size,
            mu_prompt: (avg_prompt as f64).ln()
                - sigma_prompt * sigma_prompt / 2.0,
            sigma_prompt,
            max_prompt: avg_prompt * 8,
        }
    }

    /// Sample one group: (prompt_len, per-request generation lengths).
    pub fn sample_group(&self, rng: &mut Rng) -> (u32, Vec<u32>) {
        let prompt = (rng.lognormal(self.mu_prompt, self.sigma_prompt) as u32)
            .clamp(8, self.max_prompt);
        let group_mean = rng
            .lognormal(self.mu_between, self.sigma_between)
            .min(self.max_len as f64);
        let lens = (0..self.group_size)
            .map(|_| {
                // Mean-one multiplicative factor.
                let f = rng.lognormal(
                    -self.sigma_within * self.sigma_within / 2.0,
                    self.sigma_within,
                );
                ((group_mean * f) as u32).clamp(1, self.max_len)
            })
            .collect();
        (prompt, lens)
    }

    pub fn max_len(&self) -> u32 {
        self.max_len
    }
}

/// Sample correlation of log-lengths within vs across groups: the Figure 4
/// statistic. Returns (within_group_std, between_group_std) of log lengths.
pub fn group_length_spread(groups: &[Vec<u32>]) -> (f64, f64) {
    let mut within = 0.0f64;
    let mut n_within = 0usize;
    let mut means = vec![];
    for g in groups {
        let logs: Vec<f64> = g.iter().map(|&l| (l.max(1) as f64).ln()).collect();
        let m = logs.iter().sum::<f64>() / logs.len() as f64;
        means.push(m);
        for l in &logs {
            within += (l - m) * (l - m);
            n_within += 1;
        }
    }
    let gm = means.iter().sum::<f64>() / means.len().max(1) as f64;
    let between = means.iter().map(|m| (m - gm) * (m - gm)).sum::<f64>()
        / means.len().max(1) as f64;
    ((within / n_within.max(1) as f64).sqrt(), between.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;

    fn sample_many(preset: TaskPreset, n_groups: usize) -> Vec<Vec<u32>> {
        let cfg = preset.workload();
        let s = LengthSampler::from_config(&cfg);
        let mut rng = Rng::new(42);
        (0..n_groups).map(|_| s.sample_group(&mut rng).1).collect()
    }

    #[test]
    fn mean_calibrated_within_tolerance() {
        for preset in crate::config::ALL_PRESETS {
            let cfg = preset.workload();
            let groups = sample_many(preset, 4000);
            let all: Vec<f64> = groups
                .iter()
                .flatten()
                .map(|&l| l as f64)
                .collect();
            let mean = all.iter().sum::<f64>() / all.len() as f64;
            let rel = (mean - cfg.avg_gen_len as f64).abs()
                / cfg.avg_gen_len as f64;
            assert!(
                rel < 0.12,
                "{}: mean {mean:.0} vs target {} (rel {rel:.3})",
                cfg.name,
                cfg.avg_gen_len
            );
        }
    }

    #[test]
    fn lengths_bounded() {
        for preset in crate::config::ALL_PRESETS {
            let cfg = preset.workload();
            for g in sample_many(preset, 500) {
                for l in g {
                    assert!(l >= 1 && l <= cfg.max_gen_len);
                }
            }
        }
    }

    #[test]
    fn heavy_tail_exists() {
        // Some groups should be far above the mean (the long-tail of
        // Figures 2/3): p99 group mean > 3x overall mean for Qwen.
        let cfg = TaskPreset::Qwen2Vl72b.workload();
        let groups = sample_many(TaskPreset::Qwen2Vl72b, 3000);
        let mut means: Vec<f64> = groups
            .iter()
            .map(|g| g.iter().map(|&l| l as f64).sum::<f64>() / g.len() as f64)
            .collect();
        means.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p99 = means[(means.len() * 99) / 100];
        assert!(
            p99 > 3.0 * cfg.avg_gen_len as f64,
            "p99 group mean {p99:.0} not heavy-tailed"
        );
    }

    #[test]
    fn intra_group_correlation_strong() {
        // Within-group spread of log-lengths must be much smaller than
        // between-group spread (Figure 4's visual).
        let groups = sample_many(TaskPreset::Moonlight, 2000);
        let (within, between) = group_length_spread(&groups);
        assert!(
            within < 0.5 * between,
            "within {within:.3} vs between {between:.3}"
        );
    }

    #[test]
    fn prompt_lengths_reasonable() {
        let cfg = TaskPreset::Moonlight.workload();
        let s = LengthSampler::from_config(&cfg);
        let mut rng = Rng::new(1);
        let mut total = 0u64;
        let n = 2000;
        for _ in 0..n {
            let (p, _) = s.sample_group(&mut rng);
            assert!(p >= 8 && p <= cfg.avg_prompt_len * 8);
            total += p as u64;
        }
        let mean = total as f64 / n as f64;
        let rel = (mean - cfg.avg_prompt_len as f64).abs() / cfg.avg_prompt_len as f64;
        assert!(rel < 0.15, "prompt mean {mean}");
    }
}
