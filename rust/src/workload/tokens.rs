//! Group-correlated synthetic token streams.
//!
//! The CST / grouped-SD experiments (Table 2, Figure 11) need token
//! sequences in which responses from the same GRPO group share recurring
//! local patterns — the paper's §2.3 "pattern level" observation. We model
//! a response as a walk over a group-specific library of *template
//! segments* (shared phrases: derivation steps, code idioms, judge
//! boilerplate):
//!
//! * each group owns `n_segments` segments of `seg_len` tokens drawn from
//!   a shared vocabulary;
//! * a response follows the group's canonical segment order with
//!   probability `p_follow` (otherwise it jumps to a random segment), and
//! * each emitted token is replaced by fresh noise with probability
//!   `p_mutate`.
//!
//! `similarity` in [0,1] scales both knobs, giving the experiment harness
//! a single axis from "independent streams" to "near-identical streams".

use crate::sim::Rng;

#[derive(Debug, Clone)]
pub struct TokenGenConfig {
    pub vocab: u32,
    pub n_segments: usize,
    pub seg_len: usize,
    /// Intra-group pattern similarity in [0, 1].
    pub similarity: f64,
    /// Per-request "paraphrase" rate: fraction of each segment's tokens a
    /// given request consistently rewrites its own way. Self-matches stay
    /// strong (the rewrite is stable within the request); cross-sibling
    /// matches break at ~2x this rate.
    pub request_variant: f64,
}

impl Default for TokenGenConfig {
    fn default() -> Self {
        TokenGenConfig {
            vocab: 32_000,
            n_segments: 24,
            seg_len: 24,
            similarity: 0.8,
            request_variant: 0.18,
        }
    }
}

/// Token-stream generator for one GRPO group.
#[derive(Debug, Clone)]
pub struct GroupTokenGen {
    cfg: TokenGenConfig,
    segments: Vec<Vec<u32>>,
    /// Canonical next-segment for the group's "house style" walk.
    canon_next: Vec<usize>,
    /// Second-most-likely next segment (the mass multi-path drafting can
    /// capture: real responses have a few plausible continuations, not a
    /// uniform fan-out).
    alt_next: Vec<usize>,
    prompt: Vec<u32>,
}

impl GroupTokenGen {
    pub fn new(cfg: TokenGenConfig, group_seed: u64) -> Self {
        let mut rng = Rng::new(group_seed ^ 0x7E5EED);
        let segments: Vec<Vec<u32>> = (0..cfg.n_segments)
            .map(|_| {
                (0..cfg.seg_len)
                    .map(|_| rng.below(cfg.vocab as u64) as u32)
                    .collect()
            })
            .collect();
        // A random permutation cycle as the canonical order.
        let mut order: Vec<usize> = (0..cfg.n_segments).collect();
        rng.shuffle(&mut order);
        let mut canon_next = vec![0usize; cfg.n_segments];
        let mut alt_next = vec![0usize; cfg.n_segments];
        for w in 0..cfg.n_segments {
            canon_next[order[w]] = order[(w + 1) % cfg.n_segments];
            alt_next[order[w]] = order[(w + 2) % cfg.n_segments];
        }
        let prompt = (0..32).map(|_| rng.below(cfg.vocab as u64) as u32).collect();
        GroupTokenGen {
            cfg,
            segments,
            canon_next,
            alt_next,
            prompt,
        }
    }

    /// The group's shared prompt tokens.
    pub fn prompt(&self) -> &[u32] {
        &self.prompt
    }

    /// Generate one response of `len` tokens for request index `req_idx`
    /// within the group.
    pub fn response(&self, req_idx: usize, len: usize, seed: u64) -> Vec<u32> {
        let mut rng = Rng::new(seed ^ (req_idx as u64).wrapping_mul(0x9E37));
        let p_follow = 0.35 + 0.6 * self.cfg.similarity;
        let p_mutate = 0.12 * (1.0 - self.cfg.similarity);
        let mut out = Vec::with_capacity(len);
        let mut seg = rng.below(self.cfg.n_segments as u64) as usize;
        while out.len() < len {
            for (ti, &tok) in self.segments[seg].iter().enumerate() {
                if out.len() >= len {
                    break;
                }
                // Request-stable paraphrase: deterministic per
                // (request, segment, position).
                let mut vrng = Rng::new(
                    (req_idx as u64)
                        .wrapping_mul(0x9E3779B97F4A7C15)
                        ^ ((seg as u64) << 32 | ti as u64),
                );
                let tok = if vrng.bool(self.cfg.request_variant) {
                    vrng.below(self.cfg.vocab as u64) as u32
                } else {
                    tok
                };
                if rng.bool(p_mutate) {
                    out.push(rng.below(self.cfg.vocab as u64) as u32);
                } else {
                    out.push(tok);
                }
            }
            let u = rng.f64();
            seg = if u < p_follow {
                self.canon_next[seg]
            } else if u < p_follow + 0.6 * (1.0 - p_follow) {
                self.alt_next[seg]
            } else {
                rng.below(self.cfg.n_segments as u64) as usize
            };
        }
        out
    }
}

/// Longest-common-substring-rate proxy: fraction of positions in `a` that
/// begin an 8-gram also present in `b`. Used by tests to verify the
/// similarity knob is meaningful.
pub fn shared_ngram_rate(a: &[u32], b: &[u32], n: usize) -> f64 {
    if a.len() < n || b.len() < n {
        return 0.0;
    }
    use std::collections::HashSet;
    let grams: HashSet<&[u32]> = b.windows(n).collect();
    let hits = a.windows(n).filter(|w| grams.contains(*w)).count();
    hits as f64 / (a.len() - n + 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn responses_have_requested_length() {
        let g = GroupTokenGen::new(TokenGenConfig::default(), 1);
        for (i, len) in [(0usize, 10usize), (1, 500), (2, 1000)] {
            assert_eq!(g.response(i, len, 42).len(), len);
        }
    }

    #[test]
    fn deterministic() {
        let g = GroupTokenGen::new(TokenGenConfig::default(), 5);
        assert_eq!(g.response(0, 300, 9), g.response(0, 300, 9));
        assert_ne!(g.response(0, 300, 9), g.response(1, 300, 9));
    }

    #[test]
    fn intra_group_similarity_exceeds_cross_group() {
        let cfg = TokenGenConfig::default();
        let ga = GroupTokenGen::new(cfg.clone(), 10);
        let gb = GroupTokenGen::new(cfg, 11);
        let a0 = ga.response(0, 2000, 1);
        let a1 = ga.response(1, 2000, 2);
        let b0 = gb.response(0, 2000, 3);
        let within = shared_ngram_rate(&a0, &a1, 8);
        let cross = shared_ngram_rate(&a0, &b0, 8);
        assert!(
            within > 5.0 * (cross + 0.001),
            "within {within:.3} cross {cross:.3}"
        );
    }

    #[test]
    fn similarity_knob_monotone() {
        let mut rates = vec![];
        for sim in [0.0, 0.5, 0.95] {
            let cfg = TokenGenConfig {
                similarity: sim,
                ..Default::default()
            };
            let g = GroupTokenGen::new(cfg, 7);
            let r0 = g.response(0, 3000, 1);
            let r1 = g.response(1, 3000, 2);
            rates.push(shared_ngram_rate(&r0, &r1, 8));
        }
        assert!(
            rates[0] < rates[1] && rates[1] < rates[2],
            "rates {rates:?}"
        );
    }

    #[test]
    fn self_similarity_is_high() {
        // A long response revisits its own segments: per-request history
        // alone already enables some n-gram drafting (Table 2's n=0 row).
        let g = GroupTokenGen::new(TokenGenConfig::default(), 3);
        let r = g.response(0, 4000, 1);
        let first = &r[..2000];
        let second = &r[2000..];
        let rate = shared_ngram_rate(second, first, 8);
        assert!(rate > 0.2, "self-similarity {rate:.3}");
    }
}
