//! Rollout telemetry: completion records, per-instance utilization
//! timelines, preemption counters, and the paper's tail-time metric
//! (§4.2.2: tail time = time spent *solely* processing the last 10% of
//! requests to complete). [`EventCounts`] consumes the session layer's
//! streaming event API as an ordinary observer, cross-checking the
//! driver-side counters.

use crate::rollout::observer::{RolloutEvent, RolloutObserver};
use crate::sim::clock::SimTime;
use crate::util::stats::Summary;
use crate::workload::{InstanceId, RequestId};

/// Per-request completion record.
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    pub id: RequestId,
    pub finished_at: SimTime,
    pub first_scheduled_at: SimTime,
    pub gen_len: u32,
    /// Policy version the request was generated under. Synchronous
    /// rollouts stamp every completion with the epoch's single version;
    /// async/hybrid pipelines stamp the version live when the request
    /// *finished generating* (mid-stream weight updates bump it).
    pub policy_version: u64,
}

/// A sampled point of one instance's load.
#[derive(Debug, Clone, Copy)]
pub struct LoadSample {
    pub t: SimTime,
    pub instance: InstanceId,
    pub kv_utilization: f64,
    pub running: usize,
}

/// Everything a rollout run reports; consumed by the experiment harness.
#[derive(Debug, Default)]
pub struct RolloutMetrics {
    pub completions: Vec<Completion>,
    pub load_samples: Vec<LoadSample>,
    pub preemptions: u64,
    pub migrations: u64,
    pub re_prefill_tokens: u64,
    pub migrated_bytes: u64,
    /// Total tokens generated (the throughput numerator).
    pub tokens_generated: u64,
    /// Tokens accepted from speculative drafts (subset of generated).
    pub spec_accepted_tokens: u64,
    /// Draft tokens proposed (for acceptance-rate reporting).
    pub spec_draft_tokens: u64,
    /// Engine-forward-step count across instances.
    pub engine_steps: u64,
    /// Verification forward passes (real backend; the fluid simulator
    /// folds verification into its step-time model and leaves this 0).
    pub verify_steps: u64,
    /// Mean accepted tokens per request-step including the bonus token
    /// (τ, Figure 11); 1.0 when SD is off. Set by the driver.
    pub tau: f64,
    /// Per-instance busy time (forward passes running).
    pub busy_time: Vec<SimTime>,
    /// Per-instance *live* time: how long each instance was actually part
    /// of the fleet (scale-up instances join late; crashed instances stop
    /// accruing while down). Empty (or zero entries) fall back to the
    /// makespan — backends that never lose or add instances need not
    /// fill it.
    pub live_time: Vec<SimTime>,
    pub makespan: SimTime,
    // --- fault & elasticity layer ------------------------------------
    /// Requests terminated by a scripted abort (never completed).
    pub aborted: u64,
    /// Instances lost to crashes or elastic reclamation.
    pub instances_lost: u64,
    /// Instances added by elastic scale-up.
    pub instances_added: u64,
    /// Work lost to crashes: uncommitted interval tokens discarded when
    /// an instance died (they must be re-generated later).
    pub fault_lost_tokens: u64,
    /// Requests drained off lost instances back into the waiting queue.
    pub fault_requeued: u64,
    /// Σ (re-admission time − fault time) over fault-drained requests
    /// that were re-admitted; divide by `fault_recovered` for the mean
    /// recovery latency.
    pub fault_recovery_time: SimTime,
    /// Fault-drained requests re-admitted onto a live instance.
    pub fault_recovered: u64,
    // --- tail packing (rollpacker; zero for other policies) ----------
    /// Requests the scheduler diverted onto its tail-packing path.
    pub tail_packed: u64,
    /// Generated tokens those requests carried when first diverted (the
    /// progress that resumed packed instead of restarting).
    pub tail_resume_tokens: u64,
    // --- bubble drafting (BubbleSpec; zero with the knob off) ---------
    /// Virtual draft-generation time offloaded onto end-of-rollout idle
    /// instances (removed from busy instances' critical path).
    pub bubble_draft_time: SimTime,
    /// Expected extra accepted tokens contributed by the bubble-deepened
    /// draft budgets (γ uplift toward γ_max on straggler instances).
    pub bubble_accept_tokens: u64,
    // --- off-policy staleness (async/hybrid pipelines; zero in sync) --
    /// Σ over completions of (consuming policy version − version stamped
    /// at generation completion). Filled by
    /// [`RolloutMetrics::apply_staleness`].
    pub staleness_sum: u64,
    /// Max per-request staleness (versions).
    pub staleness_max: u64,
    /// Completions with staleness ≥ 1 (i.e. generated under an older
    /// policy than the one that trains on them).
    pub stale_requests: u64,
}

impl RolloutMetrics {
    pub fn new(n_instances: usize) -> Self {
        RolloutMetrics {
            busy_time: vec![SimTime::ZERO; n_instances],
            ..Default::default()
        }
    }

    /// Output tokens per second over the whole rollout.
    pub fn throughput(&self) -> f64 {
        if self.makespan == SimTime::ZERO {
            return 0.0;
        }
        self.tokens_generated as f64 / self.makespan.as_secs_f64()
    }

    /// Paper §4.2.2: time between the (100-p)% completion point and the
    /// end of rollout. Default p = 10 (last 10% of requests).
    pub fn tail_time(&self, tail_frac: f64) -> SimTime {
        if self.completions.is_empty() {
            return SimTime::ZERO;
        }
        let mut times: Vec<SimTime> =
            self.completions.iter().map(|c| c.finished_at).collect();
        times.sort();
        // Index of the (1-tail_frac) completion quantile: the moment the
        // first (1-frac)·n requests have finished.
        let k = ((times.len() as f64) * (1.0 - tail_frac)).ceil() as usize;
        let cut = k.clamp(1, times.len()) - 1;
        self.makespan.saturating_sub(times[cut])
    }

    /// Mean instance utilization: the mean over instances of
    /// `busy_time[i] / live_time[i]`. Instances without a recorded live
    /// interval (always-live fleets, the real backend) fall back to the
    /// full makespan as denominator — for such fleets this is exactly
    /// the old `Σ busy / (makespan · n)`. Instances added mid-run by
    /// elastic `ScaleUp` (or lost to `InstanceDown`) are measured only
    /// over the interval they were actually part of the fleet, so late
    /// joiners no longer deflate the mean.
    pub fn mean_utilization(&self) -> f64 {
        if self.makespan == SimTime::ZERO || self.busy_time.is_empty() {
            return 0.0;
        }
        let total: f64 = self
            .busy_time
            .iter()
            .enumerate()
            .map(|(i, busy)| {
                let live = match self.live_time.get(i) {
                    Some(t) if *t > SimTime::ZERO => *t,
                    _ => self.makespan,
                };
                busy.as_secs_f64() / live.as_secs_f64()
            })
            .sum();
        total / self.busy_time.len() as f64
    }

    /// Mean accepted tokens per request-step, including the bonus token —
    /// the paper's tau (Figure 11).
    pub fn mean_acceptance_len(&self) -> f64 {
        if self.tau > 0.0 {
            self.tau
        } else {
            1.0
        }
    }

    /// `p`-th percentile of request finish times, in virtual seconds
    /// (0.0 with no completions). The sweep layer's p99 long-tail metric.
    pub fn finish_percentile(&self, p: f64) -> f64 {
        if self.completions.is_empty() {
            return 0.0;
        }
        self.completion_summary().percentile(p)
    }

    /// Completion-time summary.
    pub fn completion_summary(&self) -> Summary {
        let mut s = Summary::new();
        s.extend(
            self.completions
                .iter()
                .map(|c| c.finished_at.as_secs_f64()),
        );
        s
    }

    /// Mean time a fault-drained request spent queued before its next
    /// placement (zero when no fault recovery happened).
    pub fn mean_recovery_latency(&self) -> SimTime {
        if self.fault_recovered == 0 {
            SimTime::ZERO
        } else {
            SimTime::from_micros(
                self.fault_recovery_time.as_micros() / self.fault_recovered,
            )
        }
    }

    /// Fold per-request policy-version staleness into the aggregate
    /// counters: the epoch that trains on this rollout consumes it at
    /// `consume_version`, so each completion's staleness is
    /// `consume_version − policy_version`. Synchronous rollouts (and
    /// async with lag 0) stamp every completion at `consume_version`, so
    /// all three counters stay 0.
    pub fn apply_staleness(&mut self, consume_version: u64) {
        for c in &self.completions {
            let lag = consume_version.saturating_sub(c.policy_version);
            self.staleness_sum += lag;
            self.staleness_max = self.staleness_max.max(lag);
            if lag > 0 {
                self.stale_requests += 1;
            }
        }
    }

    /// Mean per-request staleness in policy versions (0.0 when nothing
    /// completed or every request was on-policy).
    pub fn staleness_mean(&self) -> f64 {
        if self.completions.is_empty() {
            0.0
        } else {
            self.staleness_sum as f64 / self.completions.len() as f64
        }
    }

    /// Difference between the earliest- and latest-finishing instance's
    /// last completion — the §4.2.2 inter-instance imbalance stat.
    pub fn check_complete(&self, expected: usize) {
        assert_eq!(
            self.completions.len(),
            expected,
            "rollout lost requests: {} of {expected} completed",
            self.completions.len()
        );
    }
}

/// Event-stream tally: metrics as just another [`RolloutObserver`].
///
/// Counts the lifecycle events a rollout backend narrates; a consistent
/// run satisfies `finished == completions.len()`, `migrations ==
/// RolloutMetrics::migrations`, `preemptions == RolloutMetrics::
/// preemptions`, and `tokens == RolloutMetrics::tokens_generated`
/// (asserted by the session tests).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct EventCounts {
    pub scheduled: u64,
    pub chunk_ends: u64,
    pub preemptions: u64,
    pub migrations: u64,
    pub finished: u64,
    pub steps: u64,
    /// Generation progress committed by Step events.
    pub tokens: u64,
    /// Fault layer: instances lost (crash or reclamation).
    pub instances_lost: u64,
    /// Fault layer: fault-drained requests re-admitted somewhere live.
    pub rebalanced: u64,
    /// Fault layer: requests terminated by scripted aborts.
    pub aborted: u64,
    /// All events, of any kind.
    pub events: u64,
}

impl EventCounts {
    /// Serialize the tally as a flat JSON object (the serve plane's
    /// telemetry frames embed exactly this).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut o = std::collections::BTreeMap::new();
        let mut put = |k: &str, v: u64| {
            o.insert(k.to_string(), Json::Num(v as f64));
        };
        put("scheduled", self.scheduled);
        put("chunk_ends", self.chunk_ends);
        put("preemptions", self.preemptions);
        put("migrations", self.migrations);
        put("finished", self.finished);
        put("steps", self.steps);
        put("tokens", self.tokens);
        put("instances_lost", self.instances_lost);
        put("rebalanced", self.rebalanced);
        put("aborted", self.aborted);
        put("events", self.events);
        Json::Obj(o)
    }
}

impl RolloutObserver for EventCounts {
    fn on_event(&mut self, ev: &RolloutEvent) {
        self.events += 1;
        match ev {
            RolloutEvent::Scheduled { .. } => self.scheduled += 1,
            RolloutEvent::ChunkEnd { preempted, .. } => {
                self.chunk_ends += 1;
                if *preempted {
                    self.preemptions += 1;
                }
            }
            RolloutEvent::Migration { .. } => self.migrations += 1,
            RolloutEvent::Finished { .. } => self.finished += 1,
            RolloutEvent::Step { steps, tokens, .. } => {
                self.steps += *steps;
                self.tokens += *tokens;
            }
            RolloutEvent::InstanceLost { .. } => self.instances_lost += 1,
            RolloutEvent::Rebalanced { .. } => self.rebalanced += 1,
            RolloutEvent::Aborted { .. } => self.aborted += 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cpl(id: u32, t: f64) -> Completion {
        Completion {
            id: RequestId(id),
            finished_at: SimTime::from_secs_f64(t),
            first_scheduled_at: SimTime::ZERO,
            gen_len: 100,
            policy_version: 0,
        }
    }

    #[test]
    fn tail_time_last_10pct() {
        let mut m = RolloutMetrics::new(1);
        // 10 requests, 9 finish by t=10, the last at t=100.
        for i in 0..9 {
            m.completions.push(cpl(i, (i + 1) as f64));
        }
        m.completions.push(cpl(9, 100.0));
        m.makespan = SimTime::from_secs_f64(100.0);
        let tail = m.tail_time(0.10);
        // 90% cut is at the 9th completion (t=9): tail = 91s.
        assert!((tail.as_secs_f64() - 91.0).abs() < 1e-6, "{tail:?}");
    }

    #[test]
    fn finish_percentile_exact() {
        let mut m = RolloutMetrics::new(1);
        assert_eq!(m.finish_percentile(99.0), 0.0);
        for i in 0..10 {
            m.completions.push(cpl(i, (i + 1) as f64));
        }
        assert_eq!(m.finish_percentile(50.0), 5.0);
        assert_eq!(m.finish_percentile(99.0), 10.0);
        assert_eq!(m.finish_percentile(100.0), 10.0);
    }

    #[test]
    fn throughput_simple() {
        let mut m = RolloutMetrics::new(2);
        m.tokens_generated = 5000;
        m.makespan = SimTime::from_secs_f64(10.0);
        assert!((m.throughput() - 500.0).abs() < 1e-9);
    }

    #[test]
    fn utilization_averages_instances() {
        let mut m = RolloutMetrics::new(2);
        m.makespan = SimTime::from_secs_f64(10.0);
        m.busy_time[0] = SimTime::from_secs_f64(10.0);
        m.busy_time[1] = SimTime::from_secs_f64(5.0);
        assert!((m.mean_utilization() - 0.75).abs() < 1e-9);
    }

    /// The live-interval denominator: an instance that joined for only
    /// the last 2s of a 10s rollout and was busy throughout is 100%
    /// utilized, not 20%. Always-live instances (no live_time entry)
    /// keep the makespan denominator.
    #[test]
    fn utilization_uses_live_intervals_for_late_joiners() {
        let mut m = RolloutMetrics::new(2);
        m.makespan = SimTime::from_secs_f64(10.0);
        m.busy_time[0] = SimTime::from_secs_f64(5.0); // always live
        m.busy_time[1] = SimTime::from_secs_f64(2.0); // joined at t=8
        m.live_time = vec![SimTime::from_secs_f64(10.0), SimTime::from_secs_f64(2.0)];
        // (5/10 + 2/2) / 2 = 0.75 — not (5+2)/(10*2) = 0.35.
        assert!((m.mean_utilization() - 0.75).abs() < 1e-9);
        // Zero live entries fall back to the makespan.
        m.live_time = vec![SimTime::ZERO, SimTime::ZERO];
        assert!((m.mean_utilization() - 0.35).abs() < 1e-9);
    }

    #[test]
    fn staleness_folds_per_completion_lag() {
        let mut m = RolloutMetrics::new(1);
        assert_eq!(m.staleness_mean(), 0.0);
        m.completions.push(cpl(0, 1.0)); // version 0
        m.completions.push(Completion {
            policy_version: 2,
            ..cpl(1, 2.0)
        });
        m.completions.push(Completion {
            policy_version: 3,
            ..cpl(2, 3.0)
        });
        m.apply_staleness(3);
        assert_eq!(m.staleness_sum, 4); // 3 + 1 + 0
        assert_eq!(m.staleness_max, 3);
        assert_eq!(m.stale_requests, 2);
        assert!((m.staleness_mean() - 4.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "lost requests")]
    fn check_complete_panics_on_loss() {
        let m = RolloutMetrics::new(1);
        m.check_complete(5);
    }

    #[test]
    fn event_counts_tally_by_kind() {
        let mut c = EventCounts::default();
        let now = SimTime::ZERO;
        let (req, inst) = (RequestId(0), InstanceId(0));
        c.on_event(&RolloutEvent::Scheduled { req, instance: inst, now });
        c.on_event(&RolloutEvent::ChunkEnd {
            req,
            instance: inst,
            preempted: true,
            now,
        });
        c.on_event(&RolloutEvent::ChunkEnd {
            req,
            instance: inst,
            preempted: false,
            now,
        });
        c.on_event(&RolloutEvent::Migration { req, to: inst, now });
        c.on_event(&RolloutEvent::Finished { req, gen_len: 7, now });
        c.on_event(&RolloutEvent::Step {
            instance: inst,
            steps: 3,
            tokens: 12,
            now,
        });
        c.on_event(&RolloutEvent::InstanceLost {
            instance: inst,
            drained: 4,
            now,
        });
        c.on_event(&RolloutEvent::Rebalanced { req, to: inst, now });
        c.on_event(&RolloutEvent::Aborted { req, generated: 5, now });
        assert_eq!(c.scheduled, 1);
        assert_eq!(c.chunk_ends, 2);
        assert_eq!(c.preemptions, 1);
        assert_eq!(c.migrations, 1);
        assert_eq!(c.finished, 1);
        assert_eq!(c.steps, 3);
        assert_eq!(c.tokens, 12);
        assert_eq!(c.instances_lost, 1);
        assert_eq!(c.rebalanced, 1);
        assert_eq!(c.aborted, 1);
        assert_eq!(c.events, 9);
    }

    #[test]
    fn mean_recovery_latency_divides() {
        let mut m = RolloutMetrics::new(1);
        assert_eq!(m.mean_recovery_latency(), SimTime::ZERO);
        m.fault_recovery_time = SimTime::from_secs(10);
        m.fault_recovered = 4;
        assert_eq!(
            m.mean_recovery_latency(),
            SimTime::from_secs_f64(2.5)
        );
    }
}
